"""Amortized calibration: the cross-round ``CapsCache`` policy.

Pins the safety model documented in ``repro.core.caps_cache``:

- served caps can never silently undercount — either the entry's caps
  cover the demand, or the payload's drop counter trips the executor's
  abort-and-retry, which invalidates the entry and re-measures (the
  no-undercount property, swept deterministically and, when available,
  with hypothesis);
- an entry must be CONFIRMED by a second fresh measure before it serves
  hits (a single seed-bound observation proves nothing about the next
  round's routing);
- the watermark band invalidates drifting entries in both directions;
- the cache snapshots with the driver (resume keeps amortization warm);
- end to end: enabling the cache leaves result rows bit-identical to the
  measure-every-round oracle across engines and fusion modes.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.caps_cache import CapsCache
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.queries import star_ghd, star_query
from repro.data.synthetic import star_data_sparse
from repro.relational.batched import GroupMeasure, SideCaps
from repro.relational.oracle import canon
from repro.relational.shuffle import pow2
from repro.relational.spmd import SPMD


def gm(c_out, cap_recv, **kw) -> GroupMeasure:
    return GroupMeasure(lhs=SideCaps(c_out, cap_recv), **kw)


# --------------------------------------------------------------- policy
def test_unconfirmed_entry_never_serves():
    cache = CapsCache()
    cache.store(("k",), gm(8, 16))
    assert cache.lookup(("k",)) is None  # one observation is not stability
    assert cache.misses == 1 and cache.hits == 0


def test_covered_restore_promotes_and_serves():
    cache = CapsCache()
    cache.store(("k",), gm(8, 16, out_recv=32))
    cache.store(("k",), gm(8, 8, out_recv=32))  # fresh measure <= stored caps
    m = cache.lookup(("k",))
    assert m is not None and cache.hits == 1
    # hits serve one pow2 notch of headroom over the stored caps: the
    # entry proved stability on past seeds only, and a single-notch
    # demand drift is the common growth mode between observations
    assert (m.lhs.c_out, m.lhs.cap_recv, m.out_recv) == (16, 32, 64)
    assert m.padded == 0 and m.n_heavy == 0 and not m.hybrid_routed


def test_growing_restore_merges_but_demotes():
    cache = CapsCache()
    cache.store(("k",), gm(8, 16))
    cache.store(("k",), gm(32, 8))  # c_out grew past the entry: not stable
    assert cache.lookup(("k",)) is None  # demoted back to unconfirmed
    e = cache.entry(("k",))
    assert e.lhs == (32, 16)  # merge is elementwise max: caps only grow
    cache.store(("k",), gm(16, 16))  # now covered again -> promoted
    assert cache.lookup(("k",)) is not None


def test_heavy_and_hybrid_measures_refused():
    cache = CapsCache()
    assert not cache.store(("h",), gm(8, 8, n_heavy=2))
    assert not cache.store(("h",), gm(8, 8, hybrid_routed=True))
    assert ("h",) not in cache


def test_watermark_band_invalidates_both_directions():
    for max_sent, gone in ((13, False), (40, True), (2, True)):
        cache = CapsCache()  # defaults: growth 1.0, shrink 0.25
        cache.store(("k",), gm(16, 16))
        cache.observe(("k",), 13, dropped=False)  # baseline sent0 = 13
        cache.observe(("k",), max_sent, dropped=False)
        assert (("k",) not in cache) == gone, max_sent
    cache = CapsCache()
    cache.store(("k",), gm(16, 16))
    cache.observe(("k",), 13, dropped=True)  # a drop always invalidates
    assert ("k",) not in cache and cache.invalidations == 1


def test_json_round_trip_preserves_confirmation():
    cache = CapsCache()
    cache.store(("a", 4), gm(8, 16, out_recv=32, out_need=64))
    cache.store(("a", 4), gm(8, 16, out_recv=32, out_need=64))
    cache.store(("b", 2), gm(4, 4))
    cache.observe(("a", 4), 7, dropped=False)
    other = CapsCache()
    other.load_json(cache.to_json())
    assert len(other) == 2
    assert other.lookup(("a", 4)) is not None  # still confirmed
    assert other.lookup(("b", 2)) is None  # still probationary
    assert other.entry(("a", 4)).sent0 == 7


# ------------------------------------------------- no-undercount property
def _protocol_covers(demands) -> None:
    """Replay the executor's protocol against an arbitrary per-round
    demand sequence for one signature: lookup -> (hit ? cached : fresh
    pow2 measure) -> payload -> on overflow abort, invalidate, re-measure.
    The pinned property: every round ends with caps >= demand, and a
    retry only ever happens on a HIT (a fresh measure can't undercount
    its own round)."""
    cache = CapsCache()
    key = ("sig",)
    for demand in demands:
        m = cache.lookup(key)
        hit = m is not None
        cap = m.lhs.c_out if hit else pow2(max(1, demand))
        if cap < demand:  # payload counts drops -> abort-and-retry
            assert hit, "fresh measure undercounted its own round"
            cache.invalidate(key)
            cap = pow2(max(1, demand))
        assert cap >= demand
        if not hit:
            cache.store(key, gm(pow2(max(1, demand)), pow2(max(1, demand))))
        cache.observe(key, demand, dropped=False)


def test_no_undercount_deterministic_sweep():
    sweeps = [
        [5, 5, 5, 5, 5],  # stable: confirms then hits
        [5, 5, 5, 90, 90],  # growth after confirmation: one retry, recovers
        [90, 5, 5, 5, 5],  # shrink: watermark re-tightens
        [1, 2, 4, 8, 16, 32],  # doubling every round: never stable
        [7, 7, 100, 7, 7, 7, 7],  # spike and return
        [0, 0, 3, 3, 3],
    ]
    for demands in sweeps:
        _protocol_covers(demands)


def test_no_undercount_property_random():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=1 << 16), max_size=30))
    def run(demands):
        _protocol_covers(demands)

    run()


# ------------------------------------------------------ driver integration
@pytest.mark.slow
def test_snapshot_resume_keeps_cache_warm(tmp_path):
    q, g = star_query(4), star_ghd(4)
    data = star_data_sparse(4, seed=7)
    drv = GymDriver(q, g, data, SPMD(4), GymConfig(seed=11))
    drv.step()
    drv.step()
    saved = drv.executor.caps_cache.to_json()
    snap = str(tmp_path / "caps_cache_snap.npz")
    drv.save(snap)

    drv2 = GymDriver(q, g, data, SPMD(4), GymConfig(seed=11))
    drv2.load(snap)
    assert drv2.executor.caps_cache.to_json() == saved  # warm, not re-measured
    want = canon(drv.run().to_numpy())
    assert canon(drv2.run().to_numpy()) == want


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["hash", "grid", "hybrid"])
@pytest.mark.parametrize("fused", [True, False])
def test_bit_parity_with_measure_every_round_oracle(strategy, fused):
    """Cache on vs off must be invisible in the results: same rows, and on
    retry-free inputs the same comm_tuples (cached caps only change how a
    round is measured, never what it ships on a successful attempt)."""
    q, g = star_query(4), star_ghd(4)
    data = star_data_sparse(4, seed=7)
    runs = {}
    for cc in (False, True):
        rows, schema, led = gym(
            q, data, ghd=g, p=4,
            config=GymConfig(
                strategy=strategy, fused=fused, seed=11,
                caps_cache=cc, prefetch_measures=False,
            ),
        )
        runs[cc] = (canon(rows), tuple(schema), led)
    assert runs[True][0] == runs[False][0]
    assert runs[True][1] == runs[False][1]
    if runs[True][2].retries == 0 == runs[False][2].retries:
        assert runs[True][2].comm_tuples == runs[False][2].comm_tuples
