"""Lemmas 8-11 cost scaling: measured communication of the grid join,
tree dedup, grid semijoin, and intersection primitives vs the paper's
analytic forms."""
from __future__ import annotations

import numpy as np

from repro.core.costs import B, lemma8_join_comm, lemma10_semijoin_comm
from repro.relational.grid import grid_join, grid_semijoin, tree_dedup
from repro.relational.ops import dist_intersect
from repro.relational.spmd import SPMD
from repro.relational.table import DTable


def _table(rows: np.ndarray, schema, p: int) -> DTable:
    return DTable.scatter_numpy(rows.astype(np.int32), schema, p)


def run() -> list:
    out = []
    p = 8
    spmd = SPMD(p)
    rng = np.random.default_rng(0)

    # Lemma 8: grid join comm ~ g_s|R| + g_r|S|
    for sz in (32, 64, 128):
        a = _table(rng.integers(0, 8, (sz, 2)), ("A", "B"), p)
        b = _table(rng.integers(0, 8, (sz, 2)), ("B", "C"), p)
        j, st = grid_join(spmd, a, b, out_cap=sz * sz)
        out.append(
            dict(bench="lemma8", n=sz, comm=st["sent"],
                 analytic=int(lemma8_join_comm([sz, sz], M=sz, out=0)))
        )
        assert st["dropped"] == 0
    # comm grows superlinearly in input (grid replication)
    assert out[-1]["comm"] > 2 * out[0]["comm"]

    # Lemma 9: tree dedup: log_fan(p) rounds, <= |S| comm per round
    dup = np.repeat(rng.integers(0, 16, (16, 2)), 8, axis=0)
    t = _table(dup, ("A", "B"), p)
    d, st, rounds = tree_dedup(spmd, t, fan=2, seed=1)
    n_unique = len({tuple(r) for r in dup})
    assert int(np.asarray(d.valid).sum()) == n_unique
    expected_rounds = int(np.ceil(np.log2(p)))
    out.append(
        dict(bench="lemma9", rounds=rounds, expected=expected_rounds,
             comm=st["sent"])
    )
    assert rounds == expected_rounds

    # Lemma 10: grid semijoin in O(1) rounds
    s = _table(rng.integers(0, 6, (96, 2)), ("A", "B"), p)
    r = _table(rng.integers(0, 6, (96, 2)), ("B", "C"), p)
    sj, st, rounds = grid_semijoin(spmd, s, r, out_cap=96)
    out.append(
        dict(bench="lemma10", rounds=rounds, comm=st["sent"],
             analytic=int(lemma10_semijoin_comm(96, 96, M=24)))
    )
    assert rounds <= 2 and st["dropped"] == 0

    # Lemma 11: intersection in 1 round, |R| + |S| comm
    a = _table(rng.integers(0, 4, (64, 2)), ("A", "B"), p)
    b = _table(rng.integers(0, 4, (64, 2)), ("A", "B"), p)
    i, st = dist_intersect(spmd, a, b, seed=2)
    out.append(dict(bench="lemma11", comm=st["sent"], bound=128))
    assert st["sent"] <= 128
    return out
