"""Appendix C: C_16 via a width-3 grouped GHD (Figure 7a) vs the width-1
chain GHD (Figure 8): ~3x fewer rounds for more communication — the
round/communication tradeoff GYM exposes."""
from __future__ import annotations

from repro.core.gym import GymConfig, gym
from repro.core.queries import chain_ghd, chain_ghd_grouped, chain_query
from repro.data.synthetic import chain_data_sparse


def run() -> list:
    n = 16
    q = chain_query(n)
    # matching-database-style inputs keep intermediates O(|R|) (Appendix A)
    data = chain_data_sparse(n, seed=7)

    g1 = chain_ghd(n)  # width 1, depth 15
    g3 = chain_ghd_grouped(n, 3)  # width 3, depth 5
    r1, _, led1 = gym(q, data, ghd=g1, p=4, config=GymConfig(seed=5))
    r3, _, led3 = gym(q, data, ghd=g3, p=4, config=GymConfig(seed=5))
    assert {tuple(r) for r in r1} == {tuple(r) for r in r3}

    out = [
        dict(bench="appendix_c", ghd="width-1 (Fig 8)", width=1,
             rounds=led1.rounds, comm=led1.comm_tuples),
        dict(bench="appendix_c", ghd="width-3 grouped (Fig 7a)", width=3,
             rounds=led3.rounds, comm=led3.comm_tuples),
    ]
    # the paper's 12c+6 vs 32c+16: grouped GHD uses ~n/group of the rounds
    assert led3.rounds < led1.rounds, (led3.rounds, led1.rounds)
    return out
