"""Kernel benchmarks, micro AND in situ.

Micro: correctness-at-size plus CPU wall time of the jnp reference paths
(the Pallas kernels themselves are TPU-target; on CPU they run in
interpret mode and are validated in tests/).

End-to-end: the same kernels INSIDE a real ``gym()`` run — a full S_8
query executed under ``local_backend='jnp'`` vs ``'pallas'`` (interpret
mode on CPU).  Asserts bit parity (rows, comm_tuples, retries) and
reports both wall clocks.  On CPU the pallas number measures the
interpret-mode tax, not kernel speed; on a TPU the same harness measures
the real thing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gym import GymConfig, gym
from repro.core.queries import star_ghd, star_query
from repro.data.synthetic import star_data_sparse
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.semijoin_probe import semijoin_probe
from repro.kernels.sorted_probe import sorted_probe_ranges


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n


def run() -> list:
    out = []
    rng = np.random.default_rng(0)

    # semijoin probe: interpret kernel == ref at benchmark size
    q = jnp.asarray(rng.integers(0, 10_000, 4096), jnp.int32)
    keys = jnp.asarray(np.sort(rng.integers(0, 10_000, 8192)), jnp.int32)
    got = semijoin_probe(q, keys, interpret=True)
    want = ref.semijoin_probe_ref(q, keys)
    assert bool((got == want).all())
    t = _time(jax.jit(ref.semijoin_probe_ref), q, keys)
    out.append(dict(bench="kernel_probe", n=4096, m=8192, ref_ms=round(t * 1e3, 3)))

    # sorted probe ranges: interpret kernel == ref at benchmark size
    lo, hi = sorted_probe_ranges(q, keys, interpret=True)
    rlo, rhi = ref.sorted_probe_ranges_ref(q, keys)
    assert bool((lo == rlo).all()) and bool((hi == rhi).all())
    t = _time(jax.jit(ref.sorted_probe_ranges_ref), q, keys)
    out.append(dict(bench="kernel_ranges", n=4096, m=8192, ref_ms=round(t * 1e3, 3)))

    # flash attention: interpret kernel ~ ref at a serving-ish size
    qq = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    got = flash_attention(qq, kk, vv, causal=True, blk_q=128, blk_k=128, interpret=True)
    want = ref.attention_ref(qq, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)
    t = _time(
        jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True)), qq, kk, vv
    )
    out.append(dict(bench="kernel_attn", shape="1x4x256x64", ref_ms=round(t * 1e3, 3)))

    # ---- end-to-end: the kernels inside a real GymDriver run ------------
    from repro.relational.spmd import SPMD

    q8, g8, data8 = star_query(8), star_ghd(8), star_data_sparse(8, seed=21)
    res = {}
    for backend in ("jnp", "pallas"):
        cfg = GymConfig(strategy="hash", seed=23, local_backend=backend)
        # jit caches live on the SPMD instance: share one across the warm
        # and timed runs so the timed number is execution, not compilation
        spmd = SPMD(8)
        gym(q8, data8, ghd=g8, p=8, spmd=spmd, config=cfg)  # warm the caches
        t0 = time.time()
        rows, _, led = gym(q8, data8, ghd=g8, p=8, spmd=spmd, config=cfg)
        secs = time.time() - t0
        res[backend] = (rows, led)
        out.append(
            dict(
                bench="kernel_e2e_gym",
                query="S_8",
                local_backend=backend,
                rows=len(rows),
                comm=led.comm_tuples,
                retries=led.retries,
                dispatches=led.measured_dispatches,
                secs=round(secs, 2),
            )
        )
    rows_j, led_j = res["jnp"]
    rows_p, led_p = res["pallas"]
    # the backends must be bit-identical in results AND cost accounting
    assert {tuple(r) for r in rows_j} == {tuple(r) for r in rows_p}
    assert led_j.comm_tuples == led_p.comm_tuples
    assert led_j.retries == led_p.retries
    return out
