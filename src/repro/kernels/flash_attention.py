"""TPU Pallas kernel: FlashAttention (online-softmax attention) — the
compute hot-spot of every assigned transformer architecture.

TPU-native design:
  - grid (B, H, num_q_blocks, num_kv_blocks), kv innermost ("arbitrary"
    semantics) so VMEM scratch carries the online-softmax state (m, l, acc
    in f32) across kv steps; outputs are written once on the last kv step;
  - q/k/v tiles live in VMEM via BlockSpec; the two matmuls per tile
    (s = q k^T, acc += p v) hit the MXU with (BLK_Q x D) x (D x BLK_K)
    shapes, D padded to 128 multiples by the wrapper;
  - GQA is handled in the k/v index_map (head h reads kv-head h // group) —
    no KV expansion in HBM;
  - causal / sliding-window masking and logit soft-capping (gemma2) are
    fused into the tile, computed from absolute block offsets.

Block sizes (512, 512): q/k/v tiles are 512*128*4B = 256 KiB each in f32,
acc 256 KiB — comfortably inside the ~16 MiB v5e VMEM with double
buffering; 512 keeps the MXU at full (128x128) occupancy for 8 passes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_Q = 512
DEFAULT_BLK_K = 512
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, causal, window, softcap, blk_q, blk_k, kv_len,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (blk_q, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (blk_k, d)
    v = v_ref[0, 0].astype(jnp.float32)  # (blk_k, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < kv_len  # kv padding
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (blk_q, 1) f32
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = corr * acc_ref[...] + pv
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0
        o_ref[0, 0, :, :] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "blk_q", "blk_k", "kv_len",
        "interpret",
    ),
)
def _flash_call(
    q, k, v, *, scale, causal, window, softcap, blk_q, blk_k, kv_len, interpret
):
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    group = h // kvh
    nq, nk = sq // blk_q, sk // blk_k
    kern = functools.partial(
        _attn_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        blk_q=blk_q, blk_k=blk_k, kv_len=kv_len,
    )
    return pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, blk_k, d), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, blk_k, d), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((blk_q, 1), jnp.float32),  # m: running max
            _vmem((blk_q, 1), jnp.float32),  # l: running denominator
            _vmem((blk_q, d), jnp.float32),  # acc: unnormalized output
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,  # 0 = no sliding window
    softcap: float = 0.0,  # 0 = no capping
    scale: Optional[float] = None,
    blk_q: int = DEFAULT_BLK_Q,
    blk_k: int = DEFAULT_BLK_K,
    interpret: bool = False,
) -> jax.Array:
    """q (B,H,Sq,D), k/v (B,KVH,Skv,D) with H % KVH == 0 -> (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    scale = float(scale) if scale is not None else float(d) ** -0.5
    bq = min(blk_q, sq)
    bk = min(blk_k, sk)
    qpad = -sq % bq
    kpad = -sk % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    out = _flash_call(
        qp, kp, vp,
        scale=scale, causal=causal, window=int(window),
        softcap=float(softcap), blk_q=bq, blk_k=bk, kv_len=sk,
        interpret=interpret,
    )
    return out[:, :, :sq, :]
