"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

12 encoder + 12 decoder layers; ``input_specs()`` provides precomputed
frame embeddings (B, S, d) in place of the mel+conv frontend."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    rope="none",  # sinusoidal positions (whisper-style)
    encdec=True,
    enc_layers=12,
    dec_ratio=8,
    tie_embeddings=True,
    notes="enc-dec; decode = 1 decoder token vs S-frame cross KV",
)
