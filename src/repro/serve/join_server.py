"""Multi-tenant join serving: cross-request fused batching (ROADMAP 3).

A single ``gym()`` call amortizes dispatch overhead *within* a query —
round fusion stacks a round's compatible op instances into one SPMD
program + one ``all_to_all``.  This server amortizes it *across* queries:
many concurrent query instances step round-by-round through shared
executors on ONE ``SPMD``, and each tick buckets every in-flight query's
prepared op groups by ``GroupWork.merge_key`` (same engine strategy +
backend, op kind, pow2-bucketed capacity, shard shapes, shared-key
count — ``relational.batched.cross_request_key``).  Buckets with several
riders run as ONE fused dispatch via ``core.physical.dispatch_merged``:
the k axis of the ``dist_*_many`` operators simply spans requests instead
of one query's op group, so a warm server pays one program launch and one
``all_to_all`` where a sequential loop pays one per query.

What stays per-tenant (the Lemma-2 audit trail):

- every query owns its ``GymDriver`` — seeds, capacity manager, retry
  decisions, and ``Ledger`` are exactly a standalone run's, so rows and
  ``comm_tuples`` are bit-identical to calling ``gym()`` alone (a merged
  dispatch widens only padding, never what moves);
- the ``ServerLedger`` aggregate IS the per-tenant sum; fusion's saving
  appears only in its ``fused_dispatches`` / ``fused_riders`` counters.

What is shared: the ``SPMD`` (so pow2 program shapes warm across
tenants), and one signature-keyed ``CapsCache`` (tenants with equal
group signatures warm each other's calibration; signatures differ =>
entries never cross-contaminate).

Admission control: at most ``max_in_flight`` queries step concurrently;
the waiting queue is FIFO-with-aging — effective priority is
``priority - aging * wait_ticks``, so an urgent (low-priority-value)
arrival can jump the queue but a long-waiting TC_9 straggler eventually
outranks any newcomer and nothing starves.  Scheduling is tick-based and
deterministic (no wall clock), so a warmup pass over the same arrival
schedule compiles exactly the merged-k program shapes the timed run uses.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.caps_cache import CapsCache
from ..core.gym import GymConfig, GymDriver
from ..core.physical import GroupWork, dispatch_merged, dispatch_work
from ..relational.ledger import Ledger, ServerLedger
from ..relational.spmd import SPMD


@dataclasses.dataclass
class JoinTicket:
    """One submitted query instance and its lifecycle state."""

    tenant: str
    query: Any
    ghd: Any
    data: Dict[str, np.ndarray]
    config: Optional[GymConfig]
    priority: float = 0.0  # LOWER = more urgent (0 = normal)
    # -- filled by the server -------------------------------------------
    order: int = -1  # arrival sequence number (FIFO tiebreak)
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    driver: Optional[GymDriver] = None
    gen: Any = None  # live ``step_gen`` generator (suspended at a yield)
    works: List[GroupWork] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def result(self):
        return self.driver.result if self.driver is not None else None

    def rows(self) -> np.ndarray:
        assert self.done and self.driver is not None
        return self.driver.result.to_numpy()

    @property
    def ledger(self) -> Optional[Ledger]:
        return self.driver.ledger if self.driver is not None else None

    @property
    def wait_ticks(self) -> int:
        """Queue wait (submission to admission)."""
        return max(0, self.admit_tick - self.submit_tick)

    @property
    def latency_ticks(self) -> int:
        """Submission-to-completion in server ticks (the deterministic
        latency metric; wall-clock latency is the bench's concern)."""
        return max(0, self.finish_tick - self.submit_tick)


class JoinServer:
    """Admit, schedule, and fuse many concurrent ``gym`` queries on one
    ``SPMD``.

    Drive with ``step()`` (one tick: admit -> bucket -> dispatch ->
    deliver) until it returns False, or call ``drain()``.  Submissions
    may arrive between ticks — the tick loop is the event loop."""

    def __init__(
        self,
        spmd: SPMD,
        *,
        max_in_flight: int = 4,
        aging: float = 1.0,
        caps_cache: Optional[CapsCache] = None,
    ):
        self.spmd = spmd
        self.max_in_flight = int(max_in_flight)
        assert self.max_in_flight >= 1
        self.aging = float(aging)
        # ONE cache for every tenant: signature-keyed, so equal group
        # shapes warm each other and different shapes never collide
        self.caps_cache = caps_cache if caps_cache is not None else CapsCache()
        self.ledger = ServerLedger()
        self.tick = 0
        self._order = itertools.count()
        self._queue: List[JoinTicket] = []
        self._active: List[JoinTicket] = []
        self.completed: List[JoinTicket] = []

    # ------------------------------------------------------------ intake
    def submit(
        self,
        tenant: str,
        query,
        ghd,
        data: Dict[str, np.ndarray],
        config: Optional[GymConfig] = None,
        *,
        priority: float = 0.0,
    ) -> JoinTicket:
        """Enqueue one query instance for ``tenant``; returns its ticket
        (poll ``ticket.done``; ``ticket.rows()`` after completion)."""
        t = JoinTicket(
            tenant=tenant, query=query, ghd=ghd, data=data, config=config,
            priority=float(priority), order=next(self._order),
            submit_tick=self.tick,
        )
        self._queue.append(t)
        return t

    @property
    def in_flight(self) -> int:
        return len(self._active)

    @property
    def queued(self) -> int:
        return len(self._queue)

    def pending_groups(self) -> Dict[Optional[Tuple], List[GroupWork]]:
        """This tick's mergeable work, bucketed by ``merge_key`` (the
        ``None`` bucket = must-dispatch-solo groups) — what ``step()``
        is about to fuse; exposed for tests and introspection."""
        buckets: Dict[Optional[Tuple], List[GroupWork]] = {}
        for t in self._active:
            for w in t.works:
                buckets.setdefault(w.merge_key, []).append(w)
        return buckets

    # -------------------------------------------------------- scheduling
    def _effective(self, t: JoinTicket) -> Tuple[float, int]:
        # FIFO-with-aging: waiting lowers the effective value linearly,
        # so no priority gap outlasts a proportional wait; arrival order
        # breaks ties exactly (pure FIFO at equal priorities)
        return (t.priority - self.aging * (self.tick - t.submit_tick), t.order)

    def _admit(self) -> None:
        while self._queue and len(self._active) < self.max_in_flight:
            t = min(self._queue, key=self._effective)
            self._queue.remove(t)
            t.admit_tick = self.tick
            t.driver = GymDriver(
                t.query, t.ghd, t.data, self.spmd, t.config,
                caps_cache=self.caps_cache,
            )
            self._active.append(t)
            self._start_round(t)

    def _start_round(self, t: JoinTicket) -> None:
        """Open the ticket's next round generator and advance it to its
        first suspended stage.  Yield-free drives (materialization, or
        the final finish step) complete inline and roll into the next
        round — or retire the ticket."""
        while True:
            t.gen = t.driver.step_gen()
            try:
                t.works = next(t.gen)
                return  # suspended: works await this tick's dispatch
            except StopIteration as stop:
                t.gen = None
                t.works = []
                if stop.value:
                    continue  # inline round done, more remain
                self._retire(t)
                return

    def _deliver(self, t: JoinTicket, results) -> None:
        try:
            t.works = t.gen.send(results)
        except StopIteration as stop:
            t.gen = None
            t.works = []
            if stop.value:
                self._start_round(t)
            else:
                self._retire(t)

    def _retire(self, t: JoinTicket) -> None:
        assert t.driver is not None and t.driver.done
        t.done = True
        t.finish_tick = self.tick
        self.ledger.add(t.tenant, t.driver.ledger)
        if t in self._active:
            self._active.remove(t)
        self.completed.append(t)

    # --------------------------------------------------------- tick loop
    def step(self) -> bool:
        """One server tick: admit waiting tickets, bucket every active
        query's pending op groups by ``merge_key``, dispatch each bucket
        (ONE fused program + one ``all_to_all`` when several riders
        share a key), and deliver the de-interleaved results so every
        query advances one stage.  Returns True while work remains."""
        self.tick += 1
        self._admit()
        if not self._active:
            return bool(self._queue)
        buckets: Dict[Tuple, List[Tuple[JoinTicket, int]]] = {}
        solo: List[Tuple[JoinTicket, int]] = []
        for t in self._active:
            for wi, w in enumerate(t.works):
                if w.merge_key is None:
                    solo.append((t, wi))
                else:
                    buckets.setdefault(w.merge_key, []).append((t, wi))
        results: Dict[Tuple[int, int], Any] = {}
        for key in sorted(buckets, key=repr):  # deterministic order
            items = buckets[key]
            works = [t.works[wi] for t, wi in items]
            if len(works) > 1:
                rs = dispatch_merged(works)
                self.ledger.fused_dispatches += 1
                self.ledger.fused_riders += len(works)
            else:
                rs = [dispatch_work(works[0])]
            for (t, wi), r in zip(items, rs):
                results[(id(t), wi)] = r
        for t, wi in solo:
            results[(id(t), wi)] = dispatch_work(t.works[wi])
        # deliver in admission order; _deliver mutates _active on retire
        for t in list(self._active):
            if t.gen is None:
                continue
            t_results = [results[(id(t), wi)] for wi in range(len(t.works))]
            self._deliver(t, t_results)
        return bool(self._queue or self._active)

    def drain(self) -> ServerLedger:
        """Run ticks until every submitted query has completed."""
        while self.step():
            pass
        return self.ledger
