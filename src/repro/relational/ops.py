"""Distributed relational operators on DTables.

Each operator = (repartition via hash shuffle) + (per-shard local op), all
inside one per-shard SPMD function so a BSP round is one program dispatch.
Operators return (result DTable, stats) where stats carry per-shard
``sent`` (tuples communicated — the paper's cost unit), ``dropped``
(capacity overflows; nonzero => the driver must retry with bigger caps),
and ``padded`` (dense ``all_to_all`` slots the wire actually shipped —
statically known from ``p`` and each exchange's ``c_out``, so it is
accounted host-side by the wrappers, never traced).

``measure_exchange`` is the sequential count-only pre-pass (see
``shuffle.exchange_counts``): the tight per-exchange capacities it returns
are what the capacity manager feeds back as ``c_out``/``cap_recv`` so the
payload ``all_to_all`` ships calibrated buckets instead of the global
worst case.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import hash_columns
from .localops import (
    compact,
    get_local_backend,
    local_dedup_mask,
    local_intersect_mask,
    local_join,
    local_join_count,
    local_project,
    local_semijoin_mask,
)
from .shuffle import exchange, exchange_counts, exchange_multi, padded_slots, pow2
from .wire import count_wire_bytes, dense_wire_bytes
from .skew import DEFAULT_SKEW_THRESHOLD
from .spmd import SPMD
from .table import DTable, schema_join


class Overflow(Exception):
    """A reducer exceeded its capacity — the paper's 'abort'."""


def _stats(sent, dropped, ubytes=None):
    out = {"sent": sent, "dropped": dropped}
    if ubytes is not None:
        # useful dense-int32 bytes the exchange occupied (traced, like sent)
        out["ubytes"] = ubytes
    return out


def agg_stats(stats, padded: int = 0, wire_bytes: int = 0) -> Dict[str, int]:
    out = {k: int(np.asarray(v).sum()) for k, v in stats.items()}
    out.setdefault("padded", int(padded))
    out.setdefault("wire_bytes", int(wire_bytes))
    out.setdefault("ubytes", 0)
    return out


# ---------------------------------------------------------------- repartition
def _repart_shard(data, valid, seed, *, cols, p, c_out, cap_recv, backend):
    dest = get_local_backend(backend).dests(data, valid, cols, p, seed)
    rd, rv, sent, ds, dr = exchange(data, valid, dest, p=p, c_out=c_out, cap_recv=cap_recv)
    return rd, rv, _stats(sent, ds + dr, ubytes=4 * data.shape[1] * sent)


def repartition(
    spmd: SPMD, t: DTable, attrs: Sequence[str], *, seed: int, c_out: int,
    cap_recv: int, backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    rd, rv, stats = spmd.run(
        _repart_shard,
        t.data,
        t.valid,
        spmd.seeds(seed),
        cols=t.cols(attrs),
        p=spmd.p,
        c_out=c_out,
        cap_recv=cap_recv,
        backend=backend,
    )
    return DTable(rd, rv, t.schema), agg_stats(
        stats,
        padded_slots(spmd.p, c_out, t.arity),
        wire_bytes=dense_wire_bytes(spmd.p, c_out, t.arity),
    )


# ------------------------------------------------------ count-only pre-pass
def _exchange_count_shard(data, valid, seed, *, cols, p, dedup, backend):
    """Mirror of the map stage of one exchange, counts only: same key
    columns, same seed, same destination hash — but the ``all_to_all``
    carries a (p,)-int count vector instead of the payload buffer."""
    be = get_local_backend(backend)
    v = valid
    if dedup:  # semijoin ships the deduplicated key projection of R
        keys, v = local_project(data, valid, cols, dedup=True)
        dest = be.dests(keys, v, tuple(range(len(cols))), p, seed)
    else:
        dest = be.dests(data, v, cols, p, seed)
    return exchange_counts(dest, p)


def measure_exchange(
    spmd: SPMD,
    t: DTable,
    attrs: Sequence[str],
    *,
    seed: int,
    dedup: bool = False,
    backend: str = "jnp",
) -> Tuple[int, int]:
    """Count-only pre-pass of ``t``'s hash exchange on ``attrs``: one tiny
    dispatch returning the tight ``(c_out, cap_recv)`` for the payload
    exchange that follows with the SAME seed — pow2-bucketed so calibrated
    capacities collapse into reusable jit cache entries."""
    out_counts, recv_tot = spmd.run(
        _exchange_count_shard,
        t.data,
        t.valid,
        spmd.seeds(seed),
        cols=t.cols(attrs),
        p=spmd.p,
        dedup=dedup,
        backend=backend,
        measure=True,
    )
    out_counts, recv_tot = jax.device_get((out_counts, recv_tot))
    return (
        pow2(max(1, int(out_counts.max()))),
        pow2(max(1, int(recv_tot.max()))),
    )


def _exchange_count_pair_shard(
    ad, av, bd, bv, seed, *, cols_a, cols_b, p, dedup_a, dedup_b, backend
):
    """Both sides of a two-table exchange counted in ONE program — the
    fused form of two ``_exchange_count_shard`` dispatches."""
    be = get_local_backend(backend)
    va, vb = av, bv
    if dedup_a:
        ka, va = local_project(ad, av, cols_a, dedup=True)
        da = be.dests(ka, va, tuple(range(len(cols_a))), p, seed)
    else:
        da = be.dests(ad, va, cols_a, p, seed)
    if dedup_b:
        kb, vb = local_project(bd, bv, cols_b, dedup=True)
        db = be.dests(kb, vb, tuple(range(len(cols_b))), p, seed)
    else:
        db = be.dests(bd, vb, cols_b, p, seed)
    return exchange_counts(da, p), exchange_counts(db, p)


def measure_exchange_pair(
    spmd: SPMD,
    a: DTable,
    b: DTable,
    attrs_a: Sequence[str],
    attrs_b: Sequence[str],
    *,
    seed: int,
    dedup: Tuple[bool, bool] = (False, False),
    backend: str = "jnp",
) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """Count-only pre-pass for BOTH sides of a join/semijoin exchange in
    one dispatch and one host sync.  Returns ``(c_out, cap_recv)`` pairs
    ordered (a, b) — identical numbers to two ``measure_exchange`` calls
    with the same seed, at half the dispatch and sync cost."""
    (oa, ra), (ob, rb) = spmd.run(
        _exchange_count_pair_shard,
        a.data, a.valid, b.data, b.valid, spmd.seeds(seed),
        cols_a=a.cols(attrs_a), cols_b=b.cols(attrs_b),
        p=spmd.p, dedup_a=dedup[0], dedup_b=dedup[1],
        backend=backend,
        measure=True,
    )
    oa, ra, ob, rb = jax.device_get((oa, ra, ob, rb))
    return (
        (pow2(max(1, int(oa.max()))), pow2(max(1, int(ob.max())))),
        (pow2(max(1, int(ra.max()))), pow2(max(1, int(rb.max())))),
    )


def _exchange_count_pairs_shard(*args, entries, p, backend):
    """SEVERAL two-table exchanges counted in ONE program — the
    cross-group fused form of ``_exchange_count_pair_shard`` (e.g. every
    2-way multijoin of one GHD materialization stage, each with its own
    seed).  ``args`` packs (a_data, a_valid, b_data, b_valid, seed) per
    entry; ``entries`` the static (cols_a, cols_b, dedup_a, dedup_b)."""
    out = []
    for i, (cols_a, cols_b, dedup_a, dedup_b) in enumerate(entries):
        ad, av, bd, bv, seed = args[5 * i: 5 * i + 5]
        out.append(
            _exchange_count_pair_shard(
                ad, av, bd, bv, seed,
                cols_a=cols_a, cols_b=cols_b, p=p,
                dedup_a=dedup_a, dedup_b=dedup_b, backend=backend,
            )
        )
    return tuple(out)


def measure_exchange_pairs(
    spmd: SPMD,
    items,
    *,
    backend: str = "jnp",
):
    """Count-only pre-pass for SEVERAL two-table exchanges in one
    dispatch and one host sync — ``measure_exchange_pair`` amortized over
    a whole stage of independent pair joins.  ``items`` are
    (a, b, attrs_a, attrs_b, seed, (dedup_a, dedup_b)) tuples; returns
    the per-item ((c_out_a, c_out_b), (cap_recv_a, cap_recv_b))."""
    arrays = []
    entries = []
    for a, b, attrs_a, attrs_b, seed, dedup in items:
        arrays += [a.data, a.valid, b.data, b.valid, spmd.seeds(seed)]
        entries.append(
            (a.cols(attrs_a), b.cols(attrs_b), bool(dedup[0]), bool(dedup[1]))
        )
    res = spmd.run(
        _exchange_count_pairs_shard,
        *arrays,
        entries=tuple(entries),
        p=spmd.p,
        backend=backend,
        measure=True,
    )
    res = jax.device_get(res)
    return [
        (
            (pow2(max(1, int(oa.max()))), pow2(max(1, int(ob.max())))),
            (pow2(max(1, int(ra.max()))), pow2(max(1, int(rb.max())))),
        )
        for (oa, ra), (ob, rb) in res
    ]


# ----------------------------------------------------------------------- join
def _join_shard(
    a_data, a_valid, b_data, b_valid, seed, *,
    a_key, b_key, b_keep, p, c_out_a, c_out_b, cap_a, cap_b, out_cap, backend,
):
    be = get_local_backend(backend)
    da = be.dests(a_data, a_valid, a_key, p, seed)
    a2, a2v, sent_a, dsa, dra = exchange(a_data, a_valid, da, p=p, c_out=c_out_a, cap_recv=cap_a)
    db = be.dests(b_data, b_valid, b_key, p, seed)
    b2, b2v, sent_b, dsb, drb = exchange(b_data, b_valid, db, p=p, c_out=c_out_b, cap_recv=cap_b)
    # key columns are unchanged by the shuffle: join on a_key/b_key directly
    out, out_v, over = local_join(a2, a2v, b2, b2v, a_key, b_key, b_keep, out_cap, backend)
    ub = 4 * (a_data.shape[1] * sent_a + b_data.shape[1] * sent_b)
    return out, out_v, _stats(sent_a + sent_b, dsa + dra + dsb + drb + over, ubytes=ub)


def _cross_join_shard(
    a_data, a_valid, b_data, b_valid, *, b_keep, p, c_out_b, cap_b, out_cap, backend,
):
    """Attribute-disjoint join: A stays put, B broadcasts to every reducer
    (comm = p * |B|), then an empty-key local join expands A_shard x B.
    Parallelism p is preserved — unlike hashing on zero columns, which is
    seed-only and funnels BOTH relations onto a single reducer."""
    dests = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32), (b_data.shape[0], p))
    b2, b2v, sent_b, dsb, drb = exchange_multi(
        b_data, b_valid, dests, p=p, c_out=c_out_b, cap_recv=cap_b
    )
    out, out_v, over = local_join(
        a_data, a_valid, b2, b2v, (), (), b_keep, out_cap, backend
    )
    return out, out_v, _stats(
        sent_b, dsb + drb + over, ubytes=4 * b_data.shape[1] * sent_b
    )


def dist_join(
    spmd: SPMD,
    a: DTable,
    b: DTable,
    *,
    seed: int,
    out_cap: int,
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    calibrate: bool = False,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """Hash join of a and b on their shared attributes (co-partitioning).

    With NO shared attributes this is an explicit broadcast cross join —
    every reducer keeps its A shard and receives all of B.

    ``calibrate=True``: when the shuffle capacities are not given, run the
    count-only pre-pass (``measure_exchange``) per side and use the tight
    pow2 capacities instead of the global worst case."""
    shared = [x for x in a.schema if x in b.schema]
    a_key = a.cols(shared)
    b_key = b.cols(shared)
    b_keep = tuple(i for i, x in enumerate(b.schema) if x not in set(a.schema))
    out_schema = schema_join(a.schema, b.schema)
    p = spmd.p
    count_pad = 0
    count_bytes = 0
    if calibrate and shared and c_out is None and cap_recv is None:
        # one fused count dispatch for both sides (one host sync)
        c_out, cap_recv = measure_exchange_pair(
            spmd, a, b, shared, shared, seed=seed, backend=backend
        )
        count_pad = 2 * p * p  # the two (p,)-int count vectors
        count_bytes = count_wire_bytes(p, 2)
    c_out = c_out or (a.cap, b.cap)           # safe: one shard sends all
    cap_recv = cap_recv or (p * a.cap, p * b.cap)  # safe: one shard gets all
    if not shared:
        od, ov, stats = spmd.run(
            _cross_join_shard,
            a.data, a.valid, b.data, b.valid,
            b_keep=b_keep, p=p,
            c_out_b=c_out[1], cap_b=cap_recv[1],
            out_cap=out_cap, backend=backend,
        )
        return DTable(od, ov, out_schema), agg_stats(
            stats,
            padded_slots(p, c_out[1], b.arity),
            wire_bytes=dense_wire_bytes(p, c_out[1], b.arity),
        )
    od, ov, stats = spmd.run(
        _join_shard,
        a.data, a.valid, b.data, b.valid, spmd.seeds(seed),
        a_key=a_key, b_key=b_key, b_keep=b_keep,
        p=p,
        c_out_a=c_out[0], c_out_b=c_out[1],
        cap_a=cap_recv[0], cap_b=cap_recv[1],
        out_cap=out_cap, backend=backend,
    )
    return DTable(od, ov, out_schema), agg_stats(
        stats,
        padded_slots(p, c_out[0], a.arity)
        + padded_slots(p, c_out[1], b.arity)
        + count_pad,
        wire_bytes=dense_wire_bytes(p, c_out[0], a.arity)
        + dense_wire_bytes(p, c_out[1], b.arity)
        + count_bytes,
    )


# --------------------------------------------- hybrid (heavy-hitter) variants
def dist_join_hybrid(
    spmd: SPMD,
    a: DTable,
    b: DTable,
    *,
    seed: int,
    out_cap: Optional[int] = None,
    skew_threshold: Optional[float] = None,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """Skew-resilient hash join: the count pre-pass detects heavy keys
    (destinations whose arrival exceeds the balanced share, see
    ``relational.skew``) and routes them grid-style — A's heavy rows
    position-partitioned over all p reducers, B's broadcast — while light
    keys keep the plain hash exchange.  Row set identical to
    ``dist_join``; stats gain ``'heavy'`` (tuple-sends on the heavy
    path), and the measure pre-pass's wire cost is folded into
    ``'padded'``.  ``out_cap=None`` uses the pre-counted exact output
    requirement under the hybrid placement."""
    shared = [x for x in a.schema if x in b.schema]
    if not shared:  # broadcast cross join: already skew-free
        assert out_cap is not None, "cross join needs an explicit out_cap"
        out, st = dist_join(spmd, a, b, seed=seed, out_cap=out_cap, backend=backend)
        st.setdefault("heavy", 0)
        return out, st
    from . import batched as B  # function-level: batched imports grid -> ops

    thresh = DEFAULT_SKEW_THRESHOLD if skew_threshold is None else skew_threshold
    m = B.measure_join_many(
        spmd, [a], [b], seeds=[seed], backend=backend,
        hybrid=True, skew_threshold=thresh,
    )
    oc = out_cap if out_cap is not None else m.out_need
    kw = dict(
        seeds=[seed], out_cap=oc,
        c_out=(m.lhs.c_out, m.rhs.c_out),
        cap_recv=(m.lhs.cap_recv, m.rhs.cap_recv),
        backend=backend,
    )
    if m.hybrid_routed:
        outs, stats = B.hybrid_join_many(
            spmd, [a], [b], heavy=m.heavy, swap=m.swap_spread, **kw
        )
    else:
        outs, stats = B.dist_join_many(spmd, [a], [b], **kw)
    st = dict(stats[0])
    st["padded"] = st.get("padded", 0) + m.padded
    st["wire_bytes"] = st.get("wire_bytes", 0) + m.wire_bytes
    st.setdefault("heavy", 0)
    return outs[0], st


def dist_semijoin_hybrid(
    spmd: SPMD,
    s: DTable,
    r: DTable,
    *,
    seed: int,
    cap_recv: Optional[int] = None,
    skew_threshold: Optional[float] = None,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """Skew-resilient S |>< R: heavy S rows spread positionally, heavy R
    keys broadcast; light keys hash as in ``dist_semijoin``.  Row set
    identical; ``cap_recv`` (the S-side output capacity) defaults to the
    measured hybrid arrival bound."""
    shared = [x for x in s.schema if x in r.schema]
    assert shared, f"semijoin with no shared attrs: {s.schema} vs {r.schema}"
    from . import batched as B  # function-level: batched imports grid -> ops

    thresh = DEFAULT_SKEW_THRESHOLD if skew_threshold is None else skew_threshold
    m = B.measure_semijoin_many(
        spmd, [s], [r], seeds=[seed], backend=backend,
        hybrid=True, skew_threshold=thresh,
    )
    cap_s = max(cap_recv or 0, m.lhs.cap_recv)
    kw = dict(
        seeds=[seed],
        c_out=(m.lhs.c_out, m.rhs.c_out),
        cap_recv=(cap_s, m.rhs.cap_recv),
        backend=backend,
    )
    if m.hybrid_routed:
        outs, stats = B.hybrid_semijoin_many(spmd, [s], [r], heavy=m.heavy, **kw)
    else:
        outs, stats = B.dist_semijoin_many(spmd, [s], [r], **kw)
    st = dict(stats[0])
    st["padded"] = st.get("padded", 0) + m.padded
    st["wire_bytes"] = st.get("wire_bytes", 0) + m.wire_bytes
    st.setdefault("heavy", 0)
    return outs[0], st


# ------------------------------------------------------------------- semijoin
def _semijoin_shard(
    s_data, s_valid, r_data, r_valid, seed, *,
    s_key, r_key, p, c_out_s, c_out_r, cap_s, cap_r, backend,
):
    be = get_local_backend(backend)
    # ship only the deduplicated key projection of R (S |>< R = S |><
    # pi_{S&R}(R)), as in Sec. 4.1
    rk, rkv = local_project(r_data, r_valid, r_key, dedup=True)
    kcols = tuple(range(len(r_key)))
    dr_dest = be.dests(rk, rkv, kcols, p, seed)
    rk2, rkv2, sent_r, dsr, drr = exchange(rk, rkv, dr_dest, p=p, c_out=c_out_r, cap_recv=cap_r)
    rkv2 = local_dedup_mask(rk2, rkv2, kcols)
    ds_dest = be.dests(s_data, s_valid, s_key, p, seed)
    s2, s2v, sent_s, dss, drs = exchange(s_data, s_valid, ds_dest, p=p, c_out=c_out_s, cap_recv=cap_s)
    mask = local_semijoin_mask(s2, s2v, s_key, rk2, rkv2, kcols, backend)
    s2 = jnp.where(mask[:, None], s2, 0)
    ub = 4 * (rk.shape[1] * sent_r + s_data.shape[1] * sent_s)
    return s2, mask, _stats(sent_r + sent_s, dsr + drr + dss + drs, ubytes=ub)


def dist_semijoin(
    spmd: SPMD,
    s: DTable,
    r: DTable,
    *,
    seed: int,
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """S |>< R on shared attributes; result has S's schema (repartitioned)."""
    shared = [x for x in s.schema if x in r.schema]
    assert shared, f"semijoin with no shared attrs: {s.schema} vs {r.schema}"
    p = spmd.p
    c_out = c_out or (s.cap, r.cap)
    cap_recv = cap_recv or (p * s.cap, p * r.cap)
    sd, sv, stats = spmd.run(
        _semijoin_shard,
        s.data, s.valid, r.data, r.valid, spmd.seeds(seed),
        s_key=s.cols(shared), r_key=r.cols(shared),
        p=p,
        c_out_s=c_out[0], c_out_r=c_out[1],
        cap_s=cap_recv[0], cap_r=cap_recv[1],
        backend=backend,
    )
    return DTable(sd, sv, s.schema), agg_stats(
        stats,
        # S ships full rows; R ships only its deduplicated key projection
        padded_slots(p, c_out[0], s.arity)
        + padded_slots(p, c_out[1], len(shared)),
        wire_bytes=dense_wire_bytes(p, c_out[0], s.arity)
        + dense_wire_bytes(p, c_out[1], len(shared)),
    )


# ------------------------------------------------------------------ intersect
def _intersect_shard(
    a_data, a_valid, b_data, b_valid, seed, *,
    a_cols, b_cols, p, c_out_a, c_out_b, cap_a, cap_b, backend,
):
    be = get_local_backend(backend)
    da = be.dests(a_data, a_valid, a_cols, p, seed)
    a2, a2v, sent_a, dsa, dra = exchange(a_data, a_valid, da, p=p, c_out=c_out_a, cap_recv=cap_a)
    db = be.dests(b_data, b_valid, b_cols, p, seed)
    b2, b2v, sent_b, dsb, drb = exchange(b_data, b_valid, db, p=p, c_out=c_out_b, cap_recv=cap_b)
    mask = local_intersect_mask(a2, a2v, b2, b2v, a_cols, b_cols, backend)
    a2 = jnp.where(mask[:, None], a2, 0)
    ub = 4 * (a_data.shape[1] * sent_a + b_data.shape[1] * sent_b)
    return a2, mask, _stats(sent_a + sent_b, dsa + dra + dsb + drb, ubytes=ub)


def dist_intersect(
    spmd: SPMD, a: DTable, b: DTable, *, seed: int,
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """A intersect B (same attr sets, any column order); result: A's rows."""
    assert set(a.schema) == set(b.schema), (a.schema, b.schema)
    a_cols = tuple(range(len(a.schema)))
    b_cols = b.cols(a.schema)
    p = spmd.p
    c_out = c_out or (a.cap, b.cap)
    cap_recv = cap_recv or (p * a.cap, p * b.cap)
    ad, av, stats = spmd.run(
        _intersect_shard,
        a.data, a.valid, b.data, b.valid, spmd.seeds(seed),
        a_cols=a_cols, b_cols=b_cols, p=p,
        c_out_a=c_out[0], c_out_b=c_out[1],
        cap_a=cap_recv[0], cap_b=cap_recv[1],
        backend=backend,
    )
    return DTable(ad, av, a.schema), agg_stats(
        stats,
        padded_slots(p, c_out[0], a.arity) + padded_slots(p, c_out[1], b.arity),
        wire_bytes=dense_wire_bytes(p, c_out[0], a.arity)
        + dense_wire_bytes(p, c_out[1], b.arity),
    )


# ---------------------------------------------------------------------- dedup
def _dedup_shard(data, valid, seed, *, cols, p, c_out, cap_recv, backend):
    dest = get_local_backend(backend).dests(data, valid, cols, p, seed)
    d2, v2, sent, ds, dr = exchange(data, valid, dest, p=p, c_out=c_out, cap_recv=cap_recv)
    mask = local_dedup_mask(d2, v2, cols)
    d2 = jnp.where(mask[:, None], d2, 0)
    return d2, mask, _stats(sent, ds + dr, ubytes=4 * data.shape[1] * sent)


def dist_dedup(
    spmd: SPMD, t: DTable, *, seed: int,
    c_out: Optional[int] = None, cap_recv: Optional[int] = None,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    p = spmd.p
    c_out = c_out or t.cap
    cap_recv = cap_recv or p * t.cap
    cols = tuple(range(len(t.schema)))
    d, v, stats = spmd.run(
        _dedup_shard, t.data, t.valid, spmd.seeds(seed),
        cols=cols, p=p, c_out=c_out, cap_recv=cap_recv, backend=backend,
    )
    return DTable(d, v, t.schema), agg_stats(
        stats,
        padded_slots(p, c_out, t.arity),
        wire_bytes=dense_wire_bytes(p, c_out, t.arity),
    )


# ------------------------------------------------- hypercube (Lemma 8/Shares)
def _hypercube_send_shard(data, valid, seed, *, dest_plan, p, c_out, cap_recv):
    """dest_plan: (fixed, wild_offsets)
    - fixed: tuple of (col, share, stride, attr_id) — coordinate =
      hash(col value; seeded by the GLOBAL attr id) % share, so every
      relation hashes a shared attribute identically;
    - wild_offsets: precomputed flat offsets over the wildcard dims."""
    fixed, wild_offsets = dest_plan
    n = data.shape[0]
    base = jnp.zeros((n,), jnp.int32)
    for col, share, stride, attr_id in fixed:
        h = hash_columns(data, (col,), seed + 7717 * (1 + attr_id))
        base = base + (h % jnp.uint32(share)).astype(jnp.int32) * stride
    dests = base[:, None] + jnp.asarray(wild_offsets, jnp.int32)[None, :]
    rd, rv, sent, ds, dr = exchange_multi(
        data, valid, dests, p=p, c_out=c_out, cap_recv=cap_recv
    )
    return rd, rv, _stats(sent, ds + dr, ubytes=4 * data.shape[1] * sent)


def hypercube_partition(
    spmd: SPMD,
    t: DTable,
    shares: Dict[str, int],
    attr_order: Sequence[str],
    *,
    seed: int,
    c_out: int,
    cap_recv: int,
) -> Tuple[DTable, Dict]:
    """Send each row of ``t`` to every hypercube cell consistent with its
    attribute hashes (Shares [2] / Lemma 8).  Cells are mixed-radix points
    over ``attr_order`` with radix ``shares[attr]``; cell ids < p."""
    strides: Dict[str, int] = {}
    acc = 1
    for a in attr_order:
        strides[a] = acc
        acc *= shares[a]
    assert acc <= spmd.p, f"cells {acc} > p {spmd.p}"
    attr_ids = {a: i for i, a in enumerate(attr_order)}
    fixed = tuple(
        (t.col(a), shares[a], strides[a], attr_ids[a])
        for a in attr_order
        if a in t.schema
    )
    wild_attrs = [a for a in attr_order if a not in t.schema]
    combos = itertools.product(*[range(shares[a]) for a in wild_attrs])
    wild_offsets = tuple(
        sum(c * strides[a] for c, a in zip(combo, wild_attrs)) for combo in combos
    ) or (0,)
    rd, rv, stats = spmd.run(
        _hypercube_send_shard,
        t.data, t.valid, spmd.seeds(seed),
        dest_plan=(fixed, wild_offsets),
        p=spmd.p, c_out=c_out, cap_recv=cap_recv,
    )
    return DTable(rd, rv, t.schema), agg_stats(
        stats,
        padded_slots(spmd.p, c_out, t.arity),
        wire_bytes=dense_wire_bytes(spmd.p, c_out, t.arity),
    )


# ------------------------------------------------------- local multiway join
def _multijoin_shard(*arrays, plan, out_caps, backend):
    """arrays: d0,v0,d1,v1,...; plan: tuple of (a_key, b_key, b_keep) for the
    left-deep fold; out_caps: per-step output capacities."""
    k = len(arrays) // 2
    datas = [arrays[2 * i] for i in range(k)]
    valids = [arrays[2 * i + 1] for i in range(k)]
    acc_d, acc_v = datas[0], valids[0]
    over_total = jnp.int32(0)
    for step in range(k - 1):
        a_key, b_key, b_keep = plan[step]
        acc_d, acc_v, over = local_join(
            acc_d, acc_v, datas[step + 1], valids[step + 1],
            a_key, b_key, b_keep, out_caps[step], backend,
        )
        over_total = over_total + over
    return acc_d, acc_v, _stats(jnp.int32(0), over_total)


def local_multiway_join(
    spmd: SPMD, tables: List[DTable], out_caps: Sequence[int],
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """Per-shard left-deep multiway join (no communication — reducers join
    their co-located buckets, the reduce stage of Lemma 8)."""
    assert len(tables) >= 1
    if len(tables) == 1:
        return tables[0], {
            "sent": 0, "dropped": 0, "padded": 0, "wire_bytes": 0, "ubytes": 0,
        }
    plan = []
    schema = tables[0].schema
    for nxt in tables[1:]:
        shared = [x for x in schema if x in nxt.schema]
        a_key = tuple(schema.index(x) for x in shared)
        b_key = tuple(nxt.schema.index(x) for x in shared)
        b_keep = tuple(i for i, x in enumerate(nxt.schema) if x not in set(schema))
        plan.append((a_key, b_key, b_keep))
        schema = schema_join(schema, nxt.schema)
    args = []
    for t in tables:
        args.extend([t.data, t.valid])
    od, ov, stats = spmd.run(
        _multijoin_shard, *args,
        plan=tuple(plan), out_caps=tuple(out_caps), backend=backend,
    )
    return DTable(od, ov, schema), agg_stats(stats)


# ------------------------------------------------------ join output counting
def _join_count_shard(
    a_data, a_valid, b_data, b_valid, seed, *,
    a_key, b_key, p, c_out_a, c_out_b, cap_a, cap_b, backend,
):
    """Shuffle ONLY the key projections with the join's hash plan and count
    the exact per-shard join output (capacity planning, no payload moved)."""
    be = get_local_backend(backend)
    ak, akv = local_project(a_data, a_valid, a_key, dedup=False)
    kc = tuple(range(len(a_key)))
    da = be.dests(ak, akv, kc, p, seed)
    a2, a2v, *_ = exchange(ak, akv, da, p=p, c_out=c_out_a, cap_recv=cap_a)
    bk, bkv = local_project(b_data, b_valid, b_key, dedup=False)
    db = be.dests(bk, bkv, kc, p, seed)
    b2, b2v, *_ = exchange(bk, bkv, db, p=p, c_out=c_out_b, cap_recv=cap_b)
    return local_join_count(a2, a2v, b2, b2v, kc, kc, backend)


def dist_join_count(
    spmd: SPMD, a: DTable, b: DTable, *, seed: int, backend: str = "jnp"
):
    """Exact per-shard output size of ``dist_join(a, b, seed=seed)`` with
    default receive capacities — (p,) int array.  Used by the capacity
    manager to pre-size a blown join's retry instead of guessing."""
    shared = [x for x in a.schema if x in b.schema]
    p = spmd.p
    counts = spmd.run(
        _join_count_shard,
        a.data, a.valid, b.data, b.valid, spmd.seeds(seed),
        a_key=a.cols(shared), b_key=b.cols(shared),
        p=p,
        c_out_a=a.cap, c_out_b=b.cap,
        cap_a=p * a.cap, cap_b=p * b.cap,
        backend=backend,
        measure=True,
    )
    return np.asarray(counts)


# -------------------------------------------------------------------- project
def _project_shard(data, valid, *, cols, dedup):
    d, v = local_project(data, valid, cols, dedup)
    return d, v


def dist_project(
    spmd: SPMD, t: DTable, attrs: Sequence[str], *, dedup: bool = False
) -> Tuple[DTable, Dict]:
    """Shard-local projection (no communication).  Returns (table, stats)
    like every other operator; stats are identically zero."""
    d, v = spmd.run(_project_shard, t.data, t.valid, cols=t.cols(attrs), dedup=dedup)
    return DTable(d, v, tuple(attrs)), {
        "sent": 0, "dropped": 0, "padded": 0, "wire_bytes": 0, "ubytes": 0,
    }


def check_no_drop(
    stats: Dict[str, int], op: str = "?", cap: Optional[int] = None
) -> None:
    """Raise ``Overflow`` if the operator dropped tuples.

    The message names the operator and the capacity that blew so
    abort-retry logs are actionable, not just a bare dropped count."""
    if stats.get("dropped", 0):
        at = f" at capacity {cap}" if cap is not None else ""
        raise Overflow(
            f"{op}: {stats['dropped']} tuples dropped{at} (capacity abort; "
            f"sent={stats.get('sent', '?')}) — retry with a larger capacity "
            "or enable the count-calibrated shuffle pre-pass"
        )
