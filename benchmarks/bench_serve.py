"""Multi-tenant join serving: batched cross-request fusion vs a
sequential ``gym()`` loop, on a zipf arrival mix over the Table-1
families (S_8 / C_8 / TC_9, hash engine, p=8).

The acceptance bar this bench enforces:

- every served query's rows and ``comm_tuples`` are bit-identical to a
  standalone ``gym()`` run of the same (query, data, config) — and zero
  abort-retries on both paths, so the comparison is well-defined;
- per-tenant ledger comm sums exactly to the server aggregate (the
  ``ServerLedger`` keeps the Lemma-2 audit per request);
- the batched server issues FEWER payload dispatches than the sequential
  loop (``dispatches_saved > 0`` — cross-request fusion happened);
- throughput: batched queries/sec must beat the sequential loop
  (smoke mode: must not lose).

The batched path amortizes across requests two ways: compatible op
groups (equal ``cross_request_key`` incl. the measured pow2 caps) merge
into shared fused dispatches, and one shared ``CapsCache`` lets the
zipf head's repeat queries skip their measure pre-pass host syncs.

Timing methodology (as in ``bench_shuffle``): one warmup pass per mode
compiles every XLA program — including the merged-k program shapes,
which exist only on the batched path — then each mode runs three times
on the shared warm ``SPMD`` and the BEST wall time is compared (min-of-N,
the noise-robust steady-state estimator).  Scheduling inside the server
is tick-based and deterministic, so the warmup pass compiles exactly
the shapes the timed pass reuses.

Per-query latency: submission-to-completion wall time within a pass
(sequential queries queue behind each other's service; batched queries
share capacity and finish in waves) — reported as p50/p99, not asserted.

``BENCH_SERVE_SMOKE=1`` (the CI lane) shrinks to p=4 and a 2-query mix;
smoke runs write ``BENCH_serve.partial.json`` so they never clobber the
committed full baseline.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks._io import write_json_atomic
from repro.core.caps_cache import CapsCache
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse
from repro.relational.spmd import SPMD
from repro.serve.join_server import JoinServer

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
PARTIAL_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serve.partial.json"
)

# the Table-1 matching-database shapes at p=8 scale (same as bench_shuffle)
FAMILIES = {
    "S_8": lambda: (
        star_query(8),
        star_ghd(8),
        star_data_sparse(8, domain=64, hub_rows=256, spoke_extra=64, seed=21),
    ),
    "C_8": lambda: (
        chain_query(8),
        chain_ghd(8),
        chain_data_sparse(8, domain=256, ident=64, extra=192, seed=24),
    ),
    "TC_9": lambda: (
        triangle_chain_query(3),
        triangle_chain_ghd(3),
        tc_data_sparse(3, domain=128, ident=32, extra=96, seed=22),
    ),
}

# admit the whole mix: the throughput story is riders-per-fused-dispatch,
# and every queued-but-unadmitted query is a merge opportunity lost (the
# admission-control *policy* itself is pinned by tests/test_join_server.py)
MAX_IN_FLIGHT = 8


def zipf_mix(names, n, *, s: float = 1.5, seed: int = 0):
    """Deterministic zipf-weighted arrival mix: rank r of ``names`` gets
    probability ~ 1/r^s (the skewed popular-query-dominates workload a
    serving layer actually sees)."""
    w = np.array([1.0 / (r + 1) ** s for r in range(len(names))])
    rng = np.random.default_rng(seed)
    return [names[i] for i in rng.choice(len(names), size=n, p=w / w.sum())]


def _cfg() -> GymConfig:
    return GymConfig(strategy="hash", seed=23)


def _sequential_pass(spmd, cases, mix):
    """One pass of the baseline: a fresh driver per query, run to
    completion back to back.  Per-query latency includes the queueing
    behind earlier queries' service (arrivals are simultaneous)."""
    t0 = time.time()
    lats, rows_by = [], []
    for name in mix:
        q, g, data = cases[name]
        drv = GymDriver(q, g, data, spmd, _cfg())
        rows_by.append((drv.run().to_numpy(), drv.ledger))
        lats.append(time.time() - t0)
    return time.time() - t0, lats, rows_by


def _batched_pass(spmd, cases, mix):
    """One pass of the server: submit the whole mix at tick 0, drain.
    Latency per ticket = wall time when its finishing tick completed.

    The server gets a shared ``CapsCache`` (fresh per pass, so passes
    stay identical): tenants with equal group signatures warm each
    other, so the zipf head's repeat queries skip their measure
    pre-pass host syncs entirely — the second half of the serving
    layer's amortization story, next to cross-request fused dispatch.
    The sequential baseline deliberately does NOT share one (it is the
    standalone ``gym()``-loop a user writes today)."""
    srv = JoinServer(
        spmd, max_in_flight=MAX_IN_FLIGHT, caps_cache=CapsCache()
    )
    tickets = []
    for i, name in enumerate(mix):
        q, g, data = cases[name]
        tickets.append(srv.submit(f"tenant-{i}:{name}", q, g, data, _cfg()))
    t0 = time.time()
    tick_done_at = {srv.tick: 0.0}
    while srv.step():
        tick_done_at[srv.tick] = time.time() - t0
    tick_done_at[srv.tick] = time.time() - t0
    secs = time.time() - t0
    lats = [tick_done_at[t.finish_tick] for t in tickets]
    return secs, lats, tickets, srv.ledger


def run() -> list:
    smoke = bool(os.environ.get("BENCH_SERVE_SMOKE"))
    p = 4 if smoke else 8
    names = list(FAMILIES)
    mix = ["S_8", "S_8"] if smoke else zipf_mix(names, 8)
    cases = {name: FAMILIES[name]() for name in set(mix)}
    spmd = SPMD(p)

    # standalone references: the parity oracle (and the solo-shape warmup)
    ref = {}
    for name in set(mix):
        q, g, data = cases[name]
        rows, _, led = gym(q, data, ghd=g, spmd=spmd, config=_cfg())
        ref[name] = ({tuple(r) for r in rows}, led)

    # warmup passes compile both modes' program shapes (incl. merged-k)
    _sequential_pass(spmd, cases, mix)
    _batched_pass(spmd, cases, mix)

    # steady state, best-of-N per mode (min = the noise-robust estimator)
    reps = 2 if smoke else 3
    seq_secs, seq_lats, seq_results = None, None, None
    for _ in range(reps):
        s, l, r = _sequential_pass(spmd, cases, mix)
        if seq_secs is None or s < seq_secs:
            seq_secs, seq_lats, seq_results = s, l, r
    bat_secs, bat_lats, tickets, served = None, None, None, None
    for _ in range(reps):
        s, l, t, led = _batched_pass(spmd, cases, mix)
        if bat_secs is None or s < bat_secs:
            bat_secs, bat_lats, tickets, served = s, l, t, led

    # acceptance: parity — every served query is bit-identical to its
    # standalone run (rows AND comm), with zero retries on either path
    for name, tkt, (rows_seq, led_seq) in zip(mix, tickets, seq_results):
        want_rows, want_led = ref[name]
        assert {tuple(r) for r in tkt.rows()} == want_rows, name
        assert {tuple(r) for r in rows_seq} == want_rows, name
        assert tkt.ledger.comm_tuples == want_led.comm_tuples, (
            name, tkt.ledger.comm_tuples, want_led.comm_tuples,
        )
        assert led_seq.comm_tuples == want_led.comm_tuples, name
        assert tkt.ledger.retries == 0 and led_seq.retries == 0, name
    # acceptance: the per-tenant ledgers reconcile with the aggregate
    tenant_leds = [l for ls in served.tenants.values() for l in ls]
    assert served.queries == len(mix)
    assert served.comm_tuples == sum(l.comm_tuples for l in tenant_leds)
    # acceptance: cross-request fusion actually shared dispatches
    assert served.dispatches_saved > 0, served.summary()
    # acceptance: batched throughput beats (smoke: doesn't lose to) the
    # sequential loop
    if smoke:
        assert bat_secs <= seq_secs, (bat_secs, seq_secs)
    else:
        assert bat_secs < seq_secs, (bat_secs, seq_secs)

    n = len(mix)
    rec = dict(
        bench="serve",
        p=p,
        engine="hash",
        mix=mix,
        max_in_flight=MAX_IN_FLIGHT,
        queries=n,
        seq_secs=round(seq_secs, 3),
        batched_secs=round(bat_secs, 3),
        seq_qps=round(n / seq_secs, 3),
        batched_qps=round(n / bat_secs, 3),
        speedup=round(seq_secs / bat_secs, 3),
        seq_p50_latency=round(float(np.percentile(seq_lats, 50)), 3),
        seq_p99_latency=round(float(np.percentile(seq_lats, 99)), 3),
        batched_p50_latency=round(float(np.percentile(bat_lats, 50)), 3),
        batched_p99_latency=round(float(np.percentile(bat_lats, 99)), 3),
        fused_dispatches=served.fused_dispatches,
        fused_riders=served.fused_riders,
        dispatches_saved=served.dispatches_saved,
        server_dispatches=served.measured_dispatches,
        seq_dispatches=sum(l.measured_dispatches for _, l in seq_results),
        comm_tuples=served.comm_tuples,
        retries=served.retries,
    )
    write_json_atomic(
        OUT_PATH if not smoke else PARTIAL_PATH,
        {"bench": "serve", "p": p, "families": names, "results": [rec]},
    )
    return [rec]
