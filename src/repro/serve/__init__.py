from .decode import generate, generate_whisper, sample
from .join_server import JoinServer, JoinTicket
