"""Gated MLP (SwiGLU) and Mixture-of-Experts feed-forward layers."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ArchConfig, init_norm, rms_norm, scaled_init


def _mesh_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None, set()
        return mesh, set(mesh.axis_names)
    except Exception:  # noqa: BLE001
        return None, set()


def _constrain(x: jax.Array, *spec):
    """Best-effort sharding constraint: applies only when tracing under a
    mesh whose axes cover the named ones (CPU tests trace mesh-less) and
    only on dims the axis size divides.

    [Perf iteration B] When experts cannot be expert-parallel (grok-1: 8
    experts vs a 16-way 'model' axis) XLA replicates the MoE scatter/gather
    dispatch buffers over 'model' and merges contributions with giant
    all-reduces (453 TB/step on grok-1 train_4k); pinning the feature dim
    to 'model' makes the scatter local.  When EP *does* engage (kimi-k2,
    384e) XLA's auto-sharding already picks the all-to-all plan and manual
    constraints only fight it — so ``moe_forward`` gates these on EP
    non-divisibility (measured: kimi 5.75 s vs 17.4 s constrained)."""
    import os

    if os.environ.get("REPRO_MOE_CONSTRAIN", "1") == "0":
        return x
    mesh, names = _mesh_axes()
    if not names:
        return x

    def ok(s, dim):
        if s is None:
            return None
        if isinstance(s, tuple):
            sub = tuple(a for a in s if a in names)
            if not sub:
                return None
            size = 1
            for a in sub:
                size *= mesh.shape[a]
            return sub if dim % size == 0 else None
        if s not in names:
            return None
        return s if dim % mesh.shape[s] == 0 else None

    fixed = tuple(ok(s, d) for s, d in zip(spec, x.shape))
    if all(s is None for s in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


FSDP = ("pod", "data")


def init_mlp(rng, cfg: ArchConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "wi": scaled_init(ks[0], (d, f), 0, cfg.jdtype),
        "wg": scaled_init(ks[1], (d, f), 0, cfg.jdtype),
        "wo": scaled_init(ks[2], (f, d), 0, cfg.jdtype),
        "ln": init_norm(d, cfg.jdtype),
    }


def mlp_forward(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    h = jax.nn.silu((xin @ p["wg"]).astype(jnp.float32)).astype(x.dtype) * (xin @ p["wi"])
    return x + (h @ p["wo"]).astype(x.dtype)


# ------------------------------------------------------------------- MoE
def init_moe(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    e = cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": scaled_init(ks[0], (d, e), 0, jnp.float32),
        "wi": scaled_init(ks[1], (e, d, f), 1, cfg.jdtype),
        "wg": scaled_init(ks[2], (e, d, f), 1, cfg.jdtype),
        "wo": scaled_init(ks[3], (e, f, d), 1, cfg.jdtype),
        "ln": init_norm(d, cfg.jdtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(
            ks[4], cfg, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts
        )
    return p


def moe_forward(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Top-k capacity-based dispatch: compiled FLOPs scale with *active*
    params (E x C x d x f with C ~ T*topk/E), the property the kimi-k2
    roofline depends on.  Dropped-over-capacity tokens pass through the
    residual (standard Switch-style behavior)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.topk
    cap = max(1, int(cfg.capacity_factor * t * k / e))

    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    xf = xin.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"])  # (t, e)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(gates, k)  # (t, k)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)

    # flatten (token, choice) pairs and rank them per expert for capacity
    flat_e = tope.reshape(-1)  # (t*k,)
    flat_w = topw.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    # position of each pair within its expert (by arrival order)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, e)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # rank per expert
    my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < cap

    # dispatch: scatter tokens into (e, cap) slots.  [Perf iteration B]
    # When EP engages (E % model == 0, e.g. kimi's 384e) XLA auto-shards the
    # dispatch with all-to-alls — leave it alone.  When it cannot (grok: 8e
    # vs 16-way 'model') run the scatter with the FEATURE dim sharded on
    # 'model' (indices replicated per shard -> fully local scatter) so XLA
    # stops replicating + all-reducing the dispatch buffers.
    mesh, names = _mesh_axes()
    ep = "model" in names and e % mesh.shape["model"] == 0

    def C(arr, *spec):
        return arr if ep else _constrain(arr, *spec)

    slot = jnp.where(keep, flat_e * cap + my_pos, e * cap)  # overflow -> drop
    src = C(xf[flat_tok], FSDP, "model")
    disp = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(src)
    disp = disp[:-1].reshape(e, cap, d)
    # d stays FSDP-aligned with the expert weights' contraction dim
    disp = C(disp, "model", None, FSDP)

    # expert computation: grouped einsum (hits the MXU per expert)
    gi = jnp.einsum("ecd,edf->ecf", disp, p["wg"])
    hi = jnp.einsum("ecd,edf->ecf", disp, p["wi"])
    act = jax.nn.silu(gi.astype(jnp.float32)).astype(hi.dtype) * hi
    out_e = jnp.einsum("ecf,efd->ecd", act, p["wo"])  # (e, cap, d)
    out_e = C(out_e, None, None, "model")  # back to feature-sharded

    # combine: gather back and weight (local gather per 'model' shard)
    gathered = out_e.reshape(e * cap, d)
    gathered = jnp.concatenate([gathered, jnp.zeros((1, d), gathered.dtype)], 0)
    per_pair = gathered[slot] * flat_w[:, None].astype(gathered.dtype)
    combined = jnp.zeros((t, d), x.dtype).at[flat_tok].add(per_pair.astype(x.dtype))
    combined = C(combined, FSDP, "model")

    y = combined
    if "shared" in p:
        sh = p["shared"]
        g = jax.nn.silu((xf @ sh["wg"]).astype(jnp.float32)).astype(xf.dtype)
        y = y + ((g * (xf @ sh["wi"])) @ sh["wo"]).astype(x.dtype)
    return x + y.reshape(b, s, d)
