"""Multi-tenant join serving (``serve.join_server``).

Pins the serving layer's contract:

- every served query's rows and ``comm_tuples`` are bit-identical to a
  standalone ``gym()`` run — cross-request fusion changes how work packs
  into SPMD programs, never what each query computes or ships;
- the ``ServerLedger`` aggregate is exactly the per-tenant ledger sum,
  and the fusion counters show real dispatch savings on a homogeneous
  mix (``fused_riders > fused_dispatches``);
- admission control: at most ``max_in_flight`` queries step at once,
  equal priorities admit FIFO, and aging lets a long-waiting
  low-priority ticket outrank an urgent newcomer (no starvation);
- the shared ``CapsCache``: tenants with equal group signatures warm
  each other, different signatures never cross-contaminate, and
  interleaved ``step()`` sequences stay bit-identical to isolated runs;
- ``GymConfig`` rejects unknown registry knobs at construction with an
  actionable message naming the valid options.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.caps_cache import CapsCache
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.queries import chain_ghd, chain_query, star_ghd, star_query
from repro.data.synthetic import chain_data_sparse, star_data_sparse
from repro.relational.spmd import SPMD
from repro.serve.join_server import JoinServer

P = 4


def star_case():
    return (
        star_query(4),
        star_ghd(4),
        star_data_sparse(4, domain=32, hub_rows=64, spoke_extra=16, seed=7),
    )


def chain_case():
    return (
        chain_query(4),
        chain_ghd(4),
        chain_data_sparse(4, domain=64, ident=16, extra=48, seed=9),
    )


def rowset(rows) -> set:
    return {tuple(r) for r in np.asarray(rows)}


# ------------------------------------------------------------- parity
def test_served_queries_bit_identical_to_standalone():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    cq, cg, cdata = chain_case()
    srv = JoinServer(spmd, max_in_flight=4)
    t1 = srv.submit("alice", sq, sg, sdata, GymConfig(seed=3))
    t2 = srv.submit("bob", sq, sg, sdata, GymConfig(seed=3))
    t3 = srv.submit("carol", cq, cg, cdata, GymConfig(seed=3))
    led = srv.drain()
    assert t1.done and t2.done and t3.done

    rs, _, ls = gym(sq, sdata, ghd=sg, spmd=spmd, config=GymConfig(seed=3))
    rc, _, lc = gym(cq, cdata, ghd=cg, spmd=spmd, config=GymConfig(seed=3))
    assert rowset(t1.rows()) == rowset(rs)
    assert rowset(t2.rows()) == rowset(rs)
    assert rowset(t3.rows()) == rowset(rc)
    assert t1.ledger.comm_tuples == ls.comm_tuples
    assert t2.ledger.comm_tuples == ls.comm_tuples
    assert t3.ledger.comm_tuples == lc.comm_tuples
    assert led.retries == 0

    # cross-request fusion actually happened on the homogeneous pair
    assert led.fused_dispatches > 0
    assert led.fused_riders > led.fused_dispatches
    assert led.dispatches_saved > 0


def test_server_aggregate_is_tenant_sum():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    cq, cg, cdata = chain_case()
    srv = JoinServer(spmd, max_in_flight=3)
    srv.submit("a", sq, sg, sdata, GymConfig(seed=1))
    srv.submit("a", cq, cg, cdata, GymConfig(seed=1))
    srv.submit("b", sq, sg, sdata, GymConfig(seed=1))
    led = srv.drain()
    tenants = [l for leds in led.tenants.values() for l in leds]
    assert led.queries == 3 and len(tenants) == 3
    assert led.comm_tuples == sum(l.comm_tuples for l in tenants)
    assert led.padded_slots == sum(l.padded_slots for l in tenants)
    assert led.payload_bytes == sum(l.payload_bytes for l in tenants)
    assert led.measured_dispatches == sum(l.measured_dispatches for l in tenants)
    ts = led.tenant_summary("a")
    assert ts["queries"] == 2
    s = led.summary()
    assert s["queries"] == 3 and set(s["tenants"]) == {"a", "b"}


# -------------------------------------------------- admission control
def test_max_in_flight_and_fifo_admission():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    srv = JoinServer(spmd, max_in_flight=1)
    ts = [
        srv.submit(f"t{i}", sq, sg, sdata, GymConfig(seed=3))
        for i in range(3)
    ]
    while srv.step():
        assert srv.in_flight <= 1
    # equal priorities: admitted (and finished) in arrival order
    admits = [t.admit_tick for t in ts]
    assert admits == sorted(admits) and len(set(admits)) == 3
    finishes = [t.finish_tick for t in ts]
    assert finishes == sorted(finishes) and len(set(finishes)) == 3
    for t in ts:
        assert t.latency_ticks >= t.wait_ticks >= 0


def test_priority_and_aging():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    # urgent (lower value) newcomer beats a same-tick normal submission
    srv = JoinServer(spmd, max_in_flight=1, aging=1.0)
    normal = srv.submit("n", sq, sg, sdata, GymConfig(seed=3))
    urgent = srv.submit("u", sq, sg, sdata, GymConfig(seed=3), priority=-5.0)
    srv.drain()
    assert urgent.admit_tick < normal.admit_tick

    # aging: a low-priority ticket that has waited long enough outranks a
    # fresh normal arrival — effective = priority - aging * wait_ticks
    srv2 = JoinServer(spmd, max_in_flight=1, aging=1.0)
    straggler = srv2.submit("s", sq, sg, sdata, GymConfig(seed=3), priority=10.0)
    srv2.tick += 20  # the straggler has now waited 20 ticks
    fresh = srv2.submit("f", sq, sg, sdata, GymConfig(seed=3), priority=0.0)
    srv2.drain()
    assert straggler.admit_tick < fresh.admit_tick


def test_pending_groups_exposes_mergeable_buckets():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    srv = JoinServer(spmd, max_in_flight=2)
    srv.submit("a", sq, sg, sdata, GymConfig(seed=3))
    srv.submit("b", sq, sg, sdata, GymConfig(seed=3))
    # step past materialization until both tickets suspend on round work
    for _ in range(20):
        if any(len(ws) > 1 for ws in srv.pending_groups().values()):
            break
        if not srv.step():
            break
    buckets = srv.pending_groups()
    assert any(
        key is not None and len(ws) > 1 for key, ws in buckets.items()
    ), "identical concurrent queries must expose a >1-rider merge bucket"
    srv.drain()


# ------------------------------------------------- shared caps cache
def test_shared_cache_warms_across_drivers():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    shared = CapsCache()
    d1 = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3), caps_cache=shared)
    d1.run()
    h1 = shared.hits
    d2 = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3), caps_cache=shared)
    out2 = d2.run()
    assert d1.executor.caps_cache is shared and d2.executor.caps_cache is shared
    # the second driver hits signatures the first confirmed
    assert shared.hits > h1
    # ... and computes exactly the standalone result
    solo = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3))
    out_solo = solo.run()
    assert rowset(out2.to_numpy()) == rowset(out_solo.to_numpy())
    assert d2.ledger.comm_tuples == solo.ledger.comm_tuples


def test_shared_cache_no_cross_contamination():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    cq, cg, cdata = chain_case()
    shared = CapsCache()
    ds = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3), caps_cache=shared)
    out_s = ds.run()
    dc = GymDriver(cq, cg, cdata, spmd, GymConfig(seed=3), caps_cache=shared)
    out_c = dc.run()
    solo_s = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3))
    solo_c = GymDriver(cq, cg, cdata, spmd, GymConfig(seed=3))
    assert rowset(out_s.to_numpy()) == rowset(solo_s.run().to_numpy())
    assert rowset(out_c.to_numpy()) == rowset(solo_c.run().to_numpy())
    assert ds.ledger.comm_tuples == solo_s.ledger.comm_tuples
    assert dc.ledger.comm_tuples == solo_c.ledger.comm_tuples
    assert ds.ledger.retries == 0 and dc.ledger.retries == 0


def test_interleaved_steps_bit_identical_to_isolated():
    spmd = SPMD(P)
    sq, sg, sdata = star_case()
    cq, cg, cdata = chain_case()
    shared = CapsCache()
    a = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3), caps_cache=shared)
    b = GymDriver(cq, cg, cdata, spmd, GymConfig(seed=3), caps_cache=shared)
    more_a, more_b = True, True
    while more_a or more_b:  # strict alternation
        if more_a:
            more_a = a.step()
        if more_b:
            more_b = b.step()
    iso_a = GymDriver(sq, sg, sdata, spmd, GymConfig(seed=3))
    iso_b = GymDriver(cq, cg, cdata, spmd, GymConfig(seed=3))
    ra, rb = iso_a.run(), iso_b.run()
    assert rowset(a.result.to_numpy()) == rowset(ra.to_numpy())
    assert rowset(b.result.to_numpy()) == rowset(rb.to_numpy())
    assert a.ledger.comm_tuples == iso_a.ledger.comm_tuples
    assert b.ledger.comm_tuples == iso_b.ledger.comm_tuples


def test_caps_cache_merge_load_keeps_live_entries():
    c1 = CapsCache()
    from repro.relational.batched import GroupMeasure, SideCaps

    def gm(c_out, cap_recv):
        return GroupMeasure(lhs=SideCaps(c_out, cap_recv))

    c1.store(("shared-sig",), gm(8, 16))
    c1.store(("shared-sig",), gm(8, 16))  # confirm
    snap = CapsCache()
    snap.store(("shared-sig",), gm(2, 2))
    snap.store(("other-sig",), gm(4, 4))
    # merge: the live confirmed entry survives, fresh signatures load
    c1.load_json(snap.to_json(), merge=True)
    assert c1.entry(("shared-sig",)).lhs == (8, 16)
    assert c1.entry(("other-sig",)) is not None
    # replace (default): the snapshot wins wholesale
    c1.load_json(snap.to_json())
    assert c1.entry(("shared-sig",)).lhs == (2, 2)


# ------------------------------------------------- config validation
def test_gymconfig_rejects_unknown_strategy():
    with pytest.raises(ValueError, match=r"unknown strategy.*'grid'"):
        GymConfig(strategy="quantum")


def test_gymconfig_rejects_unknown_wire_format():
    with pytest.raises(ValueError, match=r"unknown wire_format.*dense"):
        GymConfig(wire_format="zipped")


def test_gymconfig_rejects_unknown_local_backend():
    with pytest.raises(ValueError, match=r"unknown local_backend.*'jnp'"):
        GymConfig(local_backend="cuda")
