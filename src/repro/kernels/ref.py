"""Pure-jnp oracles for every Pallas kernel (shape-exact, f32 math)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..relational.hashing import dests_for


def semijoin_probe_ref(q: jax.Array, keys: jax.Array) -> jax.Array:
    """mask[i] = q[i] in keys (invalid key slots = INT32_MAX never match a
    valid probe)."""
    ks = jnp.sort(keys)
    lo = jnp.searchsorted(ks, q, side="left")
    hi = jnp.searchsorted(ks, q, side="right")
    return hi > lo


def sorted_probe_ranges_ref(q: jax.Array, keys: jax.Array):
    """(lo, hi) = searchsorted(keys, q, 'left'/'right'); ``keys`` sorted
    (invalid INT32_MAX slots at the back)."""
    lo = jnp.searchsorted(keys, q, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(keys, q, side="right").astype(jnp.int32)
    return lo, hi


def hash_partition_ref(
    rows: jax.Array, valid: jax.Array, cols: Sequence[int], p: int, seed: int
) -> jax.Array:
    """Bit-exact reference: the engine's own jnp hashing."""
    return dests_for(rows, valid, tuple(cols), p, seed)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Dense softmax attention, f32 accumulation, GQA via head grouping."""
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    g = h // kvh
    scale = float(scale) if scale is not None else float(d) ** -0.5
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        kk.astype(jnp.float32),
    ) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible keys -> zeros (matches kernel's l==0 guard)
    any_visible = mask.any(axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    out = jnp.where(any_visible[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)
