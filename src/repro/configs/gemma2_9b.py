"""gemma2-9b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    pattern=("local", "attn") * 21,
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    notes="head_dim=256 explicit; alternating 4k-window local / global",
)
