"""TPU Pallas kernel: multi-column hash-partition bucketing — the "map"
side of every GYM shuffle (computes each tuple's destination reducer).

Problem: rows (n, arity) int32, a static tuple of key columns, p reducers,
seed -> dest (n,) int32 in [0, p) for valid rows, p for invalid.

TPU-native design:
  - rows are blocked (ROWS_BLK, arity) into VMEM; the kernel runs the
    murmur3-style fmix32 column-combining hash entirely on the VPU
    (shift/xor/multiply are all lane ops, uint32);
  - the modulo by p is strength-reduced to a multiply-shift when p is a
    power of two (mesh sizes are), else a single vector remainder;
  - arity is a compile-time constant -> the column loop fully unrolls;
  - the seed is a TRACED (1, 1) uint32 scalar read from SMEM — reseeded
    abort-retries reuse the compiled program, matching the engine-wide
    seeds-ride-as-data contract (``SPMD.seeds`` / ``hash_columns``).

This fuses what would otherwise be several XLA HLOs (per-column hash,
combine, select) into one VMEM-resident pass over the rows.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS_BLK = 1024

# python ints (not traced arrays) so the kernel captures no constants
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_GOLD = 0x9E3779B9


def _mix32(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_C1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_C2)
    x = x ^ (x >> 16)
    return x


def _partition_kernel(seed_ref, rows_ref, valid_ref, dest_ref, *, cols, p):
    rows = rows_ref[...]  # (ROWS_BLK, arity) int32
    valid = valid_ref[...]  # (ROWS_BLK, 1) bool (2-D for TPU layout)
    seed = seed_ref[0, 0]  # traced uint32 scalar (SMEM)
    h = _mix32(jnp.full((rows.shape[0],), seed, jnp.uint32))
    for c in cols:  # static unroll
        h = _mix32(h ^ (_mix32(rows[:, c].astype(jnp.uint32)) + jnp.uint32(_GOLD)))
    if p & (p - 1) == 0:  # power of two: mask
        d = (h & jnp.uint32(p - 1)).astype(jnp.int32)
    else:
        d = (h % jnp.uint32(p)).astype(jnp.int32)
    dest_ref[...] = jnp.where(valid[:, 0], d, p)[:, None]


@functools.partial(jax.jit, static_argnames=("cols", "p", "interpret"))
def _partition_call(
    seed: jax.Array,
    rows: jax.Array,
    valid: jax.Array,
    cols: Tuple[int, ...],
    p: int,
    interpret: bool,
) -> jax.Array:
    n, ar = rows.shape
    grid = (n // ROWS_BLK,)
    kern = functools.partial(_partition_kernel, cols=cols, p=p)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((ROWS_BLK, ar), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_BLK, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(seed, rows, valid)


def hash_partition(
    rows: jax.Array,
    valid: jax.Array,
    cols: Sequence[int],
    p: int,
    seed,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Destination reducer per row; invalid rows -> p (drop sentinel).

    ``seed`` may be a python int OR a traced scalar (uint32 data operand,
    never a jit static: retries must not recompile).  Bit-identical to
    ``relational.hashing.dests_for`` (the jnp reference)."""
    n, ar = rows.shape
    pad = -n % ROWS_BLK
    rp = jnp.pad(rows, ((0, pad), (0, 0)))
    vp = jnp.pad(valid, (0, pad))
    if isinstance(seed, (int, np.integer)):
        seed = np.uint32(int(seed) & 0xFFFFFFFF)  # top-bit-set ints overflow int32
    s2 = jnp.reshape(jnp.asarray(seed).astype(jnp.uint32), (1, 1))
    out = _partition_call(s2, rp, vp[:, None], tuple(cols), int(p), interpret)
    return out[:n, 0]
