"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision patch frontend is a stub —
``input_specs()`` feeds precomputed patch/text embedding token ids plus the
(temporal, height, width) M-RoPE position ids."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    rope="mrope",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    notes="patch frontend stubbed; M-RoPE bands 2:3:3 over (t,h,w)",
)
