from .registry import CONFIGS, SHAPES, cells, cell_enabled, get_config, get_model, input_specs, make_smoke_batch, reduced_config

__all__ = [
    "CONFIGS", "SHAPES", "cells", "cell_enabled", "get_config", "get_model",
    "input_specs", "make_smoke_batch", "reduced_config",
]
