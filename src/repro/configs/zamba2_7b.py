"""zamba2-7b [hybrid] — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified].

81 layers: 75 Mamba2 blocks with the SAME shared transformer block
(weights shared, caches distinct) applied at 6 evenly spaced points —
the Zamba2 shared-block design at the assignment's sizes."""


def _pattern():
    out = []
    shared_at = {6, 19, 32, 45, 58, 71}
    for i in range(81):
        out.append("shared_attn" if i in shared_at else "mamba")
    return tuple(out)


from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    pattern=_pattern(),
    ssm_state=64,
    ssm_expand=2,
    conv_kernel=4,
    chunk=256,
    tie_embeddings=True,
    notes="runs long_500k (mamba recurrence; shared-attn KV is O(S) decode)",
)
