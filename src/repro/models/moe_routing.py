"""MoE expert dispatch as the second customer of the routed exchange.

Expert dispatch IS a skewed hash exchange (ROADMAP open item 2): tokens
are tuples, experts are destinations, hot experts are heavy hitters, and
capacity factors are exactly the ``SideCaps`` the join engines measure.
This module routes (token, choice) pairs through the SAME
``relational.routed`` primitive the hash/grid/hybrid joins run on:

- **count pre-pass** — ``calibrate_moe`` runs the router once on a
  calibration batch and ships per-expert bucket counts through
  ``route_counts`` (the exact (p,)-int ``all_to_all`` of the join
  engines' measure dispatch), picking tight pow2 send/receive capacities
  instead of a guessed ``capacity_factor``;
- **heavy split** — experts whose measured arrival exceeds the balanced
  share (``skew.heavy_dest_flags``, Joglekar & Ré's degree threshold)
  have their tokens spread round-robin over ALL expert shards
  (``split_dests``), each shard applying the hot expert's weights to its
  slice — Lemma 8's position-partitioned side with the weight table as
  the broadcast side;
- **explicit drops** — the dense scatter in ``mlp.moe_forward`` silently
  drops over-capacity tokens into the residual; the routed path reports
  the exact dropped-pair count, and a plan whose capacities come from
  the measure provably drops nothing.

The plan (``MoEPlan``) is frozen/hashable and rides inside ``ArchConfig``
(``cfg.moe_plan``), so capacities are jit-static: the forward stays one
compiled program per pow2 capacity bucket, reused across steps — the
same program-cache story as the join engine's calibrated exchanges.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..relational.ledger import Ledger
from ..relational.routed import (
    RoutePolicy,
    padded_slots,
    pow2,
    route_counts,
    routed_all_to_all,
)
from ..relational.skew import DEFAULT_SKEW_THRESHOLD, split_dests
from ..relational.spmd import AXIS
from ..relational.wire import count_wire_bytes, dense_wire_bytes
from .common import ArchConfig

#: payload columns appended to the d activation features of each
#: (token, choice) pair: [gate weight, token id, expert id].  Float32
#: carries the int ids exactly (ids < 2^24) so one homogeneous buffer
#: rides the exchange.
PAIR_EXTRA = 3


# ----------------------------------------------------------------- router
def router_pairs(
    p: Dict, xf: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing decisions shared by BOTH dispatch routes: returns
    (flat_e, flat_w, flat_tok), each (t*k,), token-major — identical
    math, so route parity is purely a dispatch-mechanics comparison."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.topk
    logits = xf.astype(jnp.float32) @ p["router"]  # (t, e)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(gates, k)  # (t, k)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
    flat_e = tope.reshape(-1)
    flat_w = topw.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    return flat_e, flat_w, flat_tok


# ------------------------------------------------------------------- plan
@dataclasses.dataclass(frozen=True)
class MoEPlan:
    """Static routing plan of one calibrated MoE dispatch.

    Frozen + tuple-valued so it is hashable (jit-static inside
    ``ArchConfig``) and pow2-bucketed so distinct batches reuse compiled
    programs.  ``e`` expert shards each own ``tpp`` tokens'
    (token, choice) pairs; ``heavy`` lists the experts the count pre-pass
    flagged hot (their pairs spread round-robin over all shards)."""

    e: int                      # experts == route shards
    k: int                      # choices per token
    tpp: int                    # tokens per shard (pairs per shard = tpp*k)
    cap_send: int               # dispatch per-destination bucket capacity
    cap_recv: int               # per-expert receive capacity
    heavy: Tuple[int, ...] = ()  # statically-known hot experts

    @property
    def ret_cap_send(self) -> int:
        """Combine-exchange send buckets: a shard returns at most what it
        received, and at most one home shard's worth of pairs."""
        return pow2(min(self.cap_recv, self.tpp * self.k))

    @property
    def ret_cap_recv(self) -> int:
        """Combine-exchange receive capacity: a home shard gets back at
        most its own ``tpp*k`` pairs — exact, so the return trip can
        never drop when the dispatch did not."""
        return pow2(self.tpp * self.k)

    @staticmethod
    def sound(t: int, k: int, e: int) -> "MoEPlan":
        """Worst-case-sound plan (no measure): capacities cover every
        pair landing on one expert, so drops are impossible — the
        fallback for jitted scenarios that cannot run a calibration
        batch first (e.g. decode serving before traffic exists)."""
        tpp = -(-t // e)
        return MoEPlan(
            e=e, k=k, tpp=tpp,
            cap_send=pow2(tpp * k), cap_recv=pow2(t * k),
        )


def apply_plan(cfg: ArchConfig, plan: MoEPlan) -> ArchConfig:
    """Config with the calibrated route + plan installed — the model
    closes over the returned config, keeping the plan jit-static."""
    return dataclasses.replace(cfg, moe_route="calibrated", moe_plan=plan)


def _heavy_vec(plan: MoEPlan) -> jax.Array:
    flags = np.zeros((plan.e,), bool)
    for h in plan.heavy:
        flags[h] = True
    return jnp.asarray(flags)


def _shard_pairs(plan: MoEPlan, t: int, flat_e, payload_cols):
    """Pad the token-major pair arrays to ``e * tpp * k`` and fold in the
    shard axis: shard s owns tokens [s*tpp, (s+1)*tpp), so all k pairs of
    a token live on one shard and the combine scatter is shard-local."""
    e, k, tpp = plan.e, plan.k, plan.tpp
    assert t <= e * tpp, (t, e, tpp, "plan sized for fewer tokens")
    pad = e * tpp * k - t * k
    valid = jnp.pad(jnp.ones((t * k,), bool), (0, pad))
    dest = jnp.pad(flat_e.astype(jnp.int32), (0, pad))
    payload = jnp.pad(payload_cols, ((0, pad), (0, 0)))
    npairs = tpp * k
    return (
        payload.reshape(e, npairs, payload.shape[1]),
        valid.reshape(e, npairs),
        dest.reshape(e, npairs),
    )


# ------------------------------------------------------------ calibration
def calibrate_moe(
    p_moe: Dict,
    xf: jax.Array,
    cfg: ArchConfig,
    *,
    threshold: Optional[float] = None,
    cap_recv_ceiling: Optional[int] = None,
) -> Tuple[MoEPlan, Dict]:
    """Measure a calibration batch and build a tight ``MoEPlan``.

    Runs the router once (host-visible), flags heavy experts from the
    per-expert arrivals, then ships the ACTUAL per-shard send counts
    through ``route_counts`` — the identical count pre-pass collective
    the join engines calibrate with — so ``cap_send``/``cap_recv`` are
    the measured maxima after heavy spreading, pow2-bucketed.

    ``cap_recv_ceiling`` clips the receive capacity (an M-style memory
    bound); the dispatch then reports its exact overflow instead of
    silently truncating.  Returns (plan, measure-info dict)."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.topk
    policy = RoutePolicy(
        skew_threshold=DEFAULT_SKEW_THRESHOLD if threshold is None else threshold
    )
    flat_e, _, _ = router_pairs(p_moe, xf, cfg)
    arrivals = np.bincount(np.asarray(flat_e), minlength=e)
    flags = policy.heavy_flags(arrivals.reshape(1, e), e)
    heavy = tuple(int(i) for i in np.nonzero(flags)[0])
    tpp = -(-t // e)
    probe = MoEPlan(e=e, k=k, tpp=tpp, cap_send=1, cap_recv=1, heavy=heavy)
    _, valid, dest = _shard_pairs(
        probe, t, flat_e, jnp.zeros((t * k, 1), jnp.float32)
    )
    hv = _heavy_vec(probe)

    def count_fn(dst, val):
        d2, _ = split_dests(jnp.where(val, dst, e), hv, e)
        return route_counts(d2, e)

    out_counts, recv_tot = jax.vmap(count_fn, axis_name=AXIS)(dest, valid)
    cap_send = pow2(int(jax.device_get(out_counts).max()))
    cap_recv = pow2(int(jax.device_get(recv_tot).max()))
    if cap_recv_ceiling is not None:
        cap_recv = min(cap_recv, int(cap_recv_ceiling))
    plan = MoEPlan(
        e=e, k=k, tpp=tpp, cap_send=cap_send, cap_recv=cap_recv, heavy=heavy
    )
    return plan, {
        "arrivals": arrivals,
        "heavy": heavy,
        "out_counts": np.asarray(jax.device_get(out_counts)),
    }


# --------------------------------------------------------------- dispatch
def calibrated_dispatch(
    p: Dict, xf: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Route (token, choice) pairs to expert shards via
    ``routed_all_to_all``, apply the expert FFNs, and route the weighted
    outputs back — two exchanges, like the production MoE all-to-all pair.

    Per shard: light pairs land on their expert's home shard and run the
    shard-local expert; pairs of each statically-known heavy expert are
    spread round-robin (``heavy=`` inside the primitive) and every shard
    applies that expert's weights to its slice.  The combine exchange
    returns pairs to the token's home shard (token-contiguous pair
    sharding), whose capacities are exact — it can never drop when the
    dispatch did not.

    Returns (combined (t, d) expert mix, stats) with stats =
    {routed, dropped, heavy} int32 scalars; ``dropped`` is the EXACT
    pair loss across both exchanges (zero under a measured plan)."""
    plan: MoEPlan = cfg.moe_plan
    assert plan is not None, "route='calibrated' needs cfg.moe_plan"
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.topk
    assert (plan.e, plan.k) == (e, k), (plan, e, k)
    tpp = plan.tpp

    flat_e, flat_w, flat_tok = router_pairs(p, xf, cfg)
    payload = jnp.concatenate(
        [
            xf[flat_tok].astype(jnp.float32),
            flat_w[:, None].astype(jnp.float32),
            flat_tok[:, None].astype(jnp.float32),
            flat_e[:, None].astype(jnp.float32),
        ],
        axis=1,
    )  # (t*k, d + PAIR_EXTRA)
    s_payload, s_valid, s_dest = _shard_pairs(plan, t, flat_e, payload)
    hv = _heavy_vec(plan)
    wg, wi, wo = p["wg"], p["wi"], p["wo"]

    def ffn(rx, w_g, w_i, w_o):
        g = jax.nn.silu((rx @ w_g).astype(jnp.float32)).astype(rx.dtype)
        return (g * (rx @ w_i)) @ w_o

    def shard_fn(pay, val, dst):
        r = routed_all_to_all(
            pay, val, dst,
            p=e, c_out=plan.cap_send, cap_recv=plan.cap_recv, heavy=hv,
        )
        rx = r.data[:, :d].astype(wg.dtype)
        rw = r.data[:, d]
        rtok = r.data[:, d + 1].astype(jnp.int32)
        rexp = r.data[:, d + 2].astype(jnp.int32)
        own = jax.lax.axis_index(AXIS)
        own_mask = r.valid & (rexp == own)
        for h in plan.heavy:  # heavy experts are handled below, everywhere
            own_mask = own_mask & (rexp != h)
        y = ffn(
            rx,
            jnp.take(wg, own, axis=0),
            jnp.take(wi, own, axis=0),
            jnp.take(wo, own, axis=0),
        ) * own_mask[:, None].astype(wg.dtype)
        for h in plan.heavy:  # static unroll: hot experts run on every shard
            mh = r.valid & (rexp == h)
            y = y + ffn(rx, wg[h], wi[h], wo[h]) * mh[:, None].astype(wg.dtype)
        yw = y.astype(jnp.float32) * rw[:, None]
        back = jnp.concatenate([yw, rtok.astype(jnp.float32)[:, None]], axis=1)
        home = jnp.clip(rtok // tpp, 0, e - 1)
        r2 = routed_all_to_all(
            back, r.valid, home,
            p=e, c_out=plan.ret_cap_send, cap_recv=plan.ret_cap_recv,
        )
        btok = r2.data[:, d].astype(jnp.int32) - own * tpp
        idx = jnp.where(r2.valid, btok, tpp)  # tpp == out-of-range -> drop
        y_blk = jnp.zeros((tpp, d), jnp.float32).at[idx].add(
            r2.data[:, :d], mode="drop"
        )
        dropped = (
            r.dropped_send + r.dropped_recv + r2.dropped_send + r2.dropped_recv
        )
        return y_blk, r.sent, dropped, r.heavy_sent

    y_blocks, sent, dropped, heavy_sent = jax.vmap(shard_fn, axis_name=AXIS)(
        s_payload, s_valid, s_dest
    )
    combined = y_blocks.reshape(e * tpp, d)[:t].astype(xf.dtype)
    stats = {
        "routed": sent.sum(),
        "dropped": dropped.sum(),
        "heavy": heavy_sent.sum(),
    }
    return combined, stats


# -------------------------------------------------------------- accounting
def calibrated_dispatch_bytes(plan: MoEPlan, d: int) -> Tuple[int, int]:
    """(payload_bytes, padded_slots) the calibrated route's two exchanges
    ship fleet-wide: dense float32 cells + valid plane, priced by the
    SAME ``wire.dense_wire_bytes`` formula the join ledger uses."""
    ar_out, ar_back = d + PAIR_EXTRA, d + 1
    pb = dense_wire_bytes(plan.e, plan.cap_send, ar_out) + dense_wire_bytes(
        plan.e, plan.ret_cap_send, ar_back
    )
    pad = padded_slots(plan.e, plan.cap_send, ar_out) + padded_slots(
        plan.e, plan.ret_cap_send, ar_back
    )
    return pb, pad


def dense_scatter_bytes(cfg: ArchConfig, t: int, d: int) -> Tuple[int, int]:
    """(payload_bytes, padded_slots) of the dense Switch-style scatter's
    dispatch buffer — the ``(e*cap+1, d)`` slots every step materializes
    whether occupied or not (its 'wire' is HBM, but the padding economics
    are the same accounting question)."""
    e, k = cfg.n_experts, cfg.topk
    cap = max(1, int(cfg.capacity_factor * t * k / e))
    return 4 * (e * cap + 1) * d, (e * cap + 1) * d


def record_moe_round(
    ledger: Ledger,
    stats: Dict,
    *,
    plan: MoEPlan,
    d: int,
    note: str = "",
    measured: bool = True,
) -> None:
    """One calibrated MoE layer's dispatch as a ledger round, in the
    join vocabulary: ``comm`` = pairs routed, ``heavy`` = pair-sends via
    the heavy spread, ``dropped`` = exact capacity losses, byte-true
    payload/useful accounting over both exchanges.  ``measured``: charge
    the calibration count pre-pass (one measure dispatch + its (e,)-int
    count vectors) to this round."""
    routed = int(stats["routed"])
    dropped = int(stats["dropped"])
    pb, pad = calibrated_dispatch_bytes(plan, d)
    measure_pb = count_wire_bytes(plan.e) if measured else 0
    delivered = max(routed - dropped, 0)
    ledger.add_round(
        "moe",
        [f"moe_dispatch[e={plan.e},k={plan.k},cap={plan.cap_recv}]"],
        comm=routed,
        note=note,
        n_rounds=2,  # dispatch + combine exchanges
        dispatches=1,
        measure_dispatches=1 if measured else 0,
        padded=pad + (plan.e * plan.e if measured else 0),
        heavy=int(stats["heavy"]),
        payload_bytes=pb + measure_pb,
        useful_bytes=4 * (routed * (d + PAIR_EXTRA) + delivered * (d + 1)),
        dropped=dropped,
        heavy_dests=len(plan.heavy),
    )


def record_dense_round(
    ledger: Ledger, stats: Dict, *, cfg: ArchConfig, t: int, d: int,
    note: str = "",
) -> None:
    """The dense scatter route in the same vocabulary, so one ledger
    compares both dispatches: ``dropped`` is the silent over-capacity
    loss the dense path never used to report."""
    routed = int(stats["routed"])
    pb, pad = dense_scatter_bytes(cfg, t, d)
    ledger.add_round(
        "moe",
        [f"moe_dense[e={cfg.n_experts},k={cfg.topk}]"],
        comm=routed,
        note=note,
        n_rounds=1,
        dispatches=1,
        padded=pad,
        payload_bytes=pb,
        useful_bytes=4 * routed * d,
        dropped=int(stats["dropped"]),
    )
