"""Occupancy-adaptive shuffle: fixed worst-case capacities vs
count-calibrated capacities vs the calibrated+packed wire format, on the
Table-1 families (S_8 / C_8 / TC_9, hash engine, p=8).

The acceptance bar this bench enforces:

- results are bit-identical (rows, ``comm_tuples``) across all modes;
- measured ``padded_slots`` drops >= 2x with calibration;
- the families complete with ZERO abort-retries when the count pre-pass
  is enabled (blown capacities are pre-floored from measured counts);
- dispatch economics: amortized calibration (combined per-stage count
  dispatch with the join output count fused in, cross-round caps cache,
  prefetch overlap) makes the calibrated mode at most as slow as fixed
  on wall-clock, with at most one measure dispatch per claimed round;
- the packed wire format (``GymConfig(wire_format="packed")``, bit-widths
  from the base relations' value ranges, ``relational/wire.py``) moves
  the SAME rows/comm/retries as calibrated-dense while improving
  byte-true ``payload_efficiency_bytes`` >= 4x, at steady-state wall
  clock no worse than calibrated-dense.

Timing methodology: each (family, mode) pair runs three times on one
shared ``SPMD`` — the first run compiles every XLA program (reported
as ``cold_secs``), the next two reuse them and the BEST wall time is
the ``secs`` the guards compare (min-of-2: the noise-robust
steady-state estimator).  The paper's cost model prices rounds and
communication, not XLA compilation; steady-state is where dispatch
economics are visible (a calibrated run launches tiny count programs
but ships ~5x fewer padded cells, which one-time compile cost would
otherwise drown out on the CPU simulator).

Besides printing JSON rows, the run writes ``BENCH_shuffle.json`` at the
repo root — the persistent perf trajectory (wall time, comm, padded
slots, retries, dispatches per family x mode) future PRs regress
against.  ``BENCH_SHUFFLE_ONLY=S_8`` (comma list) limits the families;
filtered runs write ``BENCH_shuffle.partial.json`` instead so they never
clobber the committed full baseline (the CI smoke step runs just S_8).
"""
from __future__ import annotations

import os
import time

from benchmarks._io import write_json_atomic
from repro.core.gym import GymConfig, GymDriver, gym
from repro.relational.spmd import SPMD
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_shuffle.json")
# filtered runs (BENCH_SHUFFLE_ONLY, e.g. the CI S_8 smoke) must not
# clobber the committed full-family trajectory baseline
PARTIAL_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_shuffle.partial.json"
)

# Sized so each shard holds a real workload (dozens-to-hundreds of rows):
# at toy sizes every capacity bottoms out at the pow2 floor and the fixed
# baseline has nothing to waste.  These are the matching-database shapes
# of Appendix A at p=8 scale.
FAMILIES = {
    "S_8": lambda: (
        star_query(8),
        star_ghd(8),
        star_data_sparse(8, domain=64, hub_rows=256, spoke_extra=64, seed=21),
    ),
    "C_8": lambda: (
        chain_query(8),
        chain_ghd(8),
        chain_data_sparse(8, domain=256, ident=64, extra=192, seed=24),
    ),
    "TC_9": lambda: (
        triangle_chain_query(3),
        triangle_chain_ghd(3),
        tc_data_sparse(3, domain=128, ident=32, extra=96, seed=22),
    ),
}


# mode name -> (calibrate_shuffle, wire_format)
MODES = {
    "fixed": (False, "dense"),
    "calibrated": (True, "dense"),
    "packed": (True, "packed"),
}


def _one(q, g, data, *, calibrate: bool, wire_format: str = "dense", p: int = 8):
    cfg = GymConfig(
        strategy="hash",
        seed=23,
        calibrate_shuffle=calibrate,
        wire_format=wire_format,
    )
    spmd = SPMD(p)
    t0 = time.time()
    GymDriver(q, g, data, spmd, cfg).run()  # compile warmup (cold run)
    cold = time.time() - t0
    # steady state: programs warm; best-of-2 is the noise-robust
    # steady-state estimator (single samples on a busy CPU jitter by
    # more than the mode deltas the guards compare)
    secs = float("inf")
    for _ in range(2):
        t0 = time.time()
        drv = GymDriver(q, g, data, spmd, cfg)
        rows = drv.run().to_numpy()
        secs = min(secs, time.time() - t0)
    return rows, drv.ledger, secs, cold


def run() -> list:
    only = os.environ.get("BENCH_SHUFFLE_ONLY")
    names = only.split(",") if only else list(FAMILIES)
    out = []
    trajectory = []
    for name in names:
        q, g, data = FAMILIES[name]()
        res = {}
        secs_by = {}
        for mode, (calibrate, wf) in MODES.items():
            rows, led, secs, cold = _one(
                q, g, data, calibrate=calibrate, wire_format=wf
            )
            res[mode] = (rows, led)
            secs_by[mode] = secs
            rec = dict(
                bench="shuffle",
                query=name,
                engine="hash",
                mode=mode,
                secs=round(secs, 3),
                cold_secs=round(cold, 2),
                comm_tuples=led.comm_tuples,
                shuffle_tuples=led.shuffle_tuples,
                padded_slots=led.padded_slots,
                payload_efficiency=round(led.payload_efficiency, 4),
                payload_bytes=led.payload_bytes,
                useful_bytes=led.useful_bytes,
                payload_efficiency_bytes=round(
                    led.payload_efficiency_bytes, 4
                ),
                retries=led.retries,
                dispatches=led.measured_dispatches,
                measure_dispatches=led.measure_dispatches,
                payload_dispatches=led.payload_dispatches,
                rounds_claimed=led.rounds,
                output_tuples=led.output_tuples,
            )
            out.append(rec)
            trajectory.append(rec)
        rows_f, led_f = res["fixed"]
        rows_c, led_c = res["calibrated"]
        rows_p, led_p = res["packed"]
        # calibration must not change WHAT moves — only how it is packed
        assert {tuple(r) for r in rows_c} == {tuple(r) for r in rows_f}, name
        assert led_c.comm_tuples == led_f.comm_tuples, (
            name, led_c.comm_tuples, led_f.comm_tuples,
        )
        # acceptance: the wire ships >= 2x fewer slots, calibrated
        assert 2 * led_c.padded_slots <= led_f.padded_slots, (
            name, led_c.padded_slots, led_f.padded_slots,
        )
        # acceptance: the count pre-pass pre-floors every blown capacity
        assert led_c.retries == 0, (name, led_c.retries)
        # acceptance: amortization pays for the pre-pass — calibrated
        # never loses the wall clock to fixed ...
        assert secs_by["calibrated"] <= secs_by["fixed"], (
            name, secs_by["calibrated"], secs_by["fixed"],
        )
        # ... and batching + caching keep the measure traffic at no more
        # than one count dispatch per claimed round
        assert led_c.measure_dispatches <= led_c.rounds, (
            name, led_c.measure_dispatches, led_c.rounds,
        )
        # acceptance (packed wire format): bit-identical rows and comm;
        # zero retries; the useful payload is identical by construction
        # so the byte-efficiency ratio IS the shipped-byte ratio —
        # require >= 4x over calibrated-dense.  (padded_slots is NOT
        # compared: the packed join pre-count ships the actual key
        # projections — multi-column slots — where dense ships a
        # width-1 hashed column, so the slot metric legitimately
        # diverges; bytes are what the packed mode is judged on.)
        assert {tuple(r) for r in rows_p} == {tuple(r) for r in rows_c}, name
        assert led_p.comm_tuples == led_c.comm_tuples, (
            name, led_p.comm_tuples, led_c.comm_tuples,
        )
        assert led_p.retries == 0, (name, led_p.retries)
        assert led_p.useful_bytes == led_c.useful_bytes, (
            name, led_p.useful_bytes, led_c.useful_bytes,
        )
        eff_p = led_p.payload_efficiency_bytes
        eff_c = led_c.payload_efficiency_bytes
        assert eff_p >= 4.0 * eff_c, (name, eff_p, eff_c)
        # packed encode/decode must not cost the steady-state wall clock
        assert secs_by["packed"] <= secs_by["calibrated"], (
            name, secs_by["packed"], secs_by["calibrated"],
        )
    path = OUT_PATH if not only else PARTIAL_PATH
    write_json_atomic(
        path,
        {
            "bench": "shuffle",
            "p": 8,
            "engine": "hash",
            "families": names,
            "results": trajectory,
        },
    )
    return out
