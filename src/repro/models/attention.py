"""Attention block: GQA, RoPE/M-RoPE, qk-norm, softcap, sliding window,
cross-attention, KV-cache decode — one implementation for all archs."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .common import ArchConfig, apply_mrope, apply_rope, init_norm, rms_norm, scaled_init


def init_attn(rng, cfg: ArchConfig) -> Dict:
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 6)
    p = {
        "wq": scaled_init(ks[0], (d, h * hd), 0, cfg.jdtype),
        "wk": scaled_init(ks[1], (d, kv * hd), 0, cfg.jdtype),
        "wv": scaled_init(ks[2], (d, kv * hd), 0, cfg.jdtype),
        "wo": scaled_init(ks[3], (h * hd, d), 0, cfg.jdtype),
        "ln": init_norm(d, cfg.jdtype),
    }
    if cfg.qk_norm:
        p["qn"] = init_norm(hd, cfg.jdtype)
        p["kn"] = init_norm(hd, cfg.jdtype)
    return p


def _project_qkv(p, x, cfg: ArchConfig, pos):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    if cfg.rope == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, pos, cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.rope_theta)
    return q, k, v


def attn_forward(
    p: Dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    pos: jax.Array,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    """Full-sequence attention (train / prefill).  x (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, xin, cfg, pos)
    o = kops.attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return x + (o @ p["wo"]).astype(x.dtype)


def attn_prefill(
    p: Dict, x: jax.Array, cfg: ArchConfig, *, pos, causal=True, window=0
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Like forward but also returns the KV cache (B, KV, S, hd)."""
    b, s, d = x.shape
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, xin, cfg, pos)
    o = kops.attention(
        q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return x + (o @ p["wo"]).astype(x.dtype), {"k": k, "v": v}


def attn_decode(
    p: Dict,
    x: jax.Array,  # (B, 1, D) current token activations
    cache: Dict[str, jax.Array],  # k/v (B, KV, S_cache, hd)
    cache_len: jax.Array,  # () int32 — valid prefix length
    cfg: ArchConfig,
    *,
    window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: append (k,v) at cache_len, attend to the prefix."""
    b, s1, d = x.shape
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    posv = jnp.full((b, 1), cache_len, jnp.int32)
    if cfg.rope == "mrope":
        posv = jnp.broadcast_to(posv[None], (3,) + posv.shape)
    q, k, v = _project_qkv(p, xin, cfg, posv)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, cache_len, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, cache_len, 0))
    s_cache = kc.shape[2]
    # mask positions beyond cache_len via additive bias trick: use window=0,
    # causal=False, and mask by comparing against cache_len
    g = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(kc, g, axis=1)
    vv = jnp.repeat(vc, g, axis=1)
    scale = float(cfg.hd) ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if cfg.attn_softcap > 0.0:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    idx = jnp.arange(s_cache)[None, None, None, :]
    mask = idx <= cache_len
    if window and window > 0:
        mask &= idx > cache_len - window
    s = jnp.where(mask, s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", pr, vv.astype(jnp.float32)).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return x + (o @ p["wo"]).astype(x.dtype), {"k": kc, "v": vc}


# ------------------------------------------------------- cross attention
def init_cross_attn(rng, cfg: ArchConfig) -> Dict:
    p = init_attn(rng, cfg)
    return p


def cross_attn_forward(
    p: Dict, x: jax.Array, mem_kv: Dict[str, jax.Array], cfg: ArchConfig
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (xin @ p["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    o = kops.attention(
        q, mem_kv["k"], mem_kv["v"], causal=False, softcap=cfg.attn_softcap
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return x + (o @ p["wo"]).astype(x.dtype)


def cross_kv(p: Dict, mem: jax.Array, cfg: ArchConfig) -> Dict[str, jax.Array]:
    """Precompute encoder-side K/V for cross attention (prefill)."""
    b, s, d = mem.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = (mem @ p["wk"]).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    v = (mem @ p["wv"]).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}
