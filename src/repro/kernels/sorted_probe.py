"""TPU Pallas kernel: tiled sorted-probe match ranges — the per-reducer
inner loop of join expansion and exact join counting.

Problem: given probe keys q (n,) int32 and a SORTED key table ks (m,) int32
(invalid slots = INT32_MAX, sorted to the back), produce
(lo, hi) (n,) int32 with lo[i] = #{j : ks[j] <  q[i]} and
         hi[i] = #{j : ks[j] <= q[i]} —
exactly ``searchsorted(ks, q, 'left'/'right')``, so ``ks[lo[i]:hi[i]]``
is q[i]'s match range and ``hi - lo`` is its multiplicity.

TPU-native design (same family as ``semijoin_probe``):
  - data is laid out 2-D (rows, 128) to match the VPU's (8, 128) vector
    registers; BlockSpec tiles bring a (8, 128) probe block and a
    (KEY_ROWS, 128) key block into VMEM;
  - rank-by-counting: a fori_loop walks the key block one 128-lane row at
    a time and SUM-reduces ``row < q`` / ``row <= q`` broadcast compares —
    pure VPU lane ops, no gathers, no binary search (data-dependent
    branching is what TPUs are worst at);
  - grid = (probe blocks x key blocks); per-tile partial counts are
    +=-merged into the output blocks (revisiting the same output block
    across the key grid axis), which is why counting needs no sortedness —
    sortedness is only what makes the counts usable as indices.

Contract: probe values must be < INT32_MAX (dense ranks are; invalid
probes are -1 and get lo == hi == 0 against non-negative ranks), because
key padding uses INT32_MAX and must never count.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import KEY_ROWS, LANES, PROBE_ROWS, pad_probe_key_tiles


def _range_kernel(q_ref, k_ref, lo_ref, hi_ref):
    """One (probe tile, key tile): SUM-reduced broadcast rank counts."""
    j = pl.program_id(1)
    q = q_ref[...]  # (PROBE_ROWS, 128)
    keys = k_ref[...]  # (KEY_ROWS, 128)

    def body(r, acc):
        lt, le = acc
        row = jax.lax.dynamic_slice(keys, (r, 0), (1, LANES))[0]  # (128,)
        cmp = row[None, None, :] < q[:, :, None]  # (8, 128, 128)
        lt = lt + cmp.astype(jnp.int32).sum(axis=-1)
        cmp = row[None, None, :] <= q[:, :, None]
        le = le + cmp.astype(jnp.int32).sum(axis=-1)
        return lt, le

    zero = jnp.zeros(q.shape, jnp.int32)
    lt, le = jax.lax.fori_loop(0, keys.shape[0], body, (zero, zero))

    @pl.when(j == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lt)
        hi_ref[...] = jnp.zeros_like(le)

    lo_ref[...] += lt
    hi_ref[...] += le


@functools.partial(jax.jit, static_argnames=("interpret",))
def _range_call(
    q2: jax.Array, k2: jax.Array, interpret: bool
) -> Tuple[jax.Array, jax.Array]:
    nr, mr = q2.shape[0], k2.shape[0]
    grid = (nr // PROBE_ROWS, mr // KEY_ROWS)
    return pl.pallas_call(
        _range_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((PROBE_ROWS, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((KEY_ROWS, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((PROBE_ROWS, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((PROBE_ROWS, LANES), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nr, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(q2, k2)


def sorted_probe_ranges(
    q: jax.Array, keys: jax.Array, *, interpret: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """(lo, hi) = searchsorted(keys, q, 'left'/'right') for SORTED keys.

    Probe values must be < INT32_MAX (dense ranks are); invalid key slots
    should be INT32_MAX (and sort to the back)."""
    n = q.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32)
    q2, k2 = pad_probe_key_tiles(q, keys)
    lo, hi = _range_call(q2, k2, interpret)
    return lo.reshape(-1)[:n], hi.reshape(-1)[:n]
