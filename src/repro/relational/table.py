"""Fixed-capacity relation tables (local and distributed).

``Table``  — one shard: data (cap, arity) int32 + valid (cap,) bool.
``DTable`` — p shards: data (p, cap, arity) + valid (p, cap); axis 0 is the
reducer axis (vmapped in simulation, mesh-sharded in production).

Schemas are static python tuples of attribute names; they ride along as
aux data (pytree static fields) so jitted code can do column arithmetic in
Python.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Table:
    data: jax.Array  # (cap, arity) int32
    valid: jax.Array  # (cap,) bool
    schema: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def cap(self) -> int:
        return self.data.shape[-2]

    @property
    def arity(self) -> int:
        return self.data.shape[-1]

    def count(self) -> jax.Array:
        return self.valid.sum()

    def col(self, attr: str) -> int:
        return self.schema.index(attr)

    def cols(self, attrs: Sequence[str]) -> Tuple[int, ...]:
        return tuple(self.schema.index(a) for a in attrs)

    @staticmethod
    def from_numpy(rows: np.ndarray, schema: Sequence[str], cap: Optional[int] = None) -> "Table":
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, len(schema))
        n = rows.shape[0]
        cap = cap or max(1, n)
        assert n <= cap, f"{n} rows > cap {cap}"
        data = np.zeros((cap, len(schema)), np.int32)
        data[:n] = rows
        valid = np.zeros((cap,), bool)
        valid[:n] = True
        return Table(jnp.asarray(data), jnp.asarray(valid), tuple(schema))

    def to_numpy(self) -> np.ndarray:
        """Valid rows, lexicographically sorted (canonical for comparisons)."""
        d = np.asarray(self.data)
        v = np.asarray(self.valid)
        rows = d[v]
        if rows.size == 0:
            return rows.reshape(0, self.arity)
        order = np.lexsort(rows.T[::-1])
        return rows[order]

    def to_set(self) -> set:
        return {tuple(int(x) for x in r) for r in self.to_numpy()}


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DTable:
    data: jax.Array  # (p, cap, arity) int32
    valid: jax.Array  # (p, cap) bool
    schema: Tuple[str, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def p(self) -> int:
        return self.data.shape[0]

    @property
    def cap(self) -> int:
        return self.data.shape[1]

    @property
    def arity(self) -> int:
        return self.data.shape[2]

    def col(self, attr: str) -> int:
        return self.schema.index(attr)

    def cols(self, attrs: Sequence[str]) -> Tuple[int, ...]:
        return tuple(self.schema.index(a) for a in attrs)

    def count(self) -> jax.Array:
        return self.valid.sum()

    def shard(self, i: int) -> Table:
        return Table(self.data[i], self.valid[i], self.schema)

    @staticmethod
    def scatter_numpy(
        rows: np.ndarray, schema: Sequence[str], p: int, cap: Optional[int] = None,
        seed: int = 0,
    ) -> "DTable":
        """Round-robin scatter of rows over p shards (initial 'file system'
        placement; any placement is fine — ops re-shuffle as needed)."""
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, len(schema))
        n = rows.shape[0]
        per = int(np.ceil(n / p)) if n else 1
        cap = cap or max(1, per)
        data = np.zeros((p, cap, len(schema)), np.int32)
        valid = np.zeros((p, cap), bool)
        for i in range(n):
            s, off = i % p, i // p
            assert off < cap, f"scatter overflow: {n} rows, p={p}, cap={cap}"
            data[s, off] = rows[i]
            valid[s, off] = True
        return DTable(jnp.asarray(data), jnp.asarray(valid), tuple(schema))

    def to_numpy(self) -> np.ndarray:
        d = np.asarray(self.data).reshape(-1, self.arity)
        v = np.asarray(self.valid).reshape(-1)
        rows = d[v]
        if rows.size == 0:
            return rows.reshape(0, self.arity)
        order = np.lexsort(rows.T[::-1])
        return rows[order]

    def to_set(self) -> set:
        return {tuple(int(x) for x in r) for r in self.to_numpy()}


def schema_join(a: Sequence[str], b: Sequence[str]) -> Tuple[str, ...]:
    """Output schema of a natural join: a's attrs then b's new attrs."""
    return tuple(a) + tuple(x for x in b if x not in a)


def schema_project(schema: Sequence[str], keep: Sequence[str]) -> Tuple[str, ...]:
    return tuple(a for a in schema if a in set(keep))
