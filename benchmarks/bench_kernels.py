"""Kernel micro-benchmarks: correctness-at-size plus CPU wall time of the
jnp reference paths (the Pallas kernels themselves are TPU-target; on CPU
they run in interpret mode and are validated in tests/test_kernels.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.semijoin_probe import semijoin_probe


def _time(f, *args, n=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n


def run() -> list:
    out = []
    rng = np.random.default_rng(0)

    # semijoin probe: interpret kernel == ref at benchmark size
    q = jnp.asarray(rng.integers(0, 10_000, 4096), jnp.int32)
    keys = jnp.asarray(np.sort(rng.integers(0, 10_000, 8192)), jnp.int32)
    got = semijoin_probe(q, keys, interpret=True)
    want = ref.semijoin_probe_ref(q, keys)
    assert bool((got == want).all())
    t = _time(jax.jit(ref.semijoin_probe_ref), q, keys)
    out.append(dict(bench="kernel_probe", n=4096, m=8192, ref_ms=round(t * 1e3, 3)))

    # flash attention: interpret kernel ~ ref at a serving-ish size
    qq = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    got = flash_attention(qq, kk, vv, causal=True, blk_q=128, blk_k=128, interpret=True)
    want = ref.attention_ref(qq, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)
    t = _time(
        jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True)), qq, kk, vv
    )
    out.append(dict(bench="kernel_attn", shape="1x4x256x64", ref_ms=round(t * 1e3, 3)))
    return out
