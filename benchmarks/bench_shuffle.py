"""Occupancy-adaptive shuffle: count-calibrated capacities vs the fixed
worst-case capacities, on the Table-1 families (S_8 / C_8 / TC_9, hash
engine, p=8).

The acceptance bar this bench enforces:

- results are bit-identical (rows, ``comm_tuples``) across the two modes;
- measured ``padded_slots`` drops >= 2x with calibration;
- the families complete with ZERO abort-retries when the count pre-pass
  is enabled (blown capacities are pre-floored from measured counts);
- dispatch economics: amortized calibration (combined per-stage count
  dispatch with the join output count fused in, cross-round caps cache,
  prefetch overlap) makes the calibrated mode at most as slow as fixed
  on wall-clock, with at most one measure dispatch per claimed round.

Timing methodology: each (family, mode) pair runs twice on one shared
``SPMD`` — the first run compiles every XLA program (reported as
``cold_secs``), the second reuses them and its wall time is the
``secs`` the guards compare.  The paper's cost model prices rounds and
communication, not XLA compilation; steady-state is where dispatch
economics are visible (a calibrated run launches tiny count programs
but ships ~5x fewer padded cells, which one-time compile cost would
otherwise drown out on the CPU simulator).

Besides printing JSON rows, the run writes ``BENCH_shuffle.json`` at the
repo root — the persistent perf trajectory (wall time, comm, padded
slots, retries, dispatches per family x mode) future PRs regress
against.  ``BENCH_SHUFFLE_ONLY=S_8`` (comma list) limits the families;
filtered runs write ``BENCH_shuffle.partial.json`` instead so they never
clobber the committed full baseline (the CI smoke step runs just S_8).
"""
from __future__ import annotations

import json
import os
import time

from repro.core.gym import GymConfig, GymDriver, gym
from repro.relational.spmd import SPMD
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_shuffle.json")
# filtered runs (BENCH_SHUFFLE_ONLY, e.g. the CI S_8 smoke) must not
# clobber the committed full-family trajectory baseline
PARTIAL_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_shuffle.partial.json"
)

# Sized so each shard holds a real workload (dozens-to-hundreds of rows):
# at toy sizes every capacity bottoms out at the pow2 floor and the fixed
# baseline has nothing to waste.  These are the matching-database shapes
# of Appendix A at p=8 scale.
FAMILIES = {
    "S_8": lambda: (
        star_query(8),
        star_ghd(8),
        star_data_sparse(8, domain=64, hub_rows=256, spoke_extra=64, seed=21),
    ),
    "C_8": lambda: (
        chain_query(8),
        chain_ghd(8),
        chain_data_sparse(8, domain=256, ident=64, extra=192, seed=24),
    ),
    "TC_9": lambda: (
        triangle_chain_query(3),
        triangle_chain_ghd(3),
        tc_data_sparse(3, domain=128, ident=32, extra=96, seed=22),
    ),
}


def _one(q, g, data, *, calibrate: bool, p: int = 8):
    cfg = GymConfig(strategy="hash", seed=23, calibrate_shuffle=calibrate)
    spmd = SPMD(p)
    t0 = time.time()
    GymDriver(q, g, data, spmd, cfg).run()  # compile warmup (cold run)
    cold = time.time() - t0
    t0 = time.time()
    drv = GymDriver(q, g, data, spmd, cfg)  # steady state: programs warm
    rows = drv.run().to_numpy()
    secs = time.time() - t0
    return rows, drv.ledger, secs, cold


def run() -> list:
    only = os.environ.get("BENCH_SHUFFLE_ONLY")
    names = only.split(",") if only else list(FAMILIES)
    out = []
    trajectory = []
    for name in names:
        q, g, data = FAMILIES[name]()
        res = {}
        for calibrate in (False, True):
            rows, led, secs, cold = _one(q, g, data, calibrate=calibrate)
            res[calibrate] = (rows, led)
            rec = dict(
                bench="shuffle",
                query=name,
                engine="hash",
                mode="calibrated" if calibrate else "fixed",
                secs=round(secs, 3),
                cold_secs=round(cold, 2),
                comm_tuples=led.comm_tuples,
                shuffle_tuples=led.shuffle_tuples,
                padded_slots=led.padded_slots,
                payload_efficiency=round(led.payload_efficiency, 4),
                retries=led.retries,
                dispatches=led.measured_dispatches,
                measure_dispatches=led.measure_dispatches,
                payload_dispatches=led.payload_dispatches,
                rounds_claimed=led.rounds,
                output_tuples=led.output_tuples,
            )
            out.append(rec)
            trajectory.append(rec)
        rows_f, led_f = res[False]
        rows_c, led_c = res[True]
        # calibration must not change WHAT moves — only how it is packed
        assert {tuple(r) for r in rows_c} == {tuple(r) for r in rows_f}, name
        assert led_c.comm_tuples == led_f.comm_tuples, (
            name, led_c.comm_tuples, led_f.comm_tuples,
        )
        # acceptance: the wire ships >= 2x fewer slots, calibrated
        assert 2 * led_c.padded_slots <= led_f.padded_slots, (
            name, led_c.padded_slots, led_f.padded_slots,
        )
        # acceptance: the count pre-pass pre-floors every blown capacity
        assert led_c.retries == 0, (name, led_c.retries)
        # acceptance: amortization pays for the pre-pass — calibrated
        # never loses the wall clock to fixed ...
        secs_f = next(r["secs"] for r in out
                      if r["query"] == name and r["mode"] == "fixed")
        secs_c = next(r["secs"] for r in out
                      if r["query"] == name and r["mode"] == "calibrated")
        assert secs_c <= secs_f, (name, secs_c, secs_f)
        # ... and batching + caching keep the measure traffic at no more
        # than one count dispatch per claimed round
        assert led_c.measure_dispatches <= led_c.rounds, (
            name, led_c.measure_dispatches, led_c.rounds,
        )
    path = OUT_PATH if not only else PARTIAL_PATH
    with open(path, "w") as f:
        json.dump(
            {
                "bench": "shuffle",
                "p": 8,
                "engine": "hash",
                "families": names,
                "results": trajectory,
            },
            f,
            indent=2,
        )
        f.write("\n")
    return out
