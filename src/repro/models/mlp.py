"""Gated MLP (SwiGLU) and Mixture-of-Experts feed-forward layers.

The MoE dispatch has two routes, selected by ``cfg.moe_route``:

- ``"dense"`` (default): the Switch-style capacity scatter into
  ``(e*cap+1, d)`` slots — over-capacity (token, expert) pairs silently
  fall through to the residual;
- ``"calibrated"``: the routed-exchange path (``models.moe_routing``) —
  the same count-calibrated, heavy-hitter-aware ``routed_all_to_all``
  primitive the join engines run on, with measured per-expert capacities
  and EXPLICIT drop accounting (zero when the measure proves capacity
  sufficient).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..launch.shardings import abstract_mesh_axes, constrain
from .common import ArchConfig, init_norm, rms_norm, scaled_init
from .moe_routing import calibrated_dispatch, router_pairs


def _constrain(x: jax.Array, *spec):
    """MoE-gated wrapper over ``launch.shardings.constrain``.

    [Perf iteration B] When experts cannot be expert-parallel (grok-1: 8
    experts vs a 16-way 'model' axis) XLA replicates the MoE scatter/gather
    dispatch buffers over 'model' and merges contributions with giant
    all-reduces (453 TB/step on grok-1 train_4k); pinning the feature dim
    to 'model' makes the scatter local.  When EP *does* engage (kimi-k2,
    384e) XLA's auto-sharding already picks the all-to-all plan and manual
    constraints only fight it — so ``moe_forward`` gates these on EP
    non-divisibility (measured: kimi 5.75 s vs 17.4 s constrained)."""
    import os

    if os.environ.get("REPRO_MOE_CONSTRAIN", "1") == "0":
        return x
    return constrain(x, *spec)


FSDP = ("pod", "data")


def init_mlp(rng, cfg: ArchConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "wi": scaled_init(ks[0], (d, f), 0, cfg.jdtype),
        "wg": scaled_init(ks[1], (d, f), 0, cfg.jdtype),
        "wo": scaled_init(ks[2], (f, d), 0, cfg.jdtype),
        "ln": init_norm(d, cfg.jdtype),
    }


def mlp_forward(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    h = jax.nn.silu((xin @ p["wg"]).astype(jnp.float32)).astype(x.dtype) * (xin @ p["wi"])
    return x + (h @ p["wo"]).astype(x.dtype)


# ------------------------------------------------------------------- MoE
def init_moe(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    e = cfg.n_experts
    f = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": scaled_init(ks[0], (d, e), 0, jnp.float32),
        "wi": scaled_init(ks[1], (e, d, f), 1, cfg.jdtype),
        "wg": scaled_init(ks[2], (e, d, f), 1, cfg.jdtype),
        "wo": scaled_init(ks[3], (e, f, d), 1, cfg.jdtype),
        "ln": init_norm(d, cfg.jdtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(
            ks[4], cfg, d_ff=(cfg.moe_d_ff or cfg.d_ff) * cfg.n_shared_experts
        )
    return p


def _dense_dispatch(
    p: Dict, xf: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Switch-style capacity scatter.  Over-capacity pairs fall through to
    the residual; the drop is SILENT in the output but counted in stats."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.topk
    cap = max(1, int(cfg.capacity_factor * t * k / e))

    flat_e, flat_w, flat_tok = router_pairs(p, xf, cfg)
    # position of each pair within its expert (by arrival order)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, e)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # rank per expert
    my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < cap

    # dispatch: scatter tokens into (e, cap) slots.  [Perf iteration B]
    # When EP engages (E % model == 0, e.g. kimi's 384e) XLA auto-shards the
    # dispatch with all-to-alls — leave it alone.  When it cannot (grok: 8e
    # vs 16-way 'model') run the scatter with the FEATURE dim sharded on
    # 'model' (indices replicated per shard -> fully local scatter) so XLA
    # stops replicating + all-reducing the dispatch buffers.
    mesh, names = abstract_mesh_axes()
    ep = "model" in names and e % mesh.shape["model"] == 0

    def C(arr, *spec):
        return arr if ep else _constrain(arr, *spec)

    slot = jnp.where(keep, flat_e * cap + my_pos, e * cap)  # overflow -> drop
    src = C(xf[flat_tok], FSDP, "model")
    disp = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(src)
    disp = disp[:-1].reshape(e, cap, d)
    # d stays FSDP-aligned with the expert weights' contraction dim
    disp = C(disp, "model", None, FSDP)

    # expert computation: grouped einsum (hits the MXU per expert)
    gi = jnp.einsum("ecd,edf->ecf", disp, p["wg"])
    hi = jnp.einsum("ecd,edf->ecf", disp, p["wi"])
    act = jax.nn.silu(gi.astype(jnp.float32)).astype(hi.dtype) * hi
    out_e = jnp.einsum("ecf,efd->ecd", act, p["wo"])  # (e, cap, d)
    out_e = C(out_e, None, None, "model")  # back to feature-sharded

    # combine: gather back and weight (local gather per 'model' shard)
    gathered = out_e.reshape(e * cap, d)
    gathered = jnp.concatenate([gathered, jnp.zeros((1, d), gathered.dtype)], 0)
    per_pair = gathered[slot] * flat_w[:, None].astype(gathered.dtype)
    combined = jnp.zeros((t, d), xf.dtype).at[flat_tok].add(
        per_pair.astype(xf.dtype)
    )
    combined = C(combined, FSDP, "model")
    stats = {
        "routed": keep.sum().astype(jnp.int32),
        "dropped": (~keep).sum().astype(jnp.int32),
        "heavy": jnp.int32(0),
    }
    return combined, stats


def moe_forward_stats(
    p: Dict, x: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """MoE layer with routing stats: (output, {routed, dropped, heavy})
    int32 scalars.  Route selected by ``cfg.moe_route`` (see module
    docstring); both routes share ``router_pairs`` so parity comparisons
    isolate dispatch mechanics."""
    b, s, d = x.shape
    t = b * s
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    xf = xin.reshape(t, d)
    if cfg.moe_route == "calibrated":
        combined, stats = calibrated_dispatch(p, xf, cfg)
    else:
        combined, stats = _dense_dispatch(p, xf, cfg)

    y = combined.astype(x.dtype)
    if "shared" in p:
        sh = p["shared"]
        g = jax.nn.silu((xf @ sh["wg"]).astype(jnp.float32)).astype(xf.dtype)
        y = y + ((g * (xf @ sh["wi"])) @ sh["wo"]).astype(x.dtype)
    return x + y.reshape(b, s, d), stats


def moe_forward(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Top-k MoE dispatch: compiled FLOPs scale with *active* params
    (E x C x d x f with C ~ T*topk/E), the property the kimi-k2 roofline
    depends on.  Stats-free wrapper over ``moe_forward_stats``."""
    out, _ = moe_forward_stats(p, x, cfg)
    return out
