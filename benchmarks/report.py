"""Render EXPERIMENTS.md's §Dry-run and §Roofline tables from the dry-run
JSONs (baseline + optimized).  Run after a sweep:

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(name):
    with open(os.path.join(ROOT, name)) as f:
        return json.load(f)


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def dryrun_table(db, mesh):
    rows = []
    for k in sorted(db):
        v = db[k]
        if v.get("mesh") != mesh or v.get("status") != "ok":
            continue
        c = v.get("cost_per_device", {})
        coll = sum(v.get("collective_bytes_global", {}).values())
        rows.append(
            f"| {v['arch']} | {v['shape']} | {v['chips']} | "
            f"{v['n_params']/1e9:.2f}B | {fmt_bytes(v.get('bytes_per_device'))} | "
            f"{c.get('flops', 0):.3e} | {c.get('bytes accessed', 0):.3e} | "
            f"{coll/1e12:.2f} | {v['compile_s']}s |"
        )
    head = (
        "| arch | shape | chips | params | GB/dev | flops/dev | hbm B/dev | "
        "coll TB (global) | compile |\n|---|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def roofline_table(db, db_opt, mesh="single"):
    rows = []
    for k in sorted(db):
        v = db[k]
        if v.get("mesh") != mesh or v.get("status") != "ok":
            continue
        r = v["roofline"]
        o = db_opt.get(k, {}).get("roofline", {}) if db_opt else {}
        imp = (
            f"{r['bound_s']/o['bound_s']:.1f}x" if o.get("bound_s") else "-"
        )
        rows.append(
            f"| {v['arch']} | {v['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{100*r.get('roofline_frac',0):.1f}% | "
            f"{o.get('bound_s', float('nan')):.3g} | {imp} |"
        )
    head = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline frac | optimized bound s | gain |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def main():
    base = load("dryrun_results_baseline.json")
    try:
        opt = load("dryrun_results.json")
    except FileNotFoundError:
        opt = {}
    print("### Single-pod (16x16 = 256 chips) — baseline dry-run\n")
    print(dryrun_table(base, "single"))
    print("\n### Multi-pod (2x16x16 = 512 chips) — baseline dry-run\n")
    print(dryrun_table(base, "multi"))
    print("\n### Roofline (single-pod, baseline terms; optimized bound alongside)\n")
    print(roofline_table(base, opt))


if __name__ == "__main__":
    main()
