"""BSP cost accounting: rounds and tuples communicated (the paper's two
cost metrics, Sec. 3.2).  One ledger per query execution."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class RoundRecord:
    index: int
    phase: str
    ops: List[str]
    comm_tuples: int
    note: str = ""
    n_rounds: int = 1  # CLAIMED engine BSP rounds (parallel ops: the max)
    dispatches: int = 0  # MEASURED SPMD program dispatches (0 = not measured)


class Ledger:
    def __init__(self) -> None:
        self.records: List[RoundRecord] = []
        self.output_tuples: int = 0
        self.retries: int = 0

    @property
    def rounds(self) -> int:
        return sum(r.n_rounds for r in self.records)

    @property
    def measured_dispatches(self) -> int:
        """Total SPMD program dispatches actually issued across rounds.

        ``rounds`` is what the schedule *claims* under the BSP model (a
        round of k parallel ops counts once); this is what the engine
        *did*.  With round fusion the two converge; without it this is
        ~ops-per-round times larger."""
        return sum(r.dispatches for r in self.records)

    @property
    def comm_tuples(self) -> int:
        """Total communication: shuffled tuples + output tuples (the paper
        counts reducer output as communication)."""
        return sum(r.comm_tuples for r in self.records) + self.output_tuples

    @property
    def shuffle_tuples(self) -> int:
        return sum(r.comm_tuples for r in self.records)

    def add_round(
        self,
        phase: str,
        ops: List[str],
        comm: int,
        note: str = "",
        n_rounds: int = 1,
        dispatches: int = 0,
    ) -> None:
        self.records.append(
            RoundRecord(
                len(self.records), phase, list(ops), int(comm), note, n_rounds,
                int(dispatches),
            )
        )

    def rounds_in_phase(self, phase: str) -> int:
        return sum(r.n_rounds for r in self.records if r.phase == phase)

    def comm_in_phase(self, phase: str) -> int:
        return sum(r.comm_tuples for r in self.records if r.phase == phase)

    def calibration_record(
        self,
        *,
        engine: str,
        schedule: str = "",
        query: str = "",
        predicted_comm: float = 0.0,
        predicted_rounds: float = 0.0,
    ) -> Dict[str, Any]:
        """One measured sample for ``core.costs.fit_calibration``.

        Pairs this execution's ground truth (comm_tuples, rounds,
        retries) with the advisor's *uncalibrated* predictions so the
        per-engine constants of the cost model can be fitted from real
        runs."""
        return {
            "engine": engine,
            "schedule": schedule,
            "query": query,
            "predicted_comm": float(predicted_comm),
            "predicted_rounds": float(predicted_rounds),
            "measured_comm": int(self.comm_tuples),
            "measured_shuffle": int(self.shuffle_tuples),
            "measured_rounds": int(self.rounds),
            "measured_dispatches": int(self.measured_dispatches),
            "output_tuples": int(self.output_tuples),
            "retries": int(self.retries),
        }

    def summary(self) -> Dict[str, Any]:
        phases: Dict[str, Dict[str, int]] = {}
        for r in self.records:
            ph = phases.setdefault(r.phase, {"rounds": 0, "comm": 0, "dispatches": 0})
            ph["rounds"] += r.n_rounds
            ph["comm"] += r.comm_tuples
            ph["dispatches"] += r.dispatches
        return {
            "rounds": self.rounds,
            "measured_dispatches": self.measured_dispatches,
            "comm_tuples": self.comm_tuples,
            "shuffle_tuples": self.shuffle_tuples,
            "output_tuples": self.output_tuples,
            "retries": self.retries,
            "phases": phases,
        }

    def __repr__(self) -> str:
        s = self.summary()
        lines = [
            f"Ledger(rounds={s['rounds']}, dispatches={s['measured_dispatches']}, "
            f"comm={s['comm_tuples']}, out={s['output_tuples']}, "
            f"retries={s['retries']})"
        ]
        for ph, v in s["phases"].items():
            lines.append(
                f"  {ph}: rounds={v['rounds']} dispatches={v['dispatches']} "
                f"comm={v['comm']}"
            )
        return "\n".join(lines)
