"""Local kernel backend ('jnp' | 'pallas'): the Pallas kernels wired into
the shard-local compute path must be bit-identical to the jnp reference —
per local op, per distributed op, and for a full ``gym()`` query (rows,
ledger comm_tuples, retry counts) — plus the engine bugfix batch:
cross joins, traced kernel seeds, and post-completion snapshot resume."""
from __future__ import annotations

import random

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.decompose import ghd_for
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.hypergraph import Atom, Query
from repro.core.queries import chain_query, chain_ghd, star_query, star_ghd
from repro.data.synthetic import chain_data_sparse, star_data_sparse
from repro.relational.localops import (
    LOCAL_BACKENDS,
    get_local_backend,
    local_join,
    local_join_count,
    local_semijoin_mask,
)
from repro.relational.oracle import canon, np_query_answer, reorder
from repro.relational.ops import dist_join
from repro.relational.spmd import SPMD
from repro.relational.table import DTable


def oracle_rows(query, data):
    atoms = [(a.alias, a.attrs) for a in query.atoms]
    d = {a.alias: data[a.rel] for a in query.atoms}
    rows, schema = np_query_answer(atoms, d)
    return canon(reorder(rows, schema, query.output_attrs))


# ------------------------------------------------------------- registry
def test_backend_registry():
    assert {"jnp", "pallas"} <= set(LOCAL_BACKENDS)
    assert get_local_backend("jnp").name == "jnp"
    assert get_local_backend("pallas").name == "pallas"
    with pytest.raises(ValueError, match="unknown local backend"):
        get_local_backend("cuda")


# ------------------------------------------------- per-localop parity
def _rand_tables(rng, na, nb, ar=3, dom=7):
    ad = jnp.asarray(rng.integers(0, dom, (na, ar)), jnp.int32)
    av = jnp.asarray(rng.random(na) < 0.8)
    bd = jnp.asarray(rng.integers(0, dom, (nb, ar)), jnp.int32)
    bv = jnp.asarray(rng.random(nb) < 0.8)
    return ad, av, bd, bv


@pytest.mark.parametrize("na,nb,all_invalid", [(16, 16, False), (37, 129, False), (8, 6, True)])
def test_localops_backend_parity(na, nb, all_invalid):
    rng = np.random.default_rng(na * 1000 + nb)
    ad, av, bd, bv = _rand_tables(rng, na, nb)
    if all_invalid:  # "empty" operand the way the engine represents it
        bv = jnp.zeros_like(bv)
    key = (0, 2)
    ref_mask = local_semijoin_mask(ad, av, key, bd, bv, key, "jnp")
    got_mask = local_semijoin_mask(ad, av, key, bd, bv, key, "pallas")
    np.testing.assert_array_equal(np.asarray(got_mask), np.asarray(ref_mask))

    ref_cnt = local_join_count(ad, av, bd, bv, key, key, "jnp")
    got_cnt = local_join_count(ad, av, bd, bv, key, key, "pallas")
    assert int(ref_cnt) == int(got_cnt)

    out_cap = max(4, int(ref_cnt) + 3)
    ref_j = local_join(ad, av, bd, bv, key, key, (1,), out_cap, "jnp")
    got_j = local_join(ad, av, bd, bv, key, key, (1,), out_cap, "pallas")
    for r, g in zip(ref_j, got_j):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


# ------------------------------------------- end-to-end gym() parity
CASES = {
    "chain8": lambda: (chain_query(8), chain_ghd(8), chain_data_sparse(8, seed=7)),
    "star5": lambda: (star_query(5), star_ghd(5), star_data_sparse(5, seed=9)),
}


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["hash", "grid"])
@pytest.mark.parametrize("qname", sorted(CASES))
def test_gym_backend_parity(strategy, qname):
    """Acceptance: a full gym() query is bit-identical under
    local_backend='jnp' and 'pallas' — rows, comm_tuples, retries."""
    q, g, data = CASES[qname]()
    want = oracle_rows(q, data)
    got = {}
    for be in ("jnp", "pallas"):
        rows, schema, ledger = gym(
            q, data, ghd=g, p=4,
            config=GymConfig(strategy=strategy, seed=3, local_backend=be),
        )
        assert canon(rows) == want, (qname, strategy, be)
        got[be] = (canon(rows), ledger.comm_tuples, ledger.retries)
    assert got["jnp"] == got["pallas"], (qname, strategy)


def test_gym_backend_parity_with_retries():
    """Skewed data forces overflow-retries: the retry path (reseeded
    dests + exact join presize) must agree across backends too."""
    q = chain_query(2)
    n = 24
    data = {
        "R1": np.stack([np.arange(n, dtype=np.int32), np.zeros(n, np.int32)], 1),
        "R2": np.stack([np.zeros(n, np.int32), np.arange(n, dtype=np.int32)], 1),
    }
    want = oracle_rows(q, data)
    got = {}
    for be in ("jnp", "pallas"):
        rows, _, ledger = gym(
            q, data, p=4, config=GymConfig(seed=3, local_backend=be)
        )
        assert canon(rows) == want
        got[be] = (canon(rows), ledger.comm_tuples, ledger.retries)
    assert got["jnp"]
    assert got["jnp"] == got["pallas"]


# --------------------------------------------------- cross join bugfix
def _mk(rows, schema, p=4, cap=4):
    return DTable.scatter_numpy(np.asarray(rows, np.int32), schema, p, cap=cap)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_dist_join_no_shared_attrs_is_parallel_cross_join(backend):
    """Attribute-disjoint dist_join must be an explicit broadcast cross
    join — correct result, comm = p * |B|, and NOT funneled through a
    single reducer (the old behavior hashed every row to one shard)."""
    spmd = SPMD(4)
    a_rows = [[0, 1], [2, 3], [4, 5], [6, 7], [8, 9]]
    b_rows = [[7, 8], [9, 10]]
    a = _mk(a_rows, ("A", "B"))
    b = _mk(b_rows, ("C", "D"))
    out, st = dist_join(spmd, a, b, seed=11, out_cap=64, backend=backend)
    assert out.schema == ("A", "B", "C", "D")
    want = {tuple(ar) + tuple(br) for ar in a_rows for br in b_rows}
    assert out.to_set() == want
    assert st["dropped"] == 0
    assert st["sent"] == spmd.p * len(b_rows)  # only B moves, replicated
    # A never moved: each reducer holds its own A shard, so the per-shard
    # output count mirrors the A scatter instead of collapsing to one shard
    per_shard = np.asarray(out.valid).sum(axis=1)
    a_per_shard = np.asarray(a.valid).sum(axis=1)
    np.testing.assert_array_equal(per_shard, a_per_shard * len(b_rows))


def test_gym_cartesian_bag():
    """A single GHD bag holding attribute-disjoint relations exercises the
    broadcast cross join inside materialization (HashEngine.multijoin of
    two parts with no shared attributes)."""
    from repro.core.ghd import GHD

    q = Query(
        [Atom("R1", "R", ("A", "B")), Atom("S1", "S", ("C", "D"))],
        name="Cartesian",
    )
    g = GHD.build(
        0, [], {0: ("A", "B", "C", "D")}, {0: frozenset(["R1", "S1"])}
    )
    rng = np.random.default_rng(5)
    data = {
        "R": rng.integers(0, 4, (6, 2)).astype(np.int32),
        "S": rng.integers(0, 4, (5, 2)).astype(np.int32),
    }
    want = oracle_rows(q, data)
    for be in ("jnp", "pallas"):
        rows, schema, _ = gym(
            q, data, ghd=g, p=4, config=GymConfig(seed=2, local_backend=be)
        )
        assert canon(rows) == want, be


# ------------------------------------- snapshot / resume regressions
def test_snapshot_roundtrips_local_backend(tmp_path):
    """GymConfig.local_backend must survive save/load — a resumed driver
    keeps computing on the backend the snapshot was taken with."""
    rng = random.Random(42)
    q = chain_query(4)
    data = {
        f"R{i}": np.asarray(
            [[rng.randint(0, 5), rng.randint(0, 5)] for _ in range(10)], np.int32
        )
        for i in range(1, 5)
    }
    want = oracle_rows(q, data)
    drv = GymDriver(
        q, ghd_for(q), data, SPMD(4), GymConfig(seed=1, local_backend="pallas")
    )
    drv.step()
    drv.step()
    snap = str(tmp_path / "snap.npz")
    drv.save(snap)
    # resume under a DIFFERENT config: the snapshot's must win
    drv2 = GymDriver(q, ghd_for(q), data, SPMD(4), GymConfig(seed=1))
    drv2.load(snap)
    assert drv2.config.local_backend == "pallas"
    assert drv2.executor.local_backend == "pallas"
    assert drv2.capman.local_backend == "pallas"
    out = drv2.run()
    assert canon(out.to_numpy()) == want


def test_post_completion_snapshot_resume(tmp_path):
    """Regression: loading a snapshot taken AFTER completion (done=True)
    used to leave self.result unset, so run() tripped its assert."""
    rng = random.Random(7)
    q = chain_query(3)
    data = {
        f"R{i}": np.asarray(
            [[rng.randint(0, 4), rng.randint(0, 4)] for _ in range(8)], np.int32
        )
        for i in range(1, 4)
    }
    drv = GymDriver(q, ghd_for(q), data, SPMD(4), GymConfig(seed=1))
    first = drv.run()
    assert drv.done
    snap = str(tmp_path / "done.npz")
    drv.save(snap)
    drv2 = GymDriver(q, ghd_for(q), data, SPMD(4), GymConfig(seed=1))
    drv2.load(snap)
    out = drv2.run()  # must not raise
    assert out.to_set() == first.to_set()
    assert drv2.ledger.output_tuples == drv.ledger.output_tuples
