"""Distributed relational operators vs the numpy oracle (simulation
backend, several shard counts), incl. hypothesis property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.relational.ops import (
    dist_dedup,
    dist_intersect,
    dist_join,
    dist_project,
    dist_semijoin,
    hypercube_partition,
    local_multiway_join,
)
from repro.relational.oracle import canon, np_dedup, np_join, np_semijoin
from repro.relational.spmd import SPMD
from repro.relational.table import DTable


def mk(rows, schema, p=4, cap=None):
    return DTable.scatter_numpy(np.asarray(rows, np.int32), schema, p, cap=cap)


rows_strategy = st.lists(
    st.tuples(st.integers(0, 6), st.integers(0, 6)), min_size=0, max_size=24
)


@pytest.mark.parametrize("p", [1, 2, 4, 7])
def test_join_small(p):
    spmd = SPMD(p)
    a = mk([(1, 10), (2, 20), (2, 21), (3, 30)], ("A", "B"), p)
    b = mk([(10, 5), (20, 6), (20, 7), (99, 8)], ("B", "C"), p)
    out, stats = dist_join(spmd, a, b, seed=0, out_cap=32)
    assert stats["dropped"] == 0
    expect, _ = np_join(
        np.array([(1, 10), (2, 20), (2, 21), (3, 30)]), ("A", "B"),
        np.array([(10, 5), (20, 6), (20, 7), (99, 8)]), ("B", "C"),
    )
    assert out.to_set() == canon(expect)


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(rows_strategy, rows_strategy, st.integers(1, 5))
def test_join_property(a_rows, b_rows, p):
    spmd = SPMD(p)
    a_np = np.asarray(a_rows, np.int32).reshape(-1, 2)
    b_np = np.asarray(b_rows, np.int32).reshape(-1, 2)
    a = mk(a_np, ("A", "B"), p, cap=24)
    b = mk(b_np, ("B", "C"), p, cap=24)
    expect, _ = np_join(a_np, ("A", "B"), b_np, ("B", "C"))
    out, stats = dist_join(
        spmd, a, b, seed=3, out_cap=600,
        c_out=(32, 32), cap_recv=(32, 32),
    )
    assert stats["dropped"] == 0
    assert out.to_set() == canon(expect)


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(rows_strategy, rows_strategy, st.integers(1, 5))
def test_semijoin_property(s_rows, r_rows, p):
    spmd = SPMD(p)
    s_np = np.asarray(s_rows, np.int32).reshape(-1, 2)
    r_np = np.asarray(r_rows, np.int32).reshape(-1, 2)
    s = mk(s_np, ("A", "B"), p, cap=24)
    r = mk(r_np, ("B", "C"), p, cap=24)
    out, stats = dist_semijoin(
        spmd, s, r, seed=7,
        c_out=(32, 32), cap_recv=(32, 32),
    )
    assert stats["dropped"] == 0
    expect = np_semijoin(s_np, ("A", "B"), r_np, ("B", "C"))
    assert out.to_set() == canon(expect)


def test_semijoin_ships_projection_only():
    """Comm of S|><R should be ~|S| + |distinct keys of R|, not |S|+|R|."""
    p = 4
    spmd = SPMD(p)
    s_np = np.stack([np.arange(40), np.arange(40) % 5], 1).astype(np.int32)
    # R has 200 rows but only 5 distinct key values
    r_np = np.stack([np.arange(200) % 5, np.arange(200)], 1).astype(np.int32)
    s = mk(s_np, ("A", "B"), p)
    r = mk(r_np, ("B", "C"), p)
    out, stats = dist_semijoin(spmd, s, r, seed=1,
                               c_out=(64, 64), cap_recv=(128, 128))
    assert stats["dropped"] == 0
    # sent <= |S| + p * distinct_keys (each shard ships its local distinct set)
    assert stats["sent"] <= 40 + p * 5
    assert out.count() == 40


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(rows_strategy, st.integers(1, 5))
def test_dedup_property(rows, p):
    spmd = SPMD(p)
    rows_np = np.asarray(rows, np.int32).reshape(-1, 2)
    # create duplicates explicitly
    dup = np.concatenate([rows_np, rows_np], 0) if len(rows_np) else rows_np
    t = mk(dup, ("A", "B"), p, cap=48)
    out, stats = dist_dedup(spmd, t, seed=5, c_out=56, cap_recv=64)
    assert stats["dropped"] == 0
    assert out.to_set() == canon(np_dedup(dup, 2))
    assert int(out.count()) == len(np_dedup(dup, 2))


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(rows_strategy, rows_strategy, st.integers(1, 4))
def test_intersect_property(a_rows, b_rows, p):
    spmd = SPMD(p)
    a_np = np.asarray(a_rows, np.int32).reshape(-1, 2)
    b_np = np.asarray(b_rows, np.int32).reshape(-1, 2)
    a = mk(a_np, ("A", "B"), p, cap=24)
    b = mk(b_np, ("A", "B"), p, cap=24)
    out, stats = dist_intersect(
        spmd, a, b, seed=11,
        c_out=(32, 32), cap_recv=(32, 32),
    )
    assert stats["dropped"] == 0
    expect = canon(a_np) & canon(b_np)
    assert out.to_set() == expect


def test_hypercube_grid_join_two_relations():
    """Lemma 8 for w=2: grid partition + local join == true join."""
    p = 6
    spmd = SPMD(p)
    rng = np.random.default_rng(0)
    a_np = rng.integers(0, 8, size=(30, 2)).astype(np.int32)
    b_np = rng.integers(0, 8, size=(25, 2)).astype(np.int32)
    a = mk(a_np, ("A", "B"), p)
    b = mk(b_np, ("B", "C"), p)
    shares = {"A": 2, "B": 1, "C": 3}  # 6 cells; B unsplit => no dup joins
    order = ("A", "B", "C")
    a2, st_a = hypercube_partition(spmd, a, shares, order, seed=2, c_out=64, cap_recv=128)
    b2, st_b = hypercube_partition(spmd, b, shares, order, seed=2, c_out=64, cap_recv=128)
    assert st_a["dropped"] == 0 and st_b["dropped"] == 0
    # replication factors: a replicated over C-share (3), b over A-share (2)
    assert st_a["sent"] == 30 * 3
    assert st_b["sent"] == 25 * 2
    out, st_j = local_multiway_join(spmd, [a2, b2], out_caps=(256,))
    assert st_j["dropped"] == 0
    expect, _ = np_join(a_np, ("A", "B"), b_np, ("B", "C"))
    assert out.to_set() == canon(expect)


def test_project_dedup():
    spmd = SPMD(3)
    t = mk([(1, 2), (1, 3), (2, 2)], ("A", "B"), 3)
    pr, pr_stats = dist_project(spmd, t, ("A",), dedup=True)
    assert pr_stats == {"sent": 0, "dropped": 0, "padded": 0}
    # dedup is per-shard; global count may exceed distinct but set is right
    assert pr.to_set() <= {(1,), (2,)}
    assert {(1,), (2,)} <= pr.to_set()


def test_overflow_reported_not_silent():
    spmd = SPMD(2)
    a = mk([(1, 1)] * 10, ("A", "B"), 2)
    b = mk([(1, 2)] * 10, ("B", "C"), 2)
    out, stats = dist_join(spmd, a, b, seed=0, out_cap=4)  # true out = 100
    assert stats["dropped"] > 0
