"""Cross-round capacity cache: amortizing the calibration pre-pass.

PR 4/5 made exchange capacities measured instead of guessed, but paid a
count dispatch per op group per round.  DYM schedules re-execute the same
op-group SHAPES round after round (the paper's multiround structure), and
pow2 bucketing makes the measured capacities stable whenever the data
volume is — so the measured ``SideCaps`` of a group signature can be
carried across rounds and re-measured only when the observed payload fill
drifts.

Safety model (what the property tests pin):

- a cached cap is only ever an OLD measurement applied to NEW data, so it
  can undercount.  Undercounts are caught by the payload exchange itself —
  rows overflowing a bucket are counted ``dropped``, the executor aborts
  the round, invalidates every cache entry the attempt touched, and
  retries with fresh measures (the paper's abort-and-retry).  Rows are
  bit-identical either way; a stale cache costs a retry, never wrongness.
- entries must be CONFIRMED before they serve hits: the first recurrence
  of a signature still measures fresh (the measure doubles as a free
  validation — if the stored caps cover the fresh counts, the
  distribution is stable and the entry is promoted).  Exchange routing is
  seed-dependent and seeds advance every round, so a single observation
  says nothing about the next round's per-destination maxima; demanding
  one successful revalidation before trusting an entry keeps stale-cap
  retries out of the common case instead of merely recovering from them.
- heavy-hitter measures are NEVER cached: the hybrid payload needs the
  per-destination heavy flags, which are seed- and data-bound in a way
  capacities are not.  Skewed groups re-measure every round (they are the
  rare case the skew threshold already isolates).
- a watermark band invalidates entries whose observed fill drifts from
  the baseline recorded when the entry was created: growth past the
  baseline means the caps may be about to undercount (invalidate BEFORE
  the drop, usually), and shrink far below it means the caps are now
  wastefully padded (re-tighten).

The cache is part of the executor's snapshot state: save/resume keeps the
amortization warm instead of re-measuring the first post-resume round.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from ..relational.batched import GroupMeasure, SideCaps

# watermark band defaults: invalidate when a round's max per-instance
# sent EXCEEDS the baseline (the caps were measured for at most that
# fill), or falls below a quarter of it (pow2 gives ≤2x headroom, so a
# 4x shrink means at least one wasted pow2 notch).
DEFAULT_GROWTH = 1.0
DEFAULT_SHRINK = 0.25


@dataclasses.dataclass
class CacheEntry:
    lhs: Tuple[int, int]  # (c_out, cap_recv)
    rhs: Optional[Tuple[int, int]]
    out_recv: Optional[int]
    out_need: Optional[int]
    sent0: Optional[int] = None  # fill baseline (first observed round)
    confirmed: bool = False  # caps covered a later fresh measure at least once
    hits: int = 0


class CapsCache:
    """Measured ``SideCaps`` keyed by op-group signature.

    Keys are the executor's group signatures (kind + shard shapes +
    managed output capacity) WITHOUT the per-op index, so sequential
    singleton groups of the same shape share an entry (merged by
    elementwise max, still safe: caps only grow under merge)."""

    def __init__(
        self,
        *,
        growth: float = DEFAULT_GROWTH,
        shrink: float = DEFAULT_SHRINK,
    ):
        self.growth = float(growth)
        self.shrink = float(shrink)
        self._entries: Dict[Tuple, CacheEntry] = {}
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return tuple(key) in self._entries

    def entry(self, key) -> Optional[CacheEntry]:
        return self._entries.get(tuple(key))

    # ----------------------------------------------------------- lookup
    def lookup(self, key) -> Optional[GroupMeasure]:
        """Return a zero-cost ``GroupMeasure`` for a cached signature, or
        None (measure needed).  Unconfirmed entries never hit — their next
        fresh measure is the validation that promotes them (see
        ``store``).  Hits serve the stored caps with ONE pow2 notch of
        headroom (x2): the entry only proved stability on PAST rounds,
        and a single-notch demand drift between observations is the
        common growth mode — the notch absorbs it where the bare caps
        would abort the round.  A hit ships nothing: ``padded == 0``,
        and no heavy surface (heavy groups are never stored)."""
        e = self._entries.get(tuple(key))
        if e is None or not e.confirmed:
            self.misses += 1
            return None
        self.hits += 1
        e.hits += 1
        return GroupMeasure(
            lhs=SideCaps(2 * e.lhs[0], 2 * e.lhs[1]),
            rhs=SideCaps(2 * e.rhs[0], 2 * e.rhs[1])
            if e.rhs is not None
            else None,
            out_recv=None if e.out_recv is None else 2 * e.out_recv,
            out_need=None if e.out_need is None else 2 * e.out_need,
            padded=0,
        )

    # ------------------------------------------------------------ store
    def store(self, key, m: GroupMeasure) -> bool:
        """Insert a fresh measurement; refuses heavy/hybrid measures (the
        payload needs their per-destination flags, which don't cache).
        Storing over a live entry merges by elementwise max — two
        same-signature groups in one stage stay mutually safe — and acts
        as the entry's validation: if the live caps already covered the
        fresh measure, the signature's fill is stable across seeds and
        the entry is promoted to serve hits."""
        if m.n_heavy or m.hybrid_routed:
            return False
        key = tuple(key)
        lhs = (m.lhs.c_out, m.lhs.cap_recv)
        rhs = (m.rhs.c_out, m.rhs.cap_recv) if m.rhs is not None else None
        prev = self._entries.get(key)
        if prev is not None:
            covered = lhs[0] <= prev.lhs[0] and lhs[1] <= prev.lhs[1]
            if rhs is not None and prev.rhs is not None:
                covered = covered and rhs[0] <= prev.rhs[0] and rhs[1] <= prev.rhs[1]
            covered = covered and (
                m.out_recv is None
                or (prev.out_recv is not None and m.out_recv <= prev.out_recv)
            )
            covered = covered and (
                m.out_need is None
                or (prev.out_need is not None and m.out_need <= prev.out_need)
            )
            lhs = (max(lhs[0], prev.lhs[0]), max(lhs[1], prev.lhs[1]))
            if rhs is not None and prev.rhs is not None:
                rhs = (max(rhs[0], prev.rhs[0]), max(rhs[1], prev.rhs[1]))
            out_recv = _opt_max(m.out_recv, prev.out_recv)
            out_need = _opt_max(m.out_need, prev.out_need)
            sent0 = prev.sent0
            confirmed = bool(covered)
        else:
            out_recv, out_need, sent0 = m.out_recv, m.out_need, None
            confirmed = False
        self._entries[key] = CacheEntry(
            lhs, rhs, out_recv, out_need, sent0, confirmed
        )
        return True

    # ---------------------------------------------------- fill feedback
    def observe(self, key, max_sent: int, dropped: bool) -> None:
        """Feed back one round's payload fill for a signature: the first
        observation sets the watermark baseline; later ones invalidate on
        drops (the caps provably undercounted) or when the fill leaves
        the ``[shrink * sent0, growth * sent0]`` band."""
        key = tuple(key)
        e = self._entries.get(key)
        if e is None:
            return
        if dropped:
            self.invalidate(key)
            return
        if e.sent0 is None:
            e.sent0 = int(max_sent)
            return
        if max_sent > self.growth * e.sent0 or max_sent < self.shrink * e.sent0:
            self.invalidate(key)

    def invalidate(self, key) -> None:
        if self._entries.pop(tuple(key), None) is not None:
            self.invalidations += 1

    def clear(self) -> None:
        self._entries.clear()

    # ------------------------------------------------------- snapshot IO
    def to_json(self) -> List[List[Any]]:
        return [
            [
                list(k),
                {
                    "lhs": list(e.lhs),
                    "rhs": list(e.rhs) if e.rhs is not None else None,
                    "out_recv": e.out_recv,
                    "out_need": e.out_need,
                    "sent0": e.sent0,
                    "confirmed": e.confirmed,
                },
            ]
            for k, e in sorted(self._entries.items(), key=lambda kv: repr(kv[0]))
        ]

    def load_json(self, data: List[List[Any]], merge: bool = False) -> None:
        """Restore snapshot entries.  ``merge=True`` (the serving layer,
        restoring one tenant into a cache SHARED by others) keeps any
        live entry that already covers a restored signature — a restore
        must never clobber what co-tenants have since measured and
        confirmed; fresh signatures load as usual."""
        loaded = {
            tuple(k): CacheEntry(
                lhs=tuple(v["lhs"]),
                rhs=tuple(v["rhs"]) if v["rhs"] is not None else None,
                out_recv=v["out_recv"],
                out_need=v["out_need"],
                sent0=v["sent0"],
                confirmed=bool(v.get("confirmed", False)),
            )
            for k, v in data
        }
        if not merge:
            self._entries = loaded
            return
        for k, e in loaded.items():
            self._entries.setdefault(k, e)

    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "invalidations": self.invalidations,
        }


def _opt_max(a: Optional[int], b: Optional[int]) -> Optional[int]:
    if a is None:
        return b
    if b is None:
        return a
    return max(a, b)
