"""The MapReduce shuffle as a per-shard function over the named reducer axis.

``exchange``: hash-partitioned repartitioning (map stage: bucket rows by
destination; network: one ``lax.all_to_all``; reduce stage: compact).
``exchange_multi``: each row goes to ``g`` destinations (the replicated
sends of Lemma 8 grid joins / Shares hypercube).

Overflow anywhere is reported, never silently dropped — the driver retries
the round with doubled capacities (the paper's abort-and-retry semantics).

Both exchanges are batchable: the collective refers to the named reducer
axis only, so wrapping the calling shard function in an inner (anonymous)
``jax.vmap`` fuses k independent shuffles into one program with one
``all_to_all`` — the mechanism behind ``relational.batched`` round fusion.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .localops import compact
from .spmd import AXIS


def _bucketize(
    data: jax.Array, valid_dest: jax.Array, p: int, c_out: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Scatter rows into per-destination buckets.

    ``valid_dest``: (n,) int32 in [0,p) for live rows, == p for dead rows.
    Returns (buf (p,c_out,ar), buf_valid (p,c_out), sent, dropped)."""
    n, ar = data.shape
    order = jnp.argsort(valid_dest, stable=True)
    sdest = valid_dest[order]
    srows = data[order]
    starts = jnp.searchsorted(sdest, jnp.arange(p))
    pos = jnp.arange(n) - starts[jnp.clip(sdest, 0, p - 1)]
    live = sdest < p
    ok = live & (pos < c_out)
    d_idx = jnp.where(ok, sdest, p)  # p == out-of-bounds -> dropped
    pos_c = jnp.clip(pos, 0, c_out - 1)
    buf = jnp.zeros((p, c_out, ar), data.dtype).at[d_idx, pos_c].set(
        srows, mode="drop"
    )
    buf_valid = jnp.zeros((p, c_out), bool).at[d_idx, pos_c].set(ok, mode="drop")
    sent = ok.sum()
    dropped = (live & ~ok).sum()
    return buf, buf_valid, sent, dropped


def exchange(
    data: jax.Array,
    valid: jax.Array,
    dest: jax.Array,
    *,
    p: int,
    c_out: int,
    cap_recv: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Repartition rows to ``dest`` shards.

    Returns (rdata (cap_recv, ar), rvalid, sent, dropped_send, dropped_recv).
    """
    buf, buf_valid, sent, dropped_send = _bucketize(
        data, jnp.where(valid, dest, p), p, c_out
    )
    rbuf = jax.lax.all_to_all(buf, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rvalid = jax.lax.all_to_all(buf_valid, AXIS, split_axis=0, concat_axis=0, tiled=False)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    rdata, rv, dropped_recv = compact(flat, flatv, cap_recv)
    return rdata, rv, sent, dropped_send, dropped_recv


def exchange_multi(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,  # (n, g) int32, each in [0,p) (or p to skip)
    *,
    p: int,
    c_out: int,
    cap_recv: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Replicated send: each row goes to up to g destinations."""
    n, ar = data.shape
    g = dests.shape[1]
    tiled_rows = jnp.repeat(data, g, axis=0)  # (n*g, ar)
    flat_dest = jnp.where(
        jnp.repeat(valid, g, axis=0), dests.reshape(-1), p
    )
    buf, buf_valid, sent, dropped_send = _bucketize(tiled_rows, flat_dest, p, c_out)
    rbuf = jax.lax.all_to_all(buf, AXIS, split_axis=0, concat_axis=0, tiled=False)
    rvalid = jax.lax.all_to_all(buf_valid, AXIS, split_axis=0, concat_axis=0, tiled=False)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    rdata, rv, dropped_recv = compact(flat, flatv, cap_recv)
    return rdata, rv, sent, dropped_send, dropped_recv
