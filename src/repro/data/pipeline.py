"""Relational data pipeline: training batches are assembled by GYM itself.

Corpus metadata is relational (the usual production shape):
    docs(doc_id, shard_id, len_bucket)
    shards(shard_id, quality)
    dedup(doc_id, keep)
    mix(len_bucket, weight)
The eligible-document set is the acyclic join
    docs |><| shards |><| dedup |><| mix
filtered to quality >= q_min, keep = 1, weight > 0 — evaluated by the GYM
driver on the same SPMD backend as training (the paper's contribution as a
first-class framework feature, DESIGN.md Sec. 2.3).  Token batches are
then synthesized per eligible doc id (deterministic LCG stream)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..core.gym import GymConfig, gym
from ..core.hypergraph import Atom, Query


@dataclasses.dataclass
class CorpusConfig:
    n_docs: int = 512
    n_shards: int = 16
    n_buckets: int = 4
    q_min: int = 2
    seed: int = 0


def corpus_query() -> Query:
    return Query(
        [
            Atom("docs", "docs", ("doc_id", "shard_id", "len_bucket")),
            Atom("shards", "shards", ("shard_id", "quality")),
            Atom("dedup", "dedup", ("doc_id", "keep")),
            Atom("mix", "mix", ("len_bucket", "weight")),
        ],
        name="CorpusJoin",
    )


def synth_corpus(cfg: CorpusConfig) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    docs = np.stack(
        [
            np.arange(cfg.n_docs),
            rng.integers(0, cfg.n_shards, cfg.n_docs),
            rng.integers(0, cfg.n_buckets, cfg.n_docs),
        ],
        axis=1,
    ).astype(np.int32)
    shards = np.stack(
        [np.arange(cfg.n_shards), rng.integers(0, 5, cfg.n_shards)], axis=1
    ).astype(np.int32)
    dedup = np.stack(
        [np.arange(cfg.n_docs), (rng.random(cfg.n_docs) < 0.9).astype(int)],
        axis=1,
    ).astype(np.int32)
    mix = np.stack(
        [np.arange(cfg.n_buckets), rng.integers(0, 3, cfg.n_buckets)], axis=1
    ).astype(np.int32)
    return {"docs": docs, "shards": shards, "dedup": dedup, "mix": mix}


def eligible_docs(
    cfg: CorpusConfig, data: Optional[Dict[str, np.ndarray]] = None, p: int = 4
) -> Tuple[np.ndarray, Dict]:
    """GYM-evaluated corpus join + selection predicates -> doc ids."""
    data = data or synth_corpus(cfg)
    # pre-filter the small dimension tables (selection pushdown), join with GYM
    data = dict(data)
    data["shards"] = data["shards"][data["shards"][:, 1] >= cfg.q_min]
    data["dedup"] = data["dedup"][data["dedup"][:, 1] == 1]
    data["mix"] = data["mix"][data["mix"][:, 1] > 0]
    rows, schema, ledger = gym(
        corpus_query(), data, p=p, config=GymConfig(strategy="hash")
    )
    doc_col = list(schema).index("doc_id")
    ids = np.unique(rows[:, doc_col])
    return ids.astype(np.int64), ledger.summary()


def _lcg_tokens(doc_id: int, n: int, vocab: int, seed: int) -> np.ndarray:
    """Deterministic per-doc token stream (synthetic corpus)."""
    x = np.uint64((doc_id * 2654435761 + seed * 97 + 1) % (1 << 64))
    out = np.empty(n, np.int64)
    a = np.uint64(6364136223846793005)
    c = np.uint64(1442695040888963407)
    with np.errstate(over="ignore"):  # uint64 wraparound is the algorithm
        for i in range(n):
            x = a * x + c
            out[i] = int(x >> np.uint64(33)) % vocab
    return out


def batches(
    cfg: CorpusConfig,
    *,
    batch: int,
    seq: int,
    vocab: int,
    p: int = 4,
    data: Optional[Dict[str, np.ndarray]] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite batch iterator over GYM-eligible docs (tokens, targets)."""
    ids, _ = eligible_docs(cfg, data, p=p)
    assert len(ids) > 0, "corpus join produced no eligible documents"
    rng = np.random.default_rng(cfg.seed + 1)
    while True:
        pick = rng.choice(ids, size=batch)
        toks = np.stack(
            [_lcg_tokens(int(d), seq + 1, vocab, cfg.seed) for d in pick]
        )
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
