"""Synthetic relation generators for benchmarks/examples.

``*_sparse`` generators produce matching-database-style inputs (paper
Appendix A): each relation is mostly a partial permutation, so every
pairwise join stays O(|R|) and end-to-end chain outputs are small — the
regime where round counts and communication constants are measurable
without output-size blowup."""
from __future__ import annotations

from typing import Dict

import numpy as np


def chain_data_sparse(
    n: int, *, domain: int = 32, ident: int = 8, extra: int = 12, seed: int = 0
) -> Dict[str, np.ndarray]:
    """C_n relations R_i(A_{i-1}, A_i): identity links on [0, ident) (so
    exactly ``ident`` complete chains survive) + random sparse links."""
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(1, n + 1):
        rows = [(v, v) for v in range(ident)]
        rows += [
            (int(rng.integers(ident, domain)), int(rng.integers(ident, domain)))
            for _ in range(extra)
        ]
        out[f"R{i}"] = np.unique(np.array(rows, np.int32), axis=0)
    return out


def star_data_sparse(
    n: int, *, domain: int = 16, hub_rows: int = 12, spoke_extra: int = 8,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """S_n: hub S(A_1..A_{n-1}) + spokes R_i(A_i, B_i); every hub value is
    matched in each spoke so the output is non-trivial but bounded."""
    rng = np.random.default_rng(seed)
    hub = rng.integers(0, domain // 2, (hub_rows, n - 1)).astype(np.int32)
    out = {"S": np.unique(hub, axis=0)}
    for i in range(1, n):
        vals = np.unique(hub[:, i - 1])
        rows = [(int(v), int(v) % 7) for v in vals]
        rows += [
            (int(rng.integers(domain // 2, domain)), int(rng.integers(0, 7)))
            for _ in range(spoke_extra)
        ]
        out[f"R{i}"] = np.unique(np.array(rows, np.int32), axis=0)
    return out


def tc_data_sparse(
    n_tri: int, *, domain: int = 24, ident: int = 6, extra: int = 10, seed: int = 0
) -> Dict[str, np.ndarray]:
    """TC_n triangles: identity triangles on [0, ident) + sparse noise."""
    rng = np.random.default_rng(seed)
    out = {}
    k = 1
    for _ in range(n_tri):
        for _ in range(3):
            rows = [(v, v) for v in range(ident)]
            rows += [
                (int(rng.integers(ident, domain)), int(rng.integers(ident, domain)))
                for _ in range(extra)
            ]
            out[f"R{k}"] = np.unique(np.array(rows, np.int32), axis=0)
            k += 1
    return out
