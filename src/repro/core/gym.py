"""GYM — Generalized Yannakakis in MapReduce (paper Section 5).

Given any complete GHD D(T, chi, lam) of a query Q:

  1. *Materialization stage* (Theorem 15): per tree vertex v, compute
     IDB_v = |><|_{R in lam(v)} pi_{attrs(R) & chi(v)}(R)   — schema chi(v).
     One Lemma 8 grid round (faithful) or a left-deep hash-join cascade
     (optimized).  D is now a width-1 GHD over the IDBs; Q' = |><| IDB_v is
     acyclic and equals Q (strong completeness enforces every atom).
  2. *DYM-d* (Sec. 4.3) on the IDB tree: upward semijoins, downward
     semijoins, join phase — O(d + log n) rounds total.

The driver is a thin schedule walker: lowering logical rounds to physical
op groups, engine-strategy selection ('hash' | 'grid' | 'hybrid'), round fusion (one
SPMD dispatch per homogeneous op group), capacity sizing, and the
abort-retry loop all live in ``core.physical``.  What remains here is the
resumable state machine: between BSP round-groups the full state (node
tables + cursor + ledger) can be snapshotted to disk and a new driver can
resume mid-query (fault tolerance; see ``examples/gym_fault_tolerance.py``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..relational import ops as R
from ..relational.ledger import Ledger
from ..relational.spmd import SPMD
from ..relational.table import DTable
from .ghd import GHD
from .hypergraph import Query
from .physical import CapacityManager, PhysicalExecutor, pow2 as _pow2
from .planner import Round, get_schedule


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GymConfig:
    # 'hash' (optimized, skew-sensitive) | 'grid' (paper-faithful,
    # skew-proof) | 'hybrid' (heavy-hitter routing on the count pre-pass:
    # light keys hash, heavy keys spread/broadcast grid-style)
    strategy: str = "hash"
    schedule: str = "dym_d"  # 'dym_d' (Sec 4.3) | 'dym_n' (Sec 4.2)
    seed: int = 0
    cap_growth: int = 4  # capacity multiplier on overflow-retry
    max_retries: int = 12
    count_retries_comm: bool = True  # aborted rounds still moved tuples
    fused: bool = True  # one SPMD dispatch per homogeneous op group
    # occupancy-adaptive shuffle: a count-only pre-pass per op group picks
    # tight pow2 exchange capacities (and pre-floors blown ones) instead of
    # shipping worst-case-padded all_to_all buffers.  The 'hybrid' engine
    # needs the pre-pass to route and forces it on regardless of this knob.
    calibrate_shuffle: bool = True
    # amortized calibration (only meaningful when calibrating): carry
    # measured exchange capacities across rounds in a signature-keyed cache
    # (re-measure on watermark drift; stale caps are caught by the payload
    # drop counters and fall back to abort-retry), and launch the next
    # round's combined count pre-pass behind the current round's payload
    # dispatches (JAX async dispatch overlap)
    caps_cache: bool = True
    prefetch_measures: bool = True
    local_backend: str = "jnp"  # shard-local hot loops: 'jnp' | 'pallas'
    # heavy-hitter sensitivity: a destination is heavy when its measured
    # arrival exceeds this multiple of the balanced share ceil(total/p)
    # (relational.skew; used by the hybrid engine's routing and by every
    # engine's capacity-ceiling diagnostics).  None = library default.
    skew_threshold: Optional[float] = None
    # hard per-shard capacity ceiling (tuples).  None derives 64 * M from
    # Assumption 3's M = 4*IN/p — generous for any matching-database
    # workload, but finite, so adversarial skew aborts with an actionable
    # CapacityCeiling instead of doubling into an OOM.
    max_cap_tuples: Optional[int] = None
    # exchange encoding: 'dense' ships (p, c_out, arity) int32 buffers +
    # bool valid planes; 'packed' bit-packs rows to the base relations'
    # observed value widths (relational/wire.py) and ships one segmented
    # uint8 buffer per fused group.  Rows, comm_tuples and retries are
    # bit-identical either way; only the wire bytes change.
    wire_format: str = "dense"
    # 'manual' = run exactly the knobs above; 'auto' = let the advisor
    # (core/optimizer.py) pick GHD/schedule/engine/fusion from stats.
    # After resolution the field holds the chosen Plan.key, so snapshots
    # record — and resume replays — the decision, never re-optimizing
    # mid-query.
    plan: str = "manual"

    def __post_init__(self):
        # registry-backed knobs fail HERE, naming the valid options —
        # not rounds deep inside the executor with a KeyError
        from ..relational.localops import LOCAL_BACKENDS
        from .physical import ENGINES

        if self.strategy not in ENGINES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; registered engines: "
                f"{sorted(ENGINES)} (register_engine adds more)"
            )
        if self.wire_format not in ("dense", "packed"):
            raise ValueError(
                f"unknown wire_format {self.wire_format!r}; "
                "valid: ['dense', 'packed']"
            )
        if self.local_backend not in LOCAL_BACKENDS:
            raise ValueError(
                f"unknown local_backend {self.local_backend!r}; registered "
                f"backends: {sorted(LOCAL_BACKENDS)} "
                "(register_local_backend adds more)"
            )


class GymDriver:
    """Resumable GYM execution: materialization + DYM on one SPMD backend."""

    def __init__(
        self,
        query: Query,
        ghd: GHD,
        data: Dict[str, np.ndarray],
        spmd: SPMD,
        config: Optional[GymConfig] = None,
        plan=None,  # Optional[optimizer.Plan]: execute this plan directly
        caps_cache=None,  # Optional[CapsCache]: SHARED across drivers
    ):
        self.query = query
        self.config = config or GymConfig()
        self.spmd = spmd
        # a caller-owned CapsCache instance (the serving layer passes one
        # cache to every tenant, so equal group signatures warm each
        # other); None keeps the executor's own per-query cache
        self._shared_caps_cache = caps_cache
        # dedup base relations once (relations are sets); the distinct row
        # counts double as the advisor's table statistics
        dedup_rows: Dict[str, np.ndarray] = {}
        for atom in query.atoms:
            rows = np.asarray(data[atom.rel], dtype=np.int32).reshape(
                -1, len(atom.attrs)
            )
            if rows.shape[0]:
                rows = np.unique(rows, axis=0)
            dedup_rows[atom.alias] = rows
        # sound per-attribute bit widths from the base relations' value
        # ranges (joins never create values, so these cover every
        # intermediate).  Derived unconditionally — it is one min/max per
        # base column — and applied only when wire_format == 'packed', so
        # a snapshot restored with a different wire_format (the
        # snapshot's config wins) can rebuild either executor.
        from ..relational.wire import WirePolicy

        self._wire_policy = WirePolicy.from_columns(
            [(atom.attrs, dedup_rows[atom.alias]) for atom in query.atoms]
        )
        if plan is None and self.config.plan == "auto":
            from .costs import DEFAULT_DISPATCH_OVERHEAD_SLOTS
            from .optimizer import MachineProfile, choose_plan, skew_share

            stats = {
                a.rel: int(dedup_rows[a.alias].shape[0]) for a in query.atoms
            }
            # max single-value column share per relation: the advisor's
            # skew statistic (prices hash by max per-destination load, so
            # skewed instances steer to the hybrid engine)
            skew = {
                a.rel: skew_share(dedup_rows[a.alias]) for a in query.atoms
            }
            # packed executions ship compressed rows: deflate the pad
            # factor by the mean row compression of the base-relation
            # formats so the ranking prices the wire it will actually run
            from ..relational.wire import wire_gain

            wg = (
                wire_gain(
                    [
                        self._wire_policy.format_for(a.attrs)
                        for a in query.atoms
                    ]
                )
                if self.config.wire_format == "packed"
                else 1.0
            )
            plan = choose_plan(
                query,
                stats,
                # auto mode also decides the capacity policy per query:
                # calibrated plans pay their predicted measure dispatches
                # at the dispatch-overhead price, fixed plans pay the
                # ~p-fold pad factor — whichever ships fewer wire slots
                profile=MachineProfile(
                    p=spmd.p,
                    dispatch_overhead=DEFAULT_DISPATCH_OVERHEAD_SLOTS,
                ),
                hand_ghd=ghd,
                local_backend=self.config.local_backend,
                calibrate_shuffle=self.config.calibrate_shuffle,
                skew=skew,
                skew_threshold=self.config.skew_threshold,
                calibrate_options=(True, False),
                wire_gain=wg,
            )
        self.plan = plan
        if plan is not None:
            # the plan decides GHD + engine knobs; config mirrors it so
            # snapshots round-trip the full decision
            ghd = plan.ghd
            self.config = plan.to_config(self.config)
        self.ghd = ghd.make_complete(query)
        self.ledger = Ledger()

        # stable per-node schemas: chi in first-seen attr order of the query
        attr_order = {a: i for i, a in enumerate(query.output_attrs)}
        self.node_schema: Dict[int, Tuple[str, ...]] = {
            v: tuple(sorted(self.ghd.chi[v], key=lambda a: attr_order[a]))
            for v in self.ghd.nodes()
        }

        # load base relations (round-robin scatter = the 'networked FS')
        p = spmd.p
        self.base: Dict[str, DTable] = {}
        for atom in query.atoms:
            rows = dedup_rows[atom.alias]
            cap = _pow2(max(1, -(-rows.shape[0] // p)))  # pow2: shape reuse
            self.base[atom.alias] = spmd.device_put(
                DTable.scatter_numpy(rows, atom.attrs, p, cap=cap)
            )

        cfg = self.config
        self.capman = CapacityManager(
            spmd,
            growth=cfg.cap_growth,
            local_backend=cfg.local_backend,
            max_cap=self._max_cap(),
        )
        for v in self.ghd.nodes():
            self.capman.ensure(v, self._init_cap(v))
        self.executor = self._make_executor()

        self.schedule: List[Round] = get_schedule(cfg.schedule).fn(self.ghd)
        self.tables: Dict[int, DTable] = {}
        # Upward-phase L2 accumulators: the paper's "replace R1 ... for the
        # duration of the upward semijoin phase".  Node tables stay intact
        # (the downward phase and join phase need the originals).
        self.acc: Dict[int, DTable] = {}
        self.cursor: int = -1  # -1 = materialization pending
        self.done = False
        self.result: Optional[DTable] = None

    def _max_cap(self) -> int:
        """Per-shard capacity ceiling: the configured bound, or 64x the
        Assumption-3 memory M = 4*IN/p (pow2, floored at 2^16) — far above
        any matching-database requirement at these scales, but finite, so
        skew-driven capacity doubling aborts actionably instead of OOMing."""
        if self.config.max_cap_tuples is not None:
            return int(self.config.max_cap_tuples)
        total = sum(int(t.valid.sum()) for t in self.base.values())
        m = 4 * max(1, -(-total // self.spmd.p))
        return _pow2(max(1 << 16, 64 * m))

    def _make_executor(self) -> PhysicalExecutor:
        cfg = self.config
        wp = self._wire_policy if cfg.wire_format == "packed" else None
        # a shared cache instance wins over the boolean knob (but an
        # explicitly disabled cache stays disabled)
        cc = (
            self._shared_caps_cache
            if self._shared_caps_cache is not None and cfg.caps_cache
            else cfg.caps_cache
        )
        if self.plan is not None:
            # config mirrors the plan by construction (to_config in
            # __init__); load() clears self.plan before rebuilding, so a
            # restored snapshot config can never disagree with this path
            return PhysicalExecutor.from_plan(
                self.spmd,
                self.plan,
                self.capman,
                seed=cfg.seed,
                max_retries=cfg.max_retries,
                count_retries_comm=cfg.count_retries_comm,
                calibrate=cfg.calibrate_shuffle,
                skew_threshold=cfg.skew_threshold,
                caps_cache=cc,
                prefetch=cfg.prefetch_measures,
                wire_policy=wp,
            )
        return PhysicalExecutor(
            self.spmd,
            cfg.strategy,
            self.capman,
            seed=cfg.seed,
            max_retries=cfg.max_retries,
            count_retries_comm=cfg.count_retries_comm,
            fuse=cfg.fused,
            calibrate=cfg.calibrate_shuffle,
            local_backend=cfg.local_backend,
            skew_threshold=cfg.skew_threshold,
            caps_cache=cc,
            prefetch=cfg.prefetch_measures,
            wire_policy=wp,
        )

    # caps live in the capacity manager; kept as a property for snapshots
    @property
    def caps(self) -> Dict[int, int]:
        return self.capman.caps

    @caps.setter
    def caps(self, value: Dict[int, int]) -> None:
        self.capman.caps = dict(value)

    # -- capacity heuristics ------------------------------------------------
    def _init_cap(self, v: int) -> int:
        per_shard = max(
            -(-max(1, int(np.asarray(self.base[a].valid).sum())) // self.spmd.p)
            for a in self.ghd.lam[v]
        )
        return _pow2(max(4, 4 * per_shard))

    # -- schedule walking ----------------------------------------------------
    def step(self) -> bool:
        """Run one schedule round (with abort-retry); returns True if more."""
        if self.done:
            return False
        if self.cursor < 0:
            (
                tables, comm, padded, heavy, claimed, dispatches,
                measure_dispatches, wire_bytes, useful_bytes,
            ) = self.executor.materialize(
                self.ghd, self.base, self.node_schema, self.ledger
            )
            self.tables = tables
            # overlap: the first DYM round's combined count pre-pass rides
            # behind materialization's trailing payload work (async)
            self.executor.prefetch_round(
                self.schedule[0] if self.schedule else None,
                self.tables,
                self.acc,
            )
            self.ledger.add_round(
                "materialize",
                [f"IDB({v})<=lam{sorted(self.ghd.lam[v])}" for v in self.ghd.nodes()],
                comm,
                n_rounds=claimed,
                dispatches=dispatches,
                padded=padded,
                heavy=heavy,
                measure_dispatches=measure_dispatches,
                payload_bytes=wire_bytes,
                useful_bytes=useful_bytes,
            )
            self.cursor = 0
            return True
        if self.cursor >= len(self.schedule):
            self._finish()
            return False
        rnd = self.schedule[self.cursor]
        (
            new_tab, new_acc, comm, padded, heavy, claimed, dispatches,
            measure_dispatches, wire_bytes, useful_bytes,
        ) = self.executor.execute_round(rnd, self.tables, self.acc, self.ledger)
        self.tables = {**self.tables, **new_tab}
        self.acc = {**self.acc, **new_acc}
        nxt = self.cursor + 1
        self.executor.prefetch_round(
            self.schedule[nxt] if nxt < len(self.schedule) else None,
            self.tables,
            self.acc,
        )
        self.ledger.add_round(
            rnd.phase,
            [repr(o) for o in rnd.ops],
            comm,
            n_rounds=claimed,
            dispatches=dispatches,
            padded=padded,
            heavy=heavy,
            measure_dispatches=measure_dispatches,
            payload_bytes=wire_bytes,
            useful_bytes=useful_bytes,
        )
        self.cursor += 1
        if self.cursor >= len(self.schedule):
            self._finish()
            return False
        return True

    def step_gen(self):
        """Reentrant variant of ``step()`` for the serving layer
        (``serve.join_server``): a generator that YIELDS each stage's
        prepared ``GroupWork`` list and RECEIVES the matching
        ``GroupResult`` list via ``send`` — the caller owns the dispatch,
        so compatible groups from MANY drivers can run as one merged
        dispatch.  Returns (``StopIteration.value``) True if more rounds
        remain, mirroring ``step()``.

        The materialization round runs inline (no yields): it is one-time
        per query and engine-heterogeneous (grid multiway / hash cascade
        paths), so there is nothing recurring to merge across requests —
        a driver's FIRST ``step_gen`` drive may therefore finish without
        yielding at all.  Everything data-dependent (seeds, retries,
        capacity growth) stays inside, so an interleaved drive is
        bit-identical to ``step()``."""
        if self.done:
            return False
        if self.cursor < 0 or self.cursor >= len(self.schedule):
            return self.step()
        rnd = self.schedule[self.cursor]
        gen = self.executor.round_steps(rnd, self.tables, self.acc, self.ledger)
        try:
            works = next(gen)
            while True:
                self._pending_works = works
                results = yield works
                self._pending_works = []
                works = gen.send(results)
        except StopIteration as stop:
            self._pending_works = []
            (
                new_tab, new_acc, comm, padded, heavy, claimed, dispatches,
                measure_dispatches, wire_bytes, useful_bytes,
            ) = stop.value
        self.tables = {**self.tables, **new_tab}
        self.acc = {**self.acc, **new_acc}
        nxt = self.cursor + 1
        self.executor.prefetch_round(
            self.schedule[nxt] if nxt < len(self.schedule) else None,
            self.tables,
            self.acc,
        )
        self.ledger.add_round(
            rnd.phase,
            [repr(o) for o in rnd.ops],
            comm,
            n_rounds=claimed,
            dispatches=dispatches,
            padded=padded,
            heavy=heavy,
            measure_dispatches=measure_dispatches,
            payload_bytes=wire_bytes,
            useful_bytes=useful_bytes,
        )
        self.cursor += 1
        if self.cursor >= len(self.schedule):
            self._finish()
            return False
        return True

    def pending_groups(self):
        """The ``GroupWork`` list an in-flight ``step_gen`` is currently
        suspended on (empty when none) — what the server's bucketing sees."""
        return list(getattr(self, "_pending_works", []) or [])

    def _finish(self) -> None:
        root = self.ghd.root
        out = self.tables[root]
        # canonical output column order
        want = [a for a in self.query.output_attrs if a in out.schema]
        self.result, _ = R.dist_project(self.spmd, out, want)
        self.ledger.output_tuples = int(np.asarray(self.result.valid).sum())
        self.done = True

    def run(self) -> DTable:
        while self.step():
            pass
        if not self.done:
            self._finish()
        assert self.result is not None
        return self.result

    # -- fault tolerance: snapshot / resume ----------------------------------
    def save(self, path: str) -> None:
        """Atomic snapshot of the driver state between rounds."""
        arrays = {}
        meta = {
            "cursor": self.cursor,
            "done": self.done,
            "config": dataclasses.asdict(self.config),
            # the (complete) GHD actually being executed: an auto/plan run
            # may use a different decomposition than the resuming driver
            # was constructed with, so resume must replay THIS tree
            "ghd": self.ghd.to_dict(),
            "caps": {str(k): v for k, v in self.caps.items()},
            "ledger": {
                "records": [dataclasses.asdict(r) for r in self.ledger.records],
                "output_tuples": self.ledger.output_tuples,
                "retries": self.ledger.retries,
            },
            "schemas": {str(k): list(t.schema) for k, t in self.tables.items()},
            "acc_schemas": {str(k): list(t.schema) for k, t in self.acc.items()},
        }
        if self.executor.caps_cache is not None:
            # keep the amortization warm across resume: the restored run's
            # first round hits these entries instead of re-measuring
            meta["caps_cache"] = self.executor.caps_cache.to_json()
        for k, t in self.tables.items():
            arrays[f"data_{k}"] = np.asarray(t.data)
            arrays[f"valid_{k}"] = np.asarray(t.valid)
        for k, t in self.acc.items():
            arrays[f"accdata_{k}"] = np.asarray(t.data)
            arrays[f"accvalid_{k}"] = np.asarray(t.valid)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            np.savez(f, meta=json.dumps(meta), **arrays)
        os.replace(tmp, path)  # atomic publish

    def load(self, path: str) -> None:
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["meta"]))
        self.cursor = meta["cursor"]
        self.done = meta["done"]
        if "ghd" in meta:
            # the snapshot's GHD wins: tables/caps/schedule are all keyed
            # by ITS node ids, which (for plan="auto" runs) need not match
            # the decomposition the resuming driver was constructed with
            self.ghd = GHD.from_dict(meta["ghd"])
            attr_order = {a: i for i, a in enumerate(self.query.output_attrs)}
            self.node_schema = {
                v: tuple(sorted(self.ghd.chi[v], key=lambda a: attr_order[a]))
                for v in self.ghd.nodes()
            }
        if "config" in meta:
            # the snapshot's config wins (incl. local_backend): resuming on
            # a different driver config must not change the query's plan,
            # seeds, or backend mid-flight.  The constructor's in-memory
            # Plan (if any) is superseded by the restored config.
            self.config = GymConfig(**meta["config"])
            self.plan = None
            self.capman.local_backend = self.config.local_backend
            self.capman.growth = self.config.cap_growth
            self.capman.max_cap = self._max_cap()
            self.executor = self._make_executor()
            self.schedule = get_schedule(self.config.schedule).fn(self.ghd)
        # any in-flight prefetched measure belongs to the pre-snapshot
        # timeline; the restored state must start clean
        self.executor._pending = None
        if "caps_cache" in meta and self.executor.caps_cache is not None:
            # restoring into a SHARED cache (serving layer) must not wipe
            # co-tenants' confirmed entries: merge, don't replace
            self.executor.caps_cache.load_json(
                meta["caps_cache"],
                merge=self.executor.caps_cache is self._shared_caps_cache,
            )
        self.caps = {int(k): v for k, v in meta["caps"].items()}
        led = Ledger()
        from ..relational.ledger import RoundRecord

        led.records = [RoundRecord(**r) for r in meta["ledger"]["records"]]
        led.output_tuples = meta["ledger"]["output_tuples"]
        led.retries = meta["ledger"]["retries"]
        self.ledger = led
        self.tables = {}
        for k, schema in meta["schemas"].items():
            ki = int(k)
            self.tables[ki] = self.spmd.device_put(
                DTable(
                    jnp_asarray(z[f"data_{k}"]),
                    jnp_asarray(z[f"valid_{k}"]),
                    tuple(schema),
                )
            )
        self.acc = {}
        for k, schema in meta.get("acc_schemas", {}).items():
            ki = int(k)
            self.acc[ki] = self.spmd.device_put(
                DTable(
                    jnp_asarray(z[f"accdata_{k}"]),
                    jnp_asarray(z[f"accvalid_{k}"]),
                    tuple(schema),
                )
            )
        # a post-completion snapshot has done=True but the final projection
        # is derived state, not persisted: recompute it so ``run()`` on the
        # resumed driver returns the result instead of tripping its assert
        self.result = None
        if self.done:
            self._finish()


def jnp_asarray(x):
    import jax.numpy as jnp

    return jnp.asarray(x)


# --------------------------------------------------------------------------
# front door
# --------------------------------------------------------------------------
def gym(
    query: Query,
    data: Dict[str, np.ndarray],
    *,
    ghd: Optional[GHD] = None,
    p: int = 4,
    spmd: Optional[SPMD] = None,
    config: Optional[GymConfig] = None,
    plan=None,  # Optional[optimizer.Plan]
) -> Tuple[np.ndarray, Tuple[str, ...], Ledger]:
    """Evaluate Q with GYM.  Returns (rows, schema, ledger).

    Three ways to pick the physical plan:
      - manual (default): ``ghd`` + ``GymConfig`` knobs as given;
      - ``config=GymConfig(plan="auto")``: the cost-based advisor
        (``core/optimizer.py``) enumerates GHD x schedule x engine x
        fusion candidates and executes the argmin (``ghd``, if given,
        joins the candidate set as the 'hand' GHD);
      - ``plan=<Plan>``: execute a plan the caller already chose, e.g.
        from ``optimizer.enumerate_plans`` or a previous ``explain()``.
    """
    from .decompose import ghd_for

    g = ghd if ghd is not None else (plan.ghd if plan is not None else ghd_for(query))
    s = spmd if spmd is not None else SPMD(p)
    drv = GymDriver(query, g, data, s, config, plan=plan)
    out = drv.run()
    return out.to_numpy(), out.schema, drv.ledger
