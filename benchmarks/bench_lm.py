"""LM wing micro-benchmark: reduced-config train-step wall time and
tokens/s on CPU for three representative families (dense / moe / hybrid)."""
from __future__ import annotations

import time

import jax

from repro.configs import CONFIGS, get_model, make_smoke_batch, reduced_config
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def run() -> list:
    out = []
    for arch in ("smollm-360m", "grok-1-314b", "zamba2-7b"):
        cfg = reduced_config(CONFIGS[arch])
        model = get_model(cfg)
        tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup=1))
        params, opt = init_train_state(model, tcfg, jax.random.PRNGKey(0))
        batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), b=4, s=64)
        step = jax.jit(make_train_step(model, tcfg))
        params, opt, m = step(params, opt, batch)  # compile
        t0 = time.perf_counter()
        n = 3
        for _ in range(n):
            params, opt, m = step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / n
        toks = 4 * 64
        out.append(
            dict(
                bench="lm_train", arch=arch, family=cfg.family,
                step_ms=round(dt * 1e3, 1), tokens_per_s=int(toks / dt),
                loss=round(float(m["loss"]), 3),
            )
        )
    return out
