"""Roofline analysis from the compiled dry-run artifact (no hardware).

Three terms per (arch x shape x mesh), in seconds:
    compute    = HLO_FLOPs / (chips * peak_bf16)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = collective_bytes / (chips * ici_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed out of the post-SPMD HLO text (sum of result-shape bytes
of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction — methodology recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

# TPU v5e, per chip
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9  # per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the HLO module."""
    out: Dict[str, int] = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        rhs = ls.split(" = ", 1)[1]
        opm = None
        for op in _COLL_OPS:
            # match ` op(` or `op-start(` / `op-done` variants
            m = re.search(rf"\b{op}(?:-start|-done)?\(", rhs)
            if m:
                opm = (op, m.start())
                break
        if opm is None:
            continue
        op, pos = opm
        if re.search(rf"\b{op}-done\(", rhs):
            continue  # count start only (avoid double count)
        head = rhs[:pos]  # result type(s) precede the op name
        types = _TYPE_RE.findall(head)
        if not types:
            types = _TYPE_RE.findall(rhs)
        out[op] += sum(_shape_bytes(dt, dims) for dt, dims in types)
    return out


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    chips: int,
    *,
    model_flops: Optional[float] = None,
) -> Dict[str, float]:
    compute = flops / (chips * PEAK_FLOPS_BF16)
    memory = hbm_bytes / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["bound_s"] = bound
    if model_flops is not None and flops > 0:
        terms["model_flops"] = model_flops
        terms["useful_flops_frac"] = model_flops / flops
        # roofline fraction: useful compute time over the binding term
        terms["roofline_frac"] = (
            model_flops / (chips * PEAK_FLOPS_BF16)
        ) / bound if bound > 0 else 0.0
    return terms


# ------------------------------------------------- MODEL_FLOPS = 6 N_act D
def param_count(tree) -> int:
    import jax

    return sum(
        int(l.size) for l in jax.tree_util.tree_leaves(tree)
        if hasattr(l, "size")
    )


def active_param_count(cfg, params_shapes) -> int:
    """MoE: experts count once per activated expert (topk/E scaling on the
    expert weights); dense: all params."""
    import jax

    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = int(leaf.size)
        if "moe" in ps and any(k in ps for k in ("wi", "wg", "wo")):
            n = n * max(1, cfg.topk) // max(1, cfg.n_experts)
        total += n
    return total


def model_flops_train(n_active: int, tokens: int) -> float:
    return 6.0 * n_active * tokens


def model_flops_decode(n_active: int, tokens: int) -> float:
    return 2.0 * n_active * tokens  # forward only, one token per seq
