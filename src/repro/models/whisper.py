"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_frames, d_model).  The
transformer backbone is real: bidirectional encoder, causal decoder with
cross-attention, sinusoidal positions.

Shape semantics (DESIGN.md Sec. 8):
  train:   enc(S frames) + teacher-forced dec(S // dec_ratio tokens)
  prefill: encode + build cross-attention K/V caches
  decode:  one decoder token vs the S-frame cross KV + its own self KV
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attn_decode,
    attn_forward,
    attn_prefill,
    cross_attn_forward,
    cross_kv,
    init_attn,
)
from .common import (
    ArchConfig,
    embed,
    init_embed,
    init_norm,
    rms_norm,
    softmax_xent,
    stack_init,
    unembed,
)
from .mlp import init_mlp, mlp_forward


def sinusoid(s: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(0, d, 2, dtype=jnp.float32) / d
    )
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


def _enc_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, rope="none")


def _init_enc_block(rng, cfg):
    ka, km = jax.random.split(rng)
    return {"attn": init_attn(ka, cfg), "mlp": init_mlp(km, cfg)}


def _init_dec_block(rng, cfg):
    ka, kc, km = jax.random.split(rng, 3)
    return {
        "self": init_attn(ka, cfg),
        "cross": init_attn(kc, cfg),
        "mlp": init_mlp(km, cfg),
    }


@dataclasses.dataclass
class WhisperModel:
    cfg: ArchConfig

    def init(self, rng) -> Dict:
        cfg = self.cfg
        ecfg = _enc_cfg(cfg)
        k1, k2, k3 = jax.random.split(rng, 3)
        enc_layers = cfg.enc_layers or cfg.n_layers
        return {
            "embed": init_embed(k1, cfg.vocab, cfg.d_model, cfg.jdtype),
            "enc": stack_init(k2, enc_layers, lambda r: _init_enc_block(r, ecfg)),
            "dec": stack_init(k3, cfg.n_layers, lambda r: _init_dec_block(r, cfg)),
            "enc_ln": init_norm(cfg.d_model, cfg.jdtype),
            "final_ln": init_norm(cfg.d_model, cfg.jdtype),
        }

    def init_shapes(self) -> Dict:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames: jax.Array, remat: bool = False):
        cfg = _enc_cfg(self.cfg)
        b, s, d = frames.shape
        x = frames + sinusoid(s, d, frames.dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def layer(xc, pl):
            xo = attn_forward(pl["attn"], xc, cfg, pos=pos, causal=False)
            return mlp_forward(pl["mlp"], xo, cfg), None

        if remat:
            layer = jax.checkpoint(layer)
        x, _ = jax.lax.scan(layer, x, params["enc"])
        return rms_norm(x, params["enc_ln"], cfg.norm_eps)

    # --------------------------------------------------------------- decoder
    def _decode_stack(self, params, tokens, mem, remat: bool = False):
        cfg = self.cfg
        b, s = tokens.shape
        x = embed(tokens, params["embed"]["table"])
        x = x + sinusoid(s, cfg.d_model, x.dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def layer(xc, pl):
            xo = attn_forward(pl["self"], xc, cfg, pos=pos, causal=True)
            kv = cross_kv(pl["cross"], mem, cfg)
            xo = cross_attn_forward(pl["cross"], xo, kv, cfg)
            return mlp_forward(pl["mlp"], xo, cfg), None

        if remat:
            layer = jax.checkpoint(layer)
        x, _ = jax.lax.scan(layer, x, params["dec"])
        return rms_norm(x, params["final_ln"], cfg.norm_eps)

    # ----------------------------------------------------------------- train
    def loss(self, params, batch: Dict, remat: bool = True) -> jax.Array:
        mem = self.encode(params, batch["frames"], remat=remat)
        x = self._decode_stack(params, batch["tokens"], mem, remat=remat)
        logits = unembed(x, params["embed"]["table"])
        return softmax_xent(logits, batch["targets"])

    # ----------------------------------------------------------------- serve
    def prefill(self, params, batch: Dict, s_cache: int = 0):
        """Encode frames, precompute cross K/V per decoder layer, and run
        the BOS token. ``s_cache`` sizes the decoder self-attention cache."""
        cfg = self.cfg
        frames = batch["frames"]
        b = frames.shape[0]
        mem = self.encode(params, frames)
        s_cache = s_cache or 64

        def build_cross(pl):
            return cross_kv(pl["cross"], mem, cfg)

        cross = jax.vmap(build_cross)(params["dec"])  # stacked (L, ...)
        self_cache = {
            "k": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s_cache, cfg.hd), cfg.jdtype),
            "v": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s_cache, cfg.hd), cfg.jdtype),
        }
        caches = {"cross": cross, "self": self_cache, "len": jnp.int32(0)}
        bos = batch.get("bos", jnp.zeros((b,), jnp.int32))
        logits, caches = self.decode_step(params, caches, bos)
        return logits, caches

    def init_caches(self, batch: int, s_frames: int, dec_cache: int) -> Dict:
        """ShapeDtype-friendly empty caches (dry-run decode path)."""
        cfg = self.cfg
        L = cfg.n_layers
        z = jnp.zeros
        return {
            "cross": {
                "k": z((L, batch, cfg.n_kv_heads, s_frames, cfg.hd), cfg.jdtype),
                "v": z((L, batch, cfg.n_kv_heads, s_frames, cfg.hd), cfg.jdtype),
            },
            "self": {
                "k": z((L, batch, cfg.n_kv_heads, dec_cache, cfg.hd), cfg.jdtype),
                "v": z((L, batch, cfg.n_kv_heads, dec_cache, cfg.hd), cfg.jdtype),
            },
            "len": jnp.int32(0),
        }

    def decode_step(self, params, caches, tokens):
        cfg = self.cfg
        b = tokens.shape[0]
        clen = caches["len"]
        x = embed(tokens[:, None], params["embed"]["table"])
        s_total = caches["self"]["k"].shape[3]
        pe = sinusoid(s_total, cfg.d_model, x.dtype)
        x = x + jax.lax.dynamic_slice(pe, (clen, 0), (1, cfg.d_model))[None]

        def layer(xc, inp):
            pl, cross_l, self_l = inp
            xo, self2 = attn_decode(pl["self"], xc, self_l, clen, cfg)
            xo = cross_attn_forward(pl["cross"], xo, cross_l, cfg)
            xo = mlp_forward(pl["mlp"], xo, cfg)
            return xo, self2

        x, new_self = jax.lax.scan(
            layer, x, (params["dec"], caches["cross"], caches["self"])
        )
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = unembed(x, params["embed"]["table"])[:, 0]
        return logits, {
            "cross": caches["cross"],
            "self": new_self,
            "len": clen + 1,
        }
