"""Shares [Afrati & Ullman, TKDE'11] — the optimal ONE-round join algorithm
(paper Sec. 2.3, the baseline of Tables 2 and 3).

Each attribute A gets a *share* s_A with prod(s_A) <= p; the p reducers are
cells of the hypercube prod over attrs.  A tuple of R is hashed on R's
attributes and replicated to every cell consistent with those hashes —
communication = sum_i |R_i| * prod_{A not in R_i} s_A (+ OUT).  All in one
BSP round (this is exactly Lemma 8 when every attribute is in some
relation of the join).

``optimize_shares`` picks integer shares by coordinate ascent on the
replication cost — matching the known optima for our benchmark families
(e.g. for C_n only every other attribute gets a share > 1).
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..relational import ops as R
from ..relational.ledger import Ledger
from ..relational.spmd import SPMD
from ..relational.table import DTable
from .hypergraph import Query


def replication_cost(
    query: Query, sizes: Dict[str, int], shares: Dict[str, int]
) -> float:
    """sum_i |R_i| * prod_{A not in attrs(R_i)} s_A."""
    total = 0.0
    for atom in query.atoms:
        rep = 1.0
        for a, s in shares.items():
            if a not in atom.attr_set:
                rep *= s
        total += sizes[atom.alias] * rep
    return total


def optimize_shares(
    query: Query, sizes: Dict[str, int], p: int
) -> Dict[str, int]:
    """Greedy coordinate ascent: repeatedly bump the share whose increase
    most reduces replication cost, while prod(shares) <= p."""
    attrs = sorted(query.vertices)
    shares = {a: 1 for a in attrs}

    def prod() -> int:
        return math.prod(shares.values())

    improved = True
    while improved:
        improved = False
        base = replication_cost(query, sizes, shares)
        best: Tuple[float, Optional[str]] = (base, None)
        for a in attrs:
            if prod() // shares[a] * (shares[a] + 1) > p:
                continue
            shares[a] += 1
            c = replication_cost(query, sizes, shares)
            shares[a] -= 1
            # increasing a share never increases cost; prefer the largest
            # balance gain (smaller max-load ~ smaller per-reducer input)
            if c < best[0] - 1e-9:
                best = (c, a)
        if best[1] is not None:
            shares[best[1]] += 1
            improved = True
        else:
            # cost-neutral bumps still balance load: bump the attr with the
            # most relations touching it, if it fits
            cands = [
                a
                for a in attrs
                if prod() // shares[a] * (shares[a] + 1) <= p
                and sum(a in at.attr_set for at in query.atoms) >= 2
            ]
            if cands:
                a = max(
                    cands, key=lambda a: sum(a in at.attr_set for at in query.atoms)
                )
                shares[a] += 1
                improved = True
    return shares


def shares_join(
    query: Query,
    data: Dict[str, np.ndarray],
    *,
    p: int = 4,
    spmd: Optional[SPMD] = None,
    shares: Optional[Dict[str, int]] = None,
    out_cap: Optional[int] = None,
    seed: int = 0,
    max_retries: int = 12,
    local_backend: str = "jnp",
) -> Tuple[np.ndarray, Tuple[str, ...], Ledger]:
    """One-round Shares evaluation of Q.  Returns (rows, schema, ledger)."""
    s = spmd or SPMD(p)
    p = s.p
    ledger = Ledger()

    tables: Dict[str, DTable] = {}
    sizes: Dict[str, int] = {}
    for atom in query.atoms:
        rows = np.asarray(data[atom.rel], np.int32).reshape(-1, len(atom.attrs))
        if rows.shape[0]:
            rows = np.unique(rows, axis=0)  # relations are sets
        tables[atom.alias] = s.device_put(DTable.scatter_numpy(rows, atom.attrs, p))
        sizes[atom.alias] = rows.shape[0]

    shares = shares or optimize_shares(query, sizes, p)
    attr_order = sorted(shares, key=lambda a: -shares[a])
    n_cells = math.prod(shares.values())
    assert n_cells <= p

    out_cap = out_cap or max(4, 4 * max(sizes.values()))
    in_cap = max(4, 2 * max(sizes.values()))
    attempt = 0
    while True:
        attempt += 1
        assert attempt <= max_retries, "shares: too many retries"
        comm = 0
        dropped = 0
        parts: List[DTable] = []
        for atom in query.atoms:
            t = tables[atom.alias]
            rep = math.prod(
                sh for a, sh in shares.items() if a not in atom.attr_set
            )
            part, st = R.hypercube_partition(
                s,
                t,
                shares,
                attr_order,
                seed=seed + attempt,
                c_out=t.cap * max(1, rep),
                cap_recv=in_cap,
            )
            comm += st["sent"]
            dropped += st["dropped"]
            parts.append(part)
        joined, st = R.local_multiway_join(
            s, parts, out_caps=[out_cap] * (len(parts) - 1),
            backend=local_backend,
        )
        dropped += st["dropped"]
        if dropped == 0:
            break
        in_cap *= 2
        out_cap *= 2
        ledger.retries += 1
    # each output tuple may be produced once per cell only if the cell is
    # uniquely determined by the tuple's attribute hashes — with all output
    # attrs sharded it is unique; dedup guards the general case.
    deduped, st = R.dist_dedup(
        s, joined, seed=seed + 101, c_out=joined.cap, cap_recv=joined.cap,
        backend=local_backend,
    )
    ledger.add_round("shares", [f"hypercube {shares}"], comm, n_rounds=1)
    ledger.output_tuples = int(np.asarray(deduped.valid).sum())
    want = [a for a in query.output_attrs if a in deduped.schema]
    out, _ = R.dist_project(s, deduped, want)
    return out.to_numpy(), out.schema, ledger
