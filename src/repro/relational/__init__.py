"""Distributed relational algebra in JAX — the paper's MapReduce substrate.

Tables are fixed-capacity int32 arrays with validity masks (XLA's static
shapes == the paper's memory-bounded reducers; overflow == the paper's
abort).  All distributed state carries a leading "reducer" axis that is
either vmapped (simulation, 1 device) or shard_mapped (production mesh) —
the per-shard code is identical (collectives via a named axis).
"""
from .table import Table, DTable, schema_join
from .spmd import SPMD, AXIS
from .ledger import Ledger
from .routed import RoutePolicy, RoutedResult, route_counts, routed_all_to_all

__all__ = [
    "Table", "DTable", "schema_join", "SPMD", "AXIS", "Ledger",
    "RoutePolicy", "RoutedResult", "route_counts", "routed_all_to_all",
]
