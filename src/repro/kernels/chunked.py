"""Chunked (flash-style) attention in pure XLA: ``lax.scan`` over KV
blocks with an online-softmax carry — the same algorithm as the Pallas
kernel, expressed so XLA keeps peak activation memory at O(S_q x C)
instead of O(S_q x S_kv).

This is the production train/prefill path in the dry-run (the Pallas
kernel body is TPU-codegen; this scan is its memory-equivalent XLA
formulation, so the roofline measured here is what the kernel deployment
sees).  Each scan step is remat'd: the backward pass recomputes per-chunk
scores — the flash-attention backward — keeping the O(S^2) matrices out
of saved residuals.

Perf log (EXPERIMENTS.md Sec. Perf, iteration A): replacing the dense
reference with this path took gemma2-9b prefill_32k from memory-bound
92.4 s/step to the numbers recorded there.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def chunked_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    chunk: int = 1024,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    g = h // kvh
    scale = float(scale) if scale is not None else float(d) ** -0.5
    c = min(chunk, sk)
    pad = -sk % c
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = k.shape[2] // c

    qg = q.reshape(b, kvh, g, sq, d).astype(jnp.float32)
    kcs = jnp.moveaxis(k.reshape(b, kvh, nc, c, d), 2, 0)  # (nc,b,kvh,c,d)
    vcs = jnp.moveaxis(v.reshape(b, kvh, nc, c, d), 2, 0)
    offs = jnp.arange(nc, dtype=jnp.int32) * c
    rows = jnp.arange(sq, dtype=jnp.int32)

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        kj, vj, j0 = inp
        s = jnp.einsum(
            "bkgqd,bkcd->bkgqc", qg, kj.astype(jnp.float32)
        ) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        cols = j0 + jnp.arange(c, dtype=jnp.int32)
        mask = cols[None, :] < sk  # kv padding
        if causal:
            mask = mask & (cols[None, :] <= rows[:, None])
        if window > 0:
            mask = mask & (cols[None, :] > rows[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG)
        m2 = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m2)
        corr = jnp.exp(m - m2)
        l2 = corr * l + p.sum(-1, keepdims=True)
        acc2 = corr * acc + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p, vj.astype(jnp.float32)
        )
        return (m2, l2, acc2), None

    m0 = jnp.full((b, kvh, g, sq, 1), NEG, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kcs, vcs, offs))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(b, h, sq, d)
    return out.astype(q.dtype)
