"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunked linear-
attention form — TPU-native: intra-chunk matmuls on the MXU + short
inter-chunk scan) and sLSTM (scalar memory, true recurrence -> per-step
``lax.scan`` with block-diagonal per-head recurrent weights).

Gating follows the paper: exponential input gate, sigmoid forget gate
(log-space accumulation keeps the chunked form stable in f32), max-norm
denominator for mLSTM outputs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, init_norm, rms_norm, scaled_init


def _heads(cfg: ArchConfig) -> Tuple[int, int]:
    h = cfg.n_heads
    return h, cfg.d_model // h


# =============================================================== mLSTM
def init_mlstm(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    h, hd = _heads(cfg)
    up = 2 * d  # projection factor 2 per the paper
    ks = jax.random.split(rng, 8)
    return {
        "ln": init_norm(d, cfg.jdtype),
        "w_up": scaled_init(ks[0], (d, 2 * up), 0, cfg.jdtype),  # [x_in, z]
        "wq": scaled_init(ks[1], (up, up), 0, cfg.jdtype),
        "wk": scaled_init(ks[2], (up, up), 0, cfg.jdtype),
        "wv": scaled_init(ks[3], (up, up), 0, cfg.jdtype),
        "w_if": scaled_init(ks[4], (up, 2 * h), 0, jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((h,)), 3.0 * jnp.ones((h,))]
        ),  # forget bias ~ sigmoid(3) = .95
        "ln_out": init_norm(up, cfg.jdtype),
        "w_down": scaled_init(ks[5], (up, d), 0, cfg.jdtype),
    }


def _mlstm_chunked(
    q, k, v, li, lf, chunk: int, init_c=None, init_n=None
):
    """q,k,v (B,S,H,P); li/lf (B,S,H) log input/forget gates (f32).
    Returns (y (B,S,H,P), C (B,H,P,P), n (B,H,P))."""
    b, s, h, p = q.shape
    cq = min(chunk, s)
    pad = -s % cq
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zp(q), zp(k), zp(v)
        li = jnp.pad(li, ((0, 0), (0, pad)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad)))
    nc = q.shape[1] // cq
    shp = (b, nc, cq, h, p)
    qc = q.reshape(shp).astype(jnp.float32)
    kc = k.reshape(shp).astype(jnp.float32)
    vc = v.reshape(shp).astype(jnp.float32)
    lic = li.reshape(b, nc, cq, h)
    lfc = lf.reshape(b, nc, cq, h)

    cum = jnp.cumsum(lfc, axis=2)  # inclusive log forget cumsum
    tot = cum[:, :, -1:]

    # intra-chunk: score[i,j] = q_i.k_j * exp(cum_i - cum_j + li_j), j <= i
    logw = cum[:, :, :, None, :] - cum[:, :, None, :, :] + lic[:, :, None, :, :]
    iidx = jnp.arange(cq)
    causal = (iidx[:, None] >= iidx[None, :])[None, None, :, :, None]
    logw = jnp.where(causal, logw, -jnp.inf)
    w = jnp.exp(logw)  # (b,nc,i,j,h)
    qk = jnp.einsum("bcihp,bcjhp->bcijh", qc, kc)
    att = qk * w
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, vc)
    n_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, kc)  # denominator terms

    # chunk state: C_c = sum_j exp(tot - cum_j + li_j) k_j v_j^T
    wj = jnp.exp(tot - cum + lic)  # (b,nc,cq,h)
    c_chunk = jnp.einsum("bcjh,bcjhp,bcjhr->bchpr", wj, kc, vc)
    n_chunk = jnp.einsum("bcjh,bcjhp->bchp", wj, kc)
    tot_d = jnp.exp(tot[:, :, 0])  # (b,nc,h)

    if init_c is None:
        init_c = jnp.zeros((b, h, p, p), jnp.float32)
        init_n = jnp.zeros((b, h, p), jnp.float32)

    def step(carry, inp):
        c, n = carry
        cc, nn, td = inp
        out = (c, n)
        c2 = c * td[:, :, None, None] + cc
        n2 = n * td[:, :, None] + nn
        return (c2, n2), out

    (c_fin, n_fin), (c_prev, n_prev) = jax.lax.scan(
        step,
        (init_c, init_n),
        (
            jnp.moveaxis(c_chunk, 1, 0),
            jnp.moveaxis(n_chunk, 1, 0),
            jnp.moveaxis(tot_d, 1, 0),
        ),
    )
    c_prev = jnp.moveaxis(c_prev, 0, 1)  # (b,nc,h,p,p)
    n_prev = jnp.moveaxis(n_prev, 0, 1)  # (b,nc,h,p)

    dec = jnp.exp(cum)  # (b,nc,cq,h)
    y_inter = jnp.einsum("bcihp,bchpr,bcih->bcihr", qc, c_prev, dec)
    n_inter = jnp.einsum("bcihp,bchp,bcih->bcih", qc, n_prev, dec)
    n_tot = jnp.einsum("bcihp,bcihp->bcih", qc, n_intra) + n_inter
    denom = jnp.maximum(jnp.abs(n_tot), 1.0)[..., None]
    y = (y_intra + y_inter) / denom
    y = y.reshape(b, nc * cq, h, p)[:, :s]
    return y, c_fin, n_fin


def mlstm_forward(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    out, _ = mlstm_prefill(p, x, cfg)
    return out


def mlstm_prefill(p: Dict, x: jax.Array, cfg: ArchConfig):
    b, s, d = x.shape
    h, _ = _heads(cfg)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    upz = xin @ p["w_up"]
    up = upz.shape[-1] // 2
    u, z = jnp.split(upz, 2, axis=-1)
    hd = up // h
    q = (u @ p["wq"]).reshape(b, s, h, hd)
    k = (u @ p["wk"]).reshape(b, s, h, hd) * (hd**-0.5)
    v = (u @ p["wv"]).reshape(b, s, h, hd)
    gates = u.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    gi, gf = jnp.split(gates.reshape(b, s, 2, h), 2, axis=2)
    li = gi[:, :, 0]  # log input gate (exp gating)
    lf = jax.nn.log_sigmoid(gf[:, :, 0])
    y, c_fin, n_fin = _mlstm_chunked(q, k, v, li, lf, cfg.chunk)
    y = y.reshape(b, s, up).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = x + (y @ p["w_down"]).astype(x.dtype)
    return out, {"c": c_fin, "n": n_fin}


def mlstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    h, _ = _heads(cfg)
    up = 2 * cfg.d_model
    hd = up // h
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
    }


def mlstm_decode(p: Dict, x: jax.Array, state: Dict, cfg: ArchConfig):
    b, _, d = x.shape
    h, _ = _heads(cfg)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)[:, 0]
    upz = xin @ p["w_up"]
    up = upz.shape[-1] // 2
    u, z = jnp.split(upz, 2, axis=-1)
    hd = up // h
    q = (u @ p["wq"]).reshape(b, h, hd).astype(jnp.float32)
    k = ((u @ p["wk"]) * (hd**-0.5)).reshape(b, h, hd).astype(jnp.float32)
    v = (u @ p["wv"]).reshape(b, h, hd).astype(jnp.float32)
    gates = u.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    gi, gf = jnp.split(gates.reshape(b, 2, h), 2, axis=1)
    i_t = jnp.exp(gi[:, 0])  # (b,h)
    f_t = jax.nn.sigmoid(gf[:, 0])
    c = state["c"] * f_t[:, :, None, None] + i_t[:, :, None, None] * (
        k[:, :, :, None] * v[:, :, None, :]
    )
    n = state["n"] * f_t[:, :, None] + i_t[:, :, None] * k
    num = jnp.einsum("bhp,bhpr->bhr", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), 1.0)
    y = (num / den[:, :, None]).reshape(b, up).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = x + (y @ p["w_down"]).astype(x.dtype)[:, None]
    return out, {"c": c, "n": n}


# =============================================================== sLSTM
def init_slstm(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    h, hd = _heads(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "ln": init_norm(d, cfg.jdtype),
        # input projections for (z, i, f, o) gates
        "w_in": scaled_init(ks[0], (d, 4 * d), 0, cfg.jdtype),
        # block-diagonal recurrent weights per head: (h, hd, 4*hd)
        "r": scaled_init(ks[1], (h, hd, 4 * hd), 1, jnp.float32),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "ln_out": init_norm(d, cfg.jdtype),
        # paper's up/down MLP (pf = 4/3) fused into the block
        "w_up": scaled_init(ks[2], (d, (4 * d) // 3), 0, cfg.jdtype),
        "w_down": scaled_init(ks[3], ((4 * d) // 3, d), 0, cfg.jdtype),
    }


def slstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    d = cfg.d_model
    h, hd = _heads(cfg)
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {
        "c": z,
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "h": z,
        "m": jnp.full((batch, h, hd), -1e30, jnp.float32),
    }


def _slstm_cell(p, cfg, xg, st):
    """One timestep. xg (b, 4d) pre-activations from input; st: state."""
    h_, hd = _heads(cfg)
    b = xg.shape[0]
    rec = jnp.einsum("bhp,hpq->bhq", st["h"], p["r"]).reshape(b, 4 * h_ * hd)
    g = (xg + rec + p["b"]).reshape(b, h_, hd, 4)
    zt = jnp.tanh(g[..., 0])
    it = g[..., 1]  # log-space input gate
    ft = jax.nn.log_sigmoid(g[..., 2])
    ot = jax.nn.sigmoid(g[..., 3])
    m_new = jnp.maximum(ft + st["m"], it)  # stabilizer
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + st["m"] - m_new)
    c = fp * st["c"] + ip * zt
    n = fp * st["n"] + ip
    hh = ot * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": hh, "m": m_new}


def slstm_forward(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    out, _ = slstm_prefill(p, x, cfg)
    return out


def slstm_prefill(p: Dict, x: jax.Array, cfg: ArchConfig):
    b, s, d = x.shape
    h_, hd = _heads(cfg)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    xg = (xin @ p["w_in"]).astype(jnp.float32)  # (b,s,4d)

    def step(st, xt):
        st2 = _slstm_cell(p, cfg, xt, st)
        return st2, st2["h"]

    st0 = slstm_init_state(cfg, b)
    fin, hs = jax.lax.scan(step, st0, jnp.moveaxis(xg, 0, 1))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    y = x + y
    # fused position-wise MLP (gelu)
    hmid = jax.nn.gelu((y @ p["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return y + (hmid @ p["w_down"]).astype(x.dtype), fin


def slstm_decode(p: Dict, x: jax.Array, state: Dict, cfg: ArchConfig):
    b, _, d = x.shape
    xin = rms_norm(x, p["ln"], cfg.norm_eps)[:, 0]
    xg = (xin @ p["w_in"]).astype(jnp.float32)
    st2 = _slstm_cell(p, cfg, xg, state)
    y = st2["h"].reshape(b, d).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    y = x + y[:, None]
    hmid = jax.nn.gelu((y @ p["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return y + (hmid @ p["w_down"]).astype(x.dtype), st2
