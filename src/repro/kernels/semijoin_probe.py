"""TPU Pallas kernel: set-membership probe — the per-reducer inner loop of
every semijoin (the dominant operation of Yannakakis / GYM).

Problem: given probe keys q (n,) int32 and a key table k (m,) int32
(invalid slots = INT32_MAX), produce mask (n,) bool: q[i] in k.

TPU-native design (not a CUDA hash-probe port):
  - data is laid out 2-D (rows, 128) to match the VPU's (8, 128) vector
    registers; BlockSpec tiles bring a (8, 128) probe block and a
    (KEY_ROWS, 128) key block into VMEM;
  - the probe is a *broadcast-compare*: a fori_loop walks the key block one
    128-lane row at a time and OR-reduces `q[:, :, None] == row[None, None, :]`
    — pure VPU lane ops, no gathers, no scalar loops, no MXU;
  - grid = (probe blocks x key blocks); per-tile partial hits are OR-merged
    into the output block (revisiting the same output block across the key
    grid axis).

Live VMEM per tile: 8*128*4 B probes + KEY_ROWS*128*4 B keys + the
(8,128,128) compare temp (~128 KiB bf16-free) — far under the ~16 MiB v5e
budget; KEY_ROWS=64 keeps the pipeline deep enough to hide HBM->VMEM DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import KEY_ROWS, LANES, PROBE_ROWS, pad_probe_key_tiles


def _probe_kernel(q_ref, k_ref, out_ref):
    """One (probe tile, key tile): OR-reduced broadcast compare."""
    j = pl.program_id(1)
    q = q_ref[...]  # (PROBE_ROWS, 128)
    keys = k_ref[...]  # (KEY_ROWS, 128)

    def body(r, acc):
        row = jax.lax.dynamic_slice(keys, (r, 0), (1, LANES))  # (1, 128)
        eq = q[:, :, None] == row[0][None, None, :]  # (8, 128, 128)
        return acc | eq.any(axis=-1)

    hit = jax.lax.fori_loop(
        0, keys.shape[0], body, jnp.zeros(q.shape, jnp.bool_)
    )

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(hit)

    out_ref[...] |= hit


@functools.partial(jax.jit, static_argnames=("interpret",))
def _probe_call(q2: jax.Array, k2: jax.Array, interpret: bool) -> jax.Array:
    nr, mr = q2.shape[0], k2.shape[0]
    grid = (nr // PROBE_ROWS, mr // KEY_ROWS)
    return pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((PROBE_ROWS, LANES), lambda i, j: (i, 0)),
            pl.BlockSpec((KEY_ROWS, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((PROBE_ROWS, LANES), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, LANES), jnp.bool_),
        interpret=interpret,
    )(q2, k2)


def semijoin_probe(
    q: jax.Array, keys: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """mask[i] = (q[i] in keys).  Key/probe values must be < INT32_MAX
    (dense ranks are); invalid key slots should be INT32_MAX."""
    n = q.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.bool_)
    q2, k2 = pad_probe_key_tiles(q, keys)
    return _probe_call(q2, k2, interpret).reshape(-1)[:n]
