"""Round fusion: batched op groups must be bit-compatible with sequential
execution (same rows, same comm_tuples) while measurably collapsing the
per-round dispatch count — the engine-side proof of Theorem 15's "all ops
of a round in ONE BSP round" claim."""
from __future__ import annotations

import random

import numpy as np
import pytest

from repro.core.gym import GymConfig, gym
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse
from repro.relational.batched import (
    dist_join_many,
    dist_semijoin_many,
    grid_join_many,
    grid_semijoin_many,
)
from repro.relational.oracle import canon, np_query_answer, reorder
from repro.relational.ops import dist_join, dist_semijoin
from repro.relational.spmd import SPMD
from repro.relational.table import DTable

DYM_PHASES = ("upward", "downward", "join")


def mk(rows, schema, p=4, cap=8):
    return DTable.scatter_numpy(np.asarray(rows, np.int32), schema, p, cap=cap)


def rand_tables(rng, schemas, p=4, cap=8, dom=6, rows=14):
    out = []
    for schema in schemas:
        r = [[rng.randint(0, dom - 1) for _ in schema] for _ in range(rows)]
        out.append(mk(np.unique(np.asarray(r, np.int32), axis=0), schema, p, cap))
    return out


def oracle_rows(query, data):
    atoms = [(a.alias, a.attrs) for a in query.atoms]
    d = {a.alias: data[a.rel] for a in query.atoms}
    rows, schema = np_query_answer(atoms, d)
    return canon(reorder(rows, schema, query.output_attrs))


# ------------------------------------------------- batched op <-> sequential
def test_batched_semijoin_matches_sequential():
    """One fused dispatch over instances with DIFFERENT key columns must
    reproduce each sequential dist_semijoin exactly — rows AND stats."""
    rng = random.Random(0)
    spmd = SPMD(4)
    ss = rand_tables(rng, [("A", "B"), ("C", "A"), ("B", "D")])
    rs = rand_tables(rng, [("B", "C"), ("A", "E"), ("D", "A")])
    seeds = [11, 22, 33]
    cap_recv = (16, spmd.p * rs[0].cap)
    d0 = spmd.dispatch_count
    outs, stats = dist_semijoin_many(spmd, ss, rs, seeds=seeds, cap_recv=cap_recv)
    assert spmd.dispatch_count - d0 == 1  # the whole group was one dispatch
    for s, r, seed, out, st in zip(ss, rs, seeds, outs, stats):
        ref, ref_st = dist_semijoin(spmd, s, r, seed=seed, cap_recv=cap_recv)
        assert out.schema == ref.schema
        assert out.to_set() == ref.to_set()
        assert st == ref_st


def test_batched_join_matches_sequential():
    rng = random.Random(1)
    spmd = SPMD(4)
    as_ = rand_tables(rng, [("A", "B"), ("C", "D"), ("E", "A")])
    bs = rand_tables(rng, [("B", "C"), ("D", "A"), ("A", "F")])
    seeds = [5, 6, 7]
    d0 = spmd.dispatch_count
    outs, stats = dist_join_many(spmd, as_, bs, seeds=seeds, out_cap=256)
    assert spmd.dispatch_count - d0 == 1
    for a, b, seed, out, st in zip(as_, bs, seeds, outs, stats):
        ref, ref_st = dist_join(spmd, a, b, seed=seed, out_cap=256)
        assert out.schema == ref.schema
        assert out.to_set() == ref.to_set()
        assert st == ref_st


def test_batched_grid_ops_match_singletons():
    """Grid group of k instances == k singleton groups (same batched code
    path, so this pins the inner-vmap stacking itself)."""
    rng = random.Random(2)
    spmd = SPMD(4)
    ss = rand_tables(rng, [("A", "B"), ("C", "B")])
    rs = rand_tables(rng, [("B", "C"), ("B", "A")])
    outs, stats = grid_semijoin_many(spmd, ss, rs, seeds=[3, 4], out_cap=32)
    for s, r, seed, out, st in zip(ss, rs, [3, 4], outs, stats):
        ref, ref_st = grid_semijoin_many(spmd, [s], [r], seeds=[seed], out_cap=32)
        assert out.to_set() == ref[0].to_set()
        assert st == ref_st[0]
    jouts, jstats = grid_join_many(spmd, ss, rs, out_cap=256)
    for s, r, out, st in zip(ss, rs, jouts, jstats):
        ref, ref_st = grid_join_many(spmd, [s], [r], out_cap=256)
        assert out.to_set() == ref[0].to_set()
        assert st == ref_st[0]


# ----------------------------------------------------- end-to-end parity
CASES = {
    "chain": lambda: (chain_query(4), chain_ghd(4), chain_data_sparse(4, seed=7)),
    "tc": lambda: (
        triangle_chain_query(2),
        triangle_chain_ghd(2),
        tc_data_sparse(2, seed=8),
    ),
    "star": lambda: (star_query(5), star_ghd(5), star_data_sparse(5, seed=9)),
}


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["hash", "grid"])
@pytest.mark.parametrize("qname", sorted(CASES))
def test_fused_sequential_parity(strategy, qname):
    q, g, data = CASES[qname]()
    want = oracle_rows(q, data)
    led = {}
    for fused in (True, False):
        rows, schema, ledger = gym(
            q, data, ghd=g, p=4,
            config=GymConfig(strategy=strategy, seed=3, fused=fused),
        )
        assert canon(rows) == want, (qname, strategy, fused)
        led[fused] = ledger
    lf, ls = led[True], led[False]
    # identical cost accounting: fusion repacks work, it must not change it
    assert lf.comm_tuples == ls.comm_tuples, (qname, strategy)
    assert lf.shuffle_tuples == ls.shuffle_tuples
    assert lf.rounds == ls.rounds  # claimed BSP rounds are schedule-derived
    assert lf.retries == ls.retries
    # fusion can only reduce the measured dispatch count
    assert lf.measured_dispatches <= ls.measured_dispatches
    assert lf.measured_dispatches > 0 and ls.measured_dispatches > 0


def test_chain_dispatches_at_most_ops_per_round():
    """Acceptance: on chain queries every DYM round is at most one PAYLOAD
    dispatch per op (hash path: exactly one barrier per semijoin/join).
    With the fixed-capacity shuffle that is the whole dispatch count; the
    count-calibrated default adds at most two tiny pre-pass dispatches per
    payload dispatch (counts, plus the keys-only output pre-count for
    joins), never more."""
    q, g, data = CASES["chain"]()
    for calibrate, per_op in ((False, 1), (True, 3)):
        _, _, ledger = gym(
            q, data, ghd=g, p=4,
            config=GymConfig(strategy="hash", seed=3, calibrate_shuffle=calibrate),
        )
        assert ledger.retries == 0  # sparse data: no overflow retries
        dym = [r for r in ledger.records if r.phase in DYM_PHASES]
        assert dym
        for r in dym:
            assert 0 < r.dispatches <= per_op * len(r.ops), (
                calibrate, r.phase, r.ops, r.dispatches,
            )


@pytest.mark.slow
def test_star_fusion_strictly_fewer_dispatches():
    """A star's DYM-d rounds carry parallel op groups: fused execution must
    strictly beat sequential on measured dispatches."""
    q, g, data = CASES["star"]()
    disp = {}
    for fused in (True, False):
        _, _, ledger = gym(
            q, data, ghd=g, p=4,
            config=GymConfig(strategy="hash", seed=3, fused=fused),
        )
        disp[fused] = sum(
            r.dispatches for r in ledger.records if r.phase in DYM_PHASES
        )
    assert disp[True] < disp[False], disp


def test_ledger_claimed_vs_measured_roundtrip():
    """Ledger carries both claimed rounds and measured dispatches, and the
    snapshot format round-trips them."""
    import dataclasses

    from repro.relational.ledger import Ledger, RoundRecord

    led = Ledger()
    led.add_round("upward", ["a", "b"], 10, n_rounds=2, dispatches=1)
    led.add_round("join", ["c"], 5, n_rounds=1, dispatches=3)
    assert led.rounds == 3
    assert led.measured_dispatches == 4
    assert led.summary()["phases"]["upward"]["dispatches"] == 1
    clone = [RoundRecord(**dataclasses.asdict(r)) for r in led.records]
    assert clone == led.records
