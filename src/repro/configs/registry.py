"""Architecture + shape registry: config lookup by ``--arch`` id, reduced
smoke configs, input ShapeDtypeStructs for the dry-run, and the per-cell
skip policy (DESIGN.md Sec. 3)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

from .gemma2_9b import CONFIG as _gemma2
from .grok_1_314b import CONFIG as _grok
from .kimi_k2_1t_a32b import CONFIG as _kimi
from .qwen2_vl_2b import CONFIG as _qwen2vl
from .qwen3_8b import CONFIG as _qwen3
from .smollm_360m import CONFIG as _smollm
from .starcoder2_7b import CONFIG as _starcoder2
from .whisper_small import CONFIG as _whisper
from .xlstm_125m import CONFIG as _xlstm
from .zamba2_7b import CONFIG as _zamba2

CONFIGS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _qwen2vl, _xlstm, _grok, _kimi, _whisper,
        _gemma2, _starcoder2, _smollm, _qwen3, _zamba2,
    ]
}

# shape id -> (seq_len, global_batch, kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# long_500k only for sub-quadratic (SSM/hybrid) archs, per the assignment
LONG_OK = {"xlstm-125m", "zamba2-7b"}


def cell_enabled(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_OK
    return True


def cells() -> Tuple[Tuple[str, str], ...]:
    out = []
    for a in CONFIGS:
        for s in SHAPES:
            if cell_enabled(a, s):
                out.append((a, s))
    return tuple(out)


def get_config(arch: str) -> ArchConfig:
    return CONFIGS[arch]


def get_model(cfg: ArchConfig):
    from repro.models.transformer import DecoderLM
    from repro.models.whisper import WhisperModel

    return WhisperModel(cfg) if cfg.encdec else DecoderLM(cfg)


# -------------------------------------------------------------- reductions
def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test scale: same family/block kinds, tiny everything."""
    # keep one occurrence of each distinct kind, in order
    kinds = []
    for k in cfg.blocks():
        if k not in kinds:
            kinds.append(k)
    pattern = []
    for k in kinds:
        pattern.extend([k, k] if len(kinds) <= 2 else [k])
    heads = 4
    kv = max(1, min(heads, (cfg.n_kv_heads * heads) // max(1, cfg.n_heads)) or 1)
    if kv == 0 or heads % kv:
        kv = heads
    return dataclasses.replace(
        cfg,
        n_layers=len(pattern),
        pattern=tuple(pattern),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=128,
        n_experts=min(cfg.n_experts, 4),
        topk=min(cfg.topk, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=16 if cfg.ssm_state else 0,
        window=8 if cfg.window else 0,
        chunk=16,
        enc_layers=2 if cfg.encdec else 0,
        dtype="float32",
    )


# ------------------------------------------------------------- input specs
def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ArchConfig, shape: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train  -> kwargs of train_step:  {"batch": {...}}
    prefill-> kwargs of prefill_step
    decode -> kwargs of serve_step (tokens + full caches)
    """
    s, b, kind = SHAPES[shape]
    model = get_model(cfg)
    if kind == "train":
        if cfg.encdec:
            batch = {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jdtype),
                "tokens": _tok(b, s // cfg.dec_ratio),
                "targets": _tok(b, s // cfg.dec_ratio),
            }
        else:
            batch = {"tokens": _tok(b, s), "targets": _tok(b, s)}
            if cfg.rope == "mrope":
                batch["pos"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return {"batch": batch}
    if kind == "prefill":
        if cfg.encdec:
            return {"batch": {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.jdtype)}}
        batch = {"tokens": _tok(b, s)}
        if cfg.rope == "mrope":
            batch["pos"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return {"batch": batch}
    # decode: one new token against an S-length cache
    if cfg.encdec:
        caches = jax.eval_shape(lambda: model.init_caches(b, s, 64))
    else:
        caches = jax.eval_shape(lambda: model.init_caches(b, s, s - 1))
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    return {"caches": caches, "tokens": tokens}


def make_smoke_batch(cfg: ArchConfig, rng, b: int = 2, s: int = 32) -> Dict:
    """Concrete small batch for CPU smoke tests (reduced configs)."""
    kt, kf = jax.random.split(rng)
    if cfg.encdec:
        sd = max(4, s // cfg.dec_ratio)
        return {
            "frames": jax.random.normal(kf, (b, s, cfg.d_model), cfg.jdtype),
            "tokens": jax.random.randint(kt, (b, sd), 0, cfg.vocab),
            "targets": jax.random.randint(kt, (b, sd), 0, cfg.vocab),
        }
    batch = {
        "tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab),
        "targets": jax.random.randint(kt, (b, s), 0, cfg.vocab),
    }
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        batch["pos"] = jnp.broadcast_to(pos[None], (3, b, s))
    return batch
