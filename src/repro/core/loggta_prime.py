"""Log-GTA' (paper Appendix D.2): the edge-labelled variant of Log-GTA.

Carries Lambda/X labels on active edges (copies of the child's lam/chi at
extension time).  A unique-c-gc inactivation builds the new vertex from the
*edge* labels, giving width <= 3w without needing intersection width.
Recovers Bodlaender's (TD) and Akatov's (HD) log-depth results, and is how
we realize the ACQ-MR baseline (Sec. 2.2): GYM on Log-GTA'(D) materializes
joins of <= 3w base relations per node == ACQ's shunt of 3 base relations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .ghd import GHD
from .hypergraph import Query
from .loggta import select_inactivation_sets  # reuse Lemma 16/26 selection


@dataclass
class ExtendedGHDPrime:
    ghd: GHD
    active: Set[int]
    Lam: Dict[Tuple[int, int], FrozenSet[str]]  # edge -> relation aliases
    X: Dict[Tuple[int, int], FrozenSet[str]]  # edge -> attributes
    height: Dict[int, int]
    next_id: int

    @staticmethod
    def extend(ghd: GHD) -> "ExtendedGHDPrime":
        g = ghd.copy()
        Lam = {(p, c): g.lam[c] for p, c in g.tree_edges()}
        X = {(p, c): g.chi[c] for p, c in g.tree_edges()}
        return ExtendedGHDPrime(
            ghd=g, active=set(g.nodes()), Lam=Lam, X=X,
            height={}, next_id=max(g.nodes()) + 1,
        )

    # same helper surface as ExtendedGHD so selection code can be shared
    def active_children(self, n: int) -> List[int]:
        return [c for c in self.ghd.children.get(n, []) if c in self.active]

    def active_leaves(self) -> List[int]:
        return [n for n in self.active if not self.active_children(n)]

    def unique_cgc(self) -> List[int]:
        out = []
        for u in self.active:
            cs = self.active_children(u)
            if len(cs) == 1 and len(self.active_children(cs[0])) == 1:
                out.append(u)
        return out

    def _assign_height(self, n: int) -> None:
        kids = [c for c in self.ghd.children.get(n, []) if c not in self.active]
        self.height[n] = 0 if not kids else 1 + max(self.height[k] for k in kids)

    def inactivate_leaf(self, l: int) -> None:
        p = self.ghd.parent[l]
        if p is not None:
            self.Lam.pop((p, l), None)
            self.X.pop((p, l), None)
        self.active.remove(l)
        self._assign_height(l)

    def inactivate_unique_cgc(self, u: int) -> int:
        g = self.ghd
        c = self.active_children(u)[0]
        gc = self.active_children(c)[0]
        p = g.parent[u]

        lam_pu = self.Lam.get((p, u), frozenset()) if p is not None else frozenset()
        x_pu = self.X.get((p, u), frozenset()) if p is not None else frozenset()
        lam_uc, x_uc = self.Lam[(u, c)], self.X[(u, c)]
        lam_cgc, x_cgc = self.Lam[(c, gc)], self.X[(c, gc)]

        s = self.next_id
        self.next_id += 1
        g.chi[s] = frozenset(x_pu | x_uc | x_cgc)
        g.lam[s] = frozenset(lam_pu | lam_uc | lam_cgc)

        if p is not None:
            g.children[p].remove(u)
            g.children[p].append(s)
        else:
            g.root = s
        g.parent[s] = p
        g.children[s] = [u, c, gc]
        g.children[u].remove(c)
        g.children[c].remove(gc)
        g.parent[u] = s
        g.parent[c] = s
        g.parent[gc] = s

        if p is not None:
            del self.Lam[(p, u)], self.X[(p, u)]
            self.Lam[(p, s)], self.X[(p, s)] = lam_pu, x_pu
        del self.Lam[(u, c)], self.X[(u, c)]
        del self.Lam[(c, gc)], self.X[(c, gc)]
        self.Lam[(s, gc)], self.X[(s, gc)] = lam_cgc, x_cgc

        self.active.add(s)
        self.active.discard(u)
        self.active.discard(c)
        self._assign_height(u)
        self._assign_height(c)
        return s


def log_gta_prime(ghd: GHD, query: Query) -> GHD:
    """Theorem 30: width' <= 3w, depth min(depth, O(log n))."""
    w = ghd.width
    ext = ExtendedGHDPrime.extend(ghd)
    iters = 0
    while ext.active:
        leaves, ucgcs = select_inactivation_sets(ext)  # duck-typed
        for u in sorted(ucgcs, key=lambda n: -ext.ghd.depth_of(n)):
            ext.inactivate_unique_cgc(u)
        for l in leaves:
            if l in ext.active and not ext.active_children(l):
                ext.inactivate_leaf(l)
        iters += 1
        assert iters <= 4 * max(4, ghd.size()).bit_length() + 8
    out = ext.ghd
    out.validate(query)
    assert out.width <= 3 * w
    return out
