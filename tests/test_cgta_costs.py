"""C-GTA (Theorem 25) spectrum + analytic cost model sanity."""
from __future__ import annotations

import math
import random

from repro.core.cgta import cgta, cgta_pass
from repro.core.costs import (
    B,
    gym_comm,
    gym_loggta_comm,
    acqmr_comm,
    one_round_chain_lower_bound,
    predicted_table,
)
from repro.core.decompose import ghd_for
from repro.core.loggta import log_gta
from repro.core.queries import (
    chain_ghd,
    chain_query,
    random_acyclic_query,
    triangle_chain_ghd,
    triangle_chain_query,
)


def test_cgta_pass_shrinks_and_bounds_width():
    q = chain_query(24)
    g = chain_ghd(24).make_complete(q)
    g1 = cgta_pass(g, q)
    assert g1.size() < g.size()
    assert g1.width <= 2 * g.width
    g1.validate(q)


def test_cgta_theorem25_spectrum():
    """width <= 2^i * max(w, 3iw); repeated passes keep shrinking."""
    q = triangle_chain_query(6)
    g = triangle_chain_ghd(6).make_complete(q)
    w, iw = g.width, g.intersection_width(q)
    for i in (1, 2):
        out = cgta(g, q, passes=i)
        out.validate(q)
        assert out.width <= (2**i) * max(w, 3 * iw), (i, out.width)


def test_cgta_random_acyclic():
    rng = random.Random(3)
    for _ in range(5):
        q = random_acyclic_query(rng, 10)
        g = ghd_for(q).make_complete(q)
        out = cgta(g, q, passes=1)
        out.validate(q)
        assert out.width <= 2 * max(g.width, 3 * g.intersection_width(q))


def test_cost_model_orderings():
    IN, OUT, M, n = 1e6, 1e6, 1e3, 16
    # Table 3 worst-case ordering: GYM(w=2) < GYM-LogGTA(3iw=3) < ACQ-MR(3w=6)
    c_gym = gym_comm(n, IN, OUT, M, w=2)
    c_log = gym_loggta_comm(n, IN, OUT, M, w=2, iw=1)
    c_acq = acqmr_comm(n, IN, OUT, M, w=2)
    assert c_gym < c_log < c_acq
    # B is quadratic
    assert B(2 * IN, M) == 4 * B(IN, M)
    # Sec 1: the 1-round lower bound for C_16 dwarfs multi-round GYM on the
    # width-1 chain GHD (n*(IN+OUT)^2/M)
    assert one_round_chain_lower_bound(16, IN, M) > gym_comm(16, IN, OUT, M, w=1)


def test_predicted_table_fields():
    q = triangle_chain_query(4)
    g = triangle_chain_ghd(4)
    t = predicted_table(q, g, IN=1e4, OUT=1e4, M=1e2)
    assert t["width"] == 2 and t["iw"] == 1
    assert t["gym_rounds"] <= t["depth"] + math.log2(q.n) + 1
