"""GYM — Generalized Yannakakis in MapReduce (paper Section 5).

Given any complete GHD D(T, chi, lam) of a query Q:

  1. *Materialization stage* (Theorem 15): per tree vertex v, compute
     IDB_v = |><|_{R in lam(v)} pi_{attrs(R) & chi(v)}(R)   — schema chi(v).
     One Lemma 8 grid round (faithful) or a left-deep hash-join cascade
     (optimized).  D is now a width-1 GHD over the IDBs; Q' = |><| IDB_v is
     acyclic and equals Q (strong completeness enforces every atom).
  2. *DYM-d* (Sec. 4.3) on the IDB tree: upward semijoins, downward
     semijoins, join phase — O(d + log n) rounds total.

Two operator strategies, selectable per run:
  - ``strategy='grid'``  — paper-faithful Lemmas 8/10 (skew-proof,
    B(X, M) = X^2/M communication).
  - ``strategy='hash'``  — beyond-paper: hash co-partitioning
    (comm ~ inputs + outputs, skew-sensitive; overflow triggers the
    abort-retry path with doubled capacities, the paper's own semantics).

The driver is a resumable state machine: between BSP round-groups its full
state (node tables + cursor + ledger) can be snapshotted to disk and a new
driver can resume mid-query (fault tolerance; see
``examples/gym_fault_tolerance.py``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..relational import grid as G
from ..relational import ops as R
from ..relational.ledger import Ledger
from ..relational.spmd import SPMD
from ..relational.table import DTable, Table
from .ghd import GHD
from .hypergraph import Query
from .planner import Op, Round, dym_d_schedule, dym_n_schedule


# --------------------------------------------------------------------------
# op wrappers: each returns (DTable, comm_sent, dropped, engine_rounds)
# --------------------------------------------------------------------------
class _Engine:
    def __init__(self, spmd: SPMD, strategy: str, seed: int):
        assert strategy in ("hash", "grid")
        self.spmd = spmd
        self.strategy = strategy
        self.seed = seed
        self._ctr = 0

    def _s(self) -> int:
        self._ctr += 1
        return self.seed + 7919 * self._ctr

    def semijoin(self, s: DTable, r: DTable, cap: int):
        cap = _pow2(cap)
        if self.strategy == "grid":
            out, st, rounds = G.grid_semijoin(self.spmd, s, r, out_cap=cap, seed=self._s())
            return out, st["sent"], st["dropped"], rounds
        out, st = R.dist_semijoin(
            self.spmd, s, r, seed=self._s(), cap_recv=(cap, self.spmd.p * r.cap)
        )
        return out, st["sent"], st["dropped"], 1

    def join(self, a: DTable, b: DTable, out_cap: int):
        out_cap = _pow2(out_cap)
        if self.strategy == "grid":
            out, st = G.grid_join(self.spmd, a, b, out_cap=out_cap)
            return out, st["sent"], st["dropped"], 1
        out, st = R.dist_join(self.spmd, a, b, seed=self._s(), out_cap=out_cap)
        return out, st["sent"], st["dropped"], 1

    def multijoin(self, parts: List[DTable], out_cap: int):
        out_cap = _pow2(out_cap)
        if self.strategy == "grid" or len(parts) > 2:
            out, st = G.grid_multiway_join(self.spmd, parts, out_cap=out_cap)
            return out, st["sent"], st["dropped"], 1
        if len(parts) == 1:
            return parts[0], 0, 0, 0
        out, st = R.dist_join(self.spmd, parts[0], parts[1], seed=self._s(), out_cap=out_cap)
        return out, st["sent"], st["dropped"], 1

    def intersect(self, a: DTable, b: DTable, cap: int):
        cap = _pow2(cap)
        out, st = R.dist_intersect(
            self.spmd, a, b, seed=self._s(), cap_recv=(cap, self.spmd.p * b.cap)
        )
        return out, st["sent"], st["dropped"], 1

    def dedup(self, t: DTable, cap: int):
        cap = _pow2(cap)
        out, st = R.dist_dedup(self.spmd, t, seed=self._s(), cap_recv=cap)
        return out, st["sent"], st["dropped"], 1


def _pow2(x: int) -> int:
    """Round capacities up to powers of two: distinct shapes collapse, so
    the per-op jit cache is reused across nodes/rounds/retries."""
    return 1 << max(2, int(x - 1).bit_length())


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GymConfig:
    strategy: str = "hash"  # 'hash' (optimized) | 'grid' (paper-faithful)
    schedule: str = "dym_d"  # 'dym_d' (Sec 4.3) | 'dym_n' (Sec 4.2)
    seed: int = 0
    cap_growth: int = 4  # capacity multiplier on overflow-retry
    max_retries: int = 12
    count_retries_comm: bool = True  # aborted rounds still moved tuples


class GymDriver:
    """Resumable GYM execution: materialization + DYM on one SPMD backend."""

    def __init__(
        self,
        query: Query,
        ghd: GHD,
        data: Dict[str, np.ndarray],
        spmd: SPMD,
        config: Optional[GymConfig] = None,
    ):
        self.query = query
        self.config = config or GymConfig()
        self.spmd = spmd
        self.ghd = ghd.make_complete(query)
        self.engine = _Engine(spmd, self.config.strategy, self.config.seed)
        self.ledger = Ledger()

        # stable per-node schemas: chi in first-seen attr order of the query
        attr_order = {a: i for i, a in enumerate(query.output_attrs)}
        self.node_schema: Dict[int, Tuple[str, ...]] = {
            v: tuple(sorted(self.ghd.chi[v], key=lambda a: attr_order[a]))
            for v in self.ghd.nodes()
        }

        # load base relations (round-robin scatter = the 'networked FS')
        p = spmd.p
        self.base: Dict[str, DTable] = {}
        for atom in query.atoms:
            rows = np.asarray(data[atom.rel], dtype=np.int32).reshape(-1, len(atom.attrs))
            if rows.shape[0]:
                rows = np.unique(rows, axis=0)  # relations are sets
            cap = _pow2(max(1, -(-rows.shape[0] // p)))  # pow2: shape reuse
            self.base[atom.alias] = spmd.device_put(
                DTable.scatter_numpy(rows, atom.attrs, p, cap=cap)
            )

        sched = dym_d_schedule if self.config.schedule == "dym_d" else dym_n_schedule
        self.schedule: List[Round] = sched(self.ghd)
        self.tables: Dict[int, DTable] = {}
        # Upward-phase L2 accumulators: the paper's "replace R1 ... for the
        # duration of the upward semijoin phase".  Node tables stay intact
        # (the downward phase and join phase need the originals).
        self.acc: Dict[int, DTable] = {}
        self.caps: Dict[int, int] = {}
        self.cursor: int = -1  # -1 = materialization pending
        self.done = False
        self.result: Optional[DTable] = None

    # -- capacity heuristics ------------------------------------------------
    def _init_cap(self, v: int) -> int:
        per_shard = max(
            -(-max(1, int(np.asarray(self.base[a].valid).sum())) // self.spmd.p)
            for a in self.ghd.lam[v]
        )
        return _pow2(max(4, 4 * per_shard))

    # -- materialization (Theorem 15 stage 1) --------------------------------
    def _materialize(self) -> None:
        cfg = self.config
        comm = 0
        dropped_any = True
        attempt = 0
        caps = {v: self._init_cap(v) for v in self.ghd.nodes()}
        max_engine_rounds = 0
        while dropped_any:
            attempt += 1
            assert attempt <= cfg.max_retries, "materialization: too many retries"
            dropped_any = False
            comm_try = 0
            tables: Dict[int, DTable] = {}
            max_engine_rounds = 0
            for v in self.ghd.nodes():
                parts: List[DTable] = []
                need_dedup = False
                for alias in sorted(self.ghd.lam[v]):
                    t = self.base[alias]
                    keep = [a for a in t.schema if a in self.ghd.chi[v]]
                    proj = R.dist_project(self.spmd, t, keep, dedup=True)
                    if len(keep) < len(t.schema):
                        need_dedup = True  # strict projection: cross-shard dups
                    parts.append(proj)
                # order parts by schema for deterministic joined schema, then
                # reorder columns to the canonical node schema via projection
                out, sent, drop, rnds = self.engine.multijoin(parts, caps[v])
                er = rnds
                if need_dedup:
                    out, s2, d2, r2 = self.engine.dedup(out, caps[v])
                    sent += s2
                    drop += d2
                    er += r2
                if drop:
                    dropped_any = True
                    caps[v] *= cfg.cap_growth
                comm_try += sent
                # canonicalize column order to node schema
                tables[v] = R.dist_project(self.spmd, out, self.node_schema[v])
                max_engine_rounds = max(max_engine_rounds, er)
            if cfg.count_retries_comm or not dropped_any:
                comm += comm_try
            if dropped_any:
                self.ledger.retries += 1
        self.tables = tables
        self.caps = {v: max(caps[v], tables[v].cap) for v in tables}
        self.ledger.add_round(
            "materialize",
            [f"IDB({v})<=lam{sorted(self.ghd.lam[v])}" for v in self.ghd.nodes()],
            comm,
            n_rounds=max(1, max_engine_rounds),
        )
        self.cursor = 0

    # -- one schedule round ---------------------------------------------------
    def _exec_op(
        self,
        op: Op,
        tab: Dict[int, DTable],
        acc: Dict[int, DTable],
        caps: Dict[int, int],
    ):
        """Returns (store, new_table, sent, dropped, engine_rounds) where
        ``store`` is 'tab' (real node update) or 'acc' (upward scratch)."""
        e = self.engine

        def up(v: int) -> DTable:  # upward view: accumulator if present
            return acc.get(v, tab[v])

        if op.kind == "semijoin":
            # upward L1: S := S |>< R, R read through its accumulator
            tgt, r = op.target, op.args[0]
            t, c, d, er = e.semijoin(tab[tgt], up(r), caps[tgt])
            return "tab", t, c, d, er
        if op.kind == "down_semijoin":
            tgt, s = op.target, op.args[0]
            t, c, d, er = e.semijoin(tab[tgt], tab[s], caps[tgt])
            return "tab", t, c, d, er
        if op.kind == "join":
            (r,) = op.args
            t, c, d, er = e.join(tab[op.target], tab[r], caps[op.target])
            return "tab", t, c, d, er
        if op.kind == "pair_filter":
            s, r2 = op.args
            t1, c1, d1, rr1 = e.semijoin(tab[s], up(op.target), caps[s])
            t2, c2, d2, rr2 = e.semijoin(tab[s], up(r2), caps[s])
            t3, c3, d3, rr3 = e.intersect(t1, t2, caps[s])
            return "acc", t3, c1 + c2 + c3, d1 + d2 + d3, max(rr1, rr2) + rr3
        if op.kind == "triple_filter":
            s, rb, rc = op.args
            t1, c1, d1, rr1 = e.semijoin(tab[s], up(op.target), caps[s])
            t2, c2, d2, rr2 = e.semijoin(tab[s], up(rb), caps[s])
            t3, c3, d3, rr3 = e.semijoin(tab[s], up(rc), caps[s])
            i1, c4, d4, rr4 = e.intersect(t1, t2, caps[s])
            i2, c5, d5, rr5 = e.intersect(i1, t3, caps[s])
            return (
                "acc",
                i2,
                c1 + c2 + c3 + c4 + c5,
                d1 + d2 + d3 + d4 + d5,
                max(rr1, rr2, rr3) + rr4 + rr5,
            )
        if op.kind == "pair_join":
            s, r2 = op.args
            cap = max(caps[op.target], caps[s], caps[r2])
            t1, c1, d1, rr1 = e.join(tab[op.target], tab[s], cap)
            t2, c2, d2, rr2 = e.join(tab[r2], tab[s], cap)
            t3, c3, d3, rr3 = e.join(t1, t2, cap)
            return "tab", t3, c1 + c2 + c3, d1 + d2 + d3, max(rr1, rr2) + rr3
        if op.kind == "triple_join":
            s, rb, rc = op.args
            cap = max(caps[op.target], caps[s], caps[rb], caps[rc])
            t1, c1, d1, rr1 = e.join(tab[op.target], tab[s], cap)
            t2, c2, d2, rr2 = e.join(tab[rb], tab[s], cap)
            t3, c3, d3, rr3 = e.join(tab[rc], tab[s], cap)
            j1, c4, d4, rr4 = e.join(t1, t2, cap)
            j2, c5, d5, rr5 = e.join(j1, t3, cap)
            return (
                "tab",
                j2,
                c1 + c2 + c3 + c4 + c5,
                d1 + d2 + d3 + d4 + d5,
                max(rr1, rr2, rr3) + rr4 + rr5,
            )
        raise ValueError(f"unknown op {op.kind}")

    def step(self) -> bool:
        """Run one schedule round (with abort-retry); returns True if more."""
        if self.done:
            return False
        if self.cursor < 0:
            self._materialize()
            return True
        if self.cursor >= len(self.schedule):
            self._finish()
            return False
        rnd = self.schedule[self.cursor]
        cfg = self.config
        snap_tab = dict(self.tables)
        snap_acc = dict(self.acc)
        caps = dict(self.caps)
        attempt = 0
        comm_total = 0
        while True:
            attempt += 1
            assert attempt <= cfg.max_retries, f"round {self.cursor}: too many retries"
            new_tab: Dict[int, DTable] = {}
            new_acc: Dict[int, DTable] = {}
            comm = 0
            dropped = 0
            er_max = 0
            for op in rnd.ops:
                store, t, c, d, er = self._exec_op(op, snap_tab, snap_acc, caps)
                comm += c
                dropped += d
                er_max = max(er_max, er)
                if d:
                    # grow capacities past the observed overflow so the
                    # retry converges in one attempt (drop count bounds the
                    # shortfall across all shards)
                    for g in (op.target, *op.args):
                        caps[g] = _pow2(
                            caps.get(g, 4) * cfg.cap_growth + int(d)
                        )
                (new_tab if store == "tab" else new_acc)[op.target] = t
            if cfg.count_retries_comm or dropped == 0:
                comm_total += comm
            if dropped == 0:
                break
            self.ledger.retries += 1
        self.tables = {**snap_tab, **new_tab}
        self.acc = {**snap_acc, **new_acc}
        self.caps = caps
        self.ledger.add_round(
            rnd.phase, [repr(o) for o in rnd.ops], comm_total, n_rounds=max(1, er_max)
        )
        self.cursor += 1
        if self.cursor >= len(self.schedule):
            self._finish()
            return False
        return True

    def _finish(self) -> None:
        root = self.ghd.root
        out = self.tables[root]
        # canonical output column order
        want = [a for a in self.query.output_attrs if a in out.schema]
        self.result = R.dist_project(self.spmd, out, want)
        self.ledger.output_tuples = int(np.asarray(self.result.valid).sum())
        self.done = True

    def run(self) -> DTable:
        while self.step():
            pass
        if not self.done:
            self._finish()
        assert self.result is not None
        return self.result

    # -- fault tolerance: snapshot / resume ----------------------------------
    def save(self, path: str) -> None:
        """Atomic snapshot of the driver state between rounds."""
        arrays = {}
        meta = {
            "cursor": self.cursor,
            "done": self.done,
            "caps": {str(k): v for k, v in self.caps.items()},
            "ledger": {
                "records": [dataclasses.asdict(r) for r in self.ledger.records],
                "output_tuples": self.ledger.output_tuples,
                "retries": self.ledger.retries,
            },
            "schemas": {str(k): list(t.schema) for k, t in self.tables.items()},
            "acc_schemas": {str(k): list(t.schema) for k, t in self.acc.items()},
        }
        for k, t in self.tables.items():
            arrays[f"data_{k}"] = np.asarray(t.data)
            arrays[f"valid_{k}"] = np.asarray(t.valid)
        for k, t in self.acc.items():
            arrays[f"accdata_{k}"] = np.asarray(t.data)
            arrays[f"accvalid_{k}"] = np.asarray(t.valid)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            np.savez(f, meta=json.dumps(meta), **arrays)
        os.replace(tmp, path)  # atomic publish

    def load(self, path: str) -> None:
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["meta"]))
        self.cursor = meta["cursor"]
        self.done = meta["done"]
        self.caps = {int(k): v for k, v in meta["caps"].items()}
        led = Ledger()
        from ..relational.ledger import RoundRecord

        led.records = [RoundRecord(**r) for r in meta["ledger"]["records"]]
        led.output_tuples = meta["ledger"]["output_tuples"]
        led.retries = meta["ledger"]["retries"]
        self.ledger = led
        self.tables = {}
        for k, schema in meta["schemas"].items():
            ki = int(k)
            self.tables[ki] = self.spmd.device_put(
                DTable(
                    jnp_asarray(z[f"data_{k}"]),
                    jnp_asarray(z[f"valid_{k}"]),
                    tuple(schema),
                )
            )
        self.acc = {}
        for k, schema in meta.get("acc_schemas", {}).items():
            ki = int(k)
            self.acc[ki] = self.spmd.device_put(
                DTable(
                    jnp_asarray(z[f"accdata_{k}"]),
                    jnp_asarray(z[f"accvalid_{k}"]),
                    tuple(schema),
                )
            )


def jnp_asarray(x):
    import jax.numpy as jnp

    return jnp.asarray(x)


# --------------------------------------------------------------------------
# front door
# --------------------------------------------------------------------------
def gym(
    query: Query,
    data: Dict[str, np.ndarray],
    *,
    ghd: Optional[GHD] = None,
    p: int = 4,
    spmd: Optional[SPMD] = None,
    config: Optional[GymConfig] = None,
) -> Tuple[np.ndarray, Tuple[str, ...], Ledger]:
    """Evaluate Q with GYM.  Returns (rows, schema, ledger)."""
    from .decompose import ghd_for

    g = ghd if ghd is not None else ghd_for(query)
    s = spmd if spmd is not None else SPMD(p)
    drv = GymDriver(query, g, data, s, config)
    out = drv.run()
    return out.to_numpy(), out.schema, drv.ledger
