"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified]."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    n_experts=8,
    topk=2,
    moe_d_ff=32768,
    attn_softcap=30.0,  # grok uses attention logit capping
    logit_softcap=30.0,
    tie_embeddings=False,
    notes="every layer MoE (8e top-2)",
)
