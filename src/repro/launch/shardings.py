"""Sharding rules: FSDP over ('pod','data') + TP/EP over 'model'.

Path-name-based rules with divisibility-checked fallbacks, so every
(architecture x shape x mesh) cell lowers: a dim is only sharded on an
axis whose size divides it; otherwise the rule degrades gracefully
(sub-axis, then replicated).  This is the MaxText "logical axis rules"
idea in one function, without a DSL.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import dp_axes

# parameter-name classes
_IN = {"wq", "wk", "wv", "wi", "wg", "w_in", "w_up", "w_if", "router"}
_OUT = {"wo", "w_out", "w_down"}


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, *candidates):
    """First candidate axis (or axis tuple) whose size divides dim."""
    for c in candidates:
        if c is None:
            continue
        if dim % _axsize(mesh, c) == 0:
            return c
    return None


def abstract_mesh_axes():
    """The abstract mesh a jit trace is running under (None outside one)
    plus its axis-name set — mesh-less CPU tests get ``(None, set())`` so
    best-effort constraints degrade to no-ops."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None, set()
        return mesh, set(mesh.axis_names)
    except Exception:  # noqa: BLE001
        return None, set()


def constrain(x: jax.Array, *spec):
    """Best-effort ``with_sharding_constraint``: applies only when tracing
    under a mesh whose axes cover the named ones and only on dims the
    axis size divides — the activation-side sibling of ``_fit``'s
    divisibility-checked parameter placement, shared by the MoE dispatch
    paths in ``models.mlp``/``models.moe_routing``."""
    mesh, names = abstract_mesh_axes()
    if not names:
        return x

    def ok(s, dim):
        if s is None:
            return None
        if isinstance(s, tuple):
            sub = tuple(a for a in s if a in names)
            if not sub:
                return None
            return sub if dim % _axsize(mesh, sub) == 0 else None
        if s not in names:
            return None
        return s if dim % mesh.shape[s] == 0 else None

    fixed = tuple(ok(s, d) for s, d in zip(spec, x.shape))
    if all(s is None for s in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", p))) for p in path
    )


def param_spec(
    path, shape: Tuple[int, ...], mesh: Mesh, serve_tp_only: bool = False
) -> P:
    """PartitionSpec for one parameter leaf.

    ``serve_tp_only``: inference layout for models that fit TP-sharded —
    no FSDP dim, so no per-layer weight all-gathers on the serve path
    (Perf iteration C)."""
    name = _leaf_name(path)
    ps = _path_str(path)
    fsdp = None if serve_tp_only else dp_axes(mesh)
    tp = "model"
    nd = len(shape)
    spec = [None] * nd
    if nd == 0:
        return P()
    is_moe = "moe" in ps and name in ("wi", "wg", "wo")

    def place(dim_idx: int, *cands):
        spec[dim_idx] = _fit(mesh, shape[dim_idx], *cands)

    if is_moe and nd >= 3:
        # (..., E, d, f) or (..., E, f, d): experts -> EP on model
        place(nd - 3, tp, fsdp)
        if spec[nd - 3] == tp:  # EP engaged
            place(nd - 2, fsdp if name in _IN else None)
            if name in _OUT:
                place(nd - 1, fsdp)
        else:  # E indivisible by 'model' (grok 8e vs 16): megatron-style FF
            if name in _IN:  # (E, d, f): f -> tp
                place(nd - 2, fsdp)
                place(nd - 1, tp)
            else:  # (E, f, d): f -> tp
                place(nd - 2, tp)
                place(nd - 1, fsdp)
        return P(*spec)
    if name == "table":  # (V, D) embeddings
        place(0, tp, fsdp)
        place(1, fsdp if spec[0] != fsdp else None)
        return P(*spec)
    if name == "r" and nd == 3:  # sLSTM recurrent (H, hd, 4hd)
        place(0, tp)
        return P(*spec)
    if name == "conv":  # (k, ch) depthwise conv
        place(nd - 1, tp)
        return P(*spec)
    if name in _IN and nd >= 2:
        place(nd - 2, fsdp)
        place(nd - 1, tp)
        return P(*spec)
    if name in _OUT and nd >= 2:
        place(nd - 2, tp)
        place(nd - 1, fsdp)
        return P(*spec)
    # norms, biases, gates: replicate; any big unmatched matrix: best-effort
    if nd >= 2 and shape[-1] * shape[-2] >= 1 << 20:
        place(nd - 1, tp)
        place(nd - 2, fsdp)
    return P(*spec)


def param_specs(params, mesh: Mesh, serve_tp_only: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf.shape, mesh, serve_tp_only),
        params,
    )


def opt_state_specs(opt_state, params_specs, mesh: Mesh):
    """Optimizer moments inherit their parameter's spec; factored vectors
    and scalars replicate."""

    def spec(path, leaf):
        # paths look like m/<param path>, v/<...>, f/<...>/r, step
        ps = _path_str(path)
        if ps == "step":
            return P()
        # strip the leading m/v/f and trailing r/c/v markers, then reuse
        sub = path[1:]
        if sub and _leaf_name(sub) in ("r", "c"):
            return P()  # factored vectors: small, replicate
        if sub and _leaf_name(sub) == "v" and len(leaf.shape) <= 1:
            return P()
        return param_spec(sub if sub else path, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, opt_state)


def batch_specs(batch, mesh: Mesh):
    fsdp = dp_axes(mesh)

    def spec(path, leaf):
        name = _leaf_name(path)
        shp = leaf.shape
        if name == "pos" and len(shp) == 3:  # (3, B, S)
            return P(None, _fit(mesh, shp[1], fsdp, "data"), None)
        s = [None] * len(shp)
        if len(shp) >= 1:
            s[0] = _fit(mesh, shp[0], fsdp, "data")
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_specs(caches, mesh: Mesh):
    """Decode caches: stacked (L, B, ...) pytrees.  Batch -> FSDP axes when
    divisible; heads/channels -> model; else sequence -> data."""
    fsdp = dp_axes(mesh)
    tp = "model"

    def spec(path, leaf):
        name = _leaf_name(path)
        shp = leaf.shape
        nd = len(shp)
        if nd == 0:
            return P()
        s = [None] * nd
        if name in ("k", "v") and nd == 5:  # (L, B, KV, S, hd)
            s[1] = _fit(mesh, shp[1], fsdp, "data")
            s[2] = _fit(mesh, shp[2], tp)
            if s[2] is None:
                s[3] = _fit(mesh, shp[3], tp)
            return P(*s)
        if name in ("k", "v") and nd == 4:  # whisper (L?, B, KV, S, hd) alt
            s[0] = _fit(mesh, shp[0], fsdp, "data")
            s[1] = _fit(mesh, shp[1], tp)
            return P(*s)
        if nd >= 3:  # recurrent states (L, B, H, ...) / conv (L, B, k, ch)
            s[1] = _fit(mesh, shp[1], fsdp, "data")
            if name == "conv":
                s[nd - 1] = _fit(mesh, shp[nd - 1], tp)
            else:
                s[2] = _fit(mesh, shp[2], tp)
            return P(*s)
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, caches)


def named(mesh: Mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
