"""The jitted train step: loss + grad (remat'd backbone), optional
microbatch gradient accumulation (lax.scan), global-norm clipping,
optional int8 gradient codec, optimizer update, metrics."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import compression
from .optim import OptConfig, clip_by_global_norm, opt_init, opt_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum: int = 1  # microbatches per step
    remat: bool = True
    compress_grads: bool = False  # int8 codec at the accumulation boundary
    moe_metrics: bool = False  # surface MoE routing stats (moe_* metrics)


def make_train_step(model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` leading dim = global batch; accumulation splits it
    into ``accum`` microbatches via lax.scan (keeps peak activation memory
    at 1/accum).

    ``moe_metrics``: the loss runs via ``loss_and_stats`` (has_aux grad)
    and metrics grow ``moe_routed`` / ``moe_dropped`` / ``moe_heavy`` —
    exact per-step pair counts summed over MoE layers (and microbatches),
    so a capacity drop in production is a visible metric, not silence."""

    _MOE_KEYS = ("routed", "dropped", "heavy")

    if tcfg.moe_metrics:
        def loss_fn(params, mb):
            return model.loss_and_stats(params, mb, remat=tcfg.remat)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    else:
        def loss_fn(params, mb):
            return model.loss(params, mb, remat=tcfg.remat)

        grad_fn = jax.value_and_grad(loss_fn)

    def run_grad(params, mb):
        """Uniform (loss, aux, grads) regardless of moe_metrics."""
        if tcfg.moe_metrics:
            (loss, aux), grads = grad_fn(params, mb)
        else:
            loss, grads = grad_fn(params, mb)
            aux = {k: jnp.int32(0) for k in _MOE_KEYS}
        return loss, aux, grads

    def train_step(params, opt_state, batch):
        if tcfg.accum == 1:
            loss, moe, grads = run_grad(params, batch)
        else:
            def split(x):
                b = x.shape[0] if x.ndim else 1
                per = b // tcfg.accum
                return x.reshape((tcfg.accum, per) + x.shape[1:])

            # (3,B,S) mrope pos has batch on axis 1 — handled by moving it
            def split_batch(bt):
                out = {}
                for k, v in bt.items():
                    if k == "pos" and v.ndim == 3:
                        per = v.shape[1] // tcfg.accum
                        out[k] = jnp.moveaxis(
                            v.reshape(3, tcfg.accum, per, v.shape[2]), 1, 0
                        )
                    else:
                        out[k] = split(v)
                return out

            mbs = split_batch(batch)

            def acc_step(carry, mb):
                gsum, lsum, msum = carry
                l, aux, g = run_grad(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g
                )
                msum = {k: msum[k] + aux[k] for k in _MOE_KEYS}
                return (gsum, lsum + l, msum), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            mzero = {k: jnp.int32(0) for k in _MOE_KEYS}
            (gsum, lsum, moe), _ = jax.lax.scan(
                acc_step, (zeros, 0.0, mzero), mbs
            )
            grads = jax.tree_util.tree_map(lambda g: g / tcfg.accum, gsum)
            loss = lsum / tcfg.accum

        if tcfg.compress_grads:
            grads = compression.codec_roundtrip(grads)
        grads, gnorm = clip_by_global_norm(grads, tcfg.opt.grad_clip)
        params, opt_state = opt_update(tcfg.opt, grads, opt_state, params)
        metrics = {
            "loss": loss.astype(jnp.float32),
            "grad_norm": gnorm,
            "step": opt_state["step"],
        }
        if tcfg.moe_metrics:
            metrics.update({f"moe_{k}": moe[k] for k in _MOE_KEYS})
        return params, opt_state, metrics

    return train_step


def init_train_state(model, tcfg: TrainConfig, rng):
    params = model.init(rng)
    return params, opt_init(tcfg.opt, params)


def init_train_state_shapes(model, tcfg: TrainConfig):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda: init_train_state(model, tcfg, jax.random.PRNGKey(0))
    )
