"""GHD construction.

- ``gyo_join_tree``: GYO ear-removal for acyclic queries -> width-1 GHD
  (the input Yannakakis expects, paper Sec. 4.1).
- ``minfill_ghd``: min-fill tree decomposition of the primal graph, bags
  covered greedily by hyperedges -> a (possibly suboptimal-width) GHD of any
  query.  Used for generic inputs and property tests.
- ``ghd_for``: front door — width-1 via GYO when acyclic, else min-fill.
"""
from __future__ import annotations

import itertools
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .ghd import GHD
from .hypergraph import Query, min_edge_cover


def gyo_join_tree(query: Query) -> Optional[GHD]:
    """GYO reduction. Returns a width-1 GHD (join tree) or None if cyclic.

    An atom R is an *ear* if every attribute of R that is shared with any
    other atom is contained in a single other atom W (the witness); isolated
    atoms are ears too.  Repeatedly removing ears empties exactly the acyclic
    hypergraphs.
    """
    alive: Dict[str, FrozenSet[str]] = dict(query.edges)
    parent_alias: Dict[str, Optional[str]] = {}
    order: List[str] = []

    while len(alive) > 1:
        ear = None
        for alias, attrs in sorted(alive.items()):
            others = {a: e for a, e in alive.items() if a != alias}
            shared = frozenset(
                v for v in attrs if any(v in e for e in others.values())
            )
            if not shared:
                ear, witness = alias, next(iter(sorted(others)))
                break
            w = next((a for a, e in sorted(others.items()) if shared <= e), None)
            if w is not None:
                ear, witness = alias, w
                break
        if ear is None:
            return None  # cyclic
        parent_alias[ear] = witness
        order.append(ear)
        del alive[ear]

    last = next(iter(alive))
    parent_alias[last] = None
    order.append(last)

    # Build rooted tree: node ids = dense ints, one per atom; parent links
    # point at the witness atom.
    ids = {alias: i for i, alias in enumerate(order)}
    root = ids[last]
    edges = [
        (ids[p], ids[a]) for a, p in parent_alias.items() if p is not None
    ]
    chi = {ids[a]: query.edges[a] for a in order}
    lam = {ids[a]: frozenset([a]) for a in order}
    g = GHD.build(root, edges, chi, lam)
    g.validate(query)
    return g


def minfill_ghd(query: Query) -> GHD:
    """Tree decomposition by min-fill elimination, converted to a GHD.

    Standard construction: eliminate the vertex whose neighborhood needs the
    fewest fill edges; its bag = {v} + current neighbors.  Bag b_v connects
    to the bag of the first eliminated vertex in b_v \\ {v}.  lam = greedy
    minimum-ish edge cover of each bag.
    """
    adj = {v: set(ns) for v, ns in query.primal_graph().items()}
    if not adj:
        raise ValueError("empty query")
    bags: List[Tuple[str, FrozenSet[str]]] = []
    elim_pos: Dict[str, int] = {}
    verts = set(adj)
    while verts:
        # min-fill choice
        def fill_cost(v: str) -> int:
            ns = adj[v] & verts
            return sum(
                1
                for a, b in itertools.combinations(sorted(ns), 2)
                if b not in adj[a]
            )

        v = min(sorted(verts), key=fill_cost)
        ns = adj[v] & verts
        bags.append((v, frozenset({v} | ns)))
        elim_pos[v] = len(bags) - 1
        for a, b in itertools.combinations(sorted(ns), 2):
            adj[a].add(b)
            adj[b].add(a)
        verts.remove(v)

    n_bags = len(bags)
    root = n_bags - 1
    edges: List[Tuple[int, int]] = []
    for i, (v, bag) in enumerate(bags):
        rest = [u for u in bag if u != v]
        if rest:
            j = min(elim_pos[u] for u in rest)
            edges.append((j, i))  # parent = bag of first-eliminated neighbor
    chi = {i: bag for i, (_, bag) in enumerate(bags)}
    lam: Dict[int, FrozenSet[str]] = {}
    for i, (_, bag) in enumerate(bags):
        cover = min_edge_cover(bag, query.edges, max_k=4)
        if cover is None:  # fall back to greedy (always succeeds: bags are
            cover = _greedy_cover(bag, query)  # unions of clique vertices)
        lam[i] = cover
    g = GHD.build(root, edges, chi, lam)
    g.validate(query)
    return g


def _greedy_cover(target: FrozenSet[str], query: Query) -> FrozenSet[str]:
    remaining = set(target)
    chosen: Set[str] = set()
    while remaining:
        alias = max(
            sorted(query.edges), key=lambda a: len(query.edges[a] & remaining)
        )
        if not query.edges[alias] & remaining:
            raise ValueError(f"cannot cover {sorted(remaining)}")
        chosen.add(alias)
        remaining -= query.edges[alias]
    return frozenset(chosen)


def ghd_for(query: Query) -> GHD:
    g = gyo_join_tree(query)
    if g is None:
        g = minfill_ghd(query)
    return g
