"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2; unverified].

Assignment-faithful deviations (DESIGN.md Sec. 9): attention is GQA kv=8
per the table (public K2 uses MLA); d_ff=2048 is the per-expert hidden.
First layer dense + 1 shared expert, per the K2 paper."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    pattern=("attn",) + ("moe",) * 60,
    n_experts=384,
    topk=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    tie_embeddings=False,
    notes="GQA per assignment table (public checkpoint is MLA)",
)
