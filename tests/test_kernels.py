"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
from __future__ import annotations

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hash_partition import hash_partition
from repro.kernels.semijoin_probe import semijoin_probe

I32MAX = 2**31 - 1


# ----------------------------------------------------------- semijoin probe
@pytest.mark.parametrize("n,m", [(7, 5), (128, 300), (1024, 2048), (3000, 129)])
def test_semijoin_probe_shapes(n, m):
    rng = np.random.default_rng(n * 1000 + m)
    q = jnp.asarray(rng.integers(0, 50, size=(n,)), jnp.int32)
    keys = rng.integers(0, 50, size=(m,))
    nvalid = rng.integers(0, m + 1)
    keys[nvalid:] = I32MAX
    keys = jnp.asarray(keys, jnp.int32)
    got = semijoin_probe(q, keys, interpret=True)
    want = ref.semijoin_probe_ref(q, keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_semijoin_probe_empty_keys():
    q = jnp.asarray([1, 2, 3], jnp.int32)
    keys = jnp.full((4,), I32MAX, jnp.int32)
    got = semijoin_probe(q, keys, interpret=True)
    assert not np.asarray(got).any()


def test_semijoin_probe_negative_values():
    q = jnp.asarray([-5, 0, 7, -5], jnp.int32)
    keys = jnp.asarray([-5, 7, I32MAX, I32MAX], jnp.int32)
    got = semijoin_probe(q, keys, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), [True, False, True, True])


# ----------------------------------------------------------- hash partition
@pytest.mark.parametrize("n,ar,p", [(10, 2, 4), (1024, 3, 16), (2000, 5, 7)])
@pytest.mark.parametrize("cols", [(0,), (0, 1)])
def test_hash_partition_matches_engine_hash(n, ar, p, cols):
    rng = np.random.default_rng(n + ar + p)
    rows = jnp.asarray(rng.integers(-100, 100, size=(n, ar)), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    got = hash_partition(rows, valid, cols, p, seed=13, interpret=True)
    want = ref.hash_partition_ref(rows, valid, cols, p, 13)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    g = np.asarray(got)
    v = np.asarray(valid)
    assert (g[v] < p).all() and (g[~v] == p).all()


# ---------------------------------------------------------- flash attention
def _mk_qkv(rng, b, h, kvh, sq, sk, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,h,kvh,sq,sk,d",
    [
        (1, 2, 2, 64, 64, 32),
        (2, 4, 2, 128, 128, 64),   # GQA
        (1, 3, 1, 96, 200, 16),    # MQA, non-multiple sizes, cross lengths
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(b, h, kvh, sq, sk, d, causal):
    rng = np.random.default_rng(b + h + sq + sk + causal)
    q, k, v = _mk_qkv(rng, b, h, kvh, sq, sk, d, jnp.float32)
    got = flash_attention(
        q, k, v, causal=causal, blk_q=64, blk_k=64, interpret=True
    )
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_window_and_softcap():
    rng = np.random.default_rng(0)
    q, k, v = _mk_qkv(rng, 1, 2, 2, 128, 128, 32, jnp.float32)
    got = flash_attention(
        q, k, v, causal=True, window=32, softcap=30.0,
        blk_q=64, blk_k=64, interpret=True,
    )
    want = ref.attention_ref(q, k, v, causal=True, window=32, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q, k, v = _mk_qkv(rng, 1, 2, 1, 64, 64, 64, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2, rtol=3e-2
    )


def test_flash_attention_decode_shape():
    """One query token vs a long KV (the serve_step path)."""
    rng = np.random.default_rng(2)
    q, k, v = _mk_qkv(rng, 2, 4, 2, 1, 512, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)
