"""MoE token dispatch on the join engines' routed exchange: calibrate
per-expert capacities from measured counts, spread hot experts via the
heavy split, and compare against the dense Switch-style scatter — which
silently drops over-capacity tokens the calibrated route keeps.

    PYTHONPATH=src python examples/moe_routing.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CONFIGS, reduced_config
from repro.models.common import rms_norm
from repro.models.mlp import init_moe, moe_forward_stats
from repro.models.moe_routing import (
    apply_plan,
    calibrate_moe,
    record_dense_round,
    record_moe_round,
)
from repro.relational import Ledger

# --- 1. a small MoE layer and a skewed batch ----------------------------
# tokens cluster around per-expert prototypes, so one expert runs hot —
# the heavy-hitter shape the paper's skew machinery (Lemma 8) handles.
cfg = reduced_config(CONFIGS["kimi-k2-1t-a32b"])  # 4 experts, top-2, f32
p = init_moe(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(7)
protos = rng.standard_normal((cfg.n_experts, cfg.d_model)).astype(np.float32)
pick = rng.choice(cfg.n_experts, size=256, p=[0.85, 0.07, 0.05, 0.03])
x = jnp.asarray(
    (protos[pick] * 2.0 + 0.05 * rng.standard_normal((256, cfg.d_model)))
    .reshape(4, 64, cfg.d_model),
    jnp.float32,
)

# --- 2. dense Switch-style scatter: drops are silent --------------------
y_dense, dense_stats = moe_forward_stats(p, x, cfg)
print(f"[dense]      routed={int(dense_stats['routed'])} "
      f"dropped={int(dense_stats['dropped'])}  (lost to capacity 1.25)")

# --- 3. calibrate: measure counts, flag hot experts, pick tight caps ----
xf = rms_norm(x, p["ln"], cfg.norm_eps).reshape(-1, cfg.d_model)
plan, info = calibrate_moe(p, xf, cfg, threshold=1.5)
print(f"[calibrate]  arrivals={[int(a) for a in info['arrivals']]} "
      f"heavy={list(plan.heavy)} cap_send={plan.cap_send} "
      f"cap_recv={plan.cap_recv}")

# --- 4. the calibrated route: same math, zero drops ---------------------
y_calib, calib_stats = moe_forward_stats(p, x, apply_plan(cfg, plan))
print(f"[calibrated] routed={int(calib_stats['routed'])} "
      f"dropped={int(calib_stats['dropped'])} "
      f"heavy_routed={int(calib_stats['heavy'])}")
assert int(calib_stats["dropped"]) == 0  # measured caps: provably no drop
assert int(dense_stats["dropped"]) > 0   # the dense route DID lose tokens

# parity holds wherever the dense route kept the token (check on a
# no-drop config: capacity factor e makes the dense scatter lossless)
ucfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
yd, _ = moe_forward_stats(p, x, ucfg)
uplan, _ = calibrate_moe(p, xf, ucfg)
yc, _ = moe_forward_stats(p, x, apply_plan(ucfg, uplan))
np.testing.assert_allclose(np.asarray(yd), np.asarray(yc), atol=2e-5, rtol=2e-5)

# --- 5. both routes in one byte-true cost ledger ------------------------
led = Ledger()
record_dense_round(led, {k: int(v) for k, v in dense_stats.items()},
                   cfg=cfg, t=256, d=cfg.d_model, note="zipf-hot dense")
record_moe_round(led, {k: int(v) for k, v in calib_stats.items()},
                 plan=plan, d=cfg.d_model, note="zipf-hot calibrated")
print(f"\n{led}")
s = led.summary()
print(f"[ledger] dropped_tuples={s['dropped_tuples']} "
      f"heavy_dests={s['heavy_dests']} payload={s['payload_bytes']}B "
      f"useful={s['useful_bytes']}B")
