"""Sharded, atomic, resumable checkpointing.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, then an atomic
``latest`` pointer file.  Saves can run on a background thread (async);
restore validates the manifest and rebuilds the pytree (optionally
re-sharding onto a new mesh — elastic resume: any world size whose mesh
can host the arrays works, since arrays are saved unsharded-logical)."""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Atomic checkpoint: write to a temp dir, fsync, rename, repoint
    ``latest``.  Returns the checkpoint path."""
    flat = _flatten_with_names(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays),
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):  # overwrite-resume case
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    # atomic latest pointer
    fd, ptmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptmp, os.path.join(ckpt_dir, "latest"))
    return final


def save_async(ckpt_dir: str, step: int, tree, extra=None) -> threading.Thread:
    """Background save: snapshots to host memory synchronously (cheap),
    writes on a thread.  join() the returned thread before exit."""
    host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host, extra))
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[-1])


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like`` (shapes validated).
    ``shardings``: optional matching pytree of NamedSharding for elastic
    placement on the current mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    flat_names = _flatten_with_names(tree_like)
    assert set(flat_names) == set(manifest["keys"]), (
        "checkpoint/tree key mismatch: "
        f"missing={sorted(set(flat_names) - set(manifest['keys']))[:4]} "
        f"extra={sorted(set(manifest['keys']) - set(flat_names))[:4]}"
    )
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (name, like), sh in zip(_flatten_with_names(tree_like).items(), shard_leaves):
        arr = z[name]
        assert list(arr.shape) == list(like.shape), (name, arr.shape, like.shape)
        a = jnp.asarray(arr, dtype=like.dtype)
        if sh is not None:
            a = jax.device_put(a, sh)
        out.append(a)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    return restored, manifest["extra"]
