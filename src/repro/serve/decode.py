"""Batched serving loop: prefill + greedy/temperature decode over the
model-agnostic cache interface (KV caches for attention archs, recurrent
state for SSM/xLSTM, cross-KV for whisper)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, rng, temperature: float) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)


def generate(
    model,
    params,
    prompt: jax.Array,  # (B, S) int32
    *,
    steps: int,
    s_cache: Optional[int] = None,
    temperature: float = 0.0,
    rng=None,
    pos=None,
    return_logits: bool = False,
):
    """Returns (B, steps) generated tokens (greedy if temperature=0).

    ``return_logits``: also return the per-step logits (B, steps, V) —
    the handle serving-route parity tests compare (token ids alone can
    mask near-tie divergence between dispatch implementations)."""
    b, s = prompt.shape
    s_cache = s_cache or (s + steps + 1)
    batch = {"tokens": prompt}
    if pos is not None:
        batch["pos"] = pos
    logits, caches = model.prefill(params, batch, s_cache=s_cache)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    step_fn = jax.jit(model.decode_step)
    toks = []
    lgts = [logits]
    tok = sample(logits, rng, temperature)
    toks.append(tok)
    for i in range(steps - 1):
        rng, k = jax.random.split(rng)
        logits, caches = step_fn(params, caches, tok)
        lgts.append(logits)
        tok = sample(logits, k, temperature)
        toks.append(tok)
    out = jnp.stack(toks, axis=1)
    if return_logits:
        return out, jnp.stack(lgts, axis=1)
    return out


def generate_whisper(
    model, params, frames: jax.Array, *, steps: int, dec_cache: int = 64,
    temperature: float = 0.0, rng=None,
) -> jax.Array:
    logits, caches = model.prefill(params, {"frames": frames}, s_cache=dec_cache)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    step_fn = jax.jit(model.decode_step)
    tok = sample(logits, rng, temperature)
    toks = [tok]
    for _ in range(steps - 1):
        rng, k = jax.random.split(rng)
        logits, caches = step_fn(params, caches, tok)
        tok = sample(logits, k, temperature)
        toks.append(tok)
    return jnp.stack(toks, axis=1)
