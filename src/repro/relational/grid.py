"""Paper-faithful grid operators: Lemma 8 (one-round grid multiway join),
Lemma 10 (O(1)-round grid semijoin), Lemma 9 (log-round tree dedup).

These are the *skew-proof* primitives: groups are formed by POSITION (each
group has size <= ceil(count/g)), never by key hash, so the per-reducer
input bound holds under any skew — at the price of the paper's
B(X, M) = X^2/M communication.  The hash-based operators in ``ops.py`` are
the beyond-paper optimized path (comm ~ |R|+|S|, skew-sensitive with
overflow-retry).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .localops import compact, local_dedup_mask, local_join, local_project, local_semijoin_mask
from .ops import agg_stats, _stats
from .shuffle import exchange, exchange_counts, exchange_multi, padded_slots, pow2
from .spmd import AXIS, SPMD
from .table import DTable, schema_join
from .wire import count_wire_bytes, dense_wire_bytes, packed_wire_bytes


def _position_groups(valid: jax.Array, g: int, cap: int, p: int) -> jax.Array:
    """Group id in [0,g) for each row by *global position* (shard-major).

    Positions are globally contiguous: shard s, local slot k -> s*cap + k,
    then group = pos * g // (p*cap).  Every group gets an equal slice of the
    global slot space — size bounds hold regardless of key values (the
    paper's 'disjoint groups of size M/w').
    """
    s = jax.lax.axis_index(AXIS)
    n = valid.shape[0]
    pos = s * cap + jnp.arange(n)
    per = -(-(p * cap) // g)  # ceil: slots per group (hard receive bound)
    grp = pos // per
    return jnp.where(valid, grp.astype(jnp.int32), g)


def _grid_shares(sizes: Sequence[int], p: int) -> List[int]:
    """Choose per-relation group counts g_i with prod(g_i) <= p, g_i >= 1,
    proportional to relation sizes (larger relation -> more groups, the
    paper's g_i = w|R_i|/M with M implied by p)."""
    w = len(sizes)
    if w == 1:
        return [min(p, 1) or 1]
    logs = [math.log(max(2, s)) for s in sizes]
    tot = sum(logs)
    raw = [max(1.0, p ** (l / tot)) for l in logs]
    g = [max(1, int(x)) for x in raw]
    # fix overflow from rounding
    while math.prod(g) > p:
        i = max(range(w), key=lambda i: g[i])
        g[i] -= 1
    # greedily grow while it fits
    grew = True
    while grew:
        grew = False
        for i in sorted(range(w), key=lambda i: -sizes[i]):
            g2 = list(g)
            g2[i] += 1
            if math.prod(g2) <= p:
                g = g2
                grew = True
    return g


def _grid_geometry(
    sizes: Sequence[int], p: int
) -> Tuple[List[int], List[int], List[Tuple[int, ...]]]:
    """Shared geometry of one grid join: per-relation group counts,
    reducer-index strides, and each relation's replication offsets over
    the other dimensions.  Deterministic in (sizes, p), so the count
    pre-pass and the payload always agree on the grid."""
    w = len(sizes)
    g = _grid_shares(sizes, p)
    strides = [1] * w
    acc = 1
    for i in range(w - 1, -1, -1):
        strides[i] = acc
        acc *= g[i]
    all_offs: List[Tuple[int, ...]] = []
    for i in range(w):
        offs: List[int] = []
        other = [j for j in range(w) if j != i]

        def rec(k: int, base: int):
            if k == len(other):
                offs.append(base)
                return
            j = other[k]
            for c in range(g[j]):
                rec(k + 1, base + c * strides[j])

        rec(0, 0)
        all_offs.append(tuple(offs))
    return g, strides, all_offs


def grid_multiway_count(
    spmd: SPMD, table_groups: List[List[DTable]]
) -> Tuple[List[List[Tuple[int, int]]], List[int], List[int]]:
    """ONE combined count dispatch for the position-group sends of
    SEVERAL multiway joins (one per GHD vertex at materialization) —
    the cross-vertex fused form of ``grid_multiway_join``'s internal
    pre-pass, so a query with many multi-atom bags still pays a single
    measure dispatch for the whole materialization stage.

    Returns (cals, count_pads, count_bytes): per group, the (c_out,
    cap_recv) pow2 pair for each relation (feed to
    ``grid_multiway_join(cals=...)``), the count wire cells to charge
    ((p,)-ints per relation), and their byte-true sibling."""
    entries: List[Tuple[int, int, Tuple[int, ...], int]] = []
    valids = []
    slices: List[Tuple[int, int]] = []
    for tables in table_groups:
        sizes = [t.cap * t.p for t in tables]
        g, strides, all_offs = _grid_geometry(sizes, spmd.p)
        start = len(entries)
        for i, t in enumerate(tables):
            entries.append((g[i], strides[i], all_offs[i], t.cap))
            valids.append(t.valid)
        slices.append((start, len(entries)))
    oc, rt = spmd.run(
        _grid_send_count_round,
        *valids,
        entries=tuple(entries),
        p=spmd.p,
        measure=True,
    )
    oc, rt = jax.device_get((oc, rt))  # (shards, n, p), (shards, n)
    cals = [
        [
            (
                pow2(max(1, int(oc[:, i].max()))),
                pow2(max(1, int(rt[:, i].max()))),
            )
            for i in range(a, b)
        ]
        for a, b in slices
    ]
    pads = [(b - a) * spmd.p * spmd.p for a, b in slices]
    byts = [count_wire_bytes(spmd.p, b - a) for a, b in slices]
    return cals, pads, byts


def grid_multiway_join(
    spmd: SPMD,
    tables: List[DTable],
    *,
    out_cap: int,
    c_out: Optional[int] = None,
    cap_recv: Optional[int] = None,
    sizes: Optional[Sequence[int]] = None,
    calibrate: bool = False,
    cals: Optional[List[Tuple[int, int]]] = None,
    fmts: Optional[List] = None,
    backend: str = "jnp",
) -> Tuple[DTable, Dict]:
    """Lemma 8: join w relations in ONE round on a grid of prod(g_i) <= p
    reducers; every reducer receives one position-group per relation.

    Skew-proof: group membership is positional.  Communication =
    sum_i |R_i| * prod_{j != i} g_j  (+ output), the paper's
    O((sum |R_i|)^w / M^{w-1} + OUT).

    ``calibrate=True``: a count-only pre-pass per relation replaces the
    worst-case send capacity (full shard cap replicated to every other
    grid dim) with the tight pow2 occupancy of the position groups.
    ``cals`` supplies those (c_out, cap_recv) pairs pre-measured by
    ``grid_multiway_count`` (which fuses SEVERAL multijoins' pre-passes
    into one dispatch) — the caller then owns the count-pad accounting.
    """
    w = len(tables)
    assert w >= 1
    p = spmd.p
    if w == 1:
        return tables[0], {
            "sent": 0, "dropped": 0, "padded": 0, "wire_bytes": 0, "ubytes": 0,
        }
    sizes = list(sizes) if sizes is not None else [t.cap * t.p for t in tables]
    g, strides, all_offs = _grid_geometry(sizes, p)
    acc = math.prod(g)

    count_pad = 0
    count_b = 0
    if cals is None and calibrate and c_out is None and cap_recv is None:
        # ONE combined count dispatch for every relation's position-group
        # send (and one host sync), instead of one per relation
        oc, rt = spmd.run(
            _grid_send_count_round,
            *[t.valid for t in tables],
            entries=tuple(
                (g[i], strides[i], all_offs[i], tables[i].cap)
                for i in range(w)
            ),
            p=p,
            measure=True,
        )
        oc, rt = jax.device_get((oc, rt))  # (shards, w, p), (shards, w)
        cals = [
            (
                pow2(max(1, int(oc[:, i].max()))),
                pow2(max(1, int(rt[:, i].max()))),
            )
            for i in range(w)
        ]
        count_pad = p * p  # one (p,)-int count vector per relation
        count_b = count_wire_bytes(p, 1)

    parts: List[DTable] = []
    stats_total = {
        "sent": 0, "dropped": 0, "padded": 0, "wire_bytes": 0, "ubytes": 0,
    }
    for i, t in enumerate(tables):
        n_other = acc // g[i]
        if cals is not None:
            co, cr = cals[i]
        else:
            co = c_out if c_out is not None else t.cap * n_other
            cr = cap_recv if cap_recv is not None else -(-(t.p * t.cap) // g[i])
        fmt = fmts[i] if fmts is not None else None
        rd, rv, stats = spmd.run(
            _grid_send_one,
            t.data,
            t.valid,
            g_self=g[i],
            stride=strides[i],
            offsets=all_offs[i],
            p=p,
            cap=t.cap,
            c_out=co,
            cap_recv=cr,
            fmt=fmt,
        )
        parts.append(DTable(rd, rv, t.schema))
        xb = (
            packed_wire_bytes(p, co, fmt)
            if fmt is not None
            else dense_wire_bytes(p, co, t.arity)
        )
        s = agg_stats(
            stats,
            padded_slots(p, co, t.arity) + count_pad,
            wire_bytes=xb + count_b,
        )
        stats_total["sent"] += s["sent"]
        stats_total["dropped"] += s["dropped"]
        stats_total["padded"] += s["padded"]
        stats_total["wire_bytes"] += s["wire_bytes"]
        stats_total["ubytes"] += s["ubytes"]

    # local multiway join at each grid cell (one reduce stage, no comm)
    from .ops import local_multiway_join

    out_caps = [out_cap] * (w - 1)
    joined, jstats = local_multiway_join(spmd, parts, out_caps, backend)
    stats_total["dropped"] += jstats["dropped"]
    return joined, stats_total


def _grid_send_count_one(valid, *, g_self, stride, offsets, p, cap):
    """Count-only pre-pass of one position-group send (``_grid_send_one``
    minus the payload): same dests, a (p,)-int ``all_to_all``."""
    grp = _position_groups(valid, g_self, cap, p)
    offs = jnp.asarray(offsets, jnp.int32)
    dests = jnp.where(
        (grp < g_self)[:, None], grp[:, None] * stride + offs[None, :], p
    ).astype(jnp.int32)
    return exchange_counts(dests, p)


def _grid_send_count_round(*valids, entries, p):
    """Every relation's position-group send counted in ONE program (the
    fused form of n ``_grid_send_count_one`` dispatches — n relations of
    one multijoin, or of several when ``grid_multiway_count`` batches a
    whole materialization stage).  ``entries`` is a static tuple of
    (g_self, stride, offsets, cap) per relation; returns stacked
    ((n, p) out_counts, (n,) recv totals) per shard."""
    outs, recvs = [], []
    for v, (g_self, stride, offsets, cap) in zip(valids, entries):
        o, r = _grid_send_count_one(
            v, g_self=g_self, stride=stride, offsets=offsets, p=p, cap=cap
        )
        outs.append(o)
        recvs.append(r)
    return jnp.stack(outs), jnp.stack(recvs)


def _grid_send_one(
    data, valid, *, g_self, stride, offsets, p, cap, c_out, cap_recv, fmt=None
):
    grp = _position_groups(valid, g_self, cap, p)
    offs = jnp.asarray(offsets, jnp.int32)
    dests = jnp.where(
        (grp < g_self)[:, None], grp[:, None] * stride + offs[None, :], p
    ).astype(jnp.int32)
    rd, rv, sent, ds, dr = exchange_multi(
        data, valid, dests, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt
    )
    return rd, rv, _stats(sent, ds + dr, ubytes=4 * data.shape[1] * sent)


def grid_join(
    spmd: SPMD, a: DTable, b: DTable, *, out_cap: int, **kw
) -> Tuple[DTable, Dict]:
    """Lemma 8 with w=2."""
    return grid_multiway_join(spmd, [a, b], out_cap=out_cap, **kw)


# ----------------------------------------------------------------- Lemma 10
def _grid_semijoin_mark(
    s_data, s_valid, r_data, r_valid, *,
    s_key, r_key, g_s, g_r, s_cap, r_cap, p, c_out_s, c_out_r, cap_s, cap_r,
    backend,
):
    """Round 1 of Lemma 10: grid (g_s x g_r); reducer (i,j) holds S group i
    and R-projection group j; emits S rows matched by its R block (an S row
    appears in g_r reducers -> up to g_r 'duplicates', all kept here)."""
    grp_s = _position_groups(s_valid, g_s, s_cap, p)
    offs_s = jnp.arange(g_r, dtype=jnp.int32)
    dest_s = jnp.where(
        (grp_s < g_s)[:, None], grp_s[:, None] * g_r + offs_s[None, :], p
    ).astype(jnp.int32)
    s2, s2v, sent_s, dss, drs = exchange_multi(
        s_data, s_valid, dest_s, p=p, c_out=c_out_s, cap_recv=cap_s
    )
    rk, rkv = local_project(r_data, r_valid, r_key, dedup=True)
    grp_r = _position_groups(rkv, g_r, r_cap, p)
    offs_r = jnp.arange(g_s, dtype=jnp.int32) * g_r
    dest_r = jnp.where(
        (grp_r < g_r)[:, None], grp_r[:, None] + offs_r[None, :], p
    ).astype(jnp.int32)
    r2, r2v, sent_r, dsr, drr = exchange_multi(
        rk, rkv, dest_r, p=p, c_out=c_out_r, cap_recv=cap_r
    )
    kcols = tuple(range(len(r_key)))
    mask = local_semijoin_mask(s2, s2v, s_key, r2, r2v, kcols, backend)
    s2 = jnp.where(mask[:, None], s2, 0)
    ub = 4 * (s_data.shape[1] * sent_s + rk.shape[1] * sent_r)
    return s2, mask, _stats(sent_s + sent_r, dss + drs + dsr + drr, ubytes=ub)


def grid_semijoin(
    spmd: SPMD,
    s: DTable,
    r: DTable,
    *,
    out_cap: Optional[int] = None,
    seed: int = 0,
    backend: str = "jnp",
) -> Tuple[DTable, Dict, int]:
    """Lemma 10: S |>< R in O(1) rounds, skew-proof grid + hash dedup of the
    <= g_r marked duplicates.  Returns (table, stats, engine_rounds)."""
    shared = [x for x in s.schema if x in r.schema]
    assert shared
    p = spmd.p
    sz_s = s.cap * s.p
    sz_r = r.cap * r.p
    g_s, g_r = _grid_shares([sz_s, sz_r], p)
    out_cap = out_cap or s.cap
    cap_s = -(-sz_s // g_s)
    cap_r = -(-sz_r // g_r)
    md, mv, stats = spmd.run(
        _grid_semijoin_mark,
        s.data, s.valid, r.data, r.valid,
        s_key=s.cols(shared), r_key=r.cols(shared),
        g_s=g_s, g_r=g_r, s_cap=s.cap, r_cap=r.cap, p=p,
        c_out_s=s.cap * g_r, c_out_r=r.cap * g_s,
        cap_s=cap_s, cap_r=cap_r, backend=backend,
    )
    marked = DTable(md, mv, s.schema)
    st = agg_stats(
        stats,
        padded_slots(p, s.cap * g_r, s.arity)
        + padded_slots(p, r.cap * g_s, len(shared)),
        wire_bytes=dense_wire_bytes(p, s.cap * g_r, s.arity)
        + dense_wire_bytes(p, r.cap * g_s, len(shared)),
    )
    # Round 2: dedup the marked copies (<= g_r per tuple) by full-row hash.
    from .ops import dist_dedup

    ded, dstats = dist_dedup(
        spmd, marked, seed=seed + 7, c_out=marked.cap, cap_recv=out_cap,
        backend=backend,
    )
    st2 = {
        "sent": st["sent"] + dstats["sent"],
        "dropped": st["dropped"] + dstats["dropped"],
        "padded": st["padded"] + dstats["padded"],
        "wire_bytes": st["wire_bytes"] + dstats["wire_bytes"],
        "ubytes": st["ubytes"] + dstats["ubytes"],
    }
    return ded, st2, 2


# ------------------------------------------------------------------ Lemma 9
def _tree_dedup_shard(data, valid, seed, *, cols, block, p, c_out, cap_recv):
    s = jax.lax.axis_index(AXIS)
    from .hashing import hash_columns

    h = hash_columns(data, cols, seed)
    base = (s // block) * block
    dest = base + (h % jnp.uint32(block)).astype(jnp.int32)
    dest = jnp.where(valid, dest, p)
    rd, rv, sent, ds, dr = exchange(data, valid, dest, p=p, c_out=c_out, cap_recv=cap_recv)
    mask = local_dedup_mask(rd, rv, cols)
    rd = jnp.where(mask[:, None], rd, 0)
    return rd, mask, _stats(sent, ds + dr, ubytes=4 * data.shape[1] * sent)


def tree_dedup(
    spmd: SPMD,
    t: DTable,
    *,
    fan: int = 4,
    seed: int = 0,
    cap_recv: Optional[int] = None,
) -> Tuple[DTable, Dict, int]:
    """Lemma 9: duplicate elimination in O(log_fan(p)) rounds.

    Round i merges blocks of fan^(i+1) shards: within each block, rows
    shuffle to the shard selected by hash — per-round fan-in is bounded by
    ``fan`` predecessor groups (the paper's sqrt(M)-reducer merge tree), so
    no reducer's receive volume grows with the global duplicate count k.
    Returns (table, stats, rounds)."""
    p = spmd.p
    cols = tuple(range(len(t.schema)))
    cap_recv = cap_recv or t.cap * fan
    cur = t
    total = {"sent": 0, "dropped": 0, "padded": 0, "wire_bytes": 0, "ubytes": 0}
    rounds = 0
    block = fan
    while True:
        block_eff = min(block, p)
        co = cur.cap
        d, v, stats = spmd.run(
            _tree_dedup_shard,
            cur.data, cur.valid, spmd.seeds(seed + rounds),
            cols=cols, block=block_eff, p=p,
            c_out=co, cap_recv=cap_recv,
        )
        cur = DTable(d, v, t.schema)
        s = agg_stats(
            stats,
            padded_slots(p, co, t.arity),
            wire_bytes=dense_wire_bytes(p, co, t.arity),
        )
        total["sent"] += s["sent"]
        total["dropped"] += s["dropped"]
        total["padded"] += s["padded"]
        total["wire_bytes"] += s["wire_bytes"]
        total["ubytes"] += s["ubytes"]
        rounds += 1
        if block_eff >= p:
            break
        block *= fan
    return cur, total, rounds
