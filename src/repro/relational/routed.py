"""The routed all-to-all exchange primitive — the workload-agnostic core of
the calibrated, skew-resilient, packed-wire shuffle.

PRs 4-7 grew the exchange stack (count pre-pass -> pow2 capacities,
heavy-hitter split/broadcast routing, bit-packed wire codec, split-phase
start/ship/finish for fused groups) inside the join-specific modules.
This module carves it out: anything that is "rows with destinations over
the named reducer axis" can route through here.  Two customers today:

- **joins** — ``shuffle.exchange`` / ``exchange_multi`` and the
  hash/grid/hybrid engines in ``core.physical`` are thin consumers
  (bit-identical rows/comm/retries to the pre-extraction paths);
- **MoE expert dispatch** — ``models.moe_routing`` routes (token, choice)
  pairs to expert shards: tokens are tuples, experts are destinations,
  hot experts are heavy hitters, and capacity factors are measured
  ``SideCaps`` (ROADMAP open item 2).

The primitive is dtype-generic: ``_bucketize``'s single-sort scatter and
``localops.compact`` never inspect row contents, so int32 relational
tuples and float32 token activations ride the same code.

``routed_all_to_all(data, valid, dests, ...)`` dispatches on the shape
of ``dests``: ``(n,)`` is a single-destination send (optionally with
heavy-hitter round-robin spreading via ``heavy=``), ``(n, g)`` is a
replicated send (grid offsets / hypercube wildcards / heavy broadcast).
Overflow anywhere is reported, never silently dropped — callers either
abort-retry with doubled capacities (the join engine) or surface the
exact dropped count in their stats (the MoE customer).
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .localops import compact
from .skew import DEFAULT_SKEW_THRESHOLD, heavy_dest_flags, heavy_dest_flags_many, split_dests
from .spmd import AXIS
from .wire import (
    WireFormat,
    WirePolicy,
    get_codec,
    pack_segments,
    split_segments,
    wire_decode,
    wire_encode,
)


def pow2(x: int) -> int:
    """Round capacities up to powers of two (min 4): distinct shapes
    collapse, so the per-op jit cache is reused across nodes, rounds,
    retries, and calibrated occupancies — and uniform shapes are what make
    op groups batchable at all."""
    return 1 << max(2, int(x - 1).bit_length())


def padded_slots(p: int, c_out: int, arity: int = 1) -> int:
    """int32 cells a fleet-wide exchange ships for one ``all_to_all``:
    each of the ``p`` shards sends the dense ``(p, c_out, arity)`` bucket
    buffer whether the buckets are full or empty.  Counting CELLS (slot
    rows x row width) rather than rows keeps keys-only exchanges (the
    semijoin R projection, the join measure pre-pass) honestly cheaper
    than full-payload ones.  This is the denominator of the ledger's
    payload-efficiency metric."""
    return p * p * c_out * max(1, arity)


def _bucketize(
    data: jax.Array, valid_dest: jax.Array, p: int, c_out: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Scatter rows into per-destination buckets.

    ``valid_dest``: (n,) int32 in [0,p) for live rows, == p for dead rows.
    Returns (buf (p,c_out,ar), buf_valid (p,c_out), sent, dropped).

    One sort total: rows are argsorted by destination, each sorted slot's
    in-bucket position is its distance to the last bucket boundary (a
    cummax of boundary indices), and the positions are scattered back to
    original row order — so the full-width row data is scattered into
    ``buf`` directly, with no second search over the sorted copy and no
    (n, ar) gather of a sorted row array."""
    n, ar = data.shape
    order = jnp.argsort(valid_dest, stable=True)
    sdest = valid_dest[order]
    idx = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sdest[1:] != sdest[:-1]]
    )
    bucket_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_sorted = idx - bucket_start
    # rank of original row ``order[i]`` within its bucket is pos_sorted[i]
    pos = jnp.zeros((n,), pos_sorted.dtype).at[order].set(pos_sorted)
    live = valid_dest < p
    ok = live & (pos < c_out)
    d_idx = jnp.where(ok, valid_dest, p)  # p == out-of-bounds -> dropped
    pos_c = jnp.clip(pos, 0, c_out - 1)
    buf = jnp.zeros((p, c_out, ar), data.dtype).at[d_idx, pos_c].set(
        data, mode="drop"
    )
    buf_valid = jnp.zeros((p, c_out), bool).at[d_idx, pos_c].set(ok, mode="drop")
    sent = ok.sum()
    dropped = (live & ~ok).sum()
    return buf, buf_valid, sent, dropped


def _multi_flatten(
    data: jax.Array, valid: jax.Array, dests: jax.Array, p: int
) -> Tuple[jax.Array, jax.Array]:
    """The map-side row tiling of a replicated send: dedupe each row's
    destination list to the skip slot, then flatten to one (n*g,) send.

    Duplicate destinations WITHIN a row's ``dests`` are deduplicated so a
    row reaches each reducer at most once — replicated sends can never
    double-count ``sent`` or double-deliver a tuple (which a local join
    would then double-join)."""
    g = dests.shape[1]
    if g > 1:
        eq = dests[:, :, None] == dests[:, None, :]  # (n, g, g)
        earlier = jnp.tril(jnp.ones((g, g), bool), -1)  # [j, k]: k < j
        dup = (eq & earlier[None]).any(-1)
        dests = jnp.where(dup, p, dests)
    tiled_rows = jnp.repeat(data, g, axis=0)  # (n*g, ar)
    flat_dest = jnp.where(jnp.repeat(valid, g, axis=0), dests.reshape(-1), p)
    return tiled_rows, flat_dest


def _wire_ship(
    buf: jax.Array, buf_valid: jax.Array, fmt: WireFormat, c_out: int
) -> Tuple[jax.Array, jax.Array]:
    """Packed collective: encode the dense buckets + valid plane into one
    bit-packed uint8 buffer, run ONE ``all_to_all`` (instead of the dense
    path's data + valid pair), decode back.  The optional codec hook
    wraps the bytes around the collective."""
    wire = wire_encode(buf, buf_valid, fmt)
    enc, dec = get_codec(fmt.codec)
    payload, aux = enc(wire)
    rpayload = jax.lax.all_to_all(
        payload, AXIS, split_axis=0, concat_axis=0, tiled=False
    )
    return wire_decode(dec(rpayload, aux), fmt, c_out)


def _ship(
    buf: jax.Array, buf_valid: jax.Array, fmt: Optional[WireFormat], c_out: int
) -> Tuple[jax.Array, jax.Array]:
    """The collective of one exchange: dense data + valid pair (two
    ``all_to_all``s) or one packed uint8 buffer."""
    if fmt is None:
        rbuf = jax.lax.all_to_all(buf, AXIS, split_axis=0, concat_axis=0, tiled=False)
        rvalid = jax.lax.all_to_all(
            buf_valid, AXIS, split_axis=0, concat_axis=0, tiled=False
        )
        return rbuf, rvalid
    return _wire_ship(buf, buf_valid, fmt, c_out)


# ------------------------------------------------------ count-only pre-pass
def bucket_counts(dest: jax.Array, p: int) -> jax.Array:
    """Per-destination outgoing bucket counts: (n,) or (n, g) destinations
    (== p for dead/skip slots) -> (p,) int32 counts.  The map-side half of
    the calibration pre-pass; costs one segment-add, no sort."""
    flat = dest.reshape(-1)
    live = (flat >= 0) & (flat < p)
    return (
        jnp.zeros((p,), jnp.int32)
        .at[jnp.clip(flat, 0, p - 1)]
        .add(live.astype(jnp.int32), mode="drop")
    )


def route_counts(dest: jax.Array, p: int) -> Tuple[jax.Array, jax.Array]:
    """The count-only pre-pass of a routed exchange: ship per-destination
    bucket COUNTS (a (p,)-int ``all_to_all``) instead of the payload.

    Returns ``(out_counts (p,), recv_total ())``:

    - ``max(out_counts)`` over all shards is the tight send-bucket
      capacity ``c_out`` (the payload exchange's per-destination buffer);
    - ``max(recv_total)`` over all shards is the tight receive capacity
      ``cap_recv`` (the post-``all_to_all`` compact size).

    Same collective pattern as the payload exchange (split/concat axis 0
    over the named reducer axis), so it is batchable under the same inner
    vmap as the operator bodies."""
    out = bucket_counts(dest, p)
    recv = jax.lax.all_to_all(out, AXIS, split_axis=0, concat_axis=0, tiled=False)
    return out, recv.sum()


# --------------------------------------------------------------- primitive
class RoutedResult(NamedTuple):
    """One routed exchange's received rows + byte-true-auditable stats."""

    data: jax.Array        # (cap_recv, ar) received rows, compacted
    valid: jax.Array       # (cap_recv,) bool
    sent: jax.Array        # rows that made it into a send bucket
    dropped_send: jax.Array  # rows lost to a full send bucket (c_out)
    dropped_recv: jax.Array  # rows lost to a full receive buffer (cap_recv)
    heavy_sent: jax.Array  # rows routed via the heavy-hitter spread


def routed_all_to_all(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,
    *,
    p: int,
    c_out: int,
    cap_recv: int,
    fmt: Optional[WireFormat] = None,
    heavy: Optional[jax.Array] = None,
) -> RoutedResult:
    """Route rows to destination shards over the named reducer axis.

    ``dests`` (n,) int32 in [0,p): single-destination send (the hash
    exchange / MoE token dispatch).  ``dests`` (n, g): replicated send —
    each row goes to up to g destinations (grid offsets, hypercube
    wildcards, heavy broadcast); in-row duplicates are deduplicated.

    ``heavy`` (p,) bool (single-dest only): destinations flagged heavy by
    the count pre-pass have their rows spread round-robin over all p
    shards (``skew.split_dests`` — Lemma 8's position-partitioned side,
    restricted to the heavy keys).  The consumer owns putting the
    matching state everywhere (joins broadcast the other operand; MoE
    closes over the replicated expert weights).

    ``fmt=None`` ships the dense buckets + bool valid plane (two
    collectives); a ``WireFormat`` ships one bit-packed uint8 buffer.
    Rows out are bit-identical either way.
    """
    if dests.ndim == 2:
        assert heavy is None, "heavy spreading applies to single-dest routes"
        rows, flat_dest = _multi_flatten(data, valid, dests, p)
        heavy_sent = jnp.int32(0)
    else:
        rows = data
        flat_dest = jnp.where(valid, dests, p)
        if heavy is None:
            heavy_sent = jnp.int32(0)
        else:
            flat_dest, is_heavy = split_dests(flat_dest, heavy, p)
            heavy_sent = (is_heavy & valid).sum()
    buf, buf_valid, sent, dropped_send = _bucketize(rows, flat_dest, p, c_out)
    rbuf, rvalid = _ship(buf, buf_valid, fmt, c_out)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    rdata, rv, dropped_recv = compact(flat, flatv, cap_recv)
    return RoutedResult(rdata, rv, sent, dropped_send, dropped_recv, heavy_sent)


# ------------------------------------------- segmented (fused-group) exchange
# An exchange split around its collective: ``routed_start`` buckets +
# encodes one op's send into a (p, nbytes) segment, ``ship_segments`` runs
# ONE ``all_to_all`` over every segment of a fused op group concatenated
# (mixed schemas/arities each keep their own format — arity-aware
# segmentation instead of padding every op to the widest schema), and
# ``routed_finish`` decodes + compacts each op's received segment.
def routed_start(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,
    *,
    p: int,
    c_out: int,
    fmt: WireFormat,
    heavy: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Map stage of a packed routed exchange: returns (wire segment
    (p, nbytes), sent, dropped_send, heavy_sent).  Accepts the same
    (n,) / (n, g) destination shapes as ``routed_all_to_all``."""
    if dests.ndim == 2:
        assert heavy is None, "heavy spreading applies to single-dest routes"
        rows, flat_dest = _multi_flatten(data, valid, dests, p)
        heavy_sent = jnp.int32(0)
    else:
        rows = data
        flat_dest = jnp.where(valid, dests, p)
        if heavy is None:
            heavy_sent = jnp.int32(0)
        else:
            flat_dest, is_heavy = split_dests(flat_dest, heavy, p)
            heavy_sent = (is_heavy & valid).sum()
    buf, buf_valid, sent, dropped_send = _bucketize(rows, flat_dest, p, c_out)
    return wire_encode(buf, buf_valid, fmt), sent, dropped_send, heavy_sent


def ship_segments(wires: Sequence[jax.Array]) -> List[jax.Array]:
    """ONE ``all_to_all`` for a whole fused group: concatenate each
    exchange's (p, nbytes_i) segment, ship, split back."""
    seg = pack_segments(wires)
    rseg = jax.lax.all_to_all(seg, AXIS, split_axis=0, concat_axis=0, tiled=False)
    return split_segments(rseg, [w.shape[-1] for w in wires])


def routed_finish(
    rwire: jax.Array, *, p: int, c_out: int, cap_recv: int, fmt: WireFormat
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce stage of a packed routed exchange: decode the received
    segment and compact.  Returns (rdata, rvalid, dropped_recv)."""
    rbuf, rvalid = wire_decode(rwire, fmt, c_out)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    return compact(flat, flatv, cap_recv)


# ----------------------------------------------------------------- policy
@dataclasses.dataclass(frozen=True)
class RoutePolicy:
    """Per-consumer routing configuration: the wire encoding and the
    heavy-hitter sensitivity.  One instance is shared by every exchange
    of a query (join engines) or a model (MoE dispatch), so format
    soundness and skew decisions are consistent across rounds.

    ``wire_policy``: column-range-derived packed formats (None = dense
    exchanges).  ``skew_threshold``: a destination is heavy when its
    measured arrival exceeds this multiple of the balanced share."""

    wire_policy: Optional[WirePolicy] = None
    skew_threshold: float = DEFAULT_SKEW_THRESHOLD

    # -- packed wire formats ------------------------------------------------
    def fmt_for(self, schemas: Sequence[Sequence[str]]) -> Optional[WireFormat]:
        """Group-uniform packed format of one exchange side: the widest-
        per-column union over the group's instances (wider is sound)."""
        if self.wire_policy is None:
            return None
        return WireFormat.union(
            [self.wire_policy.format_for(s) for s in schemas]
        )

    def pair_fmts(
        self,
        lhs_schemas: Sequence[Sequence[str]],
        rhs_schemas: Sequence[Sequence[str]],
        xcaps,
        rhs_keys_only: bool = False,
    ):
        """Formats of a two-sided exchange group, recorded per-exchange
        in the measurement's ``SideCaps``.  ``rhs_keys_only``: the rhs
        ships its deduplicated shared-key projection (semijoins), so its
        format covers the key columns only.  Returns (fmts, xcaps)."""
        if self.wire_policy is None:
            return None, xcaps
        fmt_l = self.fmt_for(lhs_schemas)
        if rhs_keys_only:
            rschemas = [
                tuple(x for x in l if x in set(r))
                for l, r in zip(lhs_schemas, rhs_schemas)
            ]
        else:
            rschemas = list(rhs_schemas)
        fmt_r = self.fmt_for(rschemas)
        if xcaps is not None:
            xcaps = dataclasses.replace(
                xcaps,
                lhs=dataclasses.replace(xcaps.lhs, fmt=fmt_l),
                rhs=None
                if xcaps.rhs is None
                else dataclasses.replace(xcaps.rhs, fmt=fmt_r),
            )
        return (fmt_l, fmt_r), xcaps

    def single_fmt(self, schemas: Sequence[Sequence[str]], xcaps):
        """Format of a one-sided exchange group (dedup), recorded in the
        measurement's ``SideCaps``.  Returns (fmt, xcaps)."""
        if self.wire_policy is None:
            return None, xcaps
        fmt = self.fmt_for(schemas)
        if xcaps is not None:
            xcaps = dataclasses.replace(
                xcaps, lhs=dataclasses.replace(xcaps.lhs, fmt=fmt)
            )
        return fmt, xcaps

    # -- heavy-hitter detection ---------------------------------------------
    def heavy_flags(self, out_counts, p: int):
        """(shards, p) send-count matrix -> (p,) heavy-destination flags
        at this policy's threshold."""
        return heavy_dest_flags(out_counts, p, self.skew_threshold)

    def heavy_flags_many(self, out_counts, p: int):
        """(shards, k, p) group send counts -> (k, p) flags."""
        return heavy_dest_flags_many(out_counts, p, self.skew_threshold)
