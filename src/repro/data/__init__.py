from .pipeline import CorpusConfig, batches, corpus_query, eligible_docs, synth_corpus
