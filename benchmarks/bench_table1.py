"""Table 1: widths, min-depth GHDs, and intersection widths of S_n, C_n,
TC_n — computed from our GHD machinery, checked against the paper."""
from __future__ import annotations

from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)


def run() -> list:
    rows = []
    n = 12
    # S_n: width 1, min-depth 1, iw 1
    q = star_query(n)
    g = star_ghd(n)
    rows.append(("S_n", g.width, g.depth, g.intersection_width(q), (1, 1, 1)))
    # C_n: width 1, depth n-1 (Theta(n)), iw 1
    q = chain_query(n)
    g = chain_ghd(n)
    rows.append(("C_n", g.width, g.depth, g.intersection_width(q), (1, n - 1, 1)))
    # TC_n: width 2, depth n/3-1 (Theta(n)), iw 1
    t = n // 3
    q = triangle_chain_query(t)
    g = triangle_chain_ghd(t)
    rows.append(("TC_n", g.width, g.depth, g.intersection_width(q), (2, t - 1, 1)))

    out = []
    for name, w, d, iw, (ew, ed, eiw) in rows:
        ok = (w == ew) and (d == ed) and (iw == eiw)
        out.append(
            dict(bench="table1", query=name, width=w, depth=d, iw=iw, ok=ok)
        )
        assert ok, (name, w, d, iw)
    return out
