"""Plan advisor sweep over the Table-1 query families: run every manual
(schedule x engine) pick on the hand GHD, run ``GymConfig(plan="auto")``,
and hold the advisor to its contract — the auto pick's measured
communication must never exceed the WORST manual pick's.

Also renders ``explain()``'s predicted-vs-measured table per family (the
``optimizer_explain`` rows) and demonstrates the calibration loop: per-
engine constants fitted on two families strictly reduce prediction error
on the held-out third (``optimizer_calibration`` row).
"""
from __future__ import annotations

from repro.core.costs import fit_calibration, prediction_error
from repro.core.gym import GymConfig, gym
from repro.core.optimizer import (
    MachineProfile,
    choose_plan,
    enumerate_plans,
    explain,
    stats_from_data,
)
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse

P = 8
SEED = 33
SCHEDULES = ("dym_d", "dym_n")
ENGINES = ("hash", "grid")


def _families():
    return [
        ("S_8", star_query(8), star_ghd(8), star_data_sparse(8, seed=21)),
        ("C_8", chain_query(8), chain_ghd(8), chain_data_sparse(8, seed=11)),
        ("TC_9", triangle_chain_query(3), triangle_chain_ghd(3),
         tc_data_sparse(3, seed=22)),
    ]


def run() -> list:
    out = []
    profile = MachineProfile(p=P)
    records = {}  # family -> list of calibration records (manual runs)
    per_family = {}
    for name, q, g, data in _families():
        stats = stats_from_data(q, data)
        plans = {
            pl.key: pl
            for pl in enumerate_plans(q, stats, profile=profile, hand_ghd=g)
        }
        measured = {}
        recs = []
        for sched in SCHEDULES:
            for eng in ENGINES:
                cfg = GymConfig(strategy=eng, schedule=sched, seed=SEED)
                _, _, led = gym(q, data, ghd=g, p=P, config=cfg)
                key = f"hand|{sched}|{eng}|fused"
                measured[key] = led
                recs.append(
                    led.calibration_record(
                        engine=eng,
                        schedule=sched,
                        query=name,
                        predicted_comm=plans[key].predicted_comm,
                    )
                )
        records[name] = recs
        chosen = choose_plan(q, stats, profile=profile, hand_ghd=g)
        _, _, led_auto = gym(q, data, ghd=g, p=P, config=GymConfig(plan="auto", seed=SEED))
        measured[chosen.key] = led_auto
        per_family[name] = (q, g, stats, chosen, measured)

        manual_comms = {
            k: v.comm_tuples for k, v in measured.items() if k.startswith("hand|")
        }
        worst, best = max(manual_comms.values()), min(manual_comms.values())
        # the advisor's contract (acceptance criterion): never worse than
        # the worst manual (schedule x engine) pick
        assert led_auto.comm_tuples <= worst, (
            name, chosen.key, led_auto.comm_tuples, worst
        )
        out.append(
            dict(
                bench="optimizer",
                query=name,
                plan=chosen.key,
                predicted_comm=round(chosen.predicted_comm, 1),
                auto_comm=led_auto.comm_tuples,
                auto_rounds=led_auto.rounds,
                best_manual=best,
                worst_manual=worst,
                ok=True,
            )
        )

    # predicted-vs-measured tables (markdown), one per family
    for name, (q, g, stats, chosen, measured) in per_family.items():
        md = explain(
            q, stats, hand_ghd=g, profile=profile, measured=measured,
            fmt="markdown",
        )
        out.append(dict(bench="optimizer_explain", query=name, explain=md))

    # calibration loop: fit per-engine constants on S_8 + C_8, evaluate
    # on the held-out TC_9 hand plans
    train = records["S_8"] + records["C_8"]
    cal = fit_calibration(train)
    q, g, stats, _, measured = per_family["TC_9"]
    plans_u = {
        pl.key: pl for pl in enumerate_plans(q, stats, profile=profile, hand_ghd=g)
    }
    plans_c = {
        pl.key: pl
        for pl in enumerate_plans(
            q, stats, profile=profile, hand_ghd=g, calibration=cal
        )
    }
    err_u = err_c = 0.0
    n = 0
    for key, led in measured.items():
        if not key.startswith("hand|"):
            continue
        err_u += prediction_error(plans_u[key].predicted_comm, led.comm_tuples)
        err_c += prediction_error(plans_c[key].predicted_comm, led.comm_tuples)
        n += 1
    err_u, err_c = err_u / n, err_c / n
    assert err_c < err_u, (err_c, err_u)  # calibration must help held-out
    out.append(
        dict(
            bench="optimizer_calibration",
            train="S_8+C_8",
            test="TC_9",
            scale={k: round(v, 3) for k, v in cal.comm_scale.items()},
            err_uncalibrated=round(err_u, 4),
            err_calibrated=round(err_c, 4),
        )
    )
    return out
