"""Per-shard (single-reducer) relational operations, pure jnp.

Everything is exact for arbitrary arities/domains: multi-column keys are
dictionary-encoded with ``dense_ranks`` (concat + lexsort + run ids), never
hashed.  All shapes static; "too many output tuples" surfaces as an
overflow count (the paper's abort), never silent truncation.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .hashing import dense_ranks, self_ranks

_I32MAX = jnp.int32(2**31 - 1)


def compact(data: jax.Array, valid: jax.Array, out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Move valid rows to the front and resize to ``out_cap``.

    Returns (data, valid, dropped_count)."""
    n = data.shape[0]
    order = jnp.argsort(~valid, stable=True)
    d = data[order]
    v = valid[order]
    cnt = valid.sum()
    if out_cap <= n:
        dropped = jnp.maximum(cnt - out_cap, 0)
        return d[:out_cap], v[:out_cap], dropped
    pad_d = jnp.zeros((out_cap - n, data.shape[1]), data.dtype)
    pad_v = jnp.zeros((out_cap - n,), bool)
    return (
        jnp.concatenate([d, pad_d], 0),
        jnp.concatenate([v, pad_v], 0),
        jnp.int32(0),
    )


def local_join(
    a_data: jax.Array, a_valid: jax.Array,
    b_data: jax.Array, b_valid: jax.Array,
    a_key: Sequence[int], b_key: Sequence[int],
    b_keep: Sequence[int],
    out_cap: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Natural join on the given key columns.

    Output rows are ``a_row ++ b_row[b_keep]`` (caller computes the joined
    schema).  Returns (out_data (out_cap, a_ar + len(b_keep)), out_valid,
    overflow_count)."""
    ra, rb = dense_ranks(a_data, a_valid, a_key, b_data, b_valid, b_key)
    return local_join_ranked(a_data, a_valid, ra, b_data, b_valid, rb, b_keep, out_cap)


def local_join_ranked(
    a_data: jax.Array, a_valid: jax.Array, ra: jax.Array,
    b_data: jax.Array, b_valid: jax.Array, rb: jax.Array,
    b_keep,
    out_cap: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Join expansion given precomputed shared key ranks (``dense_ranks``).

    ``b_keep`` may be a static tuple OR a traced int32 array (the batched
    path passes per-instance column indices as data); only its LENGTH must
    be static."""
    na, nb = a_data.shape[0], b_data.shape[0]
    rb_sort_key = jnp.where(b_valid, rb, _I32MAX)
    order_b = jnp.argsort(rb_sort_key)
    rb_sorted = rb_sort_key[order_b]
    lo = jnp.searchsorted(rb_sorted, ra, side="left")
    hi = jnp.searchsorted(rb_sorted, ra, side="right")
    counts = jnp.where(a_valid, hi - lo, 0)
    offsets = jnp.cumsum(counts)
    total = offsets[-1] if na else jnp.int32(0)
    t = jnp.arange(out_cap)
    i = jnp.searchsorted(offsets, t, side="right")
    i_c = jnp.clip(i, 0, na - 1)
    prev = jnp.where(i_c > 0, offsets[i_c - 1], 0)
    within = t - prev
    j_sorted = jnp.clip(lo[i_c] + within, 0, nb - 1)
    j = order_b[j_sorted]
    out_valid = t < total
    left = a_data[i_c]
    right = (
        b_data[j][:, jnp.asarray(b_keep, jnp.int32)]
        if len(b_keep)
        else jnp.zeros((out_cap, 0), a_data.dtype)
    )
    out = jnp.concatenate([left, right], axis=1)
    out = jnp.where(out_valid[:, None], out, 0)
    overflow = jnp.maximum(total - out_cap, 0)
    return out, out_valid, overflow


def local_join_count(
    a_data, a_valid, b_data, b_valid, a_key, b_key
) -> jax.Array:
    """Exact output size of the join (for capacity planning)."""
    ra, rb = dense_ranks(a_data, a_valid, a_key, b_data, b_valid, b_key)
    rb_sort_key = jnp.where(b_valid, rb, _I32MAX)
    rb_sorted = jnp.sort(rb_sort_key)
    lo = jnp.searchsorted(rb_sorted, ra, side="left")
    hi = jnp.searchsorted(rb_sorted, ra, side="right")
    return jnp.where(a_valid, hi - lo, 0).sum()


def local_semijoin_mask(
    s_data: jax.Array, s_valid: jax.Array, s_key: Sequence[int],
    r_data: jax.Array, r_valid: jax.Array, r_key: Sequence[int],
) -> jax.Array:
    """Mask of S rows whose key appears in R (S |>< R)."""
    rs, rr = dense_ranks(s_data, s_valid, s_key, r_data, r_valid, r_key)
    rr_sorted = jnp.sort(jnp.where(r_valid, rr, _I32MAX))
    lo = jnp.searchsorted(rr_sorted, rs, side="left")
    hi = jnp.searchsorted(rr_sorted, rs, side="right")
    return s_valid & (hi > lo)


def local_dedup_mask(data: jax.Array, valid: jax.Array, cols: Sequence[int]) -> jax.Array:
    """Keep-first mask of distinct rows (by ``cols``)."""
    n = data.shape[0]
    ranks = self_ranks(data, valid, cols)
    first = jax.ops.segment_min(
        jnp.where(valid, jnp.arange(n), _I32MAX),
        jnp.clip(ranks, 0, n - 1),
        num_segments=n,
    )
    return valid & (jnp.arange(n) == first[jnp.clip(ranks, 0, n - 1)])


def local_intersect_mask(
    a_data: jax.Array, a_valid: jax.Array,
    b_data: jax.Array, b_valid: jax.Array,
    a_cols: Sequence[int], b_cols: Sequence[int],
) -> jax.Array:
    """Mask of A rows present in B (full-row by aligned columns)."""
    return local_semijoin_mask(a_data, a_valid, a_cols, b_data, b_valid, b_cols)


def local_project(
    data: jax.Array, valid: jax.Array, cols: Sequence[int], dedup: bool
) -> Tuple[jax.Array, jax.Array]:
    out = data[:, jnp.asarray(cols, jnp.int32)] if cols else jnp.zeros((data.shape[0], 0), data.dtype)
    v = valid
    if dedup:
        v = local_dedup_mask(out, valid, tuple(range(len(cols))))
    out = jnp.where(v[:, None], out, 0)
    return out, v
