"""Optimizers as pure pytree functions: AdamW (fp32 or bf16 moments) and
Adafactor (factored second moment — the memory-viable choice for the
trillion-param kimi-k2 cell: O(d+f) state per (d,f) matrix)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments_dtype: str = "float32"  # bf16 halves AdamW state memory
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay (f32 scalar)."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / max(1, cfg.warmup))
    t = jnp.clip((s - cfg.warmup) / max(1, cfg.decay_steps - cfg.warmup), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), tree), g


# ------------------------------------------------------------------ AdamW
def adamw_init(cfg: OptConfig, params) -> Dict:
    dt = jnp.dtype(cfg.moments_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(z, params),
        "v": jax.tree_util.tree_map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * upd
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
    p2 = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m2 = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v2 = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return p2, {"m": m2, "v": v2, "step": step}


# -------------------------------------------------------------- Adafactor
def adafactor_init(cfg: OptConfig, params) -> Dict:
    def rows_cols(p):
        if p.ndim >= 2:
            return {
                "r": jnp.zeros(p.shape[:-1], jnp.float32),
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "f": jax.tree_util.tree_map(rows_cols, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, f, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if p.ndim >= 2:
            r = beta * f["r"] + (1 - beta) * g2.mean(-1)
            c = beta * f["c"] + (1 - beta) * g2.mean(-2)
            denom = r[..., None] * c[..., None, :] / (
                r.mean(-1)[..., None, None] + 1e-30
            )
            u = g32 / (jnp.sqrt(denom) + 1e-30)
            f2 = {"r": r, "c": c}
        else:
            v = beta * f["v"] + (1 - beta) * g2
            u = g32 / (jnp.sqrt(v) + 1e-30)
            f2 = {"v": v}
        # update clipping (Adafactor's d=1.0 RMS rule)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), f2

    # f's tree = params' tree with each leaf replaced by a {r,c}/{v} dict —
    # flatten with those dicts as leaves to re-align the three trees
    gl, treedef = jax.tree_util.tree_flatten(grads)
    pl = jax.tree_util.tree_leaves(params)
    fl = jax.tree_util.tree_leaves(
        state["f"], is_leaf=lambda x: isinstance(x, dict) and ("r" in x or "v" in x)
    )
    out = [upd(g, f, p) for g, f, p in zip(gl, fl, pl)]
    p2 = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    f2 = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return p2, {"f": f2, "step": step}


# ----------------------------------------------------------------- facade
def opt_init(cfg: OptConfig, params):
    return adamw_init(cfg, params) if cfg.kind == "adamw" else adafactor_init(cfg, params)


def opt_update(cfg: OptConfig, grads, state, params):
    if cfg.kind == "adamw":
        return adamw_update(cfg, grads, state, params)
    return adafactor_update(cfg, grads, state, params)
