"""Synthetic relation generators for benchmarks/examples.

``*_sparse`` generators produce matching-database-style inputs (paper
Appendix A): each relation is mostly a partial permutation, so every
pairwise join stays O(|R|) and end-to-end chain outputs are small — the
regime where round counts and communication constants are measurable
without output-size blowup."""
from __future__ import annotations

from typing import Dict

import numpy as np


def chain_data_sparse(
    n: int, *, domain: int = 32, ident: int = 8, extra: int = 12, seed: int = 0
) -> Dict[str, np.ndarray]:
    """C_n relations R_i(A_{i-1}, A_i): identity links on [0, ident) (so
    exactly ``ident`` complete chains survive) + random sparse links."""
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(1, n + 1):
        rows = [(v, v) for v in range(ident)]
        rows += [
            (int(rng.integers(ident, domain)), int(rng.integers(ident, domain)))
            for _ in range(extra)
        ]
        out[f"R{i}"] = np.unique(np.array(rows, np.int32), axis=0)
    return out


def star_data_sparse(
    n: int, *, domain: int = 16, hub_rows: int = 12, spoke_extra: int = 8,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """S_n: hub S(A_1..A_{n-1}) + spokes R_i(A_i, B_i); every hub value is
    matched in each spoke so the output is non-trivial but bounded."""
    rng = np.random.default_rng(seed)
    hub = rng.integers(0, domain // 2, (hub_rows, n - 1)).astype(np.int32)
    out = {"S": np.unique(hub, axis=0)}
    for i in range(1, n):
        vals = np.unique(hub[:, i - 1])
        rows = [(int(v), int(v) % 7) for v in vals]
        rows += [
            (int(rng.integers(domain // 2, domain)), int(rng.integers(0, 7)))
            for _ in range(spoke_extra)
        ]
        out[f"R{i}"] = np.unique(np.array(rows, np.int32), axis=0)
    return out


# ---------------------------------------------------------- skewed families
def zipf_values(
    rng: np.random.Generator, size: int, domain: int, s: float
) -> np.ndarray:
    """``size`` draws from a bounded zipf(s) over [0, domain): value v has
    probability ~ 1/(v+1)^s.  ``s=0`` is uniform; ``s ~ 1.1`` plants a
    rank-1 value carrying a ~1/H_{domain,s} share — the heavy-hitter
    regime the hybrid exchange routes around.  Bounded + deterministic
    (unlike ``Generator.zipf``), so benchmark inputs are reproducible."""
    if s <= 0:
        return rng.integers(0, domain, size).astype(np.int32)
    ranks = np.arange(1, domain + 1, dtype=np.float64)
    probs = ranks ** (-float(s))
    probs /= probs.sum()
    return rng.choice(domain, size=size, p=probs).astype(np.int32)


def star_data_zipf(
    n: int, *, domain: int = 16, hub_rows: int = 12, spoke_extra: int = 8,
    s: float = 1.1, seed: int = 0,
) -> Dict[str, np.ndarray]:
    """S_n with the hub's A_1 column zipf(s)-distributed (other columns
    uniform): at s >~ 1 one A_1 value carries a constant share of the hub,
    so every exchange hashing the hub on A_1 funnels that share onto one
    reducer.  Spokes match the realized hub values as in
    ``star_data_sparse`` (so the skew survives the semijoin phase).

    The zipf draw uses a quarter of the domain: H(m, 1.1) grows with the
    support m, so a narrow head keeps the rank-1 share (~1/H) above the
    heavy-hitter detection threshold at s=1.1 and p=8 — the regime the
    skew benchmark exercises — while s=0 stays a uniform control."""
    rng = np.random.default_rng(seed)
    half = max(2, domain // 2)
    cols = [zipf_values(rng, hub_rows, max(2, domain // 4), s)]
    cols += [
        rng.integers(0, half, hub_rows).astype(np.int32) for _ in range(n - 2)
    ]
    hub = np.stack(cols, 1).astype(np.int32)
    out = {"S": np.unique(hub, axis=0)}
    for i in range(1, n):
        vals = np.unique(hub[:, i - 1])
        rows = [(int(v), int(v) % 7) for v in vals]
        rows += [
            (int(rng.integers(half, domain)), int(rng.integers(0, 7)))
            for _ in range(spoke_extra)
        ]
        out[f"R{i}"] = np.unique(np.array(rows, np.int32), axis=0)
    return out


def star_data_heavy(
    n: int, *, domain: int = 32, hub_rows: int = 64, heavy_share: float = 0.8,
    spoke_extra: int = 8, seed: int = 0,
) -> Dict[str, np.ndarray]:
    """S_n with a PLANTED heavy hitter: ``heavy_share`` of the hub rows
    carry A_1 = 0 (distinct rows — the other columns are uniform draws,
    so dedup-on-load keeps them).  The adversarial single-key instance of
    the skew tests: hash exchanges on A_1 pile that share onto ONE
    reducer, while the hybrid exchange spreads it."""
    rng = np.random.default_rng(seed)
    half = max(2, domain // 2)
    k = int(hub_rows * heavy_share)
    a1 = np.concatenate(
        [np.zeros(k, np.int32), rng.integers(1, half, hub_rows - k)]
    )
    cols = [a1] + [
        rng.integers(0, half, hub_rows).astype(np.int32) for _ in range(n - 2)
    ]
    hub = np.stack(cols, 1).astype(np.int32)
    out = {"S": np.unique(hub, axis=0)}
    for i in range(1, n):
        vals = np.unique(hub[:, i - 1])
        rows = [(int(v), int(v) % 7) for v in vals]
        rows += [
            (int(rng.integers(half, domain)), int(rng.integers(0, 7)))
            for _ in range(spoke_extra)
        ]
        out[f"R{i}"] = np.unique(np.array(rows, np.int32), axis=0)
    return out


def chain_data_zipf(
    n: int, *, domain: int = 32, rows: int = 24, s: float = 1.1, seed: int = 0
) -> Dict[str, np.ndarray]:
    """C_n with each R_i's RIGHT attribute A_i zipf(s)-distributed and the
    left attribute uniform: the join/semijoin exchanges keyed on A_i see a
    heavy value (rank-1 of the zipf) on the R_i side while the R_{i+1}
    side stays uniform — skewing the exchange load without exploding the
    join output (the heavy key matches ~rows/domain partners)."""
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(1, n + 1):
        left = rng.integers(0, domain, rows).astype(np.int32)
        right = zipf_values(rng, rows, domain, s)
        out[f"R{i}"] = np.unique(np.stack([left, right], 1).astype(np.int32), axis=0)
    return out


def tc_data_sparse(
    n_tri: int, *, domain: int = 24, ident: int = 6, extra: int = 10, seed: int = 0
) -> Dict[str, np.ndarray]:
    """TC_n triangles: identity triangles on [0, ident) + sparse noise."""
    rng = np.random.default_rng(seed)
    out = {}
    k = 1
    for _ in range(n_tri):
        for _ in range(3):
            rows = [(v, v) for v in range(ident)]
            rows += [
                (int(rng.integers(ident, domain)), int(rng.integers(ident, domain)))
                for _ in range(extra)
            ]
            out[f"R{k}"] = np.unique(np.array(rows, np.int32), axis=0)
            k += 1
    return out
