from .optim import OptConfig, opt_init, opt_update
from .step import TrainConfig, init_train_state, init_train_state_shapes, make_train_step
