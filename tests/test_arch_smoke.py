"""Per-architecture smoke tests: REDUCED config of the same block family,
one forward + one train-grad step + prefill/decode on CPU; asserts output
shapes and finiteness.  Full configs are exercised only via the dry-run."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, get_model, make_smoke_batch, reduced_config

ARCHS = sorted(CONFIGS)


def _finite(tree) -> bool:
    leaves = jax.tree_util.tree_leaves(tree)
    return all(
        bool(jnp.isfinite(l).all())
        for l in leaves
        if jnp.issubdtype(l.dtype, jnp.floating)
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = reduced_config(CONFIGS[arch])
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_smoke_batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.jit(jax.value_and_grad(lambda p: model.loss(p, batch)))(
        params
    )
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert _finite(grads), f"{arch}: non-finite grads"
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch):
    cfg = reduced_config(CONFIGS[arch])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), b=b, s=s)
    if cfg.encdec:
        logits, caches = model.prefill(params, {"frames": batch["frames"]}, s_cache=8)
    else:
        pre = {"tokens": batch["tokens"]}
        if "pos" in batch:
            pre["pos"] = batch["pos"]
        logits, caches = model.prefill(params, pre, s_cache=s + 4)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: prefill logits"

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(2):
        logits, caches = model.decode_step(params, caches, tok)
        assert logits.shape == (b, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: decode logits"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-125m", "zamba2-7b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = reduced_config(CONFIGS[arch])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 12
    batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), b=b, s=s)
    toks = batch["tokens"]

    full = model.logits(params, toks)  # (b, s, v)

    pre = {"tokens": toks[:, : s - 2]}
    logits, caches = model.prefill(params, pre, s_cache=s + 2)
    np.testing.assert_allclose(
        np.asarray(logits),
        np.asarray(full[:, s - 3]),
        rtol=2e-3, atol=2e-3,
    )
    # decode the next token teacher-forced
    logits2, caches = model.decode_step(params, caches, toks[:, s - 2])
    np.testing.assert_allclose(
        np.asarray(logits2),
        np.asarray(full[:, s - 2]),
        rtol=2e-3, atol=2e-3,
    )
