"""GYM end-to-end vs the numpy brute-force oracle, both strategies, plus
round-count bounds and the resumable-driver snapshot path."""
from __future__ import annotations

import random

import numpy as np
import pytest

from repro.core.acq_mr import acq_mr, gym_loggta
from repro.core.decompose import ghd_for
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.hypergraph import Atom, Query
from repro.core.planner import dym_d_schedule, dym_n_schedule, schedule_stats
from repro.core.queries import (
    chain_ghd,
    chain_ghd_grouped,
    chain_query,
    example4_query,
    random_acyclic_query,
    random_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.core.shares import shares_join
from repro.relational.oracle import canon, np_query_answer, reorder
from repro.relational.spmd import SPMD


def rand_data(query: Query, rng: random.Random, dom: int = 6, rows: int = 12):
    """Random relation contents (shared small domain => real join matches)."""
    out = {}
    for atom in query.atoms:
        n = rng.randint(1, rows)
        out[atom.rel] = np.array(
            [[rng.randint(0, dom - 1) for _ in atom.attrs] for _ in range(n)],
            dtype=np.int32,
        )
    return out


def oracle_rows(query: Query, data):
    atoms = [(a.alias, a.attrs) for a in query.atoms]
    d = {a.alias: data[a.rel] for a in query.atoms}
    rows, schema = np_query_answer(atoms, d)
    return reorder(rows, schema, query.output_attrs)


@pytest.mark.parametrize("strategy", ["hash", "grid"])
@pytest.mark.parametrize(
    "qname", ["chain4", "star4", "tc2", "example4", "selfjoin"]
)
@pytest.mark.slow
def test_gym_matches_oracle(strategy, qname):
    rng = random.Random(hash((strategy, qname)) & 0xFFFF)
    if qname == "chain4":
        q = chain_query(4)
    elif qname == "star4":
        q = star_query(4)
    elif qname == "tc2":
        q = triangle_chain_query(2)
    elif qname == "example4":
        q = example4_query()
    else:  # self-join: R(A,B) |><| R(B,C)
        q = Query(
            [Atom("R1", "R", ("A", "B")), Atom("R2", "R", ("B", "C"))],
            name="SelfJoin",
        )
    data = rand_data(q, rng)
    want = canon(oracle_rows(q, data))
    got_rows, schema, ledger = gym(
        q, data, p=4, config=GymConfig(strategy=strategy, seed=3)
    )
    assert tuple(schema) == q.output_attrs
    assert canon(got_rows) == want
    assert ledger.output_tuples == len(want)
    assert ledger.rounds >= 1


@pytest.mark.slow
@pytest.mark.parametrize("n", [2, 3, 5, 8])
def test_gym_random_acyclic(n):
    rng = random.Random(100 + n)
    for trial in range(3):
        q = random_acyclic_query(rng, n)
        data = rand_data(q, rng)
        want = canon(oracle_rows(q, data))
        got, schema, _ = gym(q, data, p=4, config=GymConfig(seed=trial))
        assert canon(got) == want, f"{q.name} trial {trial}"


@pytest.mark.slow
@pytest.mark.parametrize("n", [3, 4, 6])
def test_gym_random_cyclic(n):
    rng = random.Random(300 + n)
    for trial in range(2):
        q = random_query(rng, n, n_attrs=4)
        data = rand_data(q, rng, dom=4, rows=8)
        want = canon(oracle_rows(q, data))
        got, schema, _ = gym(q, data, p=4, config=GymConfig(seed=trial))
        assert canon(got) == want, f"{q.name} trial {trial}"


def test_gym_empty_result():
    q = chain_query(3)
    data = {
        "R1": np.array([[0, 1]], np.int32),
        "R2": np.array([[2, 3]], np.int32),  # no match with R1
        "R3": np.array([[3, 4]], np.int32),
    }
    got, _, ledger = gym(q, data, p=4)
    assert got.shape[0] == 0
    assert ledger.output_tuples == 0


@pytest.mark.slow
def test_gym_via_loggta_and_acqmr():
    rng = random.Random(7)
    q = triangle_chain_query(3)
    data = rand_data(q, rng, dom=4, rows=10)
    want = canon(oracle_rows(q, data))
    got1, _, led1 = gym_loggta(q, data, ghd=triangle_chain_ghd(3), p=4)
    got2, _, led2 = acq_mr(q, data, ghd=triangle_chain_ghd(3), p=4)
    assert canon(got1) == want
    assert canon(got2) == want


@pytest.mark.slow
def test_shares_matches_oracle():
    rng = random.Random(11)
    for q in [chain_query(3), star_query(3), triangle_chain_query(1)]:
        data = rand_data(q, rng, dom=5, rows=10)
        want = canon(oracle_rows(q, data))
        got, schema, ledger = shares_join(q, data, p=8)
        assert canon(got) == want, q.name
        assert ledger.rounds == 1  # one-round algorithm


# ------------------------------------------------------------- round bounds
def test_dym_d_round_bound_chain():
    # chain GHD of depth n-1: schedule rounds O(d + log n)
    for n in [4, 8, 16, 32]:
        g = chain_ghd(n).make_complete(chain_query(n))
        sched = dym_d_schedule(g)
        d = g.depth
        bound = 3 * (d + int(np.ceil(np.log2(max(2, g.size())))) + 2)
        assert len(sched) <= bound, (n, len(sched), bound)


def test_dym_d_round_bound_star():
    # star: depth 1 -> O(log n) rounds total
    for n in [4, 8, 32, 64]:
        g = star_ghd(n).make_complete(star_query(n))
        sched = dym_d_schedule(g)
        assert len(sched) <= 3 * (int(np.ceil(np.log2(n))) + 3), (n, len(sched))


def test_dym_n_vs_dym_d_round_counts():
    # on a chain (no parallelism available) DYM-d degenerates to DYM-n
    n = 16
    q = chain_query(n)
    g = chain_ghd(n).make_complete(q)
    assert len(dym_n_schedule(g)) == 3 * (g.size() - 1)
    assert len(dym_d_schedule(g)) == len(dym_n_schedule(g))
    # on a star (depth 1) DYM-d contracts leaves in parallel: O(log n)
    qs = star_query(n)
    gs = star_ghd(n).make_complete(qs)
    s_n = dym_n_schedule(gs)
    s_d = dym_d_schedule(gs)
    assert len(s_n) == 3 * (gs.size() - 1)
    assert len(s_d) <= 3 * (int(np.ceil(np.log2(n))) + 2)
    assert len(s_d) < len(s_n)


def test_schedule_single_writer_per_round():
    rng = random.Random(5)
    for _ in range(5):
        q = random_acyclic_query(rng, 9)
        g = ghd_for(q).make_complete(q)
        for rnd in dym_d_schedule(g):
            targets = [op.target for op in rnd.ops]
            assert len(targets) == len(set(targets)), "write conflict in round"


# ---------------------------------------------------------- fault tolerance
@pytest.mark.slow
def test_driver_snapshot_resume(tmp_path):
    rng = random.Random(42)
    q = chain_query(5)
    data = rand_data(q, rng)
    want = canon(oracle_rows(q, data))

    spmd = SPMD(4)
    drv = GymDriver(q, ghd_for(q), data, spmd, GymConfig(seed=1))
    # run two round-groups, snapshot, "crash"
    drv.step()
    drv.step()
    snap = str(tmp_path / "gym_snapshot.npz")
    drv.save(snap)

    # resume in a brand-new driver
    drv2 = GymDriver(q, ghd_for(q), data, SPMD(4), GymConfig(seed=1))
    drv2.load(snap)
    out = drv2.run()
    assert canon(out.to_numpy()) == want


@pytest.mark.slow
def test_grid_strategy_skew_immune():
    """All tuples share one key value: hash co-partition would funnel them
    to a single reducer; the grid path bounds every reducer by position."""
    q = chain_query(2)
    n = 32
    data = {
        "R1": np.stack(
            [np.arange(n, dtype=np.int32), np.zeros(n, np.int32)], axis=1
        ),
        "R2": np.stack(
            [np.zeros(n, np.int32), np.arange(n, dtype=np.int32)], axis=1
        ),
    }
    want = canon(oracle_rows(q, data))
    got, _, ledger = gym(q, data, p=4, config=GymConfig(strategy="grid"))
    assert canon(got) == want
    assert len(want) == n * n
