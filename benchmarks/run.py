"""Benchmark harness: one module per paper table/figure + engine/kernel/LM
micro-benches.  Prints one JSON line per result row; any internal
assertion failure marks the run failed.

    PYTHONPATH=src python -m benchmarks.run [--only table2,table3]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from . import (
    bench_appendix_c,
    bench_engine,
    bench_fig6,
    bench_fusion,
    bench_kernels,
    bench_lemmas,
    bench_lm,
    bench_moe,
    bench_optimizer,
    bench_serve,
    bench_shuffle,
    bench_skew,
    bench_table1,
    bench_table2,
    bench_table3,
)

ALL = {
    "table1": bench_table1,
    "table2": bench_table2,
    "table3": bench_table3,
    "fig6": bench_fig6,
    "appendix_c": bench_appendix_c,
    "lemmas": bench_lemmas,
    "engine": bench_engine,
    "fusion": bench_fusion,
    "kernels": bench_kernels,
    "optimizer": bench_optimizer,
    "shuffle": bench_shuffle,
    "serve": bench_serve,
    "skew": bench_skew,
    "lm": bench_lm,
    "moe": bench_moe,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)

    failed = []
    for name in names:
        mod = ALL[name]
        t0 = time.time()
        try:
            rows = mod.run()
            for r in rows:
                print(json.dumps(r))
            print(
                json.dumps(
                    {"bench": name, "status": "ok", "secs": round(time.time() - t0, 1)}
                )
            )
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(json.dumps({"bench": name, "status": "FAIL", "error": str(e)}))
            failed.append(name)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    print("ALL BENCHMARKS PASSED")


if __name__ == "__main__":
    main()
