"""BSP cost accounting: rounds and tuples communicated (the paper's two
cost metrics, Sec. 3.2), plus the wire-level padded-slot accounting behind
the occupancy-adaptive shuffle.  One ledger per query execution.

``comm_tuples`` counts *useful* tuples moved — the unit of the paper's
bounds.  The physical shuffle, however, ships dense ``(p, c_out, arity)``
slot buffers per ``all_to_all``, so the wire carries ``padded_slots``
int32 CELLS (slot rows x row width — width-weighted so keys-only
exchanges and the count pre-pass's own traffic are priced honestly).
``payload_efficiency`` (useful tuples per shipped cell) is the measured
quality of the capacity calibration; it is a tuples/cells ratio, so
compare it across capacity policies on the SAME query, not across
queries of different arity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class RoundRecord:
    index: int
    phase: str
    ops: List[str]
    comm_tuples: int
    note: str = ""
    n_rounds: int = 1  # CLAIMED engine BSP rounds (parallel ops: the max)
    dispatches: int = 0  # MEASURED SPMD program dispatches (0 = not measured)
    padded_slots: int = 0  # MEASURED dense all_to_all slots shipped
    heavy_tuples: int = 0  # tuple-sends routed via the heavy-hitter path
    # the subset of ``dispatches`` that were count-only measure pre-passes.
    # Defaulted so pre-split snapshots (``RoundRecord(**r)``) keep loading.
    measure_dispatches: int = 0
    # byte-true wire accounting: ``payload_bytes`` is what the exchange
    # buffers actually occupied on the wire this round (packed bit-stream
    # bytes under the packed format, dense int32 cells + valid flags
    # otherwise — including count pre-pass vectors and keys-only
    # exchanges), ``useful_bytes`` the dense-int32 bytes of the useful
    # tuples inside them.  Defaulted so pre-wire snapshots keep loading.
    payload_bytes: int = 0
    useful_bytes: int = 0
    # routed-exchange stats shared with the MoE customer of
    # ``relational.routed``: tuples (token pairs) the round dropped at a
    # capacity — always 0 on join rounds, which abort-retry instead of
    # dropping — and the number of destinations (experts) the round's
    # count pre-pass flagged heavy.  Defaulted so pre-MoE snapshots
    # (``RoundRecord(**r)``) keep loading.
    dropped_tuples: int = 0
    heavy_dests: int = 0


class Ledger:
    def __init__(self) -> None:
        self.records: List[RoundRecord] = []
        self.output_tuples: int = 0
        self.retries: int = 0

    @property
    def rounds(self) -> int:
        return sum(r.n_rounds for r in self.records)

    @property
    def measured_dispatches(self) -> int:
        """Total SPMD program dispatches actually issued across rounds.

        ``rounds`` is what the schedule *claims* under the BSP model (a
        round of k parallel ops counts once); this is what the engine
        *did*.  With round fusion the two converge; without it this is
        ~ops-per-round times larger."""
        return sum(r.dispatches for r in self.records)

    @property
    def measure_dispatches(self) -> int:
        """Count-only calibration pre-pass dispatches — the price of
        measured capacities.  The amortized-calibration layer (combined
        per-round count dispatch + ``CapsCache`` + prefetch) bounds this at
        ~one per executed round instead of one per op group."""
        return sum(r.measure_dispatches for r in self.records)

    @property
    def payload_dispatches(self) -> int:
        """Dispatches that moved actual operator payload (total minus the
        measure pre-passes) — tracks the schedule, not the calibration
        policy."""
        return self.measured_dispatches - self.measure_dispatches

    @property
    def comm_tuples(self) -> int:
        """Total communication: shuffled tuples + output tuples (the paper
        counts reducer output as communication)."""
        return sum(r.comm_tuples for r in self.records) + self.output_tuples

    @property
    def shuffle_tuples(self) -> int:
        return sum(r.comm_tuples for r in self.records)

    @property
    def useful_tuples(self) -> int:
        """Alias of ``shuffle_tuples`` in wire terms: the occupied slots of
        the shipped exchange buffers."""
        return self.shuffle_tuples

    @property
    def padded_slots(self) -> int:
        """Dense ``all_to_all`` cells the wire actually shipped: every
        exchange pays ``p * c_out * arity`` int32 cells per shard, full or
        empty — including the count pre-pass's own count vectors and
        keys-only output-count exchanges."""
        return sum(r.padded_slots for r in self.records)

    @property
    def heavy_tuples(self) -> int:
        """Tuple-sends the hybrid engine routed through the heavy-hitter
        path (position-partitioned spreads + broadcast replicas).  Zero
        under the hash/grid engines and on unskewed instances — the
        hybrid engine's routing is data-dependent, and this is its
        measured heavy/light split."""
        return sum(r.heavy_tuples for r in self.records)

    @property
    def light_tuples(self) -> int:
        """Shuffled tuples that kept the plain hash routing."""
        return self.shuffle_tuples - self.heavy_tuples

    @property
    def dropped_tuples(self) -> int:
        """Tuples lost to a capacity across all rounds.  The join engines
        hold this at 0 by construction (overflow aborts and retries with
        doubled capacities); the MoE customer reports it explicitly —
        calibrated dispatch proves 0 when the measured counts fit, and
        capacity-ceilinged dispatch surfaces the exact overflow instead
        of the dense scatter's silent truncation."""
        return sum(r.dropped_tuples for r in self.records)

    @property
    def heavy_dests(self) -> int:
        """Destinations (reducers / experts) the count pre-pass flagged
        heavy, summed over rounds — the routed-exchange sibling of
        ``heavy_tuples`` (which counts the tuple-sends those destinations
        attracted)."""
        return sum(r.heavy_dests for r in self.records)

    @property
    def payload_bytes(self) -> int:
        """Bytes the wire actually shipped across all exchanges — the
        byte-true sibling of ``padded_slots``.  Unlike the slot metric
        (which prices every exchange at dense int32 width regardless of
        encoding), this reflects the configured wire format: packed
        exchanges charge their bit-stream byte size, dense exchanges
        charge ``4*arity + 1`` bytes per slot, and the count pre-pass's
        vectors charge their 4 bytes per counter."""
        return sum(r.payload_bytes for r in self.records)

    @property
    def useful_bytes(self) -> int:
        """Dense-int32 bytes of the useful tuples inside the shipped
        exchange buffers (4 bytes per cell of every occupied slot) —
        identical across wire formats, so ``payload_efficiency_bytes``
        ratios are comparable packed-vs-dense on the same query."""
        return sum(r.useful_bytes for r in self.records)

    @property
    def payload_efficiency_bytes(self) -> float:
        """useful_bytes per shipped wire byte (1.0 when nothing was
        shipped) — the byte-true quality of the exchange encoding.  Can
        exceed 1.0 under the packed format: a 6-bit column ships fewer
        wire bits than its 32-bit useful-payload accounting."""
        pb = self.payload_bytes
        return self.useful_bytes / pb if pb else 1.0

    @property
    def payload_efficiency(self) -> float:
        """useful_tuples per shipped cell — the measured quality of the
        shipped exchange buffers (1.0 when nothing was shuffled).  A
        tuples/cells ratio: compare across capacity policies on the same
        query, not across queries of different arity."""
        pad = self.padded_slots
        return self.useful_tuples / pad if pad else 1.0

    def add_round(
        self,
        phase: str,
        ops: List[str],
        comm: int,
        note: str = "",
        n_rounds: int = 1,
        dispatches: int = 0,
        padded: int = 0,
        heavy: int = 0,
        measure_dispatches: int = 0,
        payload_bytes: int = 0,
        useful_bytes: int = 0,
        dropped: int = 0,
        heavy_dests: int = 0,
    ) -> None:
        self.records.append(
            RoundRecord(
                len(self.records), phase, list(ops), int(comm), note, n_rounds,
                int(dispatches), int(padded), int(heavy),
                int(measure_dispatches), int(payload_bytes),
                int(useful_bytes), int(dropped), int(heavy_dests),
            )
        )

    def rounds_in_phase(self, phase: str) -> int:
        return sum(r.n_rounds for r in self.records if r.phase == phase)

    def comm_in_phase(self, phase: str) -> int:
        return sum(r.comm_tuples for r in self.records if r.phase == phase)

    def calibration_record(
        self,
        *,
        engine: str,
        schedule: str = "",
        query: str = "",
        predicted_comm: float = 0.0,
        predicted_rounds: float = 0.0,
    ) -> Dict[str, Any]:
        """One measured sample for ``core.costs.fit_calibration``.

        Pairs this execution's ground truth (comm_tuples, rounds,
        retries) with the advisor's *uncalibrated* predictions so the
        per-engine constants of the cost model can be fitted from real
        runs."""
        return {
            "engine": engine,
            "schedule": schedule,
            "query": query,
            "predicted_comm": float(predicted_comm),
            "predicted_rounds": float(predicted_rounds),
            "measured_comm": int(self.comm_tuples),
            "measured_shuffle": int(self.shuffle_tuples),
            "measured_rounds": int(self.rounds),
            "measured_dispatches": int(self.measured_dispatches),
            "measure_dispatches": int(self.measure_dispatches),
            "payload_dispatches": int(self.payload_dispatches),
            "measured_padded": int(self.padded_slots),
            "measured_heavy": int(self.heavy_tuples),
            "payload_efficiency": float(self.payload_efficiency),
            "payload_bytes": int(self.payload_bytes),
            "useful_bytes": int(self.useful_bytes),
            "payload_efficiency_bytes": float(self.payload_efficiency_bytes),
            "measured_dropped": int(self.dropped_tuples),
            "measured_heavy_dests": int(self.heavy_dests),
            "output_tuples": int(self.output_tuples),
            "retries": int(self.retries),
        }

    def summary(self) -> Dict[str, Any]:
        phases: Dict[str, Dict[str, int]] = {}
        for r in self.records:
            ph = phases.setdefault(
                r.phase,
                {
                    "rounds": 0,
                    "comm": 0,
                    "dispatches": 0,
                    "measure_dispatches": 0,
                    "padded": 0,
                    "heavy": 0,
                    "payload_bytes": 0,
                    "useful_bytes": 0,
                    "dropped": 0,
                    "heavy_dests": 0,
                },
            )
            ph["rounds"] += r.n_rounds
            ph["comm"] += r.comm_tuples
            ph["dispatches"] += r.dispatches
            ph["measure_dispatches"] += r.measure_dispatches
            ph["padded"] += r.padded_slots
            ph["heavy"] += r.heavy_tuples
            ph["payload_bytes"] += r.payload_bytes
            ph["useful_bytes"] += r.useful_bytes
            ph["dropped"] += r.dropped_tuples
            ph["heavy_dests"] += r.heavy_dests
        return {
            "rounds": self.rounds,
            "measured_dispatches": self.measured_dispatches,
            "measure_dispatches": self.measure_dispatches,
            "payload_dispatches": self.payload_dispatches,
            "comm_tuples": self.comm_tuples,
            "shuffle_tuples": self.shuffle_tuples,
            "padded_slots": self.padded_slots,
            "heavy_tuples": self.heavy_tuples,
            "light_tuples": self.light_tuples,
            "dropped_tuples": self.dropped_tuples,
            "heavy_dests": self.heavy_dests,
            "payload_efficiency": round(self.payload_efficiency, 4),
            "payload_bytes": self.payload_bytes,
            "useful_bytes": self.useful_bytes,
            "payload_efficiency_bytes": round(self.payload_efficiency_bytes, 4),
            "output_tuples": self.output_tuples,
            "retries": self.retries,
            "phases": phases,
        }

    def __repr__(self) -> str:
        s = self.summary()
        heavy = f", heavy={s['heavy_tuples']}" if s["heavy_tuples"] else ""
        if s["heavy_dests"]:
            heavy += f", heavy_dests={s['heavy_dests']}"
        if s["dropped_tuples"]:
            heavy += f", dropped={s['dropped_tuples']}"
        lines = [
            f"Ledger(rounds={s['rounds']}, dispatches={s['measured_dispatches']}, "
            f"comm={s['comm_tuples']}, out={s['output_tuples']}, "
            f"padded={s['padded_slots']}, eff={s['payload_efficiency']}, "
            f"bytes={s['payload_bytes']}, "
            f"eff_bytes={s['payload_efficiency_bytes']}, "
            f"retries={s['retries']}{heavy})"
        ]
        for ph, v in s["phases"].items():
            lines.append(
                f"  {ph}: rounds={v['rounds']} dispatches={v['dispatches']} "
                f"comm={v['comm']} padded={v['padded']}"
            )
        return "\n".join(lines)


class ServerLedger:
    """Multi-tenant accounting for the serving layer: every completed
    query's per-tenant ``Ledger`` plus the server-level fusion counters.

    The aggregate IS the per-tenant sum — cross-request fusion changes how
    work is packed into SPMD programs, never what each query's wire moved
    (each tenant's rows, ``comm_tuples``, and byte accounting stay those
    of a standalone run, Lemma-2-auditable per request).  What fusion
    saves shows up only in the dispatch split: a merged dispatch charges
    its ONE program launch to the first rider, and ``fused_dispatches`` /
    ``fused_riders`` record how many launches the merge avoided."""

    def __init__(self) -> None:
        self.tenants: Dict[str, List[Ledger]] = {}
        # merged payload dispatches issued / rider groups that shared one
        self.fused_dispatches: int = 0
        self.fused_riders: int = 0

    def add(self, tenant: str, ledger: Ledger) -> None:
        self.tenants.setdefault(tenant, []).append(ledger)

    def _all(self) -> List[Ledger]:
        return [led for leds in self.tenants.values() for led in leds]

    @property
    def queries(self) -> int:
        return len(self._all())

    @property
    def comm_tuples(self) -> int:
        return sum(led.comm_tuples for led in self._all())

    @property
    def padded_slots(self) -> int:
        return sum(led.padded_slots for led in self._all())

    @property
    def payload_bytes(self) -> int:
        return sum(led.payload_bytes for led in self._all())

    @property
    def measured_dispatches(self) -> int:
        return sum(led.measured_dispatches for led in self._all())

    @property
    def retries(self) -> int:
        return sum(led.retries for led in self._all())

    @property
    def dispatches_saved(self) -> int:
        """Payload program launches cross-request fusion avoided: riders
        that shared a merged dispatch instead of launching their own."""
        return self.fused_riders - self.fused_dispatches

    def tenant_summary(self, tenant: str) -> Dict[str, Any]:
        leds = self.tenants.get(tenant, [])
        return {
            "tenant": tenant,
            "queries": len(leds),
            "comm_tuples": sum(l.comm_tuples for l in leds),
            "output_tuples": sum(l.output_tuples for l in leds),
            "padded_slots": sum(l.padded_slots for l in leds),
            "payload_bytes": sum(l.payload_bytes for l in leds),
            "dispatches": sum(l.measured_dispatches for l in leds),
            "retries": sum(l.retries for l in leds),
        }

    def summary(self) -> Dict[str, Any]:
        return {
            "queries": self.queries,
            "comm_tuples": self.comm_tuples,
            "padded_slots": self.padded_slots,
            "payload_bytes": self.payload_bytes,
            "dispatches": self.measured_dispatches,
            "retries": self.retries,
            "fused_dispatches": self.fused_dispatches,
            "fused_riders": self.fused_riders,
            "dispatches_saved": self.dispatches_saved,
            "tenants": {t: self.tenant_summary(t) for t in sorted(self.tenants)},
        }

    def __repr__(self) -> str:
        s = self.summary()
        return (
            f"ServerLedger(queries={s['queries']}, comm={s['comm_tuples']}, "
            f"dispatches={s['dispatches']}, "
            f"saved={s['dispatches_saved']}, retries={s['retries']})"
        )
