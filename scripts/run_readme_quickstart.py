"""Extract the README quickstart code block and execute it verbatim.

CI's docs lane runs this (see .github/workflows/ci.yml), so the README
can never drift from the actual API: if the quickstart stops running,
the lane fails.

    PYTHONPATH=src python scripts/run_readme_quickstart.py
"""
from __future__ import annotations

import pathlib
import re
import sys

README = pathlib.Path(__file__).resolve().parents[1] / "README.md"


def extract_quickstart(text: str) -> str:
    m = re.search(
        r"<!-- quickstart -->\s*```python\n(.*?)```\s*<!-- /quickstart -->",
        text,
        re.S,
    )
    if not m:
        sys.exit("README.md: quickstart block markers not found")
    return m.group(1)


def main() -> None:
    code = extract_quickstart(README.read_text())
    print("--- README quickstart ---")
    print(code)
    print("--- output ---")
    exec(compile(code, "README.md:quickstart", "exec"), {"__name__": "__main__"})
    print("README quickstart OK")


if __name__ == "__main__":
    main()
