"""Train substrate: optimizer steps reduce loss, accumulation equivalence,
checkpoint round-trip, compression codec quality, elastic batch planning,
and the GYM-powered data pipeline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, get_model, make_smoke_batch, reduced_config
from repro.data import CorpusConfig, batches, eligible_docs
from repro.train import (
    OptConfig,
    TrainConfig,
    init_train_state,
    make_train_step,
)
from repro.train import checkpoint as ckpt
from repro.train.compression import codec_roundtrip, int8_allreduce
from repro.train.elastic import HeartbeatMonitor, fit_batch_to_world


def _setup(arch="smollm-360m", opt_kind="adamw", **tkw):
    cfg = reduced_config(CONFIGS[arch])
    model = get_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(kind=opt_kind, lr=1e-2, warmup=1), **tkw)
    params, opt_state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), b=4, s=16)
    return cfg, model, tcfg, params, opt_state, batch


@pytest.mark.parametrize("opt_kind", ["adamw", "adafactor"])
def test_train_reduces_loss(opt_kind):
    cfg, model, tcfg, params, opt_state, batch = _setup(opt_kind=opt_kind)
    step = jax.jit(make_train_step(model, tcfg))
    losses = []
    for _ in range(8):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


def test_grad_accumulation_equivalence():
    cfg, model, _, params, _, batch = _setup()
    t1 = TrainConfig(opt=OptConfig(lr=1e-3, warmup=1), accum=1)
    t4 = TrainConfig(opt=OptConfig(lr=1e-3, warmup=1), accum=4)
    from repro.train.optim import opt_init

    s1 = opt_init(t1.opt, params)
    s4 = opt_init(t4.opt, params)
    p1, _, m1 = jax.jit(make_train_step(model, t1))(params, s1, batch)
    p4, _, m4 = jax.jit(make_train_step(model, t4))(params, s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-5, rtol=2e-4,
        )


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, tcfg, params, opt_state, batch = _setup()
    step = jax.jit(make_train_step(model, tcfg))
    params, opt_state, _ = step(params, opt_state, batch)
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"params": params, "opt": opt_state}, extra={"foo": 1})
    assert ckpt.latest_step(d) == 1
    restored, extra = ckpt.restore(d, {"params": params, "opt": opt_state})
    assert extra == {"foo": 1}
    for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(restored["params"]),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resume training from restored state works
    p2, o2, m = step(restored["params"], restored["opt"], batch)
    assert np.isfinite(float(m["loss"]))


def test_checkpoint_async(tmp_path):
    cfg, model, tcfg, params, opt_state, batch = _setup()
    d = str(tmp_path / "ck")
    t = ckpt.save_async(d, 7, {"params": params})
    t.join()
    assert ckpt.latest_step(d) == 7


def test_compression_codec_error_bounded():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (256, 64)) * 0.01
    y = codec_roundtrip({"g": x})["g"]
    err = jnp.abs(x - y).max()
    scale = jnp.abs(x).max() / 127.0
    assert float(err) <= float(scale) * 1.01


def test_int8_allreduce_vs_exact():
    # simulate 8 data-parallel shards with vmap's named axis
    rng = jax.random.PRNGKey(1)
    xs = jax.random.normal(rng, (8, 128)) * 0.1
    out = jax.vmap(
        lambda x: int8_allreduce(x, "dp"), axis_name="dp"
    )(xs)
    exact = jnp.broadcast_to(xs.mean(0), xs.shape)
    assert float(jnp.abs(out - exact).max()) < float(jnp.abs(xs).max()) / 60


def test_fit_batch_to_world():
    p = fit_batch_to_world(256, 16, per_device_max=4)
    assert p.per_device_batch * p.accum * 16 == 256
    p2 = fit_batch_to_world(256, 8, per_device_max=4)
    assert p2.per_device_batch * p2.accum * 8 == 256
    assert p2.accum >= p.accum  # fewer chips -> more accumulation


def test_heartbeat_monitor():
    m = HeartbeatMonitor(factor=2.0)
    for _ in range(10):
        m.start()
        _, s = m.stop()
    assert isinstance(s, bool)


def test_pipeline_gym_join():
    cfg = CorpusConfig(n_docs=64, n_shards=8, seed=3)
    ids, summary = eligible_docs(cfg, p=4)
    assert len(ids) > 0
    assert summary["rounds"] >= 1
    # oracle: recompute eligibility in numpy
    from repro.data import synth_corpus

    d = synth_corpus(cfg)
    ok_shards = set(d["shards"][d["shards"][:, 1] >= cfg.q_min][:, 0])
    keep = set(d["dedup"][d["dedup"][:, 1] == 1][:, 0])
    ok_buckets = set(d["mix"][d["mix"][:, 1] > 0][:, 0])
    want = {
        int(r[0])
        for r in d["docs"]
        if r[1] in ok_shards and r[0] in keep and r[2] in ok_buckets
    }
    assert set(int(i) for i in ids) == want


def test_pipeline_batches():
    cfg = CorpusConfig(n_docs=32, n_shards=4, seed=5)
    it = batches(cfg, batch=2, seq=8, vocab=101)
    b1 = next(it)
    assert b1["tokens"].shape == (2, 8)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 101).all()
    # autoregressive consistency: targets are tokens shifted by one
    b2 = next(it)
    assert b2["targets"].shape == (2, 8)
