"""Property tests pinning Pallas(interpret) == pure-jnp reference for the
join-engine kernels at the ragged edges: n not a multiple of the block
size, arity 1-4, non-power-of-two p, empty / all-invalid inputs, and key
values at the INT32 pad sentinels.

The deterministic sweeps below always run; the hypothesis fuzzers ride on
top when hypothesis is installed (CI's full lane)."""
from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.hash_partition import ROWS_BLK, hash_partition
from repro.kernels.semijoin_probe import semijoin_probe
from repro.kernels.sorted_probe import sorted_probe_ranges

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # CI's fast lane / bare containers
    HAVE_HYPOTHESIS = False

I32MAX = 2**31 - 1
I32MIN = -(2**31)

# values at and around the kernels' pad sentinels (key pad = INT32_MAX,
# probe pad = INT32_MIN + 1), plus a small colliding pool
EDGE_VALS = [I32MAX - 1, I32MIN + 1, I32MIN + 2, -5, -1, 0, 1, 5]


# ------------------------------------------------ deterministic sweeps
def _probe_arrays(rng, n, m, nvalid):
    q = rng.choice(EDGE_VALS, size=n).astype(np.int32)
    keys = rng.choice(EDGE_VALS, size=m).astype(np.int32)
    keys[nvalid:] = I32MAX
    return jnp.asarray(q), jnp.asarray(keys)


# sizes straddle the (8*128) probe tile and (64*128) key tile boundaries
@pytest.mark.parametrize(
    "n,m,nvalid",
    [
        (1, 1, 1),
        (7, 5, 3),
        (1023, 1025, 1000),  # just off the probe tile boundary
        (1024, 1024, 1024),  # exactly one probe tile
        (1025, 8193, 8192),  # just past probe/key tile boundaries
        (13, 0, 0),          # empty key table
        (17, 9, 0),          # all-invalid key table
        (0, 5, 5),           # no probes at all
    ],
)
def test_probe_kernels_ragged_edges(n, m, nvalid):
    rng = np.random.default_rng(n * 31 + m * 7 + nvalid)
    q, keys = _probe_arrays(rng, n, m, nvalid)

    got = semijoin_probe(q, keys, interpret=True)
    want = ref.semijoin_probe_ref(q, keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    ks = jnp.sort(keys)
    lo, hi = sorted_probe_ranges(q, ks, interpret=True)
    rlo, rhi = ref.sorted_probe_ranges_ref(q, ks)
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))
    # the ranges really are match ranges: hi > lo <=> membership
    np.testing.assert_array_equal(np.asarray(hi > lo), np.asarray(want))


@pytest.mark.parametrize("ar", [1, 2, 3, 4])
@pytest.mark.parametrize("p", [1, 3, 4, 7, 31])  # incl. non-powers-of-two
def test_hash_partition_ragged_edges(ar, p):
    rng = np.random.default_rng(ar * 100 + p)
    n = ROWS_BLK + 17  # not a multiple of the row block
    rows = jnp.asarray(
        rng.choice(EDGE_VALS + [I32MAX, I32MIN], size=(n, ar)).astype(np.int32)
    )
    valid = jnp.asarray(rng.random(n) < 0.8)
    cols = tuple(range(ar))[: max(1, ar - 1)]
    for seed in (0, 13):
        got = hash_partition(rows, valid, cols, p, seed, interpret=True)
        want = ref.hash_partition_ref(rows, valid, cols, p, seed)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        g, v = np.asarray(got), np.asarray(valid)
        assert (g[v] < p).all() and (g[~v] == p).all()


def test_hash_partition_traced_seed_matches_static():
    """Regression: seed is a traced operand — a traced uint32 seed must
    hash identically to the same python-int seed (and to the jnp ref)."""
    rng = np.random.default_rng(3)
    rows = jnp.asarray(rng.integers(-100, 100, (ROWS_BLK + 17, 3)), jnp.int32)
    valid = jnp.asarray(rng.random(ROWS_BLK + 17) < 0.8)
    for seed in (0, 13, 2**32 - 1):
        a = hash_partition(rows, valid, (1, 0), 7, seed, interpret=True)
        b = hash_partition(rows, valid, (1, 0), 7, jnp.uint32(seed), interpret=True)
        c = ref.hash_partition_ref(rows, valid, (1, 0), 7, seed)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_hash_partition_traced_seed_no_recompile():
    """Distinct traced seeds must reuse ONE compiled program (the whole
    point of taking the seed as data: reseeded abort-retries are free)."""
    from repro.kernels.hash_partition import _partition_call

    rng = np.random.default_rng(4)
    rows = jnp.asarray(rng.integers(0, 100, (64, 2)), jnp.int32)
    valid = jnp.asarray(np.ones(64, bool))
    n0 = _partition_call._cache_size()
    for s in range(5):
        hash_partition(rows, valid, (0,), 4, jnp.uint32(s), interpret=True)
    assert _partition_call._cache_size() - n0 <= 1


def test_all_invalid_inputs():
    """Empty and all-invalid inputs at block-unaligned sizes."""
    q = jnp.asarray([1, 2, 3], jnp.int32)
    no_keys = jnp.zeros((0,), jnp.int32)
    assert not np.asarray(semijoin_probe(q, no_keys, interpret=True)).any()
    lo, hi = sorted_probe_ranges(q, no_keys, interpret=True)
    assert (np.asarray(lo) == 0).all() and (np.asarray(hi) == 0).all()

    all_invalid = jnp.full((13,), I32MAX, jnp.int32)
    assert not np.asarray(semijoin_probe(q, all_invalid, interpret=True)).any()
    lo, hi = sorted_probe_ranges(q, all_invalid, interpret=True)
    assert (np.asarray(lo) == 0).all() and (np.asarray(hi) == 0).all()

    rows = jnp.zeros((5, 2), jnp.int32)
    invalid = jnp.zeros((5,), bool)
    got = hash_partition(rows, invalid, (0,), 4, 9, interpret=True)
    assert (np.asarray(got) == 4).all()


# ------------------------------------------------- hypothesis fuzzers
if HAVE_HYPOTHESIS:
    _sizes = st.integers(min_value=0, max_value=40)
    _vals = st.one_of(
        st.integers(min_value=-5, max_value=5),
        st.sampled_from([I32MAX - 1, I32MIN + 1, I32MIN + 2, 0]),
    )

    @st.composite
    def probe_case(draw):
        n = draw(_sizes)
        m = draw(_sizes)
        q = draw(st.lists(_vals, min_size=n, max_size=n))
        nvalid = draw(st.integers(min_value=0, max_value=m))
        keys = draw(st.lists(_vals, min_size=nvalid, max_size=nvalid))
        keys = keys + [I32MAX] * (m - nvalid)  # invalid slots = pad sentinel
        return (
            jnp.asarray(np.asarray(q, np.int32).reshape(n)),
            jnp.asarray(np.asarray(keys, np.int32).reshape(m)),
        )

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(probe_case())
    def test_semijoin_probe_property(case):
        q, keys = case
        got = semijoin_probe(q, keys, interpret=True)
        want = ref.semijoin_probe_ref(q, keys)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(probe_case())
    def test_sorted_probe_property(case):
        q, keys = case
        # contract: probes < INT32_MAX; keys sorted (sentinels to the back)
        q = jnp.minimum(q, I32MAX - 1)
        keys = jnp.sort(keys)
        lo, hi = sorted_probe_ranges(q, keys, interpret=True)
        rlo, rhi = ref.sorted_probe_ranges_ref(q, keys)
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))

    @st.composite
    def partition_case(draw):
        n = draw(st.integers(min_value=1, max_value=ROWS_BLK + 40))
        ar = draw(st.integers(min_value=1, max_value=4))
        ncols = draw(st.integers(min_value=1, max_value=ar))
        cols = tuple(draw(st.permutations(range(ar)))[:ncols])
        p = draw(st.sampled_from([1, 2, 3, 4, 7, 16, 31]))
        seed = draw(st.integers(min_value=0, max_value=2**32 - 1))
        rows = draw(
            st.lists(
                st.lists(_vals, min_size=ar, max_size=ar), min_size=n, max_size=n
            )
        )
        valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
        return (
            jnp.asarray(np.asarray(rows, np.int32).reshape(n, ar)),
            jnp.asarray(np.asarray(valid, bool).reshape(n)),
            cols,
            p,
            seed,
        )

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(partition_case())
    def test_hash_partition_property(case):
        rows, valid, cols, p, seed = case
        got = hash_partition(rows, valid, cols, p, seed, interpret=True)
        want = ref.hash_partition_ref(rows, valid, cols, p, seed)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
