"""Packed wire format: the codec must be an exact inverse pair for any
values that fit their column widths (round-trip identity, pinned by a
hypothesis property over random schemas/widths/occupancies and a golden
byte fixture of one S_8 exchange buffer), and a packed end-to-end run
must be bit-identical to dense (rows, comm_tuples, retries) while
shipping strictly fewer payload bytes — across engines, fusion, and
calibration, and across a snapshot/resume boundary."""
from __future__ import annotations

import os
import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse
from repro.relational.spmd import SPMD
from repro.relational.wire import (
    WireFormat,
    WirePolicy,
    codec_roundtrip,
    count_wire_bytes,
    dense_wire_bytes,
    get_codec,
    pack_segments,
    packed_wire_bytes,
    split_segments,
    value_bits,
    wire_decode,
    wire_encode,
    wire_overflow,
)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


# ------------------------------------------------------------- width policy
def test_value_bits_boundaries():
    assert value_bits(0, 0) == 1
    assert value_bits(0, 1) == 1
    assert value_bits(0, 2) == 2
    assert value_bits(0, 63) == 6
    assert value_bits(0, 64) == 7
    assert value_bits(0, 2**31 - 1) == 31
    # negatives fall back to the full bitcast width
    assert value_bits(-1, 5) == 32


def test_format_shapes_and_bucket_bytes():
    fmt = WireFormat((6, 6))
    assert fmt.arity == 2
    assert fmt.row_bits == 13  # 1 valid bit + 2 x 6
    # one group of 8 slots packs to exactly row_bits bytes
    assert fmt.bucket_bytes(8) == 13
    assert fmt.bucket_bytes(9) == 26  # padded up to two groups
    assert fmt.bucket_bytes(0) == 0
    # the dense sibling of the same bucket: 8 slots x (2*4B + 1B valid)
    assert dense_wire_bytes(1, 8, 2) == 8 * 9
    assert packed_wire_bytes(4, 8, fmt) == 16 * 13
    assert count_wire_bytes(4, n=3) == 3 * 16 * 4


def test_union_is_widest_per_column():
    u = WireFormat.union([WireFormat((3, 9)), WireFormat((5, 2))])
    assert u.col_bits == (5, 9)
    with pytest.raises(AssertionError):
        WireFormat.union([WireFormat((3,)), WireFormat((3, 3))])


def test_policy_covers_every_base_column_of_an_attribute():
    pol = WirePolicy.from_columns(
        [
            (("A", "B"), np.asarray([[3, 200], [1, 5]], np.int32)),
            (("B", "C"), np.asarray([[7, 1]], np.int32)),
            (("D",), np.zeros((0, 1), np.int32)),  # empty: packs to 1 bit
        ]
    )
    assert pol.bits_for("A") == 2
    assert pol.bits_for("B") == 8  # covers 200 from the FIRST relation
    assert pol.bits_for("C") == 1
    assert pol.bits_for("D") == 1
    assert pol.bits_for("Z") == 32  # unknown attrs stay at full width
    assert pol.format_for(("B", "A")).col_bits == (8, 2)


# ------------------------------------------------------------------- codec
def _roundtrip(buf, valid, fmt):
    wire = wire_encode(jnp.asarray(buf), jnp.asarray(valid), fmt)
    assert wire.dtype == jnp.uint8
    assert wire.shape[-1] == fmt.bucket_bytes(valid.shape[-1])
    got_buf, got_valid = wire_decode(wire, fmt, valid.shape[-1])
    assert np.array_equal(np.asarray(got_buf), buf)
    assert np.array_equal(np.asarray(got_valid), valid)
    return np.asarray(wire)


def test_roundtrip_exact_deterministic():
    fmt = WireFormat((6, 6))
    rng = np.random.default_rng(0)
    for c in (1, 7, 8, 16, 33):  # non-multiples of 8 exercise the padding
        buf = rng.integers(0, 64, (c, 2)).astype(np.int32)
        valid = rng.integers(0, 2, (c,)).astype(bool)
        _roundtrip(buf, valid, fmt)


def test_roundtrip_leading_batch_dims():
    # the exchange encodes (p, c_out, arity) buckets in one call
    fmt = WireFormat((4, 9, 1))
    rng = np.random.default_rng(1)
    buf = np.stack(
        [rng.integers(0, 2**b, (4, 16)) for b in fmt.col_bits], axis=-1
    ).astype(np.int32)
    valid = rng.integers(0, 2, (4, 16)).astype(bool)
    _roundtrip(buf, valid, fmt)


def test_roundtrip_32bit_column_carries_negatives():
    fmt = WireFormat((32,))
    buf = np.asarray([[-1], [-(2**31)], [2**31 - 1], [0]], np.int32)
    valid = np.asarray([True, True, True, False])
    _roundtrip(buf, valid, fmt)


def test_roundtrip_arity_zero_and_empty_full_shards():
    fmt = WireFormat(())
    assert fmt.row_bits == 1
    for valid in (np.zeros(12, bool), np.ones(12, bool)):
        buf = np.zeros((12, 0), np.int32)
        _roundtrip(buf, valid, fmt)


def test_wire_overflow_flags_valid_rows_only():
    fmt = WireFormat((3, 32))
    buf = np.asarray([[7, -5], [8, 0], [9, 1]], np.int32)
    valid = np.asarray([True, True, False])
    bad = np.asarray(wire_overflow(jnp.asarray(buf), jnp.asarray(valid), fmt))
    # row 0 fits (32-bit col takes any int32); row 1 overflows its 3-bit
    # column; row 2 would overflow but is invalid
    assert bad.tolist() == [False, True, False]


def test_pack_split_segments_roundtrip():
    rng = np.random.default_rng(2)
    parts = [jnp.asarray(rng.integers(0, 256, (4, n)), jnp.uint8) for n in (3, 1, 8)]
    seg = pack_segments(parts)
    assert seg.shape == (4, 12)
    back = split_segments(seg, [3, 1, 8])
    for a, b in zip(parts, back):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(AssertionError):
        split_segments(seg, [3, 1])  # sizes must cover the buffer


def test_codec_registry_raw_is_identity():
    buf = jnp.asarray(np.arange(24, dtype=np.uint8).reshape(2, 12))
    assert np.array_equal(np.asarray(codec_roundtrip(buf, "raw")), np.asarray(buf))
    enc, dec = get_codec("raw")
    payload, aux = enc(buf)
    assert payload is buf
    with pytest.raises(KeyError):
        get_codec("no-such-codec")


# ------------------------------------------------- property: random schemas
def test_roundtrip_property_random_schemas():
    """Round-trip identity over random schemas, widths, occupancies and
    value ranges — including empty and full shards, bucket sizes that are
    not a multiple of 8, arity 0, and full-width negative columns."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        col_bits=st.lists(st.integers(1, 32), min_size=0, max_size=4),
        c=st.integers(1, 40),
        occupancy=st.sampled_from(["empty", "full", "random"]),
    )
    def prop(seed, col_bits, c, occupancy):
        fmt = WireFormat(tuple(col_bits))
        rng = np.random.default_rng(seed)
        cols = []
        for nb in col_bits:
            if nb == 32:  # full width: any int32, sign bit included
                col = rng.integers(-(2**31), 2**31, (c,), dtype=np.int64)
            else:
                col = rng.integers(0, 2**nb, (c,), dtype=np.int64)
            cols.append(col.astype(np.int32))
        buf = (
            np.stack(cols, axis=-1)
            if cols
            else np.zeros((c, 0), np.int32)
        )
        if occupancy == "empty":
            valid = np.zeros(c, bool)
        elif occupancy == "full":
            valid = np.ones(c, bool)
        else:
            valid = rng.integers(0, 2, (c,)).astype(bool)
        assert not np.asarray(
            wire_overflow(jnp.asarray(buf), jnp.asarray(valid), fmt)
        ).any()
        _roundtrip(buf, valid, fmt)

    prop()


# -------------------------------------------------------- golden fixture
def test_golden_fixture_pins_s8_packed_bytes():
    """Byte-level snapshot of one S_8 packed exchange buffer: the hub
    relation of the bench dataset, bucketized deterministically at p=8,
    encoded with the policy-derived format.  Any change to the bit
    layout (bit order, valid-bit position, group transpose, padding)
    shows up here as a byte diff — regenerate ONLY with an explicit
    format-version bump (scripts in the fixture header)."""
    q = star_query(8)
    data = star_data_sparse(8, domain=64, hub_rows=256, spoke_extra=64, seed=21)
    pol = WirePolicy.from_columns(
        [(a.attrs, data[a.rel]) for a in q.atoms]
    )
    hub = next(a for a in q.atoms if len(a.attrs) > 2)
    fmt = pol.format_for(hub.attrs)
    # the policy covers every base column of an attribute: the spokes
    # carry hub attrs at full domain width, so 6 bits each
    assert fmt.col_bits == (6,) * 7

    # deterministic bucketization: row i of the (deduped) hub lands in
    # bucket i % 8, slot i // 8, c_out=32; the tail slots stay invalid
    rows = np.unique(data[hub.rel], axis=0)[:200]
    p, c_out = 8, 32
    buf = np.zeros((p, c_out, rows.shape[1]), np.int32)
    valid = np.zeros((p, c_out), bool)
    for i, r in enumerate(rows):
        buf[i % p, i // p] = r
        valid[i % p, i // p] = True
    wire = _roundtrip(buf, valid, fmt)
    assert wire.shape == (p, fmt.bucket_bytes(c_out))

    path = os.path.join(FIXTURES, "wire_s8_packed.npz")
    assert os.path.exists(path), (
        f"golden fixture missing: {path} — regenerate with "
        "scripts/make_wire_fixture.py"
    )
    z = np.load(path)
    assert tuple(z["col_bits"].tolist()) == fmt.col_bits
    assert np.array_equal(z["wire"], wire), (
        "packed bit layout drifted from the golden fixture"
    )
    # and the fixture bytes decode back to the exact buckets
    got_buf, got_valid = wire_decode(jnp.asarray(z["wire"]), fmt, c_out)
    assert np.array_equal(np.asarray(got_buf), buf)
    assert np.array_equal(np.asarray(got_valid), valid)


# ---------------------------------------------- differential: packed = dense
CASES = {
    "chain": lambda: (chain_query(4), chain_ghd(4), chain_data_sparse(4, seed=7)),
    "star": lambda: (star_query(5), star_ghd(5), star_data_sparse(5, seed=9)),
    "tc": lambda: (
        triangle_chain_query(2),
        triangle_chain_ghd(2),
        tc_data_sparse(2, seed=8),
    ),
}


def _run(qname, strategy, fused, calibrate, wire_format):
    q, g, data = CASES[qname]()
    rows, _, led = gym(
        q, data, ghd=g, p=4,
        config=GymConfig(
            strategy=strategy, seed=3, fused=fused,
            calibrate_shuffle=calibrate, wire_format=wire_format,
        ),
    )
    return sorted(map(tuple, rows)), led


def _assert_parity(packed, dense, key):
    rows_p, led_p = packed
    rows_d, led_d = dense
    assert rows_p == rows_d, key
    assert led_p.comm_tuples == led_d.comm_tuples, key
    assert led_p.shuffle_tuples == led_d.shuffle_tuples, key
    assert led_p.retries == led_d.retries == 0, key
    assert led_p.rounds == led_d.rounds, key
    # the useful payload is mode-independent by construction; the wire
    # bytes are what packing shrinks.  (padded_slots is NOT compared:
    # the packed join pre-count ships multi-column key slots where dense
    # ships a width-1 hashed column.)
    assert led_p.useful_bytes == led_d.useful_bytes, key
    assert led_p.payload_bytes < led_d.payload_bytes, key
    assert led_p.payload_efficiency_bytes > led_d.payload_efficiency_bytes, key


def test_packed_vs_dense_parity_fast():
    """Fast-lane pin of the differential property: packed moves the SAME
    rows/comm/retries as dense while shipping strictly fewer bytes."""
    _assert_parity(
        _run("chain", "hash", True, True, "packed"),
        _run("chain", "hash", True, True, "dense"),
        ("chain", "hash"),
    )


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["hash", "grid", "hybrid"])
@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("qname", sorted(CASES))
def test_packed_vs_dense_parity_calibrated(strategy, fused, qname):
    """The full matrix at calibrated capacities: three engines x
    fused/sequential x three query shapes."""
    key = (qname, strategy, fused)
    _assert_parity(
        _run(qname, strategy, fused, True, "packed"),
        _run(qname, strategy, fused, True, "dense"),
        key,
    )


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["hash", "grid", "hybrid"])
def test_packed_vs_dense_parity_fixed_caps(strategy):
    """Packing is orthogonal to calibration: parity must also hold at
    fixed worst-case capacities."""
    _assert_parity(
        _run("chain", strategy, True, False, "packed"),
        _run("chain", strategy, True, False, "dense"),
        ("chain", strategy, "fixed"),
    )


# ------------------------------------------------------- snapshot / resume
@pytest.mark.slow
def test_snapshot_roundtrips_wire_format(tmp_path):
    """A packed run snapshotted mid-query must resume PACKED even when
    the resuming driver was constructed dense — the snapshot's config
    wins — and still produce the dense run's exact rows."""
    q, g, data = CASES["chain"]()
    spmd = SPMD(4)
    cfg_p = GymConfig(
        strategy="hash", seed=3, calibrate_shuffle=True, wire_format="packed"
    )
    want, _, _ = gym(q, data, ghd=g, p=4, config=dataclasses_replace_dense(cfg_p))

    drv = GymDriver(q, g, data, spmd, cfg_p)
    drv.step()
    snap = str(tmp_path / "wire_snapshot.npz")
    drv.save(snap)

    cfg_d = dataclasses_replace_dense(cfg_p)
    drv2 = GymDriver(q, g, data, SPMD(4), cfg_d)
    drv2.load(snap)
    assert drv2.config.wire_format == "packed"  # the snapshot's config wins
    assert drv2.executor.engine.wire_policy is not None
    out = sorted(map(tuple, drv2.run().to_numpy()))
    assert out == sorted(map(tuple, np.asarray(want)))


def dataclasses_replace_dense(cfg):
    import dataclasses

    return dataclasses.replace(cfg, wire_format="dense")
