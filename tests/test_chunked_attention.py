"""Property tests: the chunked (flash-in-XLA) attention path must agree
with the dense reference across random shapes/flags, including the
gradient (it is the production train path in the dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.chunked import chunked_attention
from repro.kernels.ref import attention_ref


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    kvh=st.integers(1, 3),
    g=st.integers(1, 3),
    sq=st.integers(1, 70),
    sk=st.integers(1, 70),
    d=st.sampled_from([4, 16]),
    causal=st.booleans(),
    chunk=st.sampled_from([8, 16, 64]),
)
@pytest.mark.slow
def test_chunked_matches_dense(b, kvh, g, sq, sk, d, causal, chunk):
    if causal and sq != sk:
        sk = sq  # causal masks assume aligned positions here
    h = kvh * g
    rng = np.random.default_rng(b * 1000 + sq * 10 + sk)
    q = jnp.asarray(rng.standard_normal((b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kvh, sk, d)), jnp.float32)
    got = chunked_attention(q, k, v, causal=causal, chunk=chunk)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("window,softcap", [(16, 0.0), (0, 20.0), (8, 10.0)])
def test_chunked_window_softcap(window, softcap):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 4, 96, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 96, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 96, 16)), jnp.float32)
    got = chunked_attention(
        q, k, v, causal=True, window=window, softcap=softcap, chunk=32
    )
    want = attention_ref(q, k, v, causal=True, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_chunked_gradients_match_dense():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 48, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 48, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 48, 8)), jnp.float32)

    def loss_chunked(q, k, v):
        return chunked_attention(q, k, v, causal=True, chunk=16).sum()

    def loss_dense(q, k, v):
        return attention_ref(q, k, v, causal=True).astype(jnp.float32).sum()

    gc = jax.grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)


def test_chunked_fully_masked_rows_are_zero():
    # window=1 + causal: row 0 sees only itself; a fully-masked row can't
    # occur causally, so craft one via cross lengths: sq > sk with causal
    q = jnp.ones((1, 1, 8, 4), jnp.float32)
    k = jnp.ones((1, 1, 4, 4), jnp.float32)
    v = jnp.ones((1, 1, 4, 4), jnp.float32)
    out = chunked_attention(q, k, v, causal=False, window=0, chunk=2)
    assert bool(jnp.isfinite(out).all())
