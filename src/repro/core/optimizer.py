"""Cost-based plan advisor: enumerate (GHD x schedule x engine x fusion)
candidates, score them with the paper's formulas (``core/costs.py``),
and return the argmin as an executable ``Plan``.

The paper's headline contribution is a *spectrum* of round/communication
tradeoffs: the same query runs as O(n)-round DYM on a width-w GHD
(Theorem 12), O(log n)-round GYM on a Log-GTA decomposition of width
max(w, 3iw) (Theorem 23), or anywhere in between via C-GTA (Theorem 25).
This module turns that spectrum into a decision:

  1. **GHD candidates** — the hand GHD (if given), the generic
     ``ghd_for`` construction, Log-GTA (Sec. 6), Log-GTA' (Appendix
     D.2), and one C-GTA pass composed with Log-GTA (Sec. 7), deduped by
     structural signature.
  2. **Schedules** — every entry of ``planner.SCHEDULES`` (``dym_n``:
     Sec. 4.2 / Theorem 12; ``dym_d``: Sec. 4.3 / Theorem 14).
  3. **Engines** — the ``core.physical`` strategy registry: ``'hash'``
     (comm ~ inputs+outputs, skew-sensitive), ``'grid'`` (Lemmas 8/10,
     skew-proof, B(X, M) = X^2/M), and ``'hybrid'`` (heavy-hitter
     routing on the count pre-pass: hash for light keys, grid-style
     spread/broadcast for heavy ones).  With a ``skew`` statistic
     (``skew_share`` / ``skew_from_data``) the model prices hash by its
     MAX per-destination load, so skewed instances steer to hybrid; ties
     on uniform data resolve to hash by key order.
  4. **Fusion** — one SPMD dispatch per homogeneous op group, or one
     per op.  Identical comm/rounds; distinguished by the predicted
     dispatch count.

Scoring walks the *actual* schedule op-by-op (``predict_plan_cost``)
under a machine profile (p, M) and an optional ``CostCalibration``
fitted from measured ``Ledger`` numbers.  Ranking is lexicographic:
predicted WIRE slots (communication inflated by the shuffle pad factor
for the configured capacity policy — what the all_to_all actually
ships), then calibrated predicted communication, then claimed BSP
rounds, then predicted dispatches — the paper's two cost metrics
(Sec. 3.2) seen through the physical shuffle, plus the engine's own
measure of dispatch overhead.

``explain()`` renders the full candidate table (plain text or markdown,
with predicted-vs-measured error when ledgers are supplied), so the
advisor doubles as the repo's teaching tool.  ``GymConfig(plan="auto")``
runs ``choose_plan`` inside the driver and executes the winner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .cgta import cgta
from .costs import (
    OP_STAGES,
    CostCalibration,
    predict_plan_cost,
)
from .decompose import ghd_for
from .ghd import GHD
from .hypergraph import Query
from .loggta import log_gta
from .loggta_prime import log_gta_prime
from .planner import SCHEDULES, Round, get_schedule


# --------------------------------------------------------------------------
# inputs: machine profile + table statistics
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """The paper's machine model (Sec. 3.2): p machines with M tuples of
    memory each.  ``M=None`` derives a default from the input size —
    4 * IN / p, floored — matching Assumption 3 (inputs fit with room to
    rehash)."""

    p: int = 4
    M: Optional[float] = None
    # wire-slot-equivalent price of one program dispatch (see
    # ``costs.DEFAULT_DISPATCH_OVERHEAD_SLOTS``); 0 keeps the classic
    # pure-volume ranking.  Nonzero lets the advisor charge the count
    # pre-pass for its dispatches and decide calibrated-vs-fixed per
    # query (``enumerate_plans(calibrate_options=...)``).
    dispatch_overhead: float = 0.0

    def memory(self, total_input: float) -> float:
        if self.M is not None:
            return float(self.M)
        return max(16.0, 4.0 * float(total_input) / max(1, self.p))


def skew_share(rows: np.ndarray) -> float:
    """Max single-value column share of a relation: the fraction of rows
    carrying the most frequent value of any one column — the ``share``
    that ``costs.skew_amplification`` turns into a hot-reducer load
    factor.  0.0 for empty relations; ~1/|domain| on uniform data."""
    rows = np.asarray(rows)
    if rows.size == 0:
        return 0.0
    rows = rows.reshape(rows.shape[0], -1)
    n = rows.shape[0]
    share = 0.0
    for c in range(rows.shape[1]):
        _, counts = np.unique(rows[:, c], return_counts=True)
        share = max(share, float(counts.max()) / n)
    return share


def skew_from_data(
    query: Query, data: Mapping[str, np.ndarray]
) -> Dict[str, float]:
    """Per-relation ``skew_share`` under the SAME cast+dedup the driver
    applies on load (mirrors ``stats_from_data``)."""
    out: Dict[str, float] = {}
    for atom in query.atoms:
        if atom.rel in out:
            continue
        rows = np.asarray(data[atom.rel], dtype=np.int32).reshape(
            -1, len(atom.attrs)
        )
        if rows.shape[0]:
            rows = np.unique(rows, axis=0)
        out[atom.rel] = skew_share(rows)
    return out


def stats_from_data(query: Query, data: Mapping[str, np.ndarray]) -> Dict[str, int]:
    """Table-size statistics (distinct rows per base relation) — the
    driver casts to int32 and dedups relations on load
    (``GymDriver.__init__``), so the SAME cast+dedup here guarantees the
    advisor scores exactly the tables the engine will see."""
    sizes: Dict[str, int] = {}
    for atom in query.atoms:
        if atom.rel in sizes:
            continue
        rows = np.asarray(data[atom.rel], dtype=np.int32).reshape(
            -1, len(atom.attrs)
        )
        sizes[atom.rel] = (
            int(np.unique(rows, axis=0).shape[0]) if rows.shape[0] else 0
        )
    return sizes


# --------------------------------------------------------------------------
# the Plan: a fully-resolved, directly-executable choice
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Plan:
    """One point on the paper's tradeoff spectrum, resolved to something
    the driver can execute: a complete GHD plus the engine knobs.

    ``key`` is the stable identity (``source|schedule|engine|fusion``)
    used by explain() tables, measured-ledger joins, and snapshots
    (``GymConfig.plan`` records it so resume stays on the same plan).
    """

    key: str
    ghd_source: str  # 'hand' | 'auto' | 'loggta' | 'loggta_prime' | 'cgta1'
    schedule: str  # planner.SCHEDULES name
    engine: str  # physical.ENGINES name
    fused: bool
    local_backend: str
    ghd: GHD  # complete (Lemma 7) form
    width: int
    depth: int
    iw: int
    nodes: int
    predicted_comm: float
    predicted_wire: float  # comm inflated by the shuffle pad factor
    predicted_rounds: float
    predicted_dispatches: float
    out_est: float
    calibrated: bool
    # the shuffle capacity policy this plan was priced under, when the
    # enumeration competed calibrated against fixed
    # (``calibrate_options``); None = the policy wasn't part of the
    # decision and the executing config's own knob stands.
    calibrate_shuffle: Optional[bool] = None
    # predicted count-pre-pass dispatches under amortized calibration
    # (0 for fixed-capacity plans)
    predicted_measure_dispatches: float = 0.0

    def to_config(self, base=None):
        """A ``GymConfig`` with this plan's choices applied (engine,
        schedule, fusion, backend) and ``plan`` set to the key so
        snapshots round-trip the decision."""
        from .gym import GymConfig

        base = base if base is not None else GymConfig()
        cfg = dataclasses.replace(
            base,
            strategy=self.engine,
            schedule=self.schedule,
            fused=self.fused,
            local_backend=self.local_backend,
            plan=self.key,
        )
        if self.calibrate_shuffle is not None:
            cfg = dataclasses.replace(
                cfg, calibrate_shuffle=self.calibrate_shuffle
            )
        return cfg


def _plan_order(p: Plan) -> Tuple:
    # ranked by what the wire actually carries (padded slots), then the
    # paper's two metrics, then dispatch overhead
    return (
        p.predicted_wire,
        p.predicted_comm,
        p.predicted_rounds,
        p.predicted_dispatches,
        p.key,
    )


# --------------------------------------------------------------------------
# candidate enumeration
# --------------------------------------------------------------------------
def candidate_ghds(
    query: Query, hand_ghd: Optional[GHD] = None
) -> List[Tuple[str, GHD]]:
    """The GHD leg of the spectrum, all in complete (Lemma 7) form:
    hand / auto (GYO or min-fill) / Log-GTA / Log-GTA' / C-GTA+Log-GTA.
    Structurally identical candidates are deduped (first source wins, so
    'hand' shadows an identical 'auto')."""
    out: List[Tuple[str, GHD]] = []
    seen: set = set()

    def add(source: str, g: GHD) -> None:
        try:
            gc = g.make_complete(query)
        except (AssertionError, ValueError):
            return
        sig = tuple(
            sorted(
                (tuple(sorted(gc.chi[v])), tuple(sorted(gc.lam[v])))
                for v in gc.nodes()
            )
        ) + (gc.depth,)
        if sig in seen:
            return
        seen.add(sig)
        out.append((source, gc))

    if hand_ghd is not None:
        add("hand", hand_ghd)
    add("auto", ghd_for(query))
    if not out:
        raise ValueError(
            f"no valid GHD candidate for query {query.name!r}: the hand GHD "
            "(if any) and the constructed one both failed completion"
        )
    base = out[0][1]  # best-known starting point for the transforms
    for source, transform in (
        ("loggta", lambda g: log_gta(g, query)),
        ("loggta_prime", lambda g: log_gta_prime(g, query)),
        ("cgta1", lambda g: cgta(g, query, passes=1)),
    ):
        try:
            add(source, transform(base.copy()))
        except (AssertionError, ValueError):
            continue  # transform not applicable (e.g. trivial trees)
    return out


def _predicted_dispatches(rounds: Sequence[Round], fused: bool) -> float:
    """Schedule-phase dispatch estimate: fused execution issues ~one SPMD
    program per (stage, op kind) group; sequential issues one per
    physical op (``costs.OP_STAGES`` carries the per-stage instance
    counts of ``physical.lower_op``).  Materialization is counted as one
    — a deliberate simplification (its dispatch count varies per bag), so
    this column is a relative tie-break, not a measured-dispatch
    prediction."""
    total = 1.0  # materialization
    for rnd in rounds:
        per_stage: Dict[int, List] = {}
        for op in rnd.ops:
            for i, (sk, n_ops) in enumerate(OP_STAGES[op.kind]):
                per_stage.setdefault(i, []).append((sk, n_ops))
        for stage in per_stage.values():
            if fused:
                total += len({sk for sk, _ in stage})
            else:
                total += sum(n for _, n in stage)
    return total


def _predicted_measure_dispatches(rounds: Sequence[Round]) -> float:
    """Count-pre-pass dispatch estimate under AMORTIZED calibration: a
    stage shape pays one combined count dispatch (plus one fused
    keys-only output pre-count when it joins) the FIRST time it appears
    in a phase; repeats of the same shape hit the cross-round
    ``CapsCache`` for free.  Materialization's own measure counts one.
    Mirrors ``physical.PhysicalExecutor._measure_stage`` the way
    ``_predicted_dispatches`` mirrors the payload schedule."""
    total = 1.0  # materialization measure
    seen: set = set()
    for rnd in rounds:
        per_stage: Dict[int, set] = {}
        for op in rnd.ops:
            for i, (sk, _n) in enumerate(OP_STAGES[op.kind]):
                per_stage.setdefault(i, set()).add(sk)
        for i, kinds in per_stage.items():
            sig = (rnd.phase, i, frozenset(kinds))
            if sig in seen:
                continue
            seen.add(sig)
            total += 1.0
            if "join" in kinds:
                total += 1.0  # the fused join-output count pass
    return total


def enumerate_plans(
    query: Query,
    stats: Mapping[str, int],
    *,
    profile: Optional[MachineProfile] = None,
    hand_ghd: Optional[GHD] = None,
    calibration: Optional[CostCalibration] = None,
    local_backend: str = "jnp",
    engines: Sequence[str] = ("hash", "grid", "hybrid"),
    schedules: Optional[Sequence[str]] = None,
    fused_options: Sequence[bool] = (True, False),
    calibrate_shuffle: bool = True,
    skew: Optional[Mapping[str, float]] = None,
    skew_threshold: Optional[float] = None,
    calibrate_options: Optional[Sequence[bool]] = None,
    wire_gain: float = 1.0,
) -> List[Plan]:
    """Score every candidate plan; returns them best-first (by predicted
    wire slots under the given shuffle mode, see ``_plan_order``).

    ``wire_gain`` is the executing wire format's mean row compression
    ratio (``relational.wire.wire_gain``): 1.0 for the dense exchange,
    > 1 when ``GymConfig.wire_format == "packed"``.  It deflates the
    shuffle pad factor so a packed execution's plan ranking reflects
    the bytes its wire will actually carry.

    ``skew`` maps relation names to their max single-key share
    (``skew_from_data``); without it every engine prices at balanced
    load and hybrid ties with hash (hash wins the tie by key order).

    ``calibrate_options``: None (default) prices every plan under the
    single ``calibrate_shuffle`` mode and leaves the executing config's
    knob alone.  A sequence like ``(True, False)`` makes the capacity
    policy part of the decision: each candidate is scored per mode
    (key suffix ``|cal`` / ``|fixed``), the calibrated variant paying
    its predicted measure dispatches at ``profile.dispatch_overhead``
    wire slots each, the fixed variant paying the ~p-fold pad factor.
    The hybrid engine requires the pre-pass and never enumerates
    ``|fixed``."""
    profile = profile or MachineProfile()
    schedules = tuple(schedules) if schedules is not None else tuple(sorted(SCHEDULES))
    alias_sizes = {a.alias: float(stats[a.rel]) for a in query.atoms}
    alias_skew = (
        {a.alias: float(skew.get(a.rel, 0.0)) for a in query.atoms}
        if skew is not None
        else None
    )
    plans: List[Plan] = []
    for source, g in candidate_ghds(query, hand_ghd):
        width, depth, nodes = g.width, g.depth, g.size()
        iw = g.intersection_width(query)
        for sched in schedules:
            rounds = get_schedule(sched).fn(g)
            meas_est = _predicted_measure_dispatches(rounds)
            for engine in engines:
                if calibrate_options is None:
                    modes: List[Tuple[bool, str]] = [(calibrate_shuffle, "")]
                else:
                    modes = [
                        (bool(m), "|cal" if m else "|fixed")
                        for m in calibrate_options
                        # data-dependent routing NEEDS the pre-pass: the
                        # executor would force it back on anyway
                        if m or engine != "hybrid"
                    ]
                for fused in fused_options:
                    disp = _predicted_dispatches(rounds, fused)
                    for mode, suffix in modes:
                        meas = meas_est if mode else 0.0
                        cost = predict_plan_cost(
                            query, g, rounds, engine, alias_sizes,
                            profile.p, calibration,
                            calibrate_shuffle=mode,
                            alias_skew=alias_skew,
                            skew_threshold=skew_threshold,
                            dispatch_overhead=profile.dispatch_overhead,
                            dispatches=disp,
                            measure_dispatches=meas,
                            wire_gain=wire_gain,
                        )
                        plans.append(
                            Plan(
                                key=f"{source}|{sched}|{engine}|"
                                + ("fused" if fused else "seq")
                                + suffix,
                                ghd_source=source,
                                schedule=sched,
                                engine=engine,
                                fused=fused,
                                local_backend=local_backend,
                                ghd=g,
                                width=width,
                                depth=depth,
                                iw=iw,
                                nodes=nodes,
                                predicted_comm=cost["comm"],
                                predicted_wire=cost["wire"],
                                predicted_rounds=cost["rounds"],
                                predicted_dispatches=disp,
                                out_est=cost["out_est"],
                                calibrated=calibration is not None,
                                calibrate_shuffle=(
                                    None if calibrate_options is None else mode
                                ),
                                predicted_measure_dispatches=meas,
                            )
                        )
    plans.sort(key=_plan_order)
    return plans


def choose_plan(
    query: Query,
    stats: Mapping[str, int],
    *,
    profile: Optional[MachineProfile] = None,
    hand_ghd: Optional[GHD] = None,
    calibration: Optional[CostCalibration] = None,
    local_backend: str = "jnp",
    calibrate_shuffle: bool = True,
    skew: Optional[Mapping[str, float]] = None,
    skew_threshold: Optional[float] = None,
    calibrate_options: Optional[Sequence[bool]] = None,
    wire_gain: float = 1.0,
) -> Plan:
    """The advisor's decision: argmin over the candidate plans by
    (predicted wire slots under the configured shuffle mode, calibrated
    predicted comm, claimed rounds, predicted dispatches).  Pass the
    execution's ``GymConfig.calibrate_shuffle`` so the pad factor the
    ranking uses matches the shuffle the plan will actually run on, and
    ``skew`` (``skew_from_data``) so skewed instances price hash by its
    hot reducer and steer to the hybrid engine.  ``calibrate_options``
    (e.g. ``(True, False)`` with a nonzero ``profile.dispatch_overhead``)
    additionally lets the advisor decide per query whether the count
    pre-pass pays for itself (see ``enumerate_plans``)."""
    plans = enumerate_plans(
        query,
        stats,
        profile=profile,
        hand_ghd=hand_ghd,
        calibration=calibration,
        local_backend=local_backend,
        calibrate_shuffle=calibrate_shuffle,
        skew=skew,
        skew_threshold=skew_threshold,
        calibrate_options=calibrate_options,
        wire_gain=wire_gain,
    )
    assert plans, "no executable plan candidates"
    return plans[0]


# --------------------------------------------------------------------------
# explain(): the candidate table as a teaching tool
# --------------------------------------------------------------------------
def _measured_comm(entry) -> Optional[float]:
    if entry is None:
        return None
    if hasattr(entry, "comm_tuples"):  # a Ledger
        return float(entry.comm_tuples)
    return float(entry)


def _measured_padded(entry) -> Optional[Tuple[float, float]]:
    """(padded_slots, payload_efficiency) from a Ledger entry, or None for
    plain measured-comm numbers (which carry no wire accounting)."""
    if entry is None or not hasattr(entry, "padded_slots"):
        return None
    return float(entry.padded_slots), float(entry.payload_efficiency)


def _render_table(header: List[str], rows: List[List[str]], fmt: str) -> str:
    if fmt == "markdown":
        lines = [
            "| " + " | ".join(header) + " |",
            "|" + "|".join("---" for _ in header) + "|",
        ]
        lines += ["| " + " | ".join(r) + " |" for r in rows]
        return "\n".join(lines)
    widths = [
        max(len(header[i]), *(len(r[i]) for r in rows)) if rows else len(header[i])
        for i in range(len(header))
    ]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths)).rstrip()]
    lines.append("  ".join("-" * w for w in widths))
    lines += [
        "  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip() for r in rows
    ]
    return "\n".join(lines)


def _fmt_num(x: float) -> str:
    if x >= 1e6 or (x != 0 and x < 0.01):
        return f"{x:.3g}"
    if float(x).is_integer():
        return str(int(x))
    return f"{x:.1f}"


def explain(
    query: Query,
    stats: Mapping[str, int],
    *,
    hand_ghd: Optional[GHD] = None,
    profile: Optional[MachineProfile] = None,
    p: Optional[int] = None,
    M: Optional[float] = None,
    calibration: Optional[CostCalibration] = None,
    measured: Optional[Mapping[str, object]] = None,
    local_backend: str = "jnp",
    calibrate_shuffle: bool = True,
    skew: Optional[Mapping[str, float]] = None,
    fmt: str = "text",
) -> str:
    """Render the advisor's full candidate table.

    ``measured`` maps plan keys to ``Ledger`` objects (or plain measured
    comm numbers); when given, the table grows measured-comm,
    prediction-error, and wire-level (``meas_padded`` slots shipped /
    ``eff`` payload efficiency) columns, turning explain() into the
    predicted-vs-measured report of ``benchmarks/bench_optimizer.py``.
    Output is deterministic for fixed inputs (stable ordering and
    formatting), which the tests pin.
    """
    assert fmt in ("text", "markdown"), fmt
    profile = profile or MachineProfile(p=p if p is not None else 4, M=M)
    plans = enumerate_plans(
        query,
        stats,
        profile=profile,
        hand_ghd=hand_ghd,
        calibration=calibration,
        local_backend=local_backend,
        calibrate_shuffle=calibrate_shuffle,
        skew=skew,
    )
    chosen = plans[0]
    with_measured = measured is not None
    header = [
        "plan",
        "ghd(w/iw/d/n)",
        "pred_rounds",
        "pred_comm",
        "pred_wire",
        "pred_dispatches",
    ]
    if with_measured:
        header += ["meas_comm", "err", "meas_padded", "eff"]
    rows = []
    for pl in plans:
        mark = "*" if pl.key == chosen.key else " "
        row = [
            f"{mark} {pl.key}",
            f"{pl.width}/{pl.iw}/{pl.depth}/{pl.nodes}",
            _fmt_num(pl.predicted_rounds),
            _fmt_num(pl.predicted_comm),
            _fmt_num(pl.predicted_wire),
            _fmt_num(pl.predicted_dispatches),
        ]
        if with_measured:
            entry = measured.get(pl.key)
            meas = _measured_comm(entry)
            if meas is None:
                row += ["-", "-"]
            else:
                err = (pl.predicted_comm - meas) / max(1.0, meas)
                row += [_fmt_num(meas), f"{100 * err:+.0f}%"]
            pad = _measured_padded(entry)
            if pad is None:
                row += ["-", "-"]
            else:
                row += [_fmt_num(pad[0]), f"{pad[1]:.2f}"]
        rows.append(row)
    total_in = sum(float(stats[a.rel]) for a in query.atoms)
    cal = (
        "none"
        if calibration is None
        else " ".join(
            f"{e}x{s:.3g}" for e, s in sorted(calibration.comm_scale.items())
        )
        or "identity"
    )
    body = _render_table(header, rows, fmt)
    footer = (
        f"query={query.name} atoms={query.n} IN={_fmt_num(total_in)} "
        f"profile: p={profile.p} M={_fmt_num(profile.memory(total_in))} "
        f"calibration: {cal}\n"
        f"chosen: {chosen.key} — lowest predicted wire slots (comm x "
        f"shuffle pad factor), then predicted comm, then claimed BSP "
        f"rounds ({get_schedule(chosen.schedule).paper}, "
        f"{get_schedule(chosen.schedule).claimed_rounds}), then dispatches"
    )
    return body + "\n" + footer
