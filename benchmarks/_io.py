"""Shared bench I/O: atomic JSON trajectory writes.

The ``BENCH_*.json`` trajectories at the repo root are committed
baselines future PRs regress against; the ``BENCH_*.partial.json``
siblings are per-run smoke artifacts.  Either way a plain ``open(path,
"w")`` that dies mid-``json.dump`` (Ctrl-C, OOM, CI timeout) leaves a
truncated file — which for the committed baselines means a corrupted
regression reference.  Write to a tempfile in the destination directory
and ``os.replace`` (atomic on POSIX): readers see the old content or the
new, never a torn write."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any


def write_json_atomic(path: str, obj: Any) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".bench-", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
