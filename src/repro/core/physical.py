"""Physical execution layer: logical planner rounds -> fused SPMD dispatches.

The planner (``planner.py``) emits *logical* rounds — sets of independent
semijoin/intersect/join ops that the BSP model (Theorem 15 / Sec. 4.3)
charges as ONE round.  This module makes the engine keep that promise:

  1. **Lowering** — each logical ``Op`` becomes a short dataflow of
     *physical* ops (``PhysOp``) over named slots, arranged in stages.
     Every op in a stage is independent, so a stage is one BSP round.
  2. **Grouping** — within a stage, physical ops with the same kind and
     uniform static signature (shard shapes, key count, capacity) form an
     op group.
  3. **Fused dispatch** — each group executes as ONE SPMD program via the
     stacked operators in ``relational.batched`` (one ``all_to_all`` per
     shuffle stage for the whole group), instead of one program per op.

Engine strategies are a registry (``register_engine``): ``'hash'`` — hash
co-partitioning, comm ~ inputs+outputs, skew-sensitive with abort-retry;
``'grid'`` — the paper's skew-proof Lemma 8/10 grid operators;
``'hybrid'`` — heavy/light decomposition on top of the count pre-pass
(``relational.skew``): light keys hash, heavy keys route grid-style
(spread + broadcast), so the engine is comm-optimal on uniform data AND
capacity-bounded under skew.  New strategies subclass ``Engine`` and
register under a new name; the driver selects them by string.

Capacity sizing and the paper's abort-and-retry semantics live in
``CapacityManager``: heuristic initial caps, multiplicative growth on
overflow, and — for blown joins — an EXACT key-only counting dispatch
(``dist_join_count`` / ``local_join_count``) that floors the retry at the
true output size instead of guessing upward by powers of the growth
factor.

Occupancy-adaptive shuffle (``calibrate=True``, the default): a count-only
pre-pass (``relational.batched`` — a (p,)-int ``all_to_all`` of bucket
counts) sizes every exchange with tight pow2 send/receive capacities
instead of the global worst case.  Capacities stay pow2-bucketed
(``SideCaps``), so calibrated programs are reused across rounds with
different occupancies; when the measured arrival (or, for hash joins, the
exact pre-counted output) exceeds a managed capacity, the capacity is
pre-floored and the round that would have aborted never does.

Amortized calibration makes the pre-pass ~free: every measuring group of
a stage shares ONE combined count dispatch (``RoundCounts``), measured
capacities persist across rounds in a ``CapsCache`` keyed by group
signature (re-measuring only on watermark drift or overflow), and the
next round's combined pre-pass is PREFETCHED behind the current round's
payload dispatches (JAX async dispatch).  The ledger splits
``measure_dispatches`` from payload dispatches so the calibration policy
and the schedule are priced separately.

The ledger records what a round *claims* under the BSP model
(``n_rounds``), what the engine *measured* (``dispatches``, counted at
the SPMD layer; fusion is proven by claims and measurements converging),
and what the wire *carried* (``padded_slots`` vs useful ``comm_tuples``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..relational import batched as B
from ..relational import grid as G
from ..relational import ops as R
from ..relational.batched import GroupMeasure
from ..relational.ledger import Ledger
from .caps_cache import CapsCache
from ..relational.routed import RoutePolicy
from ..relational.shuffle import pow2
from ..relational.skew import DEFAULT_SKEW_THRESHOLD
from ..relational.spmd import SPMD
from ..relational.table import DTable
from ..relational.wire import WireFormat, WirePolicy, count_wire_bytes
from .ghd import GHD
from .planner import Op, Round

# ``pow2`` now lives in ``relational.shuffle`` (capacity bucketing is a
# shuffle concern since calibration); re-exported here for existing callers.


# --------------------------------------------------------------------------
# engine strategy registry
# --------------------------------------------------------------------------
ENGINES: Dict[str, type] = {}


def register_engine(name: str):
    """Class decorator: make an ``Engine`` subclass selectable by name."""

    def deco(cls):
        ENGINES[name] = cls
        cls.name = name
        return cls

    return deco


def get_engine(
    name: str,
    spmd: SPMD,
    local_backend: str = "jnp",
    skew_threshold: Optional[float] = None,
    wire_policy: Optional[WirePolicy] = None,
) -> "Engine":
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine strategy {name!r}; registered: {sorted(ENGINES)}"
        ) from None
    return cls(
        spmd, local_backend, skew_threshold=skew_threshold,
        wire_policy=wire_policy,
    )


class Engine:
    """Strategy interface: batched group execution of homogeneous physical
    ops.  Each ``*_many`` method takes k uniform instances plus per-instance
    seeds (and an optional ``xcaps`` measurement from ``measure_group``)
    and returns (outputs, per-instance stats, claimed BSP rounds).

    Intersect and dedup have no grid variant (they only ever run on
    already-bounded intermediates), so their hash implementations are
    shared by every strategy — exactly the old ``_Engine`` behavior.
    """

    name = "?"
    # whether dist_join_count predicts this engine's per-shard join output
    # (true only for hash co-partitioning; grid placement is positional)
    exact_join_presize = False
    # whether the strategy's routing is data-dependent and therefore NEEDS
    # the count pre-pass (the executor forces calibrate on for such
    # engines regardless of GymConfig.calibrate_shuffle)
    requires_measure = False

    def __init__(
        self,
        spmd: SPMD,
        local_backend: str = "jnp",
        skew_threshold: Optional[float] = None,
        wire_policy: Optional[WirePolicy] = None,
    ):
        self.spmd = spmd
        self.local_backend = local_backend
        # the extracted routing policy (relational.routed): wire encoding
        # + heavy-hitter sensitivity, shared by every exchange of the
        # query.  The wire policy (None = dense exchanges) is derived by
        # the driver from the base relations' value ranges, so any format
        # built from it is sound for every intermediate of the query.
        self.route = RoutePolicy(
            wire_policy=wire_policy,
            skew_threshold=(
                DEFAULT_SKEW_THRESHOLD
                if skew_threshold is None
                else skew_threshold
            ),
        )

    @property
    def skew_threshold(self) -> float:
        return self.route.skew_threshold

    @property
    def wire_policy(self) -> Optional[WirePolicy]:
        return self.route.wire_policy

    # -- packed wire formats (delegates to the routing policy) --------------
    def _fmt_for(self, schemas) -> Optional[WireFormat]:
        return self.route.fmt_for(schemas)

    def _pair_fmts(self, lhs, rhs, xcaps, rhs_keys_only: bool = False):
        return self.route.pair_fmts(
            [t.schema for t in lhs],
            [t.schema for t in rhs],
            xcaps,
            rhs_keys_only=rhs_keys_only,
        )

    def _single_fmt(self, ts, xcaps):
        return self.route.single_fmt([t.schema for t in ts], xcaps)

    # -- calibration pre-pass ----------------------------------------------
    def measure_group(
        self, kind: str, lhs, rhs, seeds
    ) -> Optional[GroupMeasure]:
        """ONE count-only dispatch for the whole group: tight pow2
        send/receive capacities per exchange side (max over the group),
        plus the output-side arrival bound where the output IS an exchange
        buffer.  Returns None for kinds this strategy cannot pre-measure
        (the payload then runs with the worst-case defaults)."""
        if kind == "intersect":
            return B.measure_intersect_many(
                self.spmd, lhs, rhs, seeds=seeds, backend=self.local_backend
            )
        if kind == "dedup":
            return B.measure_dedup_many(
                self.spmd, lhs, seeds=seeds, backend=self.local_backend
            )
        return None

    # -- combined round-level pre-pass (amortized calibration) -------------
    # whether this strategy's pair measures may re-route under the hybrid
    # heavy-hitter exchange (drives ``measure_finish``'s re-measure)
    hybrid_measure = False

    def measure_spec(self, kind: str, lhs, rhs, seeds) -> Optional["B.MeasureSpec"]:
        """Build this group's slice of the round's COMBINED count
        pre-pass (``relational.batched.RoundCounts``) — stacking only, no
        dispatch; the executor fuses every group's slice into ONE count
        dispatch per round stage.  None = kind not measurable here (the
        executor falls back to the per-group ``measure_group``)."""
        if kind == "intersect":
            return B.pair_measure_spec(
                self.spmd, lhs, rhs,
                [tuple(range(a.arity)) for a in lhs],
                [b.cols(a.schema) for a, b in zip(lhs, rhs)],
                seeds, dedup_b=False,
            )
        if kind == "dedup":
            return B.single_measure_spec(self.spmd, lhs, seeds)
        return None

    def measure_finish(
        self, kind: str, lhs, rhs, seeds, m: GroupMeasure
    ) -> GroupMeasure:
        """Engine-specific tail applied to a combined-pass slice — the
        host-side post that ``measure_*_many`` used to run inline (plus,
        for hybrid strategies, the rare skew-triggered re-measure)."""
        if kind == "intersect":
            return dataclasses.replace(m, out_recv=m.lhs.cap_recv)
        return m  # dedup slices already carry out_recv

    def measure_needs_join_count(self, kind: str) -> bool:
        """Whether groups of ``kind`` need the fused keys-only join
        output count (``relational.batched.join_need_many``) after their
        capacities are calibrated."""
        return False

    # -- per-kind batched ops ----------------------------------------------
    def semijoin_many(
        self, ss, rs, cap: int, seeds, xcaps: Optional[GroupMeasure] = None
    ) -> Tuple[List[DTable], List[Dict], int]:
        raise NotImplementedError

    def join_many(
        self, as_, bs, cap: int, seeds, xcaps: Optional[GroupMeasure] = None
    ) -> Tuple[List[DTable], List[Dict], int]:
        raise NotImplementedError

    def intersect_many(self, as_, bs, cap: int, seeds, xcaps=None):
        fmts, xcaps = self._pair_fmts(as_, bs, xcaps)
        kw = {"fmts": fmts}
        if xcaps is not None:
            kw["c_out"] = (xcaps.lhs.c_out, xcaps.rhs.c_out)
            kw["cap_recv"] = (max(cap, xcaps.lhs.cap_recv), xcaps.rhs.cap_recv)
        else:
            kw["cap_recv"] = (cap, self.spmd.p * bs[0].cap)
        outs, stats = B.dist_intersect_many(
            self.spmd, as_, bs, seeds=seeds, backend=self.local_backend, **kw
        )
        return outs, stats, 1

    def dedup_many(self, ts, cap: int, seeds, xcaps=None):
        fmt, xcaps = self._single_fmt(ts, xcaps)
        kw = {"cap_recv": cap, "fmt": fmt}
        if xcaps is not None:
            kw["c_out"] = xcaps.lhs.c_out
            kw["cap_recv"] = max(cap, xcaps.lhs.cap_recv)
        outs, stats = B.dist_dedup_many(
            self.spmd, ts, seeds=seeds, backend=self.local_backend, **kw
        )
        return outs, stats, 1

    # -- materialization (one-time per query) ------------------------------
    def _multijoin_grid(self, parts: List[DTable]) -> bool:
        """Whether ``multijoin`` would take the grid path for these parts
        (those pre-passes batch across vertices; engine-specific paths
        like the hash engine's 2-way join measure on their own)."""
        return len(parts) >= 2

    def multijoin_measure_batch(self, parts_list, seeds):
        """Phase A of materialization: resolve the grid-path multijoin
        calibrations for every multi-atom vertex with at most ONE
        combined count dispatch (``grid_multiway_count``), mirroring the
        round executor's per-stage combined pre-pass.  ``seeds`` are the
        vertices' payload seeds (position grids ignore them; hash-path
        engines count with the routing seed the payload will use).
        Returns {vertex_index: (cal, count_pad)} for ``multijoin(cal=...)``."""
        idx = [
            i for i, ps in enumerate(parts_list)
            if len(ps) >= 2 and self._multijoin_grid(ps)
        ]
        if not idx:
            return {}
        cals, pads, byts = G.grid_multiway_count(
            self.spmd, [parts_list[i] for i in idx]
        )
        return {
            i: (c, pad, by)
            for i, c, pad, by in zip(idx, cals, pads, byts)
        }

    def multijoin(
        self, parts: List[DTable], cap: int, seed: int, calibrate=False,
        cal=None,
    ):
        if len(parts) == 1:
            return parts[0], {
                "sent": 0, "dropped": 0, "padded": 0,
                "wire_bytes": 0, "ubytes": 0,
            }, 0
        fmts = (
            None
            if self.wire_policy is None
            else [self.wire_policy.format_for(t.schema) for t in parts]
        )
        out, st = G.grid_multiway_join(
            self.spmd, parts, out_cap=cap, calibrate=calibrate, cals=cal,
            fmts=fmts, backend=self.local_backend,
        )
        return out, st, 1


@register_engine("hash")
class HashEngine(Engine):
    """Beyond-paper hash co-partitioning (comm ~ inputs + outputs,
    skew-sensitive; overflow triggers the abort-retry path)."""

    exact_join_presize = True

    def measure_group(self, kind, lhs, rhs, seeds):
        # skew_threshold threads through so the pre-pass reports heavy
        # destinations even on the hash path — the capacity manager's
        # ceiling diagnostic names that count when abort-retry is doomed
        if kind == "semijoin":
            return B.measure_semijoin_many(
                self.spmd, lhs, rhs, seeds=seeds, backend=self.local_backend,
                skew_threshold=self.skew_threshold,
            )
        if kind == "join":
            return B.measure_join_many(
                self.spmd, lhs, rhs, seeds=seeds, backend=self.local_backend,
                skew_threshold=self.skew_threshold,
            )
        return Engine.measure_group(self, kind, lhs, rhs, seeds)

    def measure_spec(self, kind, lhs, rhs, seeds):
        if kind in ("semijoin", "join"):
            shareds = [
                [x for x in a.schema if x in b.schema]
                for a, b in zip(lhs, rhs)
            ]
            a_keys = [a.cols(sh) for a, sh in zip(lhs, shareds)]
            b_keys = [b.cols(sh) for b, sh in zip(rhs, shareds)]
            if kind == "join":
                # fuse the output pre-count into the same dispatch; the
                # keys-only exchanges ride at a static guess (4x the
                # uniform per-destination share) that the counts verify
                # post hoc — see join_pair_measure_spec.  Packed runs
                # ship the actual key projections bit-packed (exact
                # count) instead of the dense hashed-key column.
                return B.join_pair_measure_spec(
                    self.spmd, lhs, rhs, a_keys, b_keys, seeds,
                    g_a=self._keys_guess(lhs[0].cap),
                    g_b=self._keys_guess(rhs[0].cap),
                    skew_threshold=self.skew_threshold,
                    fmt=self._fmt_for([tuple(sh) for sh in shareds]),
                )
            return B.pair_measure_spec(
                self.spmd, lhs, rhs, a_keys, b_keys,
                seeds, dedup_b=True,
                skew_threshold=self.skew_threshold,
            )
        return Engine.measure_spec(self, kind, lhs, rhs, seeds)

    def _keys_guess(self, cap: int) -> int:
        per = -(-cap // self.spmd.p)  # ceil: the uniform share
        # The guess trades slot headroom against wire bytes: headroom
        # avoids the one fallback ``join_need_many`` dispatch an
        # undershot guess costs, but every guessed slot ships.  Dense
        # already pays 5 bytes per slot elsewhere, so 4x headroom is
        # cheap insurance; a packed run's contract is byte-minimality,
        # so it guesses the uniform share and accepts the (rare, still
        # exact) fallback dispatch under skew.
        mult = 1 if self.wire_policy is not None else 4
        return pow2(min(cap, max(8, mult * per)))

    def measure_finish(self, kind, lhs, rhs, seeds, m):
        if kind == "semijoin":
            return B.finish_semijoin_measure(
                self.spmd, lhs, rhs, seeds, m,
                hybrid=self.hybrid_measure, backend=self.local_backend,
            )
        if kind == "join":
            return B.hybridize_join_measure(
                self.spmd, lhs, rhs, seeds, m,
                hybrid=self.hybrid_measure, backend=self.local_backend,
            )
        return Engine.measure_finish(self, kind, lhs, rhs, seeds, m)

    def measure_needs_join_count(self, kind):
        return kind == "join"

    def semijoin_many(self, ss, rs, cap, seeds, xcaps=None):
        fmts, xcaps = self._pair_fmts(ss, rs, xcaps, rhs_keys_only=True)
        kw = {"fmts": fmts}
        if xcaps is not None:
            kw["c_out"] = (xcaps.lhs.c_out, xcaps.rhs.c_out)
            # S receives the output: never below the managed capacity (so
            # fixed/calibrated stay bit-identical when nothing overflows)
            kw["cap_recv"] = (max(cap, xcaps.lhs.cap_recv), xcaps.rhs.cap_recv)
        else:
            kw["cap_recv"] = (cap, self.spmd.p * rs[0].cap)
        outs, stats = B.dist_semijoin_many(
            self.spmd, ss, rs, seeds=seeds, backend=self.local_backend, **kw
        )
        return outs, stats, 1

    def join_many(self, as_, bs, cap, seeds, xcaps=None):
        fmts, xcaps = self._pair_fmts(as_, bs, xcaps)
        kw = {"fmts": fmts}
        if xcaps is not None:
            kw["c_out"] = (xcaps.lhs.c_out, xcaps.rhs.c_out)
            kw["cap_recv"] = (xcaps.lhs.cap_recv, xcaps.rhs.cap_recv)
        outs, stats = B.dist_join_many(
            self.spmd, as_, bs, seeds=seeds, out_cap=cap,
            backend=self.local_backend, **kw,
        )
        return outs, stats, 1

    def _multijoin_grid(self, parts):
        return len(parts) != 2  # 2-way takes the hash path below

    def multijoin_measure_batch(self, parts_list, seeds):
        """Grid-path vertices batch as in ``Engine``; the hash-path 2-way
        vertices batch their pair-exchange counts into one further
        combined dispatch (``measure_exchange_pairs``) — a whole
        materialization stage of 2-way bags pays a single pre-pass
        instead of one ``dist_join`` count each."""
        cal_map = Engine.multijoin_measure_batch(self, parts_list, seeds)
        pidx = [
            i for i, ps in enumerate(parts_list)
            if len(ps) == 2
            and [x for x in ps[0].schema if x in ps[1].schema]
        ]
        if pidx:
            res = R.measure_exchange_pairs(
                self.spmd,
                [
                    (
                        parts_list[i][0],
                        parts_list[i][1],
                        [x for x in parts_list[i][0].schema
                         if x in parts_list[i][1].schema],
                        [x for x in parts_list[i][0].schema
                         if x in parts_list[i][1].schema],
                        seeds[i],
                        (False, False),
                    )
                    for i in pidx
                ],
                backend=self.local_backend,
            )
            pad = 2 * self.spmd.p * self.spmd.p  # two (p,)-int vectors
            for i, cal in zip(pidx, res):
                cal_map[i] = (cal, pad, count_wire_bytes(self.spmd.p, 2))
        return cal_map

    def multijoin(self, parts, cap, seed, calibrate=False, cal=None):
        if len(parts) == 2:
            kw = {}
            if cal is not None:
                kw["c_out"], kw["cap_recv"] = cal
            shared = [x for x in parts[0].schema if x in parts[1].schema]
            if self.wire_policy is not None and shared:
                # packed runs route the materialization 2-way join through
                # the batched exchange (same shard semantics, fmt-aware
                # wire) — sequential dist_join ships dense only
                fmts, _ = self._pair_fmts([parts[0]], [parts[1]], None)
                outs, stats = B.dist_join_many(
                    self.spmd, [parts[0]], [parts[1]], seeds=[seed],
                    out_cap=cap, fmts=fmts, backend=self.local_backend, **kw,
                )
                return outs[0], stats[0], 1
            out, st = R.dist_join(
                self.spmd, parts[0], parts[1], seed=seed, out_cap=cap,
                calibrate=calibrate, backend=self.local_backend, **kw,
            )
            return out, st, 1
        return Engine.multijoin(self, parts, cap, seed, calibrate, cal)


@register_engine("hybrid")
class HybridEngine(HashEngine):
    """Skew-resilient heavy/light decomposition (``relational.skew``):
    the count pre-pass flags heavy destinations, the payload routes light
    keys through the hash exchange and heavy keys grid-style (output side
    position-partitioned over all p reducers, other side broadcast) in
    the SAME fused dispatch.  On unskewed groups the measure finds no
    heavy keys and the payload is the hash engine's, bit for bit.

    The routing is data-dependent, so the engine REQUIRES the count
    pre-pass: the executor forces ``calibrate`` on (``requires_measure``)
    even when the config disables the calibrated shuffle."""

    requires_measure = True
    hybrid_measure = True
    # abort-retry pre-sizing stays valid: blown joins only happen on
    # hash-routed (no-heavy) groups — hybrid-routed groups pre-floor the
    # exact spread output from the measure — and there dist_join_count's
    # hash placement is the placement that blew
    exact_join_presize = True

    def measure_group(self, kind, lhs, rhs, seeds):
        if kind == "semijoin":
            return B.measure_semijoin_many(
                self.spmd, lhs, rhs, seeds=seeds, backend=self.local_backend,
                hybrid=True, skew_threshold=self.skew_threshold,
            )
        if kind == "join":
            return B.measure_join_many(
                self.spmd, lhs, rhs, seeds=seeds, backend=self.local_backend,
                hybrid=True, skew_threshold=self.skew_threshold,
            )
        return Engine.measure_group(self, kind, lhs, rhs, seeds)

    def semijoin_many(self, ss, rs, cap, seeds, xcaps=None):
        if xcaps is None or not xcaps.hybrid_routed:
            return HashEngine.semijoin_many(self, ss, rs, cap, seeds, xcaps)
        fmts, xcaps = self._pair_fmts(ss, rs, xcaps, rhs_keys_only=True)
        outs, stats = B.hybrid_semijoin_many(
            self.spmd, ss, rs, seeds=seeds, heavy=xcaps.heavy,
            c_out=(xcaps.lhs.c_out, xcaps.rhs.c_out),
            cap_recv=(max(cap, xcaps.lhs.cap_recv), xcaps.rhs.cap_recv),
            fmts=fmts, backend=self.local_backend,
        )
        return outs, stats, 1

    def join_many(self, as_, bs, cap, seeds, xcaps=None):
        if xcaps is None or not xcaps.hybrid_routed:
            return HashEngine.join_many(self, as_, bs, cap, seeds, xcaps)
        fmts, xcaps = self._pair_fmts(as_, bs, xcaps)
        outs, stats = B.hybrid_join_many(
            self.spmd, as_, bs, seeds=seeds, out_cap=cap, heavy=xcaps.heavy,
            c_out=(xcaps.lhs.c_out, xcaps.rhs.c_out),
            cap_recv=(xcaps.lhs.cap_recv, xcaps.rhs.cap_recv),
            swap=xcaps.swap_spread,
            fmts=fmts, backend=self.local_backend,
        )
        return outs, stats, 1

    def multijoin_measure_batch(self, parts_list, seeds):
        # 2-way bags take dist_join_hybrid, whose heavy-hitter routing
        # needs its own per-destination flags — only the grid-path
        # vertices batch here
        return Engine.multijoin_measure_batch(self, parts_list, seeds)

    def multijoin(self, parts, cap, seed, calibrate=False, cal=None):
        if len(parts) == 2:
            out, st = R.dist_join_hybrid(
                self.spmd, parts[0], parts[1], seed=seed, out_cap=cap,
                skew_threshold=self.skew_threshold, backend=self.local_backend,
            )
            return out, st, 1
        return Engine.multijoin(self, parts, cap, seed, calibrate, cal)


@register_engine("grid")
class GridEngine(Engine):
    """Paper-faithful Lemmas 8/10 (skew-proof, B(X, M) = X^2/M comm)."""

    def measure_group(self, kind, lhs, rhs, seeds):
        if kind == "semijoin":
            return B.measure_grid_semijoin_many(
                self.spmd, lhs, rhs, backend=self.local_backend
            )
        if kind == "join":
            return B.measure_grid_join_many(
                self.spmd, lhs, rhs, backend=self.local_backend
            )
        return Engine.measure_group(self, kind, lhs, rhs, seeds)

    def measure_spec(self, kind, lhs, rhs, seeds):
        if kind == "semijoin":
            return B.grid_rkeys_measure_spec(self.spmd, lhs, rhs)
        if kind == "join":
            return B.grid_pair_measure_spec(self.spmd, lhs, rhs)
        return Engine.measure_spec(self, kind, lhs, rhs, seeds)

    def semijoin_many(self, ss, rs, cap, seeds, xcaps=None):
        fmts, xcaps = self._pair_fmts(ss, rs, xcaps, rhs_keys_only=True)
        kw = {"fmts": fmts}
        if xcaps is not None:
            kw["c_out"] = (xcaps.lhs.c_out, xcaps.rhs.c_out)
            kw["cap_recv"] = (xcaps.lhs.cap_recv, xcaps.rhs.cap_recv)
        outs, stats = B.grid_semijoin_many(
            self.spmd, ss, rs, seeds=seeds, out_cap=cap,
            backend=self.local_backend, **kw,
        )
        return outs, stats, 2

    def join_many(self, as_, bs, cap, seeds, xcaps=None):
        fmts, xcaps = self._pair_fmts(as_, bs, xcaps)
        kw = {"fmts": fmts}
        if xcaps is not None:
            kw["c_out"] = (xcaps.lhs.c_out, xcaps.rhs.c_out)
            kw["cap_recv"] = (xcaps.lhs.cap_recv, xcaps.rhs.cap_recv)
        outs, stats = B.grid_join_many(
            self.spmd, as_, bs, out_cap=cap, backend=self.local_backend, **kw
        )
        return outs, stats, 1


# --------------------------------------------------------------------------
# capacity management (the paper's abort-and-retry, centralized)
# --------------------------------------------------------------------------
class CapacityCeiling(R.Overflow):
    """A capacity would grow past the configured per-shard memory bound.

    Raised instead of letting the abort-retry doubling loop walk past any
    budget: under adversarial skew the hash engine's retries double
    forever (the heavy key still lands on one reducer at ANY capacity),
    so a hard M-tied ceiling with an actionable diagnosis beats an OOM."""


class CapacityManager:
    """Per-GHD-node output capacities + overflow policy.

    - ``cap_for(nodes)``: pow2 capacity for an op writing into ``nodes``.
    - ``grow(nodes, dropped)``: multiplicative growth past the observed
      overflow (drop count bounds the shortfall across all shards), the
      retry-convergence rule the driver previously inlined twice.
    - ``presize_join(a, b, seed)``: EXACT per-shard output count of the
      blown join via a key-only counting dispatch — the retry is floored
      at the true requirement instead of walking up by growth factors.
      (Retries reseed the hash partition, which can shift per-shard counts
      slightly; the multiplicative growth above still guarantees
      termination, the exact floor just makes one retry almost always
      enough.)
    - ``max_cap``: hard per-shard capacity ceiling tied to the configured
      memory M (``GymConfig.max_cap_tuples``; the driver derives a
      default from Assumption 3's M = 4*IN/p when unset).  Any growth or
      measured floor past it raises ``CapacityCeiling`` naming the heavy
      destination count the last count pre-pass saw (``heavy_hint``) and
      pointing at the skew-resilient engines — growth without a ceiling
      is an OOM under adversarial skew, never convergence.
    """

    def __init__(
        self,
        spmd: SPMD,
        growth: int = 4,
        local_backend: str = "jnp",
        max_cap: Optional[int] = None,
    ):
        self.spmd = spmd
        self.growth = growth
        self.local_backend = local_backend
        self.caps: Dict[int, int] = {}
        self.max_cap = max_cap
        # heavy destinations flagged by the CURRENT round's count
        # pre-passes (max over its groups; the executor resets this at
        # each round attempt and updates it per measured group) — so a
        # ceiling hit is diagnosed from the round that is actually
        # aborting, not from skew seen rounds ago
        self.heavy_hint: int = 0

    def _check(self, nodes: Sequence[int], cap: int) -> None:
        if self.max_cap is not None and cap > self.max_cap:
            if self.heavy_hint:
                hint = (
                    f"{self.heavy_hint} heavy destination(s) were flagged by "
                    "this round's count pre-passes — the round is skew-bound, "
                    "and abort-retry doubling cannot fix skew (the heavy key "
                    "lands on one reducer at ANY capacity); switch to "
                    "engine='hybrid' (heavy-hitter routing) or engine='grid' "
                    "(skew-proof)"
                )
            else:
                hint = (
                    "this round's count pre-passes flagged no heavy "
                    "destinations (none measured if calibrate_shuffle is "
                    "off), so the load may genuinely be this large; raise "
                    "GymConfig.max_cap_tuples — or, under skew, switch to "
                    "engine='hybrid' or engine='grid'"
                )
            raise CapacityCeiling(
                f"capacity for node(s) {tuple(nodes)} would grow to {cap} > "
                f"max_cap {self.max_cap} (bound tied to the configured "
                f"per-machine memory M); {hint}"
            )

    def cap_for(self, nodes: Sequence[int]) -> int:
        return pow2(max(self.caps.get(v, 4) for v in nodes))

    def ensure(self, v: int, cap: int) -> None:
        self._check((v,), cap)
        self.caps[v] = max(self.caps.get(v, 0), cap)

    def grow(self, nodes: Sequence[int], dropped: int) -> None:
        for v in nodes:
            cap = pow2(self.caps.get(v, 4) * self.growth + int(dropped))
            self._check((v,), cap)
            self.caps[v] = cap

    def grow_node(self, v: int) -> None:
        cap = pow2(self.caps.get(v, 4) * self.growth)
        self._check((v,), cap)
        self.caps[v] = cap

    def presize_join(self, a: DTable, b: DTable, seed: int) -> int:
        counts = R.dist_join_count(
            self.spmd, a, b, seed=seed, backend=self.local_backend
        )
        return pow2(max(4, int(counts.max())))

    def floor(self, nodes: Sequence[int], cap: int) -> None:
        for v in nodes:
            self.ensure(v, cap)


# --------------------------------------------------------------------------
# lowering: logical Op -> staged physical dataflow over named slots
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PhysOp:
    """One physical operator instance.

    Slots: ``tab:v`` (node v's table), ``up:v`` (node v read through its
    upward accumulator if present), ``tmp:j:i`` (temporary i of logical op
    j).  ``cap_nodes`` are the GHD nodes whose managed capacity sizes this
    op's output; ``logical`` indexes the owning logical op for retry blame.
    """

    kind: str  # 'semijoin' | 'join' | 'intersect' | 'dedup'
    out: str
    a: str
    b: Optional[str]
    cap_nodes: Tuple[int, ...]
    logical: int
    seed: int = 0


def _tab(v: int) -> str:
    return f"tab:{v}"


def _up(v: int) -> str:
    return f"up:{v}"


def lower_op(op: Op, j: int) -> Tuple[List[List[PhysOp]], Tuple[str, int, str]]:
    """Lower one logical op: (stages, (store, node, result_slot)).

    Stage i of every logical op in a round runs concurrently — the
    single-writer property of planner rounds guarantees independence."""

    def tmp(i: int) -> str:
        return f"tmp:{j}:{i}"

    k = op.kind
    if k == "semijoin":
        # upward L1: S := S |>< R, R read through its accumulator
        (r,) = op.args
        ops = [[PhysOp("semijoin", tmp(0), _tab(op.target), _up(r), (op.target,), j)]]
        return ops, ("tab", op.target, tmp(0))
    if k == "down_semijoin":
        (s,) = op.args
        ops = [[PhysOp("semijoin", tmp(0), _tab(op.target), _tab(s), (op.target,), j)]]
        return ops, ("tab", op.target, tmp(0))
    if k == "join":
        (r,) = op.args
        ops = [[PhysOp("join", tmp(0), _tab(op.target), _tab(r), (op.target,), j)]]
        return ops, ("tab", op.target, tmp(0))
    if k == "pair_filter":
        s, r2 = op.args
        stages = [
            [
                PhysOp("semijoin", tmp(0), _tab(s), _up(op.target), (s,), j),
                PhysOp("semijoin", tmp(1), _tab(s), _up(r2), (s,), j),
            ],
            [PhysOp("intersect", tmp(2), tmp(0), tmp(1), (s,), j)],
        ]
        return stages, ("acc", op.target, tmp(2))
    if k == "triple_filter":
        s, rb, rc = op.args
        stages = [
            [
                PhysOp("semijoin", tmp(0), _tab(s), _up(op.target), (s,), j),
                PhysOp("semijoin", tmp(1), _tab(s), _up(rb), (s,), j),
                PhysOp("semijoin", tmp(2), _tab(s), _up(rc), (s,), j),
            ],
            [PhysOp("intersect", tmp(3), tmp(0), tmp(1), (s,), j)],
            [PhysOp("intersect", tmp(4), tmp(3), tmp(2), (s,), j)],
        ]
        return stages, ("acc", op.target, tmp(4))
    if k == "pair_join":
        s, r2 = op.args
        nodes = (op.target, s, r2)
        stages = [
            [
                PhysOp("join", tmp(0), _tab(op.target), _tab(s), nodes, j),
                PhysOp("join", tmp(1), _tab(r2), _tab(s), nodes, j),
            ],
            [PhysOp("join", tmp(2), tmp(0), tmp(1), nodes, j)],
        ]
        return stages, ("tab", op.target, tmp(2))
    if k == "triple_join":
        s, rb, rc = op.args
        nodes = (op.target, s, rb, rc)
        stages = [
            [
                PhysOp("join", tmp(0), _tab(op.target), _tab(s), nodes, j),
                PhysOp("join", tmp(1), _tab(rb), _tab(s), nodes, j),
                PhysOp("join", tmp(2), _tab(rc), _tab(s), nodes, j),
            ],
            [PhysOp("join", tmp(3), tmp(0), tmp(1), nodes, j)],
            [PhysOp("join", tmp(4), tmp(3), tmp(2), nodes, j)],
        ]
        return stages, ("tab", op.target, tmp(4))
    raise ValueError(f"unknown op {op.kind}")


def lower_round(rnd: Round) -> Tuple[List[List[PhysOp]], List[Tuple[str, int, str]]]:
    """Zip-merge per-op stage lists: round stage i = all ops' stage i."""
    stages: List[List[PhysOp]] = []
    writes: List[Tuple[str, int, str]] = []
    for j, op in enumerate(rnd.ops):
        op_stages, write = lower_op(op, j)
        while len(stages) < len(op_stages):
            stages.append([])
        for i, st in enumerate(op_stages):
            stages[i].extend(st)
        writes.append(write)
    return stages, writes


# --------------------------------------------------------------------------
# prepared group work: the executor <-> dispatcher interface
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GroupWork:
    """ONE prepared op group, ready to dispatch: operand tables resolved,
    managed capacities pre-floored, calibration attached.  This is the
    unit the round generator (``PhysicalExecutor.round_steps``) yields and
    the unit the serving layer merges across requests — ``merge_key``
    (``relational.batched.cross_request_key``) is the cross-request
    bucketing key, None when the group must dispatch solo.

    ``mpad``/``mbytes``: wire cells (and byte-true size) the group's count
    pre-pass slices shipped — the owner charges them to its own round
    alongside the payload stats (they are never merged; see
    ``merge_measures``)."""

    kind: str
    ops: List[PhysOp]
    lhs: List[DTable]
    rhs: Optional[List[DTable]]
    seeds: List[int]
    cap: int
    xcaps: Optional[GroupMeasure]
    key: Optional[Tuple]  # caps-cache signature (None when not calibrating)
    engine: Engine
    mpad: int
    mbytes: int
    merge_key: Optional[Tuple]


@dataclasses.dataclass
class GroupResult:
    """What dispatching one ``GroupWork`` produced: per-instance outputs
    and stats (in the work's op order), the claimed BSP rounds, and the
    SPMD dispatch deltas measured around the payload — incremental, so
    accounting survives many executors interleaving on one ``SPMD``.
    For a merged dispatch the shared deltas are charged to the FIRST
    rider (the others ride free; the server ledger records the saving)."""

    outs: List[DTable]
    stats: List[Dict]
    rounds: int
    dispatches: int
    measure_dispatches: int


def _engine_payload(eng: Engine, kind, lhs, rhs, cap, seeds, xcaps):
    if kind == "dedup":
        return eng.dedup_many(lhs, cap, seeds, xcaps)
    if kind == "semijoin":
        return eng.semijoin_many(lhs, rhs, cap, seeds, xcaps)
    if kind == "join":
        return eng.join_many(lhs, rhs, cap, seeds, xcaps)
    if kind == "intersect":
        return eng.intersect_many(lhs, rhs, cap, seeds, xcaps)
    raise ValueError(f"unknown physical op kind {kind}")


def dispatch_work(w: GroupWork) -> GroupResult:
    """Phase B for ONE group: the payload dispatch at the capacities its
    measure resolved."""
    spmd = w.engine.spmd
    d0, md0 = spmd.dispatch_count, spmd.measure_dispatch_count
    outs, stats, rounds = _engine_payload(
        w.engine, w.kind, w.lhs, w.rhs, w.cap, w.seeds, w.xcaps
    )
    return GroupResult(
        outs, stats, rounds,
        spmd.dispatch_count - d0, spmd.measure_dispatch_count - md0,
    )


def dispatch_merged(works: Sequence[GroupWork]) -> List[GroupResult]:
    """ONE fused payload dispatch for several same-``merge_key`` groups
    (typically from different requests): operand lists concatenate on the
    k axis of the ``dist_*_many`` operators, calibrations merge by
    elementwise max (``merge_measures``), and the per-instance outputs /
    stats de-interleave back to one ``GroupResult`` per rider.  Each
    instance's rows depend only on its own data, seed, and the (equal by
    key) statics, so every rider's outputs are bit-identical to a solo
    dispatch of its group."""
    if len(works) == 1:
        return [dispatch_work(works[0])]
    mk = works[0].merge_key
    assert mk is not None and all(w.merge_key == mk for w in works), (
        "dispatch_merged: all works must share a non-None merge_key"
    )
    eng = works[0].engine
    spmd = eng.spmd
    lhs = [t for w in works for t in w.lhs]
    rhs = (
        None
        if works[0].rhs is None
        else [t for w in works for t in w.rhs]
    )
    seeds = [s for w in works for s in w.seeds]
    xcaps = B.merge_measures([w.xcaps for w in works])
    d0, md0 = spmd.dispatch_count, spmd.measure_dispatch_count
    outs, stats, rounds = _engine_payload(
        eng, works[0].kind, lhs, rhs, works[0].cap, seeds, xcaps
    )
    dd = spmd.dispatch_count - d0
    md = spmd.measure_dispatch_count - md0
    results: List[GroupResult] = []
    off = 0
    for j, w in enumerate(works):
        k = len(w.ops)
        results.append(
            GroupResult(
                outs[off:off + k], stats[off:off + k], rounds,
                dd if j == 0 else 0, md if j == 0 else 0,
            )
        )
        off += k
    return results


# --------------------------------------------------------------------------
# executor
# --------------------------------------------------------------------------
class PhysicalExecutor:
    """Runs lowered rounds (and the materialization stage) with grouping,
    fused dispatch, and the centralized abort-retry loop.

    ``fuse=False`` forces singleton groups — every physical op becomes its
    own dispatch.  Results, stats, seeds, and retries are bit-identical to
    the fused path (grouping only changes how work is packed into
    programs), which is what the parity tests assert and what makes the
    dispatch-count comparison in ``bench_fusion`` apples-to-apples.

    ``calibrate=True`` (the default, ``GymConfig.calibrate_shuffle``):
    rounds run a two-phase measure→dispatch schedule.  Phase A resolves
    every group's capacities — from the ``CapsCache`` (signatures measured
    in an earlier round whose observed fill stayed inside the watermark
    band), from the PREFETCHED combined count pre-pass (launched while the
    previous round's payloads were still in flight), or from ONE fresh
    combined count dispatch covering all remaining groups of the stage
    (plus one fused keys-only pass pre-counting every join group's
    output).  Phase B runs the payload dispatches with those tight pow2
    capacities, pre-flooring managed capacities the measurement proves too
    small (``CapacityManager.floor``) — rows, ``comm_tuples``, and retries
    stay bit-identical to the fixed-capacity path whenever that path would
    not have aborted, while the wire ships calibrated buckets
    (``padded_slots`` drops by ~p).  A stale cache entry can undercount;
    the payload's drop counters catch it, the entry is invalidated, and
    the existing abort-retry re-measures — rows stay bit-identical, the
    stale hit costs one retry."""

    def __init__(
        self,
        spmd: SPMD,
        strategy: str,
        capman: CapacityManager,
        *,
        seed: int = 0,
        max_retries: int = 12,
        count_retries_comm: bool = True,
        fuse: bool = True,
        calibrate: bool = True,
        local_backend: str = "jnp",
        skew_threshold: Optional[float] = None,
        caps_cache: "bool | CapsCache" = True,
        prefetch: bool = True,
        wire_policy: Optional[WirePolicy] = None,
    ):
        self.spmd = spmd
        self.engine = get_engine(
            strategy, spmd, local_backend, skew_threshold,
            wire_policy=wire_policy,
        )
        self.local_backend = local_backend
        self.capman = capman
        self.seed = seed
        self.max_retries = max_retries
        self.count_retries_comm = count_retries_comm
        self.fuse = fuse
        # data-dependent engines (hybrid) cannot route without the count
        # pre-pass: force it on for them regardless of the config knob
        self.calibrate = calibrate or self.engine.requires_measure
        self._seed_ctr = 0
        # amortized calibration: cross-round capacity cache + the pending
        # prefetched measure of the next round (a ``B.RoundCounts`` whose
        # device futures were launched behind the previous round's
        # payloads, consumed by the next ``execute_round``).  ``caps_cache``
        # also accepts a CapsCache INSTANCE — the serving layer passes one
        # shared cache across executors so tenants with equal group
        # signatures warm each other (signature-keyed: different shapes
        # can never cross-contaminate).
        if isinstance(caps_cache, CapsCache):
            self.caps_cache = caps_cache if self.calibrate else None
        else:
            self.caps_cache = (
                CapsCache() if (caps_cache and self.calibrate) else None
            )
        self.prefetch = bool(prefetch) and self.calibrate
        self._pending: Optional[Dict] = None

    @classmethod
    def from_plan(
        cls,
        spmd: SPMD,
        plan,  # optimizer.Plan
        capman: CapacityManager,
        *,
        seed: int = 0,
        max_retries: int = 12,
        count_retries_comm: bool = True,
        calibrate: bool = True,
        skew_threshold: Optional[float] = None,
        caps_cache: "bool | CapsCache" = True,
        prefetch: bool = True,
        wire_policy: Optional[WirePolicy] = None,
    ) -> "PhysicalExecutor":
        """Build an executor straight from an advisor ``Plan``: engine
        strategy, round fusion, and local backend all come from the plan
        (``core/optimizer.py``), so a chosen plan needs no hand-threading
        of knobs through configs."""
        return cls(
            spmd,
            plan.engine,
            capman,
            seed=seed,
            max_retries=max_retries,
            count_retries_comm=count_retries_comm,
            fuse=plan.fused,
            calibrate=calibrate,
            local_backend=plan.local_backend,
            skew_threshold=skew_threshold,
            caps_cache=caps_cache,
            prefetch=prefetch,
            wire_policy=wire_policy,
        )

    def _next_seed(self) -> int:
        self._seed_ctr += 1
        return self.seed + 7919 * self._seed_ctr

    # -- grouping ----------------------------------------------------------
    def _signature(self, op: PhysOp, resolve) -> Tuple:
        a = resolve(op.a)
        sig: Tuple = (op.kind, self.capman.cap_for(op.cap_nodes), a.cap, a.arity)
        if op.b is not None:
            b = resolve(op.b)
            n_shared = sum(1 for x in a.schema if x in set(b.schema))
            sig += (b.cap, b.arity, n_shared)
        return sig

    def _group(self, stage: List[PhysOp], resolve) -> List[List[PhysOp]]:
        groups: Dict[Tuple, List[PhysOp]] = {}
        for i, op in enumerate(stage):
            sig = self._signature(op, resolve)
            if not self.fuse:
                sig += (i,)  # singleton groups: one dispatch per op
            groups.setdefault(sig, []).append(op)
        return list(groups.values())

    def _measure_stage(self, groups, resolve, pending=None):
        """Phase A of the two-phase round schedule: resolve a
        ``GroupMeasure`` for every group of the stage with at most ONE
        fresh combined count dispatch (plus one fused keys-only join
        output count when the engine needs it).

        Sources, cheapest first: ``CapsCache`` hit (zero dispatches), the
        prefetched pending ``RoundCounts`` (its dispatch already in
        flight, matched by signature AND seeds), one fresh combined
        ``RoundCounts`` over the remaining groups.  Kinds with no
        ``MeasureSpec`` fall back to the legacy per-group
        ``measure_group``.  Returns (measures, keys, orphan_padded,
        orphan_bytes) — the last two being wire cells (and their
        byte-true size) of prefetched count slices no group consumed
        (schedule drift), still charged to the round."""
        n = len(groups)
        if not self.calibrate:
            return [None] * n, [None] * n, 0, 0
        keys = [self._signature(g[0], resolve) for g in groups]
        measures: List[Optional[GroupMeasure]] = [None] * n
        orphan_pad = 0
        orphan_bytes = 0
        todo: List[int] = []
        for gi in range(n):
            m = (
                self.caps_cache.lookup(keys[gi])
                if self.caps_cache is not None
                else None
            )
            if m is not None:
                measures[gi] = m
            else:
                todo.append(gi)
        fresh: List[int] = []  # measured THIS call (cache hits excluded)
        if pending is not None:
            index, counts = pending["index"], pending["counts"]
            matched = {}
            for gi in todo:
                skey = (keys[gi], tuple(op.seed for op in groups[gi]))
                if skey in index:
                    matched[gi] = index[skey]
            if matched:
                pm = counts.measures()
                used = set(matched.values())
                for gi, si in matched.items():
                    measures[gi] = pm[si]
                    fresh.append(gi)
                todo = [gi for gi in todo if gi not in matched]
                orphan_pad += sum(
                    s.count_padded
                    for si, s in enumerate(counts.specs)
                    if si not in used
                )
                orphan_bytes += sum(
                    s.count_bytes
                    for si, s in enumerate(counts.specs)
                    if si not in used
                )
            else:
                # nothing matched (schedule drifted since the prefetch):
                # the whole in-flight dispatch is orphaned — charge its
                # wire cells, never fetch it to the host
                orphan_pad += counts.count_padded
                orphan_bytes += counts.count_bytes

        def operands(gi):
            g = groups[gi]
            kind = g[0].kind
            lhs = [resolve(op.a) for op in g]
            rhs = None if kind == "dedup" else [resolve(op.b) for op in g]
            return kind, lhs, rhs, [op.seed for op in g]

        legacy: List[int] = []
        spec_gis: List[int] = []
        specs: List["B.MeasureSpec"] = []
        for gi in todo:
            kind, lhs, rhs, seeds = operands(gi)
            spec = self.engine.measure_spec(kind, lhs, rhs, seeds)
            if spec is None:
                legacy.append(gi)
            else:
                spec_gis.append(gi)
                specs.append(spec)
        if specs:
            counts = B.RoundCounts(
                self.spmd, specs, backend=self.local_backend
            )
            for gi, m in zip(spec_gis, counts.measures()):
                measures[gi] = m
                fresh.append(gi)
        for gi in legacy:
            kind, lhs, rhs, seeds = operands(gi)
            measures[gi] = self.engine.measure_group(kind, lhs, rhs, seeds)
        fresh.sort()
        # engine tails the combined pass can't express: out_recv adoption,
        # the hybrid engine's rare skew-triggered re-measure
        for gi in fresh:
            kind, lhs, rhs, seeds = operands(gi)
            measures[gi] = self.engine.measure_finish(
                kind, lhs, rhs, seeds, measures[gi]
            )
        # exact keys-only output pre-count for the fresh join groups the
        # combined pass could NOT resolve: hybrid re-routed groups (the
        # light-placement count is void) and groups whose hashed-key
        # guess capacity proved too small — the common case fused its
        # out_need into the combined dispatch already
        join_gis = [
            gi for gi in fresh
            if groups[gi][0].kind == "join"
            and self.engine.measure_needs_join_count("join")
            and measures[gi].out_need is None
        ]
        if join_gis:
            items = []
            fmts = []
            for gi in join_gis:
                _, lhs, rhs, seeds = operands(gi)
                items.append((lhs, rhs, seeds, measures[gi]))
                fmts.append(self.engine._fmt_for([
                    tuple(x for x in a.schema if x in set(b.schema))
                    for a, b in zip(lhs, rhs)
                ]) if self.engine.wire_policy is not None else None)
            needs = B.join_need_many(
                self.spmd, items, fmts=fmts, backend=self.local_backend
            )
            for gi, m in zip(join_gis, needs):
                measures[gi] = m
        if self.caps_cache is not None:
            for gi in fresh + legacy:
                if measures[gi] is not None:
                    self.caps_cache.store(keys[gi], measures[gi])
        for m in measures:
            if m is not None and m.n_heavy:
                # remember the measured skew so a capacity-ceiling abort
                # can name the heavy destinations in its diagnosis
                self.capman.heavy_hint = max(
                    self.capman.heavy_hint, m.n_heavy
                )
        return measures, keys, orphan_pad, orphan_bytes

    def prepare_group(
        self, ops_g: List[PhysOp], resolve, xcaps, key
    ) -> GroupWork:
        """Bind one measured group to a dispatchable ``GroupWork``:
        resolve the operand tables, pre-floor managed capacities the
        measurement proves too small (the round that would have aborted
        never runs short), and compute the cross-request ``merge_key``."""
        seeds = [op.seed for op in ops_g]
        lhs = [resolve(op.a) for op in ops_g]
        kind = ops_g[0].kind
        rhs = None if kind == "dedup" else [resolve(op.b) for op in ops_g]
        if xcaps is not None:
            need = max(xcaps.out_recv or 0, xcaps.out_need or 0)
            if need:
                for op in ops_g:
                    self.capman.floor(op.cap_nodes, need)
        cap = self.capman.cap_for(ops_g[0].cap_nodes)
        return GroupWork(
            kind=kind, ops=list(ops_g), lhs=lhs, rhs=rhs, seeds=seeds,
            cap=cap, xcaps=xcaps, key=key, engine=self.engine,
            mpad=xcaps.padded if xcaps is not None else 0,
            mbytes=xcaps.wire_bytes if xcaps is not None else 0,
            merge_key=B.cross_request_key(
                kind, self.engine, cap, lhs, rhs, xcaps
            ),
        )

    def _dispatch_group(self, ops_g: List[PhysOp], resolve, xcaps):
        """Phase B for one group (legacy shape): prepare + dispatch.
        Returns (outputs, per-instance stats, claimed rounds,
        measure_padded, measure_bytes)."""
        w = self.prepare_group(ops_g, resolve, xcaps, None)
        res = dispatch_work(w)
        return res.outs, res.stats, res.rounds, w.mpad, w.mbytes

    # -- one schedule round ------------------------------------------------
    def execute_round(
        self,
        rnd: Round,
        tables: Dict[int, DTable],
        acc: Dict[int, DTable],
        ledger: Ledger,
    ) -> Tuple[
        Dict[int, DTable], Dict[int, DTable],
        int, int, int, int, int, int, int, int,
    ]:
        """Run one logical round (with abort-retry) to completion: the
        standalone driver of ``round_steps`` — every yielded group is
        dispatched solo, immediately.  Returns
        (new_tables, new_acc, comm, padded, heavy, claimed_rounds,
        dispatches, measure_dispatches, payload_bytes, useful_bytes) —
        dispatches including any prefetched measure dispatch launched on
        this round's behalf, and the byte pair being what the wire
        actually shipped (dense or packed, pre-pass included) vs the
        dense-int32 bytes of the useful tuples inside it."""
        gen = self.round_steps(rnd, tables, acc, ledger)
        try:
            works = next(gen)
            while True:
                works = gen.send([dispatch_work(w) for w in works])
        except StopIteration as stop:
            return stop.value

    def round_steps(
        self,
        rnd: Round,
        tables: Dict[int, DTable],
        acc: Dict[int, DTable],
        ledger: Ledger,
    ):
        """Reentrant round execution: a generator that YIELDS each stage's
        prepared ``GroupWork`` list and RECEIVES the matching
        ``GroupResult`` list (same order) via ``send``.  The caller owns
        the dispatch — ``execute_round`` runs each group solo; the
        serving layer (``serve.join_server``) collects works from MANY
        concurrent queries and answers with merged dispatches
        (``dispatch_merged``).  Return value (via ``StopIteration``) is
        ``execute_round``'s tuple.

        Everything data-dependent — seeds, retry decisions, capacity
        growth, caps-cache fills — stays inside the generator, so a
        round driven one-group-at-a-time is bit-identical to the fused
        standalone path.  Dispatch accounting is incremental (measured
        around the measure stage, carried per-result by the dispatcher,
        and around the retry pre-size), never a round-level counter
        delta: many executors interleaving on one ``SPMD`` each see only
        their own dispatches."""
        stages, writes = lower_round(rnd)
        # slot liveness: tmp slots die after their last reading stage (the
        # written results live on); dropping them frees the device buffers
        # so multi-stage rounds (and their retries) stop double-buffering
        last_use: Dict[str, int] = {}
        for i, stage in enumerate(stages):
            for op in stage:
                for nm in (op.a, op.b):
                    if nm is not None and nm.startswith("tmp:"):
                        last_use[nm] = i
        keep = {slot for _, _, slot in writes}
        # the prefetched combined count pre-pass for this round (launched
        # behind the previous round's payloads); its dispatch deltas were
        # held back then and are charged to THIS round's accounting
        pending = self._pending
        self._pending = None
        disp_total = pending["dispatches"] if pending is not None else 0
        meas_total = pending["measure_dispatches"] if pending is not None else 0
        attempt = 0
        comm_total = 0
        padded_total = 0
        heavy_total = 0
        bytes_total = 0
        ubytes_total = 0
        while True:
            attempt += 1
            assert attempt <= self.max_retries, f"round {rnd.phase}: too many retries"
            self.capman.heavy_hint = 0  # per-attempt: groups re-measure below
            slots: Dict[str, DTable] = {}

            def resolve(name: str) -> DTable:
                if name.startswith("tab:"):
                    return tables[int(name[4:])]
                if name.startswith("up:"):
                    v = int(name[3:])
                    return acc.get(v, tables[v])
                return slots[name]

            comm = 0
            padded = 0
            heavy = 0
            wireb = 0
            ub = 0
            claimed = 0
            dropped_by_logical: Dict[int, int] = {}
            blown_joins: List[Tuple[PhysOp, DTable, DTable]] = []
            # per-attempt fill feedback for the CapsCache watermark: key ->
            # [max per-instance sent, any drop], merged across stages
            fills: Dict[Tuple, List] = {}
            for i, stage in enumerate(stages):
                # seeds advance per attempt in lowering order, independent of
                # grouping — fused and sequential execution stay identical
                for op in stage:
                    op.seed = self._next_seed()
                stage_claimed = 0
                groups = self._group(stage, resolve)
                # the prefetched counts can only match attempt 1's stage 0
                # (later stages read tmp slots; retries reseed)
                use_pending = pending if (i == 0 and attempt == 1) else None
                d0 = self.spmd.dispatch_count
                md0 = self.spmd.measure_dispatch_count
                measures, keys, orphan_pad, orphan_b = self._measure_stage(
                    groups, resolve, use_pending
                )
                disp_total += self.spmd.dispatch_count - d0
                meas_total += self.spmd.measure_dispatch_count - md0
                padded += orphan_pad
                wireb += orphan_b
                works = [
                    self.prepare_group(ops_g, resolve, xcaps, key)
                    for ops_g, xcaps, key in zip(groups, measures, keys)
                ]
                results = yield works
                assert results is not None and len(results) == len(works), (
                    "round_steps: send() one GroupResult per yielded GroupWork"
                )
                for w, res in zip(works, results):
                    padded += w.mpad
                    wireb += w.mbytes
                    disp_total += res.dispatches
                    meas_total += res.measure_dispatches
                    stage_claimed = max(stage_claimed, res.rounds)
                    g_sent, g_drop = 0, False
                    for oi, (op, out, st) in enumerate(
                        zip(w.ops, res.outs, res.stats)
                    ):
                        slots[op.out] = out
                        comm += st["sent"]
                        padded += st.get("padded", 0)
                        heavy += st.get("heavy", 0)
                        wireb += st.get("wire_bytes", 0)
                        ub += st.get("ubytes", 0)
                        g_sent = max(g_sent, st["sent"])
                        if st["dropped"]:
                            g_drop = True
                            dropped_by_logical[op.logical] = (
                                dropped_by_logical.get(op.logical, 0) + st["dropped"]
                            )
                            if op.kind == "join" and self.engine.exact_join_presize:
                                blown_joins.append(
                                    (op, w.lhs[oi], w.rhs[oi])
                                )
                    if self.caps_cache is not None and w.key is not None:
                        f = fills.setdefault(w.key, [0, False])
                        f[0] = max(f[0], g_sent)
                        f[1] = f[1] or g_drop
                claimed += stage_claimed
                for nm, li in last_use.items():
                    if li == i and nm not in keep:
                        slots.pop(nm, None)
            if self.count_retries_comm or not dropped_by_logical:
                comm_total += comm
                padded_total += padded
                heavy_total += heavy
                bytes_total += wireb
                ubytes_total += ub
            if not dropped_by_logical:
                if self.caps_cache is not None:
                    for key, (s, dr) in fills.items():
                        self.caps_cache.observe(key, s, dr)
                break
            ledger.retries += 1
            if self.caps_cache is not None:
                # a failed attempt invalidates EVERY signature it touched:
                # the retry re-measures fresh (with new seeds) instead of
                # re-trusting caps that may have caused the abort
                for key in fills:
                    self.caps_cache.invalidate(key)
            for j, d in dropped_by_logical.items():
                lop = rnd.ops[j]
                self.capman.grow((lop.target, *lop.args), d)
            d0 = self.spmd.dispatch_count
            md0 = self.spmd.measure_dispatch_count
            for op, a, b in blown_joins:
                lop = rnd.ops[op.logical]
                self.capman.floor(
                    (lop.target, *lop.args), self.capman.presize_join(a, b, op.seed)
                )
            disp_total += self.spmd.dispatch_count - d0
            meas_total += self.spmd.measure_dispatch_count - md0
        new_tab: Dict[int, DTable] = {}
        new_acc: Dict[int, DTable] = {}
        for store, node, slot in writes:
            (new_tab if store == "tab" else new_acc)[node] = slots[slot]
        return (
            new_tab, new_acc, comm_total, padded_total, heavy_total,
            max(1, claimed), disp_total, meas_total,
            bytes_total, ubytes_total,
        )

    # -- measure prefetch (overlap) ----------------------------------------
    def prefetch_round(
        self,
        rnd: Optional[Round],
        tables: Dict[int, DTable],
        acc: Dict[int, DTable],
    ) -> None:
        """Launch the NEXT round's stage-0 combined count pre-pass while
        THIS round's payload exchanges are still in flight.  JAX dispatch
        is async — nothing here blocks the host — so by the time
        ``execute_round`` needs the counts, the device has overlapped
        them with payload work.

        Seeds are PEEKED (the counter is not advanced), reproducing
        exactly what the next ``execute_round``'s first attempt will
        assign; the pending counts are consumed by (signature, seeds)
        identity and any unconsumed slice is discarded with its wire
        cells charged.  Stage 0 only: later stages read tmp slots that
        do not exist yet."""
        self._pending = None
        if rnd is None or not self.prefetch:
            return
        stages, _ = lower_round(rnd)
        if not stages:
            return
        stage0 = stages[0]
        if any(
            nm is not None and nm.startswith("tmp:")
            for op in stage0
            for nm in (op.a, op.b)
        ):
            return

        def resolve(name: str) -> DTable:
            if name.startswith("tab:"):
                return tables[int(name[4:])]
            v = int(name[3:])
            return acc.get(v, tables[v])

        for i, op in enumerate(stage0):
            op.seed = self.seed + 7919 * (self._seed_ctr + i + 1)
        d0 = self.spmd.dispatch_count
        md0 = self.spmd.measure_dispatch_count
        index: Dict[Tuple, int] = {}
        specs: List["B.MeasureSpec"] = []
        for g in self._group(stage0, resolve):
            key = self._signature(g[0], resolve)
            if self.caps_cache is not None and key in self.caps_cache:
                continue  # the next round will hit the cache for free
            kind = g[0].kind
            lhs = [resolve(op.a) for op in g]
            rhs = None if kind == "dedup" else [resolve(op.b) for op in g]
            spec = self.engine.measure_spec(
                kind, lhs, rhs, [op.seed for op in g]
            )
            if spec is None:
                continue
            index[(key, tuple(op.seed for op in g))] = len(specs)
            specs.append(spec)
        if not specs:
            return
        counts = B.RoundCounts(self.spmd, specs, backend=self.local_backend)
        self._pending = {
            "counts": counts,
            "index": index,
            # held back from the CURRENT round's deltas (they were already
            # snapshotted); execute_round charges them to the consumer
            "dispatches": self.spmd.dispatch_count - d0,
            "measure_dispatches": self.spmd.measure_dispatch_count - md0,
        }

    # -- materialization (Theorem 15 stage 1) ------------------------------
    def materialize(
        self,
        ghd: GHD,
        base: Dict[str, DTable],
        node_schema: Dict[int, Tuple[str, ...]],
        ledger: Ledger,
    ) -> Tuple[Dict[int, DTable], int, int, int, int, int, int, int, int]:
        """Compute IDB_v per tree vertex (one grid round or a hash-join
        cascade), with the centralized retry loop.  Returns
        (tables, comm, padded, heavy, claimed_rounds, dispatches,
        measure_dispatches, payload_bytes, useful_bytes)."""
        d0 = self.spmd.dispatch_count
        md0 = self.spmd.measure_dispatch_count
        comm = 0
        padded = 0
        heavy = 0
        wireb = 0
        ubytes = 0
        dropped_any = True
        attempt = 0
        max_engine_rounds = 0
        tables: Dict[int, DTable] = {}
        while dropped_any:
            attempt += 1
            assert attempt <= self.max_retries, "materialization: too many retries"
            self.capman.heavy_hint = 0  # per-attempt, as in execute_round
            dropped_any = False
            comm_try = 0
            padded_try = 0
            heavy_try = 0
            bytes_try = 0
            ubytes_try = 0
            tables = {}
            max_engine_rounds = 0
            # phase A (as in execute_round): project every vertex's parts,
            # then resolve the grid-path multijoin calibrations for ALL
            # multi-atom vertices with one combined count dispatch
            verts = list(ghd.nodes())
            parts_by_v: Dict[int, List[DTable]] = {}
            dedup_by_v: Dict[int, bool] = {}
            for v in verts:
                parts: List[DTable] = []
                need_dedup = False
                for alias in sorted(ghd.lam[v]):
                    t = base[alias]
                    keep = [a for a in t.schema if a in ghd.chi[v]]
                    proj, _ = R.dist_project(self.spmd, t, keep, dedup=True)
                    if len(keep) < len(t.schema):
                        need_dedup = True  # strict projection: cross-shard dups
                    parts.append(proj)
                parts_by_v[v] = parts
                dedup_by_v[v] = need_dedup
            # payload seeds drawn up front: hash-path engines count with
            # the routing seed the payload dispatch will reuse
            mj_seeds = [self._next_seed() for _ in verts]
            cal_map = (
                self.engine.multijoin_measure_batch(
                    [parts_by_v[v] for v in verts], mj_seeds
                )
                if self.calibrate
                else {}
            )
            for vi, v in enumerate(verts):
                parts = parts_by_v[v]
                need_dedup = dedup_by_v[v]
                vcal = cal_map.get(vi)
                cap = self.capman.cap_for((v,))
                out, st, er = self.engine.multijoin(
                    parts, cap, mj_seeds[vi], calibrate=self.calibrate,
                    cal=None if vcal is None else vcal[0],
                )
                sent, drop = st["sent"], st["dropped"]
                pad = st.get("padded", 0)
                wb = st.get("wire_bytes", 0)
                ubytes_try += st.get("ubytes", 0)
                if vcal is not None:
                    pad += vcal[1]  # the combined pre-pass's count cells
                    wb += vcal[2]  # ... and their byte-true size
                heavy_try += st.get("heavy", 0)
                if need_dedup:
                    seeds = [self._next_seed()]
                    # materialization dedups cache like round groups do:
                    # same projected shape across attempts/vertices reuses
                    # the measured caps (signature sans seeds — caps are
                    # seed-independent, only routing is)
                    dkey = ("mat_dedup", out.cap, out.arity, cap)
                    dx = None
                    if self.calibrate:
                        if self.caps_cache is not None:
                            dx = self.caps_cache.lookup(dkey)
                        if dx is None:
                            dx = self.engine.measure_group(
                                "dedup", [out], None, seeds
                            )
                            if self.caps_cache is not None and dx is not None:
                                self.caps_cache.store(dkey, dx)
                    if dx is not None:
                        pad += dx.padded
                        wb += dx.wire_bytes
                        if dx.out_recv and dx.out_recv > cap:
                            self.capman.ensure(v, dx.out_recv)
                            cap = self.capman.cap_for((v,))
                    outs, dstats, r2 = self.engine.dedup_many(
                        [out], cap, seeds, dx
                    )
                    out = outs[0]
                    sent += dstats[0]["sent"]
                    drop += dstats[0]["dropped"]
                    pad += dstats[0].get("padded", 0)
                    wb += dstats[0].get("wire_bytes", 0)
                    ubytes_try += dstats[0].get("ubytes", 0)
                    er += r2
                    if self.caps_cache is not None:
                        self.caps_cache.observe(
                            dkey, dstats[0]["sent"], bool(dstats[0]["dropped"])
                        )
                if drop:
                    dropped_any = True
                    self.capman.grow_node(v)
                comm_try += sent
                padded_try += pad
                bytes_try += wb
                # canonicalize column order to node schema
                tables[v], _ = R.dist_project(self.spmd, out, node_schema[v])
                max_engine_rounds = max(max_engine_rounds, er)
            if self.count_retries_comm or not dropped_any:
                comm += comm_try
                padded += padded_try
                heavy += heavy_try
                wireb += bytes_try
                ubytes += ubytes_try
            if dropped_any:
                ledger.retries += 1
        for v in tables:
            self.capman.ensure(v, tables[v].cap)
        return (
            tables, comm, padded, heavy, max(1, max_engine_rounds),
            self.spmd.dispatch_count - d0,
            self.spmd.measure_dispatch_count - md0,
            wireb, ubytes,
        )
