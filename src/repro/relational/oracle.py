"""Numpy brute-force relational algebra — the oracle for every engine test."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .table import schema_join


def np_join(
    a: np.ndarray, a_schema: Sequence[str], b: np.ndarray, b_schema: Sequence[str]
) -> Tuple[np.ndarray, Tuple[str, ...]]:
    a = np.asarray(a).reshape(-1, len(a_schema))
    b = np.asarray(b).reshape(-1, len(b_schema))
    shared = [x for x in a_schema if x in b_schema]
    ai = [list(a_schema).index(x) for x in shared]
    bi = [list(b_schema).index(x) for x in shared]
    b_keep = [i for i, x in enumerate(b_schema) if x not in set(a_schema)]
    out_schema = schema_join(a_schema, b_schema)
    rows = []
    for ra in a:
        for rb in b:
            if all(ra[i] == rb[j] for i, j in zip(ai, bi)):
                rows.append(list(ra) + [rb[k] for k in b_keep])
    out = np.asarray(rows, dtype=np.int64).reshape(-1, len(out_schema))
    return out, out_schema


def np_semijoin(
    s: np.ndarray, s_schema: Sequence[str], r: np.ndarray, r_schema: Sequence[str]
) -> np.ndarray:
    s = np.asarray(s).reshape(-1, len(s_schema))
    r = np.asarray(r).reshape(-1, len(r_schema))
    shared = [x for x in s_schema if x in r_schema]
    si = [list(s_schema).index(x) for x in shared]
    ri = [list(r_schema).index(x) for x in shared]
    rkeys = {tuple(row[i] for i in ri) for row in r}
    keep = [row for row in s if tuple(row[i] for i in si) in rkeys]
    return np.asarray(keep, dtype=np.int64).reshape(-1, len(s_schema))


def np_dedup(rows: np.ndarray, arity: int) -> np.ndarray:
    rows = np.asarray(rows).reshape(-1, arity)
    return np.unique(rows, axis=0) if rows.size else rows


def np_query_answer(
    atoms: List[Tuple[str, Sequence[str]]], data: Dict[str, np.ndarray]
) -> Tuple[np.ndarray, Tuple[str, ...]]:
    """Full join of atoms [(alias, attrs)] with data[alias] = rows."""
    out, schema = np.asarray(data[atoms[0][0]], np.int64), tuple(atoms[0][1])
    out = out.reshape(-1, len(schema))
    for alias, attrs in atoms[1:]:
        out, schema = np_join(out, schema, data[alias], attrs)
    return out, schema


def canon(rows: np.ndarray) -> set:
    rows = np.asarray(rows)
    return {tuple(int(x) for x in r) for r in rows.reshape(-1, rows.shape[-1])}


def reorder(rows: np.ndarray, schema: Sequence[str], target: Sequence[str]) -> np.ndarray:
    rows = np.asarray(rows).reshape(-1, len(schema))
    idx = [list(schema).index(x) for x in target]
    return rows[:, idx]
