"""Beyond-paper engine comparison: paper-faithful grid operators
(Lemmas 8/10, skew-proof, B(X,M)=X^2/M comm) vs the optimized hash
co-partitioning operators (comm ~ inputs+outputs, abort-retry on skew).

This is the engine-side Section-Perf table: same GYM schedule, same
query, same data — only the operator strategy changes."""
from __future__ import annotations

from repro.core.gym import GymConfig, gym
from repro.core.queries import (
    chain_ghd,
    chain_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, tc_data_sparse


def run() -> list:
    out = []
    cases = [
        ("C_8", chain_query(8), chain_ghd(8), chain_data_sparse(8, seed=11)),
        ("TC_9", triangle_chain_query(3), triangle_chain_ghd(3), tc_data_sparse(3, seed=12)),
    ]
    for name, q, g, data in cases:
        res = {}
        for strat in ("grid", "hash"):
            rows, _, led = gym(
                q, data, ghd=g, p=8, config=GymConfig(strategy=strat, seed=13)
            )
            res[strat] = (rows, led)
        assert {tuple(r) for r in res["grid"][0]} == {
            tuple(r) for r in res["hash"][0]
        }
        gl, hl = res["grid"][1], res["hash"][1]
        out.append(
            dict(bench="engine", query=name, strategy="grid(paper)",
                 rounds=gl.rounds, comm=gl.comm_tuples)
        )
        out.append(
            dict(bench="engine", query=name, strategy="hash(optimized)",
                 rounds=hl.rounds, comm=hl.comm_tuples,
                 comm_reduction=round(gl.comm_tuples / max(1, hl.comm_tuples), 2))
        )
        # the optimized path must communicate strictly less on uniform data
        assert hl.shuffle_tuples < gl.shuffle_tuples, (name, hl.shuffle_tuples, gl.shuffle_tuples)
    return out
