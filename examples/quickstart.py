"""Quickstart: evaluate a join with GYM, inspect the BSP cost ledger, and
compare against the one-round Shares baseline.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.gym import GymConfig, gym
from repro.core.hypergraph import Atom, Query
from repro.core.queries import triangle_chain_ghd, triangle_chain_query
from repro.core.shares import shares_join

# --- 1. a simple acyclic query: users |><| orders |><| items ------------
q = Query(
    [
        Atom("users", "users", ("uid", "region")),
        Atom("orders", "orders", ("uid", "item")),
        Atom("items", "items", ("item", "price")),
    ],
    name="UsersOrdersItems",
)
rng = np.random.default_rng(0)
data = {
    "users": np.stack([np.arange(20), rng.integers(0, 4, 20)], 1),
    "orders": np.stack([rng.integers(0, 20, 50), rng.integers(0, 10, 50)], 1),
    "items": np.stack([np.arange(10), rng.integers(1, 100, 10)], 1),
}

rows, schema, ledger = gym(q, data, p=4)
print(f"[gym] {q.name}: {len(rows)} result rows, schema={schema}")
print(ledger)

# --- 2. a cyclic query (TC_6, width 2) via grid (paper-faithful) ops -----
q2 = triangle_chain_query(2)
data2 = {
    f"R{i}": np.stack(
        [rng.integers(0, 4, 30), rng.integers(0, 4, 30)], 1
    )
    for i in range(1, 7)
}
rows2, _, led2 = gym(
    q2, data2, ghd=triangle_chain_ghd(2), p=4,
    config=GymConfig(strategy="grid"),
)
print(f"\n[gym/grid] {q2.name}: {len(rows2)} rows")
print(led2)

# --- 3. the same query with one-round Shares ----------------------------
rows3, _, led3 = shares_join(q2, data2, p=8)
assert {tuple(r) for r in rows3} == {tuple(r) for r in rows2}
print(f"\n[shares] {q2.name}: {len(rows3)} rows in {led3.rounds} round, "
      f"comm={led3.comm_tuples} tuples (vs GYM {led2.comm_tuples})")
