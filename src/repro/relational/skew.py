"""Heavy-hitter detection and hybrid (hash + grid) exchange routing.

The hash exchange is communication-optimal but skew-sensitive: every row
of a join key lands on ``hash(key) % p``, so one heavy key concentrates
its whole load on a single reducer — the capacity blows, and the engine
either abort-retries or ships a huge calibrated pad.  The grid exchange
is skew-proof but pays Lemma 8's B(X, M) replication on EVERY row.  The
instance-optimal point between them is heavy/light decomposition
(Joglekar & Ré "It's all a matter of degree"; Hu & Yi "Instance and
Output Optimal Parallel Algorithms for Acyclic Joins" — see PAPERS.md):

- **light keys** (the common case) keep the hash routing — comm ~ inputs;
- **heavy keys** (detected from the PR-4 count pre-pass, which already
  ships per-destination load statistics for free) switch to grid-style
  routing: the left/output side is **position-partitioned** (spread
  round-robin over all p reducers, the positional trick of
  ``grid._position_groups``), the right side is **broadcast** to every
  reducer — Lemma 8 with g_left = p, g_right = 1, restricted to the
  heavy keys only.

Because the hash is key-consistent across both operands (same seed, same
shared attributes), a *destination-level* decision is automatically a
*key-level* decision: key k is heavy iff destination ``hash(k) % p`` is
flagged heavy, and both sides agree.  Correctness of the hybrid join is
then a disjoint union: a light pair (a, b) meets exactly once at
``hash(k)``; a heavy pair meets exactly once at the unique reducer
holding the position-partitioned copy of ``a`` (``b`` is everywhere).
Heavy and light keys can never cross-match — heaviness is a function of
the key.

Detection is host-side (the per-destination arrival totals come back
from the count pre-pass anyway); the resulting (p,)-bool flag vector
rides into the payload dispatch as DATA, so one compiled hybrid program
serves every flag pattern — including all-light, where the routing
degenerates to the plain hash exchange bit-for-bit.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .spmd import AXIS

#: Default heavy-hitter sensitivity: a destination is heavy when its
#: arrival exceeds this multiple of the perfectly balanced share
#: ceil(total / p).  3x is far above the multinomial max/mean noise of
#: uniform data at the p's this repo runs (<= 1.5x), and well below the
#: p * share amplification of a planted heavy key.
DEFAULT_SKEW_THRESHOLD = 3.0

#: Destinations with fewer arrivals than this are never heavy — a tiny
#: table cannot blow a capacity, and pow2 capacities floor at 4 anyway.
MIN_HEAVY_ARRIVAL = 8


# --------------------------------------------------------------- detection
def heavy_dest_flags(
    out_counts: np.ndarray, p: int, threshold: float = DEFAULT_SKEW_THRESHOLD
) -> np.ndarray:
    """Heavy-destination flags of ONE exchange side from its count
    pre-pass: ``out_counts`` is the (shards, p) per-shard send-count
    matrix (``shuffle.bucket_counts`` per shard), so column d sums to the
    total arrival at reducer d.  Returns a (p,) bool vector.

    The threshold is tied to the balanced per-reducer share (which is
    what the capacity manager's M-derived capacities assume): destination
    d is heavy iff ``arrival(d) > max(MIN_HEAVY_ARRIVAL,
    threshold * ceil(total / p))``."""
    counts = np.asarray(out_counts).reshape(-1, p)
    arrivals = counts.sum(axis=0)
    total = int(arrivals.sum())
    balanced = -(-total // p) if total else 0
    cut = max(float(MIN_HEAVY_ARRIVAL), threshold * balanced)
    return arrivals > cut


def heavy_dest_flags_many(
    out_counts: np.ndarray, p: int, threshold: float = DEFAULT_SKEW_THRESHOLD
) -> np.ndarray:
    """Batched ``heavy_dest_flags``: (shards, k, p) send counts of a
    k-instance op group -> (k, p) bool flags, each instance thresholded
    against its own balanced share."""
    counts = np.asarray(out_counts).reshape(out_counts.shape[0], -1, p)
    arrivals = counts.sum(axis=0)  # (k, p)
    totals = arrivals.sum(axis=1, keepdims=True)
    balanced = -(-totals // p)
    cut = np.maximum(float(MIN_HEAVY_ARRIVAL), threshold * balanced)
    return arrivals > cut


# ----------------------------------------------------------------- routing
def _is_heavy(dest: jax.Array, heavy: jax.Array, p: int) -> jax.Array:
    """Per-row heavy mask: ``heavy[dest]`` with dead rows (dest == p)
    always light."""
    padded = jnp.concatenate([heavy, jnp.zeros((1,), bool)])
    return padded[jnp.clip(dest, 0, p)]


def split_dests(
    dest: jax.Array, heavy: jax.Array, p: int
) -> Tuple[jax.Array, jax.Array]:
    """Position-partitioned routing of the spread side: light rows keep
    their hash destination; heavy rows are dealt round-robin over all p
    reducers (offset by the shard index so shards don't synchronize on
    reducer 0).  Each row still goes to exactly ONE destination, so the
    spread side stays a plain single-dest ``exchange``.

    ``dest``: (n,) int32 in [0, p] (p = dead); ``heavy``: (p,) bool flag
    vector riding as data.  Returns (dest', is_heavy)."""
    is_heavy = _is_heavy(dest, heavy, p)
    s = jax.lax.axis_index(AXIS)
    hidx = jnp.cumsum(is_heavy.astype(jnp.int32)) - 1
    spread = ((hidx + s) % p).astype(jnp.int32)
    return jnp.where(is_heavy, spread, dest), is_heavy


def bcast_dests(
    dest: jax.Array, heavy: jax.Array, p: int
) -> Tuple[jax.Array, jax.Array]:
    """Broadcast routing of the replicated side: light rows go to their
    hash destination only (slot 0; slots 1..p-1 are dead ``p``); heavy
    rows go to every reducer — wherever the spread side scattered their
    join partners.  Shaped for ``exchange_multi`` with g = p.

    Returns (dests (n, p), is_heavy)."""
    n = dest.shape[0]
    is_heavy = _is_heavy(dest, heavy, p)
    cols = jnp.arange(p, dtype=jnp.int32)[None, :]
    light = jnp.where(cols == 0, dest[:, None], p)
    dests = jnp.where(is_heavy[:, None], jnp.broadcast_to(cols, (n, p)), light)
    return dests, is_heavy
