from .common import ArchConfig
from .transformer import DecoderLM
from .whisper import WhisperModel

__all__ = ["ArchConfig", "DecoderLM", "WhisperModel"]
