"""The paper's example query families (Table 1, Example 4) and their GHDs
from Figure 1, plus random query generators for property tests.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

from .ghd import GHD
from .hypergraph import Atom, Query


# --------------------------------------------------------------------------
# Table 1 families
# --------------------------------------------------------------------------
def star_query(n: int) -> Query:
    """S_n: S(A_1..A_{n-1}) |><| R_1(A_1,B_1) ... R_{n-1}(A_{n-1},B_{n-1})."""
    assert n >= 2
    hub = Atom("S", "S", tuple(f"A{i}" for i in range(1, n)))
    spokes = [Atom(f"R{i}", f"R{i}", (f"A{i}", f"B{i}")) for i in range(1, n)]
    return Query([hub] + spokes, name=f"S_{n}")


def star_ghd(n: int) -> GHD:
    """Figure 1a: root=S with n-1 leaf children; width 1, depth 1."""
    q = star_query(n)
    chi = {0: q.edges["S"]}
    lam = {0: frozenset(["S"])}
    edges = []
    for i in range(1, n):
        chi[i] = q.edges[f"R{i}"]
        lam[i] = frozenset([f"R{i}"])
        edges.append((0, i))
    g = GHD.build(0, edges, chi, lam)
    g.validate(q)
    return g


def chain_query(n: int) -> Query:
    """C_n: R_1(A_0,A_1) |><| R_2(A_1,A_2) ... R_n(A_{n-1},A_n)."""
    assert n >= 1
    atoms = [Atom(f"R{i}", f"R{i}", (f"A{i-1}", f"A{i}")) for i in range(1, n + 1)]
    return Query(atoms, name=f"C_{n}")


def chain_ghd(n: int) -> GHD:
    """Figure 1b: the path GHD; width 1, depth n-1 (rooted at R_n)."""
    q = chain_query(n)
    chi = {i: q.edges[f"R{i}"] for i in range(1, n + 1)}
    lam = {i: frozenset([f"R{i}"]) for i in range(1, n + 1)}
    edges = [(i + 1, i) for i in range(1, n)]  # parent = next atom
    g = GHD.build(n, edges, chi, lam)
    g.validate(q)
    return g


def chain_ghd_grouped(n: int, group: int) -> GHD:
    """Appendix C / Figure 7a style: group consecutive chain atoms into
    width-``group`` bags -> depth ~ n/group chain GHD of C_n."""
    q = chain_query(n)
    groups: List[List[str]] = []
    for start in range(1, n + 1, group):
        groups.append([f"R{i}" for i in range(start, min(start + group, n + 1))])
    chi: Dict[int, frozenset] = {}
    lam: Dict[int, frozenset] = {}
    for gidx, aliases in enumerate(groups):
        attrs = set()
        for a in aliases:
            attrs |= q.edges[a]
        chi[gidx] = frozenset(attrs)
        lam[gidx] = frozenset(aliases)
    edges = [(g + 1, g) for g in range(len(groups) - 1)]
    g = GHD.build(len(groups) - 1, edges, chi, lam)
    g.validate(q)
    return g


def triangle_chain_query(n_triangles: int) -> Query:
    """TC_n from Table 1 with n = 3*n_triangles atoms.

    Triangle t (0-indexed) spans attributes A_{2t}, A_{2t+1}, A_{2t+2} with
    relations on each pair; consecutive triangles share attribute A_{2t+2}.
    """
    assert n_triangles >= 1
    atoms: List[Atom] = []
    k = 1
    for t in range(n_triangles):
        a, b, c = f"A{2*t}", f"A{2*t+1}", f"A{2*t+2}"
        atoms.append(Atom(f"R{k}", f"R{k}", (a, b))); k += 1
        atoms.append(Atom(f"R{k}", f"R{k}", (a, c))); k += 1
        atoms.append(Atom(f"R{k}", f"R{k}", (b, c))); k += 1
    return Query(atoms, name=f"TC_{3*n_triangles}")


def triangle_chain_ghd(n_triangles: int) -> GHD:
    """Figure 1c: one bag per triangle covered by 2 relations; width 2,
    intersection width 1, depth n/3 - 1."""
    q = triangle_chain_query(n_triangles)
    chi: Dict[int, frozenset] = {}
    lam: Dict[int, frozenset] = {}
    for t in range(n_triangles):
        a, b, c = f"A{2*t}", f"A{2*t+1}", f"A{2*t+2}"
        chi[t] = frozenset({a, b, c})
        # two relations cover the triangle: (a,b) and (b,c)
        lam[t] = frozenset({f"R{3*t+1}", f"R{3*t+3}"})
    edges = [(t + 1, t) for t in range(n_triangles - 1)]
    g = GHD.build(n_triangles - 1, edges, chi, lam)
    g.validate(q)
    return g


def example4_query() -> Query:
    """Example 4: R1(A,B,C) R2(B,F) R3(B,C,D) R4(C,D,E) R5(D,E,G)."""
    return Query(
        [
            Atom("R1", "R1", ("A", "B", "C")),
            Atom("R2", "R2", ("B", "F")),
            Atom("R3", "R3", ("B", "C", "D")),
            Atom("R4", "R4", ("C", "D", "E")),
            Atom("R5", "R5", ("D", "E", "G")),
        ],
        name="Example4",
    )


# --------------------------------------------------------------------------
# Random generators (property tests)
# --------------------------------------------------------------------------
def random_acyclic_query(rng: random.Random, n_atoms: int, max_arity: int = 3) -> Query:
    """Random acyclic query built by growing a join tree: each new atom
    shares a random nonempty attr subset with one existing atom and adds
    fresh attrs."""
    attr_id = 0

    def fresh(k: int) -> List[str]:
        nonlocal attr_id
        out = [f"X{attr_id + i}" for i in range(k)]
        attr_id += k
        return out

    atoms: List[Atom] = []
    first_arity = rng.randint(1, max_arity)
    atoms.append(Atom("T0", "T0", tuple(fresh(first_arity))))
    for i in range(1, n_atoms):
        host = rng.choice(atoms)
        k_shared = rng.randint(1, len(host.attrs))
        shared = rng.sample(list(host.attrs), k_shared)
        k_new = rng.randint(0, max(0, max_arity - k_shared))
        attrs = tuple(shared + fresh(k_new))
        atoms.append(Atom(f"T{i}", f"T{i}", attrs))
    return Query(atoms, name=f"RandAcyc{n_atoms}")


def random_query(rng: random.Random, n_atoms: int, n_attrs: int, max_arity: int = 3) -> Query:
    """Random (usually cyclic) connected query over a fixed attr universe."""
    universe = [f"X{i}" for i in range(n_attrs)]
    atoms: List[Atom] = []
    covered: List[str] = []
    for i in range(n_atoms):
        arity = rng.randint(1, max_arity)
        if covered:
            anchor = [rng.choice(covered)]
        else:
            anchor = []
        rest = rng.sample(universe, k=min(arity, len(universe)))
        attrs = tuple(dict.fromkeys(anchor + rest))[:max_arity]
        atoms.append(Atom(f"T{i}", f"T{i}", attrs))
        covered.extend(a for a in attrs if a not in covered)
    q = Query(atoms, name=f"Rand{n_atoms}")
    return q if q.is_connected() else random_query(rng, n_atoms, n_attrs, max_arity)
