"""The MapReduce shuffle as a per-shard function over the named reducer axis.

Thin join-facing veneer over the ``relational.routed`` exchange primitive
(which owns bucketing, the count pre-pass, heavy-hitter routing, the
packed wire codec, and the split-phase collective):

``exchange``: hash-partitioned repartitioning (map stage: bucket rows by
destination; network: one ``lax.all_to_all``; reduce stage: compact).
``exchange_multi``: each row goes to ``g`` destinations (the replicated
sends of Lemma 8 grid joins / Shares hypercube).

Overflow anywhere is reported, never silently dropped — the driver retries
the round with doubled capacities (the paper's abort-and-retry semantics).

Both exchanges are batchable: the collective refers to the named reducer
axis only, so wrapping the calling shard function in an inner (anonymous)
``jax.vmap`` fuses k independent shuffles into one program with one
``all_to_all`` — the mechanism behind ``relational.batched`` round fusion.

Capacity calibration: the wire ships the dense ``(p, c_out)`` slot buffer,
so every ``all_to_all`` pays ``p * c_out`` slots per shard regardless of
occupancy.  Passing a ``wire.WireFormat`` (``fmt=``) replaces the dense
int32 cells + bool valid pair with ONE bit-packed uint8 buffer per
exchange (same rows out, exact round-trip); ``exchange_start`` /
``exchange_finish`` split an exchange around its collective so a fused
group can concatenate many encoded exchanges into a single segmented
``all_to_all`` (``ship_segments``).  ``exchange_counts`` is the count-only pre-pass behind the
engine's occupancy-adaptive shuffle: a tiny ``(p,)``-int ``all_to_all`` of
per-destination bucket counts, from which the capacity manager picks tight
``c_out``/``cap_recv`` *before* the payload moves (Hu & Yi's per-instance
load calibration, driven by Joglekar & Ré-style cheap count statistics —
see PAPERS.md).  Calibrated capacities are rounded up to power-of-two
buckets (``pow2``) so jitted programs are reused across rounds with
different occupancies instead of recompiled per capacity.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from .routed import (  # noqa: F401  (re-exported: the join data plane's names)
    _bucketize,
    _multi_flatten,
    _wire_ship,
    bucket_counts,
    padded_slots,
    pow2,
    route_counts,
    routed_all_to_all,
    routed_finish,
    routed_start,
    ship_segments,
)
from .wire import WireFormat


def exchange_counts(dest: jax.Array, p: int) -> Tuple[jax.Array, jax.Array]:
    """The count-only pre-pass of an exchange (``routed.route_counts``):
    ship per-destination bucket COUNTS instead of the payload.  Returns
    ``(out_counts (p,), recv_total ())``."""
    return route_counts(dest, p)


def exchange(
    data: jax.Array,
    valid: jax.Array,
    dest: jax.Array,
    *,
    p: int,
    c_out: int,
    cap_recv: int,
    fmt: Optional[WireFormat] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Repartition rows to ``dest`` shards.

    ``fmt=None`` ships the dense int32 buckets + bool valid plane (two
    collectives); a ``WireFormat`` ships one bit-packed uint8 buffer.
    Rows out are bit-identical either way.

    Returns (rdata (cap_recv, ar), rvalid, sent, dropped_send, dropped_recv).
    """
    r = routed_all_to_all(
        data, valid, dest, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt
    )
    return r.data, r.valid, r.sent, r.dropped_send, r.dropped_recv


def exchange_multi(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,  # (n, g) int32, each in [0,p) (or p to skip)
    *,
    p: int,
    c_out: int,
    cap_recv: int,
    fmt: Optional[WireFormat] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Replicated send: each row goes to up to g destinations.

    Duplicate destinations WITHIN a row's ``dests`` are deduplicated to
    the skip slot ``p`` before bucketing (see ``routed._multi_flatten``).
    Today's callers construct distinct destinations (grid offsets are
    distinct even with size-1 dimensions, hypercube wildcard offsets are
    a product of distinct coordinates, hybrid broadcast is ``arange``),
    so this is defense-in-depth; the regression tests pin both the
    construction-site distinctness and this dedupe."""
    r = routed_all_to_all(
        data, valid, dests, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt
    )
    return r.data, r.valid, r.sent, r.dropped_send, r.dropped_recv


# ------------------------------------------- segmented (fused-group) exchange
def exchange_start(
    data: jax.Array,
    valid: jax.Array,
    dest: jax.Array,
    *,
    p: int,
    c_out: int,
    fmt: WireFormat,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map stage of a packed exchange: returns (wire segment (p, nbytes),
    sent, dropped_send)."""
    wire, sent, dropped_send, _ = routed_start(
        data, valid, dest, p=p, c_out=c_out, fmt=fmt
    )
    return wire, sent, dropped_send


def exchange_multi_start(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,
    *,
    p: int,
    c_out: int,
    fmt: WireFormat,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map stage of a packed replicated send (``exchange_multi``)."""
    wire, sent, dropped_send, _ = routed_start(
        data, valid, dests, p=p, c_out=c_out, fmt=fmt
    )
    return wire, sent, dropped_send


def exchange_finish(
    rwire: jax.Array, *, p: int, c_out: int, cap_recv: int, fmt: WireFormat
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce stage of a packed exchange: decode the received segment and
    compact.  Returns (rdata, rvalid, dropped_recv)."""
    return routed_finish(rwire, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt)
