"""Figure 6: Log-GTA transformation trace on the TC_15 GHD (width 2,
iw 1, depth 4 in our node-count convention) -> log-depth, width <= 3."""
from __future__ import annotations

import math

from repro.core.loggta import log_gta
from repro.core.queries import triangle_chain_ghd, triangle_chain_query


def run() -> list:
    q = triangle_chain_query(5)  # 15 relations
    g = triangle_chain_ghd(5)
    iw = g.intersection_width(q)
    trace: list = []
    out = log_gta(g.make_complete(q), q, check=True, trace=trace)
    res = dict(
        bench="fig6",
        width_in=g.width,
        iw_in=iw,
        depth_in=g.depth,
        width_out=out.width,
        depth_out=out.depth,
        iterations=len(trace),
    )
    assert out.width <= max(g.width, 3 * iw) == 3
    assert out.depth <= 2 * math.ceil(math.log2(out.size())) + 2
    out.validate(q)
    return [res] + [
        dict(bench="fig6_trace", **t) for t in trace
    ]
