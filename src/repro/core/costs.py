"""Analytic cost formulas from the paper, plus the calibrated per-plan
cost model behind the advisor in ``core/optimizer.py``.

Two layers live here:

1. **Closed-form worst-case formulas** (Tables 2 & 3, Lemmas 8-11,
   Theorems 12/14/15/23) — used by the benchmarks to place measured
   ledger numbers next to the paper's predictions.
2. **Per-schedule cost entries** (``predict_plan_cost``) — walk an
   actual planner schedule op-by-op under per-engine communication
   formulas and the matching-database size assumption (Appendix A), so
   candidate plans with the *same* asymptotics still get distinguishable
   scores.  Constants are calibrated from measured ``Ledger`` numbers
   via ``fit_calibration`` (records exported by
   ``Ledger.calibration_record``).

Every formula cites its paper source inline; ``benchmarks/report.py``
renders the column -> formula provenance table from the same citations.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence

from ..relational.skew import DEFAULT_SKEW_THRESHOLD
from .ghd import GHD
from .hypergraph import Query


def B(X: float, M: float) -> float:
    """The paper's B(X, M) = X^2 / M (Assumption 4, Sec. 3.3): the
    communication of sorting/hashing X tuples across machines with
    memory M each."""
    return X * X / M


def lemma8_join_comm(sizes, M: float, out: float) -> float:
    """Lemma 8 (Sec. 3.3): one-round grid join of w relations costs
    O((sum |R_i|)^w / M^(w-1) + OUT) communication."""
    s = float(sum(sizes))
    w = len(sizes)
    return s**w / M ** (w - 1) + out


def lemma10_semijoin_comm(r: float, s: float, M: float) -> float:
    """Lemma 10 (Sec. 3.3): skew-proof grid semijoin S |>< R in O(1)
    rounds and O(B(|R| + |S|, M)) communication."""
    return B(r + s, M)


def gym_comm(n: int, IN: float, OUT: float, M: float, w: int) -> float:
    """Theorem 15 (Sec. 5): GYM on a width-w GHD communicates
    O(n * B(IN^w + OUT, M))."""
    return n * B(IN**w + OUT, M)


def gym_rounds(d: int, n: int) -> float:
    """Theorem 15 (Sec. 5, via Theorem 14's DYM-d): O(d + log n) rounds
    on a depth-d GHD of n vertices."""
    return d + math.log2(max(2, n))


def gym_loggta_comm(
    n: int, IN: float, OUT: float, M: float, w: int, iw: int
) -> float:
    """Theorem 23 (Sec. 6): GYM on the Log-GTA transform runs in
    O(log n) rounds with O(n * B(IN^max(w,3iw) + OUT, M)) communication."""
    return n * B(IN ** max(w, 3 * iw) + OUT, M)


def acqmr_comm(n: int, IN: float, OUT: float, M: float, w: int) -> float:
    """Sec. 2.2 (ACQ-MR baseline, realized via Log-GTA', Appendix D.2 /
    Theorem 30): O(n * B(IN^{3w} + OUT, M))."""
    return n * B(IN ** (3 * w) + OUT, M)


def shares_comm_star(n: int, IN: float, M: float, OUT: float) -> float:
    """Table 2 (S_n via one-round Shares, Sec. 2.3):
    O(IN^{n/2} / M^{n/2} + OUT) worst case."""
    half = n / 2.0
    return IN**half / M**half + OUT


def shares_comm_tc(n: int, IN: float, M: float, OUT: float) -> float:
    """Table 3 (TC_n via one-round Shares, Sec. 2.3):
    O(IN^{n/6} / M^{n/6} + OUT) worst case."""
    sixth = n / 6.0
    return IN**sixth / M**sixth + OUT


def one_round_chain_lower_bound(n: int, IN: float, M: float) -> float:
    """Sec. 1: any 1-round algorithm for C_n needs >= (IN/M)^{n/4} comm."""
    return (IN / M) ** (n / 4.0)


def predicted_table(
    query: Query, ghd: GHD, IN: float, OUT: float, M: float
) -> Dict[str, float]:
    """Paper worst-case predictions for one (query, GHD) pair: GYM
    (Theorem 15), GYM(Log-GTA) (Theorem 23), and ACQ-MR (Sec. 2.2),
    keyed by the GHD statistics of Sec. 3.1 (width / intersection width /
    depth)."""
    w = ghd.width
    iw = ghd.intersection_width(query)
    n = query.n
    d = ghd.depth
    return {
        "width": w,
        "iw": iw,
        "depth": d,
        "gym_rounds": gym_rounds(d, n),
        "gym_comm": gym_comm(n, IN, OUT, M, w),
        "gym_loggta_rounds": gym_rounds(int(math.log2(max(2, 4 * n))) + 1, n),
        "gym_loggta_comm": gym_loggta_comm(n, IN, OUT, M, w, iw),
        "acqmr_comm": acqmr_comm(n, IN, OUT, M, w),
    }


# ==========================================================================
# Per-schedule cost entries (the advisor's model, Sec. 4.2/4.3 schedules
# priced per engine) + calibration from measured ledgers
# ==========================================================================

#: Physical-stage decomposition of each logical planner op (mirrors
#: ``core.physical.lower_op``): per stage, the physical op kind and how
#: many instances of it the lowering emits.  The advisor charges one BSP
#: round per stage, exactly as the executor's lowering does, and uses
#: the instance counts to estimate sequential dispatches.
OP_STAGES: Dict[str, Sequence] = {
    "semijoin": (("semijoin", 1),),
    "down_semijoin": (("semijoin", 1),),
    "join": (("join", 1),),
    "pair_filter": (("semijoin", 2), ("intersect", 1)),
    "triple_filter": (("semijoin", 3), ("intersect", 1), ("intersect", 1)),
    "pair_join": (("join", 2), ("join", 1)),
    "triple_join": (("join", 3), ("join", 1), ("join", 1)),
}


def join_size_estimate(a: float, b: float, shared: bool = True) -> float:
    """Matching-database join-size estimate (Appendix A): on (near-)
    partial-permutation inputs every pairwise join output stays O(max of
    the inputs).  This is the regime the paper measures in, and the
    advisor's calibration absorbs the constant.

    ``shared=False`` means the operands have NO common attribute — the
    join is a cartesian product (|a| * |b|), which is how C-GTA's
    pair-merged leaf bags can blow up a careless plan; pricing it
    honestly is what steers the advisor away from those GHDs."""
    if not shared:
        return a * b
    return max(a, b)


def shuffle_pad_factor(p: int, calibrated: bool, wire_gain: float = 1.0) -> float:
    """Predicted inflation of wire slots over useful tuples for one hash
    exchange on a p-shard SPMD.

    The physical shuffle ships the dense ``(p, c_out)`` bucket buffer per
    shard (``relational.shuffle``).  With a FIXED global capacity, c_out
    is the worst-case shard cap, so the fleet ships ~p x the useful
    volume (each shard pays all p buckets at full depth).  With the
    count-calibrated pre-pass c_out hugs the true max bucket, leaving
    only the pow2 rounding loss (< 2x) plus per-bucket remainders.  The
    paper prices *useful* tuples (Sec. 3.2); this factor converts that to
    what the wire actually carries, so the advisor can rank by shipped
    slots (``predict_plan_cost(..., calibrate_shuffle=...)``).

    ``wire_gain`` (>= 1) reprices the PACKED wire format: the mean
    dense-bits/packed-bits row compression of the query's exchange
    formats (``relational.wire.wire_gain``).  The packed codec shrinks
    every shipped slot — occupied or padding — by that ratio, so the
    pad factor divides through; 1.0 (dense) recovers the slot prices
    above."""
    base = 2.0 if calibrated else 2.0 * float(max(1, p))
    return base / max(1.0, float(wire_gain))


# Wire-slot-equivalent price of ONE extra program dispatch (launch latency
# + compile-cache probe + the host sync a measure implies), used by
# ``predict_plan_cost`` to reprice calibration: the count pre-pass buys a
# ~p-fold pad reduction but costs measure dispatches, and on small inputs
# the dispatches dominate.  Fit loosely to the shuffle benchmarks (an
# extra dispatch costs on the order of a few-thousand-slot exchange).
DEFAULT_DISPATCH_OVERHEAD_SLOTS = 2048.0


def grid_replication(p: int, w: int = 2) -> float:
    """Per-tuple replication of a w-way grid op on p reducers: each
    relation is sent to p^((w-1)/w) grid cells (Lemma 8's g_i sizing).
    This is the engine-accurate instantiation of B(X, M) for a FIXED
    p-shard SPMD: with the grid sized to memory M the two coincide
    (sqrt(p) * X = X^2/M exactly when sqrt(p) = X/M, Sec. 3.3)."""
    return float(max(1, p)) ** ((w - 1) / w)


def skew_amplification(
    p: int, share: float, threshold: Optional[float] = None
) -> float:
    """Max-over-mean per-destination load of a hash exchange when one key
    carries ``share`` of the rows: the hot reducer holds ~``share`` of
    the relation against the 1/p balanced mean, so the BSP round is paced
    (and the calibrated send bucket sized) by ``p * share``, not by the
    mean.

    Gated on the SAME threshold the engine's heavy-hitter detection uses
    (``threshold``, defaulting to the engine default
    ``relational.skew.DEFAULT_SKEW_THRESHOLD``): a max/mean ratio the
    pow2-calibrated shuffle absorbs without flagging anything heavy is
    not an amplification — 1.0 there, the full ``p * share`` beyond."""
    t = DEFAULT_SKEW_THRESHOLD if threshold is None else float(threshold)
    amp = float(max(1, p)) * float(share)
    return amp if amp > t else 1.0


def _heavy_share(p: int, share: float, threshold: Optional[float] = None) -> float:
    """The share that actually routes heavy under the detection
    threshold: 0 below it (hybrid == hash there), ``share`` beyond."""
    t = DEFAULT_SKEW_THRESHOLD if threshold is None else float(threshold)
    return float(share) if float(max(1, p)) * float(share) > t else 0.0


def engine_op_comm(
    engine: str,
    kind: str,
    left: float,
    right: float,
    p: int,
    skew_l: float = 0.0,
    skew_r: float = 0.0,
    skew_threshold: Optional[float] = None,
) -> float:
    """Predicted shuffle communication of ONE physical op under an engine
    on a p-shard SPMD.

    - ``'grid'`` (paper-faithful): semijoins by Lemma 10 (grid round +
      mark dedup), pairwise joins by Lemma 8 with w=2 — skew-proof, at
      the cost of ~sqrt(p) per-tuple replication (``grid_replication``).
    - ``'hash'`` (beyond-paper co-partitioning): every op shuffles its
      inputs once — but priced by the MAX per-destination load, not the
      mean: a heavy key amplifies the effective cost by ``p * share``
      (``skew_amplification``; ``skew_l``/``skew_r`` are each side's max
      single-key share, 0 when unknown, reducing to left + right).
    - ``'hybrid'`` (heavy/light decomposition, ``relational.skew``):
      light keys price as hash at balanced load; heavy left rows spread
      positionally (still 1x); heavy right rows broadcast p-ways — so
      skew costs ``p * skew_r * right`` extra replication instead of
      amplifying the whole exchange.
    - ``intersect`` / ``dedup`` are hash-implemented under every engine
      (see ``core.physical.Engine``) and key on full distinct rows, so
      no single heavy column value can skew them — they price as plain
      hash ops, unamplified, under every engine.  A semijoin's RIGHT
      side likewise ships its deduplicated key projection (one row per
      key), so only its left side can amplify.

    ``skew_threshold`` is the execution's ``GymConfig.skew_threshold``,
    so the model's amplification gate matches the engine that will run.
    """
    if engine == "grid":
        rep = grid_replication(p, 2)
        if kind == "semijoin":
            # Lemma 10: grid round replicates both sides; round 2 dedups
            # the marked left side with a hash pass
            return rep * (left + right) + left
        if kind == "join":
            return rep * (left + right)
        return left + right
    if kind not in ("semijoin", "join"):
        return left + right
    if kind == "semijoin":
        skew_r = 0.0  # R ships one row per key after dedup
    if engine == "hybrid":
        heavy = _heavy_share(p, skew_r, skew_threshold)
        return left + right + float(max(1, p)) * heavy * right
    return left * skew_amplification(p, skew_l, skew_threshold) + (
        right * skew_amplification(p, skew_r, skew_threshold)
    )


def materialization_comm(
    engine: str,
    parts: Sequence[float],
    part_attrs: Sequence,  # attribute sets aligned with ``parts``
    p: int,
):
    """Stage-1 (Theorem 15) cost of computing one IDB_v = |><| lam(v).
    Returns ``(comm, size_estimate_of_IDB_v)``.

    Single-atom bags materialize by projection only (no shuffle).  Grid
    materializes in one Lemma 8 round over all w parts (w-way grid
    replication); hash runs a left-deep cascade in sorted-alias order
    (matching ``PhysicalExecutor.materialize``), shuffling each pairwise
    join's inputs — except attribute-disjoint steps, which the hash
    engine executes as a broadcast cross join (right side replicated
    p ways, left stays put).  The size cascade and the comm cascade walk
    the same (part, attrs) sequence so the two can never drift apart."""
    cur = float(parts[0])
    if len(parts) <= 1:
        return 0.0, cur
    total = 0.0
    seen = set(part_attrs[0])
    for nxt, nat in zip(parts[1:], part_attrs[1:]):
        shared = bool(seen & set(nat))
        if engine != "grid":
            total += cur + nxt if shared else p * nxt  # else: broadcast
        cur = join_size_estimate(cur, nxt, shared=shared)
        seen |= set(nat)
    if engine == "grid":
        total = grid_replication(p, len(parts)) * float(sum(parts)) + cur
    return total, cur


def predict_plan_cost(
    query: Query,
    ghd: GHD,
    rounds,  # List[planner.Round]
    engine: str,
    alias_sizes: Mapping[str, float],
    p: int,
    calibration: Optional["CostCalibration"] = None,
    calibrate_shuffle: bool = True,
    alias_skew: Optional[Mapping[str, float]] = None,
    skew_threshold: Optional[float] = None,
    dispatch_overhead: float = 0.0,
    dispatches: float = 0.0,
    measure_dispatches: float = 0.0,
    wire_gain: float = 1.0,
) -> Dict[str, float]:
    """Walk one planner schedule op-by-op and price it under ``engine``
    on a p-shard SPMD.

    Returns ``{"comm", "rounds", "ops", "out_est", "wire"}`` where

    - ``comm`` = materialization (Theorem 15 stage 1) + per-op shuffle
      (Lemma 8/10 grid replication for grid, inputs-sized for hash) +
      the estimated output (the paper counts reducer output as
      communication, Sec. 3.2), scaled by the calibration's per-engine
      constant when given;
    - ``rounds`` = claimed BSP rounds: 1 for materialization plus, per
      logical round, the max over its ops of the stage count (grid
      semijoin stages claim 2 rounds each, per Lemma 10);
    - ``wire`` = predicted SLOTS shipped: the shuffled volume inflated by
      ``shuffle_pad_factor`` (fixed capacities pad ~p x; the
      count-calibrated pre-pass pads < 2x) plus the un-padded output,
      plus — when ``dispatch_overhead`` > 0 — a slot-equivalent charge of
      ``dispatch_overhead * (dispatches + measure_dispatches)`` pricing
      program-launch latency.  This is how calibrated-vs-fixed becomes a
      per-query decision: calibration shrinks the pad factor but adds
      measure dispatches, and tiny inputs can lose the trade.  This is
      what the advisor ranks by — the wire carries slots, not the
      paper's useful tuples.  ``wire_gain`` > 1 (the packed wire
      format's mean row compression) deflates the pad factor, so a
      packed execution reprices calibrated-vs-fixed honestly.

    Node sizes evolve under the matching-database assumption
    (``join_size_estimate``); semijoins never grow a table, so sizes are
    upper bounds there.  ``alias_skew`` (max single-key share per base
    relation, e.g. ``optimizer.skew_share``) propagates through the node
    walk as the max over contributing relations, pricing the hash engine
    by its hot reducer and the hybrid engine by its broadcast overhead.
    """
    skew_in = alias_skew or {}

    def op_comm(kind: str, l: float, r: float, sl: float, sr: float) -> float:
        return engine_op_comm(
            engine, kind, l, r, p, sl, sr, skew_threshold=skew_threshold
        )

    # --- stage 1: per-node IDB materialization (Theorem 15) -------------
    est: Dict[int, float] = {}
    skw: Dict[int, float] = {}
    comm = 0.0
    for v in ghd.nodes():
        aliases = sorted(ghd.lam[v])
        parts = [float(alias_sizes[a]) for a in aliases]
        part_attrs = [query.edges[a] for a in aliases]
        mat_comm, out_v = materialization_comm(engine, parts, part_attrs, p)
        comm += mat_comm
        # strict projection (chi(v) drops columns of some atom) forces a
        # cross-shard dedup pass: one more shuffle of the node table
        if any(query.edges[a] - ghd.chi[v] for a in aliases):
            comm += out_v
        est[v] = out_v
        skw[v] = max((float(skew_in.get(a, 0.0)) for a in aliases), default=0.0)

    # --- stage 2: the DYM schedule op walk (Sec. 4.2 / 4.3) -------------
    claimed = 1  # materialization
    n_ops = 0
    for rnd in rounds:
        round_claim = 1
        for op in rnd.ops:
            n_ops += 1
            k, t = op.kind, op.target
            round_claim = max(
                round_claim,
                sum(
                    2 if engine == "grid" and sk == "semijoin" else 1
                    for sk, _ in OP_STAGES[k]
                ),
            )
            if k in ("semijoin", "down_semijoin"):
                r = op.args[0]
                comm += op_comm("semijoin", est[t], est[r], skw[t], skw[r])
            elif k == "join":
                r = op.args[0]
                comm += op_comm("join", est[t], est[r], skw[t], skw[r])
                est[t] = join_size_estimate(est[t], est[r])
                skw[t] = max(skw[t], skw[r])
            elif k == "pair_filter":
                s, r2 = op.args
                comm += op_comm("semijoin", est[s], est[t], skw[s], skw[t])
                comm += op_comm("semijoin", est[s], est[r2], skw[s], skw[r2])
                comm += op_comm("intersect", est[s], est[s], skw[s], skw[s])
            elif k == "triple_filter":
                s, rb, rc = op.args
                for other in (t, rb, rc):
                    comm += op_comm(
                        "semijoin", est[s], est[other], skw[s], skw[other]
                    )
                comm += 2 * op_comm("intersect", est[s], est[s], skw[s], skw[s])
            elif k == "pair_join":
                s, r2 = op.args
                comm += op_comm("join", est[t], est[s], skw[t], skw[s])
                comm += op_comm("join", est[r2], est[s], skw[r2], skw[s])
                j1 = join_size_estimate(est[t], est[s])
                j2 = join_size_estimate(est[r2], est[s])
                sk12 = max(skw[t], skw[s], skw[r2])
                comm += op_comm("join", j1, j2, sk12, sk12)
                est[t] = join_size_estimate(j1, j2)
                skw[t] = sk12
            elif k == "triple_join":
                s, rb, rc = op.args
                j1 = join_size_estimate(est[t], est[s])
                j2 = join_size_estimate(est[rb], est[s])
                j3 = join_size_estimate(est[rc], est[s])
                comm += op_comm("join", est[t], est[s], skw[t], skw[s])
                comm += op_comm("join", est[rb], est[s], skw[rb], skw[s])
                comm += op_comm("join", est[rc], est[s], skw[rc], skw[s])
                skj = max(skw[t], skw[s], skw[rb], skw[rc])
                comm += op_comm("join", j1, j2, skj, skj)
                j12 = join_size_estimate(j1, j2)
                comm += op_comm("join", j12, j3, skj, skj)
                est[t] = join_size_estimate(j12, j3)
                skw[t] = skj
            else:  # pragma: no cover - planner emits only the kinds above
                raise ValueError(f"unknown logical op kind {k!r}")
        claimed += round_claim

    out_est = est[ghd.root]
    shuffled = comm  # everything priced so far moved through an exchange
    comm += out_est  # Sec. 3.2: output tuples count as communication
    if calibration is not None:
        comm = calibration.apply(engine, comm)
        shuffled = calibration.apply(engine, shuffled)
    # the wire ships padded slots for the shuffled part; the output is
    # written compacted, so it rides un-inflated (same calibration scale
    # as ``comm`` so the two stay comparable)
    wire = shuffled * shuffle_pad_factor(p, calibrate_shuffle, wire_gain) + (
        comm - shuffled
    )
    overhead = float(dispatch_overhead) * (
        float(dispatches) + float(measure_dispatches)
    )
    wire += overhead
    return {
        "comm": comm,
        "rounds": float(claimed),
        "ops": float(n_ops),
        "out_est": out_est,
        "wire": wire,
        "dispatch_overhead": overhead,
    }


# --------------------------------------------------------------------------
# calibration: fit the model's constants from measured Ledger numbers
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CostCalibration:
    """Multiplicative per-engine constants for ``predict_plan_cost``.

    The paper's formulas are O(.)-bounds; a real engine has constants
    (replication factors, dedup passes, retry re-sends).  We fit one
    scalar per engine as the geometric mean of measured/predicted
    communication over a set of executed plans — the log-space least
    squares solution for a single multiplicative constant — so the model
    keeps its *shape* and only its scale is learned.
    """

    comm_scale: Dict[str, float] = dataclasses.field(default_factory=dict)
    samples: int = 0

    def comm_factor(self, engine: str) -> float:
        return self.comm_scale.get(engine, 1.0)

    def apply(self, engine: str, predicted_comm: float) -> float:
        return predicted_comm * self.comm_factor(engine)

    def to_dict(self) -> Dict:
        return {"comm_scale": dict(self.comm_scale), "samples": self.samples}

    @staticmethod
    def from_dict(d: Mapping) -> "CostCalibration":
        return CostCalibration(
            comm_scale={k: float(v) for k, v in d.get("comm_scale", {}).items()},
            samples=int(d.get("samples", 0)),
        )


def fit_calibration(records: Iterable[Mapping]) -> CostCalibration:
    """Fit a ``CostCalibration`` from ``Ledger.calibration_record`` dicts.

    Each record needs ``engine``, ``predicted_comm`` (uncalibrated model
    output) and ``measured_comm`` (the ledger's ground truth).  Records
    with non-positive entries are skipped."""
    logs: Dict[str, List[float]] = {}
    n = 0
    for r in records:
        pred = float(r.get("predicted_comm", 0.0))
        meas = float(r.get("measured_comm", 0.0))
        if pred <= 0.0 or meas <= 0.0:
            continue
        logs.setdefault(str(r["engine"]), []).append(math.log(meas / pred))
        n += 1
    scale = {e: math.exp(sum(v) / len(v)) for e, v in logs.items()}
    return CostCalibration(comm_scale=scale, samples=n)


def prediction_error(predicted: float, measured: float) -> float:
    """Symmetric relative error in log space: |log(pred / measured)|.

    This is the quantity the calibration fit minimizes, so 'calibration
    reduces prediction error' is a statement about this metric."""
    assert predicted > 0 and measured > 0
    return abs(math.log(predicted / measured))
