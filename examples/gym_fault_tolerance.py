"""Fault tolerance demo: kill a GYM query mid-flight, resume from the
round-level snapshot, and verify the answer is identical.

    PYTHONPATH=src python examples/gym_fault_tolerance.py
"""
import os
import tempfile

import numpy as np

from repro.core.decompose import ghd_for
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.queries import chain_query
from repro.data.synthetic import chain_data_sparse
from repro.relational.spmd import SPMD

q = chain_query(6)
data = chain_data_sparse(6, seed=5)

# ground truth in one uninterrupted run
want, _, _ = gym(q, data, p=4, config=GymConfig(seed=9))
want = {tuple(r) for r in want}

# run 1: execute a few BSP round-groups, snapshot after each, then "crash"
snap = os.path.join(tempfile.gettempdir(), "gym_ft_snapshot.npz")
drv = GymDriver(q, ghd_for(q), data, SPMD(4), GymConfig(seed=9))
total = len(drv.schedule) + 1
crash_after = 4
for i in range(crash_after):
    drv.step()
    drv.save(snap)
print(f"[run 1] executed {crash_after}/{total} round-groups, snapshot at "
      f"cursor={drv.cursor}; simulating crash now")
del drv

# run 2: a fresh driver resumes from the snapshot and finishes the query
drv2 = GymDriver(q, ghd_for(q), data, SPMD(4), GymConfig(seed=9))
drv2.load(snap)
print(f"[run 2] resumed at cursor={drv2.cursor}")
out = drv2.run()
got = out.to_set()
assert got == want, "resumed answer differs!"
print(f"[run 2] finished: {len(got)} rows — identical to the uninterrupted run")
print(drv2.ledger)
os.remove(snap)
