"""The extracted ``routed_all_to_all`` primitive (PR 10).

The refactor moved the exchange machinery (count pre-pass, heavy split,
wire packing, split-phase) out of ``shuffle``/``physical`` into
``relational.routed``; these tests pin the primitive's semantics
directly — delivery against a numpy oracle, dtype-generality (float
payloads, the MoE customer's requirement), heavy-hitter spreading
conservation, split-phase equivalence, and the ``RoutePolicy`` facade
the engines now consume."""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational import shuffle as S
from repro.relational.routed import (
    RoutePolicy,
    route_counts,
    routed_all_to_all,
    routed_finish,
    routed_start,
    ship_segments,
)
from repro.relational.spmd import AXIS
from repro.relational.wire import WirePolicy

P, N, AR = 4, 12, 3


def _mk(seed=0, dom=7, frac_valid=0.8):
    rng = np.random.RandomState(seed)
    data = rng.randint(0, dom, size=(P, N, AR)).astype(np.int32)
    valid = rng.rand(P, N) < frac_valid
    dest = rng.randint(0, P, size=(P, N)).astype(np.int32)
    return jnp.asarray(data), jnp.asarray(valid), jnp.asarray(dest)


def _run(fn, *args):
    return jax.vmap(fn, axis_name=AXIS)(*args)


def _sent_rows(data, valid, dest, want=None):
    """Multiset (sorted array) of rows sent (optionally to one dest)."""
    data, valid, dest = map(np.asarray, (data, valid, dest))
    rows = []
    for s in range(P):
        for i in range(N):
            if valid[s, i] and (want is None or dest[s, i] == want):
                rows.append(data[s, i])
    if not rows:
        return np.zeros((0, data.shape[-1]), np.int32)
    r = np.stack(rows)
    return r[np.lexsort(r.T[::-1])]


def _recv_rows(rdata, rvalid, shard=None):
    rdata, rvalid = np.asarray(rdata), np.asarray(rvalid)
    sel = rdata[rvalid] if shard is None else rdata[shard][rvalid[shard]]
    if not len(sel):
        return np.zeros((0, rdata.shape[-1]), rdata.dtype)
    return sel[np.lexsort(sel.T[::-1])]


# ------------------------------------------------------------- delivery
def test_delivery_matches_oracle():
    data, valid, dest = _mk(1)
    r = _run(
        lambda d, v, t: routed_all_to_all(d, v, t, p=P, c_out=8, cap_recv=64),
        data, valid, dest,
    )
    assert int(r.dropped_send.sum()) == 0 and int(r.dropped_recv.sum()) == 0
    assert int(r.sent.sum()) == int(np.asarray(valid).sum())
    for s in range(P):
        np.testing.assert_array_equal(
            _recv_rows(r.data, r.valid, s), _sent_rows(data, valid, dest, s)
        )


def test_packed_wire_bit_identical_to_dense():
    data, valid, dest = _mk(2)
    fmt = WirePolicy((("a", 3), ("b", 3), ("c", 3))).format_for(("a", "b", "c"))
    args = dict(p=P, c_out=8, cap_recv=64)
    rd = _run(lambda d, v, t: routed_all_to_all(d, v, t, **args), data, valid, dest)
    rp = _run(
        lambda d, v, t: routed_all_to_all(d, v, t, fmt=fmt, **args),
        data, valid, dest,
    )
    for s in range(P):
        np.testing.assert_array_equal(
            _recv_rows(rd.data, rd.valid, s), _recv_rows(rp.data, rp.valid, s)
        )
    np.testing.assert_array_equal(np.asarray(rd.sent), np.asarray(rp.sent))


def test_float_payload_roundtrip():
    """The MoE customer ships float32 activation rows — the primitive must
    be dtype-generic, not int32-only like the join tables."""
    rng = np.random.RandomState(3)
    data = rng.randn(P, N, AR).astype(np.float32)
    valid = jnp.asarray(rng.rand(P, N) < 0.7)
    dest = jnp.asarray(rng.randint(0, P, size=(P, N)).astype(np.int32))
    r = _run(
        lambda d, v, t: routed_all_to_all(d, v, t, p=P, c_out=8, cap_recv=64),
        jnp.asarray(data), valid, dest,
    )
    assert np.asarray(r.data).dtype == np.float32
    for s in range(P):
        got = np.sort(_recv_rows(r.data, r.valid, s), axis=0)
        want = np.sort(_sent_rows(data, valid, dest, s), axis=0)
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------- heavy split
def test_heavy_spread_conserves_and_balances():
    """All rows aimed at one hot destination: the heavy split must deliver
    every row exactly once, spread them ~evenly over all shards, and
    report the spread count in ``heavy_sent``."""
    rng = np.random.RandomState(4)
    data = rng.randint(0, 9, size=(P, N, AR)).astype(np.int32)
    valid = np.ones((P, N), bool)
    dest = np.zeros((P, N), np.int32)  # everyone hits shard 0
    heavy = jnp.asarray(np.arange(P) == 0)
    r = _run(
        lambda d, v, t: routed_all_to_all(
            d, v, t, p=P, c_out=16, cap_recv=64, heavy=heavy
        ),
        jnp.asarray(data), jnp.asarray(valid), jnp.asarray(dest),
    )
    assert int(r.dropped_send.sum()) == 0 and int(r.dropped_recv.sum()) == 0
    assert int(r.heavy_sent.sum()) == P * N  # every row went via the spread
    np.testing.assert_array_equal(  # union of deliveries == all rows
        _recv_rows(r.data, r.valid), _sent_rows(data, valid, dest)
    )
    per_shard = np.asarray(r.valid).sum(axis=1)
    assert per_shard.max() - per_shard.min() <= 1  # round-robin balance


def test_heavy_light_mix_keeps_light_local():
    rng = np.random.RandomState(5)
    data = rng.randint(0, 9, size=(P, N, AR)).astype(np.int32)
    valid = np.ones((P, N), bool)
    dest = rng.randint(0, P, size=(P, N)).astype(np.int32)
    heavy_id = 2
    heavy = jnp.asarray(np.arange(P) == heavy_id)
    r = _run(
        lambda d, v, t: routed_all_to_all(
            d, v, t, p=P, c_out=16, cap_recv=64, heavy=heavy
        ),
        jnp.asarray(data), jnp.asarray(valid), jnp.asarray(dest),
    )
    assert int(r.heavy_sent.sum()) == int((dest == heavy_id).sum())
    for s in range(P):  # light rows still land on their destination
        if s == heavy_id:
            continue
        want = _sent_rows(data, valid, dest, s)
        got = _recv_rows(r.data, r.valid, s)
        # shard s also receives its round-robin slice of heavy rows:
        # every light row must be a subset of what landed there
        wset = {tuple(x) for x in want}
        gl = [tuple(x) for x in got]
        for w in wset:
            assert w in gl


# ----------------------------------------------------------- split phase
def test_split_phase_equals_fused():
    data, valid, dest = _mk(6)
    fmt = WirePolicy((("a", 3), ("b", 3), ("c", 3))).format_for(("a", "b", "c"))
    fused = _run(
        lambda d, v, t: routed_all_to_all(
            d, v, t, p=P, c_out=8, cap_recv=64, fmt=fmt
        ),
        data, valid, dest,
    )

    def split(d, v, t):
        wire, sent, dsend, hs = routed_start(d, v, t, p=P, c_out=8, fmt=fmt)
        (rwire,) = ship_segments([wire])
        rdata, rvalid, drecv = routed_finish(
            rwire, p=P, c_out=8, cap_recv=64, fmt=fmt
        )
        return rdata, rvalid, sent, dsend, drecv, hs

    sp = _run(split, data, valid, dest)
    for s in range(P):
        np.testing.assert_array_equal(
            _recv_rows(fused.data, fused.valid, s), _recv_rows(sp[0], sp[1], s)
        )
    np.testing.assert_array_equal(np.asarray(fused.sent), np.asarray(sp[2]))


# ----------------------------------------------------------- multi-dest
def test_multi_dest_matches_exchange_multi():
    rng = np.random.RandomState(7)
    data = rng.randint(0, 7, size=(P, N, AR)).astype(np.int32)
    valid = rng.rand(P, N) < 0.8
    dests = rng.randint(0, P, size=(P, N, 2)).astype(np.int32)
    args = dict(p=P, c_out=16, cap_recv=128)
    r = _run(
        lambda d, v, t: routed_all_to_all(d, v, t, **args),
        jnp.asarray(data), jnp.asarray(valid), jnp.asarray(dests),
    )
    old = _run(
        lambda d, v, t: S.exchange_multi(d, v, t, **args),
        jnp.asarray(data), jnp.asarray(valid), jnp.asarray(dests),
    )
    for s in range(P):
        np.testing.assert_array_equal(
            _recv_rows(r.data, r.valid, s), _recv_rows(old[0], old[1], s)
        )
    np.testing.assert_array_equal(np.asarray(r.sent), np.asarray(old[2]))


# ------------------------------------------------------------ veneer pin
def test_shuffle_exchange_is_thin_veneer():
    """``shuffle.exchange`` (the join engines' entry) must return exactly
    the primitive's fields minus ``heavy_sent`` — same arrays, same order."""
    data, valid, dest = _mk(8)
    args = dict(p=P, c_out=8, cap_recv=64)
    r = _run(
        lambda d, v, t: routed_all_to_all(d, v, t, **args), data, valid, dest
    )
    old = _run(lambda d, v, t: S.exchange(d, v, t, **args), data, valid, dest)
    np.testing.assert_array_equal(np.asarray(r.data), np.asarray(old[0]))
    np.testing.assert_array_equal(np.asarray(r.valid), np.asarray(old[1]))
    np.testing.assert_array_equal(np.asarray(r.sent), np.asarray(old[2]))


def test_route_counts_is_exchange_counts():
    data, valid, dest = _mk(9)
    flat = jnp.where(valid, dest, P)
    a = _run(lambda t: route_counts(t, P), flat)
    b = _run(lambda t: S.exchange_counts(t, P), flat)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# -------------------------------------------------------------- policy
def test_route_policy_heavy_flags_and_fmt():
    pol = RoutePolicy(
        wire_policy=WirePolicy((("a", 3), ("b", 3), ("c", 3))),
        skew_threshold=2.0,
    )
    fmt = pol.fmt_for([("a", "b"), ("b", "c")])
    assert fmt is not None and fmt.arity == 2
    counts = np.zeros((P, P), np.int64)
    counts[:, 0] = 100  # shard 0 is hammered
    counts[:, 1:] = 1
    flags = pol.heavy_flags(counts, P)
    assert bool(flags[0]) and not flags[1:].any()
    assert RoutePolicy().fmt_for([("a",)]) is None  # no policy -> dense
