"""Plan advisor: enumeration/ranking invariants, explain() stability on
S_8 and the Figure-1 cyclic example (TC), calibration math + strict
held-out error reduction, and plan round-trip through snapshot/resume."""
from __future__ import annotations

import math
import random

import numpy as np
import pytest

from repro.core.costs import (
    CostCalibration,
    engine_op_comm,
    fit_calibration,
    join_size_estimate,
    prediction_error,
)
from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.optimizer import (
    MachineProfile,
    Plan,
    candidate_ghds,
    choose_plan,
    enumerate_plans,
    explain,
    stats_from_data,
)
from repro.core.planner import SCHEDULES, get_schedule
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse
from repro.relational.oracle import canon, np_query_answer, reorder
from repro.relational.spmd import SPMD


def _cases():
    # S_8 (Figure 1a) and the Figure-1c cyclic example (triangle chain)
    return [
        ("S_8", star_query(8), star_ghd(8), star_data_sparse(8, seed=21)),
        (
            "TC_9",
            triangle_chain_query(3),
            triangle_chain_ghd(3),
            tc_data_sparse(3, seed=22),
        ),
    ]


def _oracle(query, data):
    atoms = [(a.alias, a.attrs) for a in query.atoms]
    d = {a.alias: data[a.rel] for a in query.atoms}
    rows, schema = np_query_answer(atoms, d)
    return canon(reorder(rows, schema, query.output_attrs))


# ------------------------------------------------------------ enumeration
def test_enumeration_covers_spectrum_and_ranks():
    for name, q, g, data in _cases():
        stats = stats_from_data(q, data)
        plans = enumerate_plans(q, stats, profile=MachineProfile(p=8), hand_ghd=g)
        keys = [p.key for p in plans]
        assert len(keys) == len(set(keys)), "plan keys must be unique"
        # the full grid: every schedule x engine x fusion appears for 'hand'
        for sched in SCHEDULES:
            for eng in ("hash", "grid"):
                for fz in ("fused", "seq"):
                    assert f"hand|{sched}|{eng}|{fz}" in keys, (name, sched, eng, fz)
        # ranked best-first by (wire, comm, rounds, dispatches)
        order = [
            (
                p.predicted_wire,
                p.predicted_comm,
                p.predicted_rounds,
                p.predicted_dispatches,
            )
            for p in plans
        ]
        assert order == sorted(order)
        assert all(p.predicted_comm > 0 and p.predicted_rounds >= 2 for p in plans)
        # the wire carries padded slots: never less than the useful volume
        assert all(p.predicted_wire >= p.predicted_comm for p in plans)
        chosen = choose_plan(q, stats, profile=MachineProfile(p=8), hand_ghd=g)
        assert chosen.key == plans[0].key


def test_candidate_ghds_complete_and_deduped():
    for name, q, g, _ in _cases():
        cands = candidate_ghds(q, hand_ghd=g)
        sources = [s for s, _ in cands]
        assert sources[0] == "hand"
        assert len(sources) == len(set(sources))
        for src, cg in cands:
            cg.validate(q)
            assert cg.is_strongly_complete(q), (name, src)


def test_fused_preferred_on_ties():
    q, g = star_query(6), star_ghd(6)
    stats = stats_from_data(q, star_data_sparse(6, seed=3))
    plans = enumerate_plans(q, stats, profile=MachineProfile(p=4), hand_ghd=g)
    by_cfg = {}
    for p in plans:
        by_cfg.setdefault((p.ghd_source, p.schedule, p.engine), []).append(p)
    for (src, sched, eng), pair in by_cfg.items():
        assert len(pair) == 2
        # identical predicted comm/rounds; fused wins on dispatches
        assert pair[0].predicted_comm == pair[1].predicted_comm
        assert pair[0].fused and not pair[1].fused


def test_schedule_registry_bounds():
    for n in (4, 8, 16):
        for qf, gf in ((chain_query, chain_ghd), (star_query, star_ghd)):
            q = qf(n)
            g = gf(n).make_complete(q)
            for name, info in SCHEDULES.items():
                assert len(info.fn(g)) <= info.round_bound(g), (name, n)
    with pytest.raises(ValueError):
        get_schedule("nope")


def test_plan_to_config_round_trips_choice():
    q, g = star_query(5), star_ghd(5)
    stats = stats_from_data(q, star_data_sparse(5, seed=1))
    plan = choose_plan(q, stats, profile=MachineProfile(p=4), hand_ghd=g)
    cfg = plan.to_config(GymConfig(seed=9, max_retries=7))
    assert cfg.strategy == plan.engine
    assert cfg.schedule == plan.schedule
    assert cfg.fused == plan.fused
    assert cfg.local_backend == plan.local_backend
    assert cfg.plan == plan.key
    # unrelated knobs preserved
    assert cfg.seed == 9 and cfg.max_retries == 7


# ---------------------------------------------------------------- explain
def test_explain_stable_and_marks_choice():
    for name, q, g, data in _cases():
        stats = stats_from_data(q, data)
        kw = dict(hand_ghd=g, p=8)
        text1 = explain(q, stats, **kw)
        text2 = explain(q, stats, **kw)
        assert text1 == text2, f"explain() not deterministic on {name}"
        chosen = choose_plan(q, stats, profile=MachineProfile(p=8), hand_ghd=g)
        assert f"* {chosen.key}" in text1
        assert f"chosen: {chosen.key}" in text1
        assert "pred_comm" in text1 and "pred_rounds" in text1
        md = explain(q, stats, fmt="markdown", **kw)
        assert md.splitlines()[0].startswith("| plan |")
        assert f"chosen: {chosen.key}" in md


def test_explain_measured_columns():
    q, g = star_query(5), star_ghd(5)
    data = star_data_sparse(5, seed=2)
    stats = stats_from_data(q, data)
    chosen = choose_plan(q, stats, profile=MachineProfile(p=4), hand_ghd=g)
    out = explain(
        q, stats, hand_ghd=g, p=4, measured={chosen.key: 1234}
    )
    assert "meas_comm" in out and "1234" in out and "%" in out


# ------------------------------------------------------------ calibration
def test_fit_calibration_geometric_mean():
    recs = [
        {"engine": "hash", "predicted_comm": 100.0, "measured_comm": 200.0},
        {"engine": "hash", "predicted_comm": 100.0, "measured_comm": 800.0},
        {"engine": "grid", "predicted_comm": 50.0, "measured_comm": 25.0},
        {"engine": "hash", "predicted_comm": 0.0, "measured_comm": 10.0},  # skipped
    ]
    cal = fit_calibration(recs)
    assert cal.samples == 3
    assert cal.comm_factor("hash") == pytest.approx(4.0)  # gm of 2x and 8x
    assert cal.comm_factor("grid") == pytest.approx(0.5)
    assert cal.comm_factor("unknown") == 1.0
    assert cal.apply("hash", 10.0) == pytest.approx(40.0)
    # serialization round-trip
    back = CostCalibration.from_dict(cal.to_dict())
    assert back.comm_scale == pytest.approx(cal.comm_scale)


def test_cost_model_units():
    # hash op comm is input-sized; grid pays replication on p
    assert engine_op_comm("hash", "join", 10, 20, p=16) == 30
    assert engine_op_comm("grid", "join", 10, 20, p=16) == pytest.approx(120.0)
    assert engine_op_comm("grid", "semijoin", 10, 20, p=16) > engine_op_comm(
        "hash", "semijoin", 10, 20, p=16
    )
    # cartesian blowup when operands share no attribute
    assert join_size_estimate(10, 20, shared=False) == 200
    assert join_size_estimate(10, 20, shared=True) == 20
    with pytest.raises(AssertionError):
        prediction_error(0.0, 1.0)


@pytest.mark.slow
def test_calibration_strictly_reduces_heldout_error():
    """Fit per-engine constants on S_8 + C_8 measured ledgers; the
    prediction error on the held-out TC_9 manual plans must strictly
    drop."""
    profile = MachineProfile(p=8)
    fams = [
        ("S_8", star_query(8), star_ghd(8), star_data_sparse(8, seed=21)),
        ("C_8", chain_query(8), chain_ghd(8), chain_data_sparse(8, seed=11)),
        ("TC_9", triangle_chain_query(3), triangle_chain_ghd(3),
         tc_data_sparse(3, seed=22)),
    ]
    recs = []
    measured_tc = {}
    for name, q, g, data in fams:
        stats = stats_from_data(q, data)
        plans = {
            p.key: p
            for p in enumerate_plans(q, stats, profile=profile, hand_ghd=g)
        }
        for eng in ("hash", "grid"):
            key = f"hand|dym_d|{eng}|fused"
            _, _, led = gym(
                q, data, ghd=g, p=8,
                config=GymConfig(strategy=eng, schedule="dym_d", seed=33),
            )
            if name == "TC_9":
                measured_tc[key] = (plans[key].predicted_comm, led.comm_tuples)
            else:
                recs.append(
                    led.calibration_record(
                        engine=eng, query=name,
                        predicted_comm=plans[key].predicted_comm,
                    )
                )
    cal = fit_calibration(recs)
    err_u = err_c = 0.0
    for key, (pred, meas) in measured_tc.items():
        eng = key.split("|")[2]
        err_u += prediction_error(pred, meas)
        err_c += prediction_error(cal.apply(eng, pred), meas)
    assert err_c < err_u, (err_c, err_u)


# ------------------------------------------- auto plan execution + resume
@pytest.mark.slow
def test_auto_plan_matches_oracle():
    for name, q, g, data in _cases():
        want = _oracle(q, data)
        rows, schema, led = gym(
            q, data, ghd=g, p=4, config=GymConfig(plan="auto", seed=5)
        )
        assert tuple(schema) == q.output_attrs
        assert canon(rows) == want, name
        assert led.rounds >= 1


@pytest.mark.slow
def test_chosen_plan_round_trips_snapshot_resume(tmp_path):
    q, g = star_query(8), star_ghd(8)
    data = star_data_sparse(8, seed=21)
    want = _oracle(q, data)

    drv = GymDriver(q, g, data, SPMD(4), GymConfig(plan="auto", seed=2))
    chosen_key = drv.config.plan
    assert chosen_key not in ("auto", "manual")  # resolved to a Plan.key
    assert drv.plan is not None and drv.plan.key == chosen_key
    assert drv.config.strategy == drv.plan.engine
    assert drv.config.schedule == drv.plan.schedule
    drv.step()
    drv.step()
    snap = str(tmp_path / "auto_plan_snapshot.npz")
    drv.save(snap)

    # a fresh driver re-advises deterministically, then the snapshot's
    # resolved config wins — same plan either way
    drv2 = GymDriver(q, g, data, SPMD(4), GymConfig(plan="auto", seed=2))
    drv2.load(snap)
    assert drv2.config.plan == chosen_key
    assert drv2.config.strategy == drv.config.strategy
    assert drv2.config.schedule == drv.config.schedule
    assert drv2.config.fused == drv.config.fused
    out = drv2.run()
    assert canon(out.to_numpy()) == want


@pytest.mark.slow
def test_snapshot_replays_plan_ghd_on_plain_driver(tmp_path):
    """An auto-plan run may execute a different GHD than the hand one; the
    snapshot carries that decomposition, so a resuming driver built with
    the hand GHD and a plain manual config still replays the plan's tree
    instead of mispairing tables with its own."""
    q, g = triangle_chain_query(3), triangle_chain_ghd(3)
    data = tc_data_sparse(3, seed=22)
    want = _oracle(q, data)

    drv = GymDriver(q, g, data, SPMD(4), GymConfig(plan="auto", seed=3))
    drv.step()
    drv.step()
    snap = str(tmp_path / "auto_plan_tc.npz")
    drv.save(snap)

    drv2 = GymDriver(q, g, data, SPMD(4), GymConfig(seed=3))  # manual driver
    drv2.load(snap)
    assert sorted(drv2.ghd.nodes()) == sorted(drv.ghd.nodes())
    assert drv2.config.plan == drv.config.plan
    assert drv2.config.strategy == drv.config.strategy
    out = drv2.run()
    assert canon(out.to_numpy()) == want
