"""Per-shard (single-reducer) relational operations.

Everything is exact for arbitrary arities/domains: multi-column keys are
dictionary-encoded with ``dense_ranks`` (concat + lexsort + run ids), never
hashed.  All shapes static; "too many output tuples" surfaces as an
overflow count (the paper's abort), never silent truncation.

The *hot loops* — hash bucketing, membership probes, and sorted match
ranges — are routed through a **local backend registry**
(``register_local_backend``, mirroring the engine-strategy registry in
``core.physical``):

- ``'jnp'``    — the pure-jnp reference path (sort + searchsorted), the
  CPU default;
- ``'pallas'`` — the TPU Pallas kernels in ``repro.kernels`` (interpret
  mode off-TPU), probing the same ``dense_ranks`` int32 encoding so
  exactness is preserved.

Both backends are bit-identical (pinned by tests/test_local_backend.py and
the kernel property tests); the engine threads the selection down from
``GymConfig.local_backend``.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as K
from .hashing import dense_ranks, dests_for, self_ranks

_I32MAX = jnp.int32(2**31 - 1)


# --------------------------------------------------------------------------
# local backend registry: who executes the per-shard hot loops
# --------------------------------------------------------------------------
LOCAL_BACKENDS: Dict[str, "LocalBackend"] = {}


def register_local_backend(name: str):
    """Class decorator: make a ``LocalBackend`` selectable by name."""

    def deco(cls):
        cls.name = name
        LOCAL_BACKENDS[name] = cls()
        return cls

    return deco


def get_local_backend(name: str) -> "LocalBackend":
    try:
        return LOCAL_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown local backend {name!r}; registered: {sorted(LOCAL_BACKENDS)}"
        ) from None


class LocalBackend:
    """The three per-shard hot loops every operator is built from.

    Implementations must be bit-identical: ``dests`` to
    ``hashing.dests_for``; ``member_mask`` / ``probe_ranges`` to
    sort+searchsorted over the ``dense_ranks`` int32 encoding (probe
    values < INT32_MAX; invalid key slots == INT32_MAX)."""

    name = "?"

    def dests(self, data, valid, cols, p: int, seed) -> jax.Array:
        """Reducer destination in [0,p) per valid row; p for invalid."""
        raise NotImplementedError

    def member_mask(self, q: jax.Array, keys: jax.Array) -> jax.Array:
        """mask[i] = q[i] in keys (keys need NOT be sorted)."""
        raise NotImplementedError

    def probe_ranges(self, q: jax.Array, sorted_keys: jax.Array):
        """(lo, hi) = searchsorted(sorted_keys, q, 'left'/'right')."""
        raise NotImplementedError


@register_local_backend("jnp")
class JnpBackend(LocalBackend):
    """Pure-jnp reference: XLA sort + searchsorted (CPU default).

    Delegates to ``kernels.ops`` with ``use_pallas=False`` — the SAME
    oracle (``kernels.ref``) the pallas kernels are property-tested
    against, so there is exactly one copy of the reference semantics."""

    def dests(self, data, valid, cols, p, seed):
        return dests_for(data, valid, cols, p, seed)

    def member_mask(self, q, keys):
        return K.semijoin_probe(q, keys, use_pallas=False)

    def probe_ranges(self, q, sorted_keys):
        return K.sorted_probe_ranges(q, sorted_keys, use_pallas=False)


@register_local_backend("pallas")
class PallasBackend(LocalBackend):
    """TPU Pallas kernels (``repro.kernels``); interpret mode off-TPU.

    ``member_mask`` is a broadcast-compare probe (no sort of the keys at
    all); ``probe_ranges`` is rank-by-counting over the sorted keys."""

    def dests(self, data, valid, cols, p, seed):
        return K.hash_partition(data, valid, cols, p, seed, use_pallas=True)

    def member_mask(self, q, keys):
        return K.semijoin_probe(q, keys, use_pallas=True)

    def probe_ranges(self, q, sorted_keys):
        return K.sorted_probe_ranges(q, sorted_keys, use_pallas=True)


def compact(data: jax.Array, valid: jax.Array, out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Move valid rows to the front and resize to ``out_cap``.

    Returns (data, valid, dropped_count)."""
    n = data.shape[0]
    order = jnp.argsort(~valid, stable=True)
    d = data[order]
    v = valid[order]
    cnt = valid.sum()
    if out_cap <= n:
        dropped = jnp.maximum(cnt - out_cap, 0)
        return d[:out_cap], v[:out_cap], dropped
    pad_d = jnp.zeros((out_cap - n, data.shape[1]), data.dtype)
    pad_v = jnp.zeros((out_cap - n,), bool)
    return (
        jnp.concatenate([d, pad_d], 0),
        jnp.concatenate([v, pad_v], 0),
        jnp.int32(0),
    )


def local_join(
    a_data: jax.Array, a_valid: jax.Array,
    b_data: jax.Array, b_valid: jax.Array,
    a_key: Sequence[int], b_key: Sequence[int],
    b_keep: Sequence[int],
    out_cap: int,
    backend: str = "jnp",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Natural join on the given key columns.

    Output rows are ``a_row ++ b_row[b_keep]`` (caller computes the joined
    schema).  Returns (out_data (out_cap, a_ar + len(b_keep)), out_valid,
    overflow_count)."""
    ra, rb = dense_ranks(a_data, a_valid, a_key, b_data, b_valid, b_key)
    return local_join_ranked(
        a_data, a_valid, ra, b_data, b_valid, rb, b_keep, out_cap, backend
    )


def local_join_ranked(
    a_data: jax.Array, a_valid: jax.Array, ra: jax.Array,
    b_data: jax.Array, b_valid: jax.Array, rb: jax.Array,
    b_keep,
    out_cap: int,
    backend: str = "jnp",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Join expansion given precomputed shared key ranks (``dense_ranks``).

    ``b_keep`` may be a static tuple OR a traced int32 array (the batched
    path passes per-instance column indices as data); only its LENGTH must
    be static."""
    be = get_local_backend(backend)
    na, nb = a_data.shape[0], b_data.shape[0]
    rb_sort_key = jnp.where(b_valid, rb, _I32MAX)
    order_b = jnp.argsort(rb_sort_key)
    rb_sorted = rb_sort_key[order_b]
    lo, hi = be.probe_ranges(ra, rb_sorted)
    counts = jnp.where(a_valid, hi - lo, 0)
    offsets = jnp.cumsum(counts)
    total = offsets[-1] if na else jnp.int32(0)
    t = jnp.arange(out_cap)
    i = jnp.searchsorted(offsets, t, side="right")
    i_c = jnp.clip(i, 0, na - 1)
    prev = jnp.where(i_c > 0, offsets[i_c - 1], 0)
    within = t - prev
    j_sorted = jnp.clip(lo[i_c] + within, 0, nb - 1)
    j = order_b[j_sorted]
    out_valid = t < total
    left = a_data[i_c]
    right = (
        b_data[j][:, jnp.asarray(b_keep, jnp.int32)]
        if len(b_keep)
        else jnp.zeros((out_cap, 0), a_data.dtype)
    )
    out = jnp.concatenate([left, right], axis=1)
    out = jnp.where(out_valid[:, None], out, 0)
    overflow = jnp.maximum(total - out_cap, 0)
    return out, out_valid, overflow


def local_join_count(
    a_data, a_valid, b_data, b_valid, a_key, b_key, backend: str = "jnp"
) -> jax.Array:
    """Exact output size of the join (for capacity planning)."""
    be = get_local_backend(backend)
    ra, rb = dense_ranks(a_data, a_valid, a_key, b_data, b_valid, b_key)
    rb_sorted = jnp.sort(jnp.where(b_valid, rb, _I32MAX))
    lo, hi = be.probe_ranges(ra, rb_sorted)
    return jnp.where(a_valid, hi - lo, 0).sum()


def local_semijoin_mask(
    s_data: jax.Array, s_valid: jax.Array, s_key: Sequence[int],
    r_data: jax.Array, r_valid: jax.Array, r_key: Sequence[int],
    backend: str = "jnp",
) -> jax.Array:
    """Mask of S rows whose key appears in R (S |>< R)."""
    be = get_local_backend(backend)
    rs, rr = dense_ranks(s_data, s_valid, s_key, r_data, r_valid, r_key)
    keys = jnp.where(r_valid, rr, _I32MAX)
    return s_valid & be.member_mask(rs, keys)


def local_dedup_mask(data: jax.Array, valid: jax.Array, cols: Sequence[int]) -> jax.Array:
    """Keep-first mask of distinct rows (by ``cols``)."""
    n = data.shape[0]
    ranks = self_ranks(data, valid, cols)
    first = jax.ops.segment_min(
        jnp.where(valid, jnp.arange(n), _I32MAX),
        jnp.clip(ranks, 0, n - 1),
        num_segments=n,
    )
    return valid & (jnp.arange(n) == first[jnp.clip(ranks, 0, n - 1)])


def local_intersect_mask(
    a_data: jax.Array, a_valid: jax.Array,
    b_data: jax.Array, b_valid: jax.Array,
    a_cols: Sequence[int], b_cols: Sequence[int],
    backend: str = "jnp",
) -> jax.Array:
    """Mask of A rows present in B (full-row by aligned columns)."""
    return local_semijoin_mask(
        a_data, a_valid, a_cols, b_data, b_valid, b_cols, backend
    )


def local_project(
    data: jax.Array, valid: jax.Array, cols: Sequence[int], dedup: bool
) -> Tuple[jax.Array, jax.Array]:
    out = data[:, jnp.asarray(cols, jnp.int32)] if cols else jnp.zeros((data.shape[0], 0), data.dtype)
    v = valid
    if dedup:
        v = local_dedup_mask(out, valid, tuple(range(len(cols))))
    out = jnp.where(v[:, None], out, 0)
    return out, v
