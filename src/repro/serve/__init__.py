from .decode import generate, generate_whisper, sample
