"""MoE dispatch routes on a zipf-hot expert mix: the dense Switch-style
capacity scatter vs the calibrated routed-exchange path (PR 10).

Workload: tokens are noisy copies of per-expert prototype directions,
with prototype popularity zipf-distributed — the skewed
popular-expert-dominates traffic a real MoE sees, and exactly the
heavy-hitter shape the join engines' skew machinery handles.  On this
mix the dense scatter (capacity factor 1.25) drops over-capacity tokens
SILENTLY; the calibrated route measures per-expert counts, flags hot
experts into the heavy split, and provably drops nothing.

Reported per route: step wall time (min-of-N on a jitted forward),
dropped (token, choice) pairs, and the byte-true payload/padded-slot
accounting (``dense_scatter_bytes`` vs ``calibrated_dispatch_bytes`` —
the same ledger formulas both customers share).

Acceptance asserted here:
- numerical parity dense == calibrated on a no-drop input (capacity
  factor ``e``), atol 2e-5;
- on the zipf-hot mix: dense drops > 0, calibrated drops == 0;
- exact conservation: routed pairs == t*k on the calibrated route.

``BENCH_MOE_SMOKE=1`` (the CI lane) shrinks the batch and rep count and
writes ``BENCH_moe.partial.json`` so it never clobbers the committed
full baseline ``BENCH_moe.json``.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._io import write_json_atomic
from repro.configs import CONFIGS, reduced_config
from repro.models.common import rms_norm
from repro.models.mlp import init_moe, moe_forward_stats
from repro.models.moe_routing import (
    apply_plan,
    calibrate_moe,
    calibrated_dispatch_bytes,
    dense_scatter_bytes,
    record_dense_round,
    record_moe_round,
)
from repro.relational import Ledger

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_moe.json")
PARTIAL_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_moe.partial.json"
)


def zipf_hot_batch(cfg, b, s, *, zs: float = 1.5, seed: int = 0):
    """(b, s, d) tokens whose router traffic is zipf-skewed: each token
    is a noisy copy of one of ``e`` prototype directions, prototypes
    drawn ~ 1/rank^zs — so one expert's arrivals dominate."""
    e, d = cfg.n_experts, cfg.d_model
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((e, d)).astype(np.float32) * 2.0
    w = np.array([1.0 / (r + 1) ** zs for r in range(e)])
    pick = rng.choice(e, size=b * s, p=w / w.sum())
    x = protos[pick] + 0.05 * rng.standard_normal((b * s, d)).astype(np.float32)
    return jnp.asarray(x.reshape(b, s, d), jnp.float32)


def _timed(fn, *args, reps: int):
    fn(*args)[0].block_until_ready()  # compile
    best = None
    for _ in range(reps):
        t0 = time.time()
        fn(*args)[0].block_until_ready()
        dt = time.time() - t0
        best = dt if best is None else min(best, dt)
    return best


def run() -> list:
    smoke = bool(os.environ.get("BENCH_MOE_SMOKE"))
    b, s = (2, 32) if smoke else (8, 128)
    reps = 2 if smoke else 5

    cfg = reduced_config(CONFIGS["kimi-k2-1t-a32b"])  # e=4, top-2, f32
    t, d = b * s, cfg.d_model
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = zipf_hot_batch(cfg, b, s)
    xf = rms_norm(x, p["ln"], cfg.norm_eps).reshape(t, d)

    # ---- parity gate on a no-drop input (capacity factor e: dense can't
    # drop, so the two routes must agree numerically)
    ucfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    ux = jax.random.normal(jax.random.PRNGKey(1), (b, s, d), jnp.float32)
    uxf = rms_norm(ux, p["ln"], ucfg.norm_eps).reshape(t, d)
    uplan, _ = calibrate_moe(p, uxf, ucfg)
    yd, sd = moe_forward_stats(p, ux, ucfg)
    yc, sc = moe_forward_stats(p, ux, apply_plan(ucfg, uplan))
    assert int(sd["dropped"]) == 0 and int(sc["dropped"]) == 0
    np.testing.assert_allclose(
        np.asarray(yd), np.asarray(yc), atol=2e-5, rtol=2e-5
    )

    # ---- the zipf-hot mix: dense (cf=1.25) vs calibrated (measured)
    plan, info = calibrate_moe(p, xf, cfg, threshold=1.5)
    ccfg = apply_plan(cfg, plan)

    dense_fn = jax.jit(lambda p, x: moe_forward_stats(p, x, cfg))
    calib_fn = jax.jit(lambda p, x: moe_forward_stats(p, x, ccfg))
    dense_secs = _timed(dense_fn, p, x, reps=reps)
    calib_secs = _timed(calib_fn, p, x, reps=reps)
    _, sdn = dense_fn(p, x)
    _, scl = calib_fn(p, x)
    sdn = {k: int(v) for k, v in sdn.items()}
    scl = {k: int(v) for k, v in scl.items()}

    # acceptance: the dense route drops on this mix, the calibrated route
    # does not — and conservation is exact
    assert sdn["dropped"] > 0, sdn
    assert scl["dropped"] == 0, scl
    assert scl["routed"] == t * cfg.topk, scl

    # byte-true accounting, both routes in one ledger
    led = Ledger()
    record_dense_round(led, sdn, cfg=cfg, t=t, d=d, note="zipf-hot")
    record_moe_round(led, scl, plan=plan, d=d, note="zipf-hot")
    dense_pb, dense_pad = dense_scatter_bytes(cfg, t, d)
    calib_pb, calib_pad = calibrated_dispatch_bytes(plan, d)

    rec = dict(
        bench="moe",
        experts=cfg.n_experts,
        topk=cfg.topk,
        d_model=d,
        tokens=t,
        zipf_s=1.5,
        arrivals=[int(a) for a in info["arrivals"]],
        heavy_experts=list(plan.heavy),
        plan=dict(
            tpp=plan.tpp, cap_send=plan.cap_send, cap_recv=plan.cap_recv
        ),
        dense_secs=round(dense_secs, 5),
        calibrated_secs=round(calib_secs, 5),
        dense_dropped=sdn["dropped"],
        calibrated_dropped=scl["dropped"],
        routed_pairs=scl["routed"],
        heavy_routed=scl["heavy"],
        dense_payload_bytes=dense_pb,
        calibrated_payload_bytes=calib_pb,
        dense_padded_slots=dense_pad,
        calibrated_padded_slots=calib_pad,
        ledger_dropped=led.dropped_tuples,
        ledger_heavy_dests=led.heavy_dests,
    )
    write_json_atomic(
        OUT_PATH if not smoke else PARTIAL_PATH,
        {"bench": "moe", "results": [rec]},
    )
    return [rec]
