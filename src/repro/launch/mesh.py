"""Production meshes.  A FUNCTION (not a module constant) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) ('pod', 'data', 'model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices exist — tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel (FSDP) axes of a mesh: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
