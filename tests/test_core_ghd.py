"""GHD machinery: widths/depths/iw of the Table 1 families, Lemma 7
completion, GYO/min-fill construction."""
import random

import pytest

from repro.core.decompose import ghd_for, gyo_join_tree, minfill_ghd
from repro.core.ghd import GHD
from repro.core.queries import (
    chain_ghd,
    chain_ghd_grouped,
    chain_query,
    example4_query,
    random_acyclic_query,
    random_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)


# ------------------------------------------------------------- Table 1 rows
def test_star_stats():
    for n in (2, 5, 9):
        q, g = star_query(n), star_ghd(n)
        g.validate(q)
        assert g.width == 1
        assert g.depth == 1
        assert g.intersection_width(q) == 1


def test_chain_stats():
    for n in (1, 2, 8, 16):
        q, g = chain_query(n), chain_ghd(n)
        g.validate(q)
        assert g.width == 1
        assert g.depth == n - 1 if n > 1 else g.depth == 0
        assert g.intersection_width(q) <= 1


def test_triangle_chain_stats():
    for t in (1, 3, 5):
        q, g = triangle_chain_query(t), triangle_chain_ghd(t)
        g.validate(q)
        assert g.width == 2
        assert g.depth == t - 1
        # Table 1 row 3 (a single-bag GHD has no tree edges -> iw 0)
        assert g.intersection_width(q) == (1 if t > 1 else 0)


def test_chain_grouped_matches_appendix_c():
    # Figure 7a: width-3, depth-5 GHD of C_16
    q = chain_query(16)
    g = chain_ghd_grouped(16, 3)
    g.validate(q)
    assert g.width == 3
    assert g.depth == 5


# ------------------------------------------------------------- construction
def test_gyo_on_acyclic():
    for q in (star_query(6), chain_query(7), example4_query()):
        g = gyo_join_tree(q)
        assert g is not None, f"{q.name} should be acyclic"
        g.validate(q)
        assert g.width == 1


def test_gyo_rejects_cyclic():
    q = triangle_chain_query(2)
    assert gyo_join_tree(q) is None


def test_minfill_on_cyclic():
    q = triangle_chain_query(3)
    g = minfill_ghd(q)
    g.validate(q)
    assert g.width >= 2


def test_random_acyclic_gyo_roundtrip():
    rng = random.Random(0)
    for _ in range(25):
        q = random_acyclic_query(rng, rng.randint(2, 10))
        g = gyo_join_tree(q)
        assert g is not None
        g.validate(q)
        assert g.width == 1


def test_random_query_minfill_valid():
    rng = random.Random(1)
    for _ in range(25):
        q = random_query(rng, rng.randint(2, 7), rng.randint(3, 8))
        g = ghd_for(q)
        g.validate(q)


# --------------------------------------------------------------- Lemma 7
def test_make_complete_properties():
    rng = random.Random(2)
    for _ in range(20):
        q = random_acyclic_query(rng, rng.randint(3, 10))
        g = gyo_join_tree(q)
        d0, w0 = g.depth, g.width
        iw0 = g.intersection_width(q)
        gc = g.make_complete(q)
        gc.validate(q)
        assert gc.is_complete(q)
        assert gc.width <= w0
        assert gc.depth <= d0 + 1
        assert gc.intersection_width(q) <= max(iw0, 1)
        assert gc.size() <= 4 * q.n


def test_make_complete_on_grouped_chain():
    q = chain_query(12)
    g = chain_ghd_grouped(12, 3)
    gc = g.make_complete(q)
    gc.validate(q)
    assert gc.is_complete(q)
    assert gc.width <= 3
    assert gc.size() <= 4 * q.n
