"""Log-GTA / Log-GTA' / C-GTA invariants (Main Result 2, Theorems 21/25/30),
including hypothesis property tests over random queries."""
import math
import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cgta import cgta, cgta_pass
from repro.core.decompose import ghd_for, gyo_join_tree
from repro.core.loggta import ExtendedGHD, log_gta
from repro.core.loggta_prime import log_gta_prime
from repro.core.queries import (
    chain_ghd,
    chain_query,
    random_acyclic_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)


def _log_bound(n_nodes: int) -> int:
    # iterations <= log_{4/3}(N) and height grows <= 1 per iteration
    return math.ceil(math.log(max(2, n_nodes)) / math.log(4 / 3)) + 2


# ------------------------------------------------------------- paper examples
def test_loggta_on_tc15_matches_figure6():
    """Figure 6: TC_15 (5 triangles), width-2/iw-1 GHD of depth 4 ->
    log-depth width-<=3 GHD."""
    q = triangle_chain_query(5)
    g = triangle_chain_ghd(5)
    assert g.depth == 4 and g.width == 2
    out = log_gta(g, q, check=True)
    out.validate(q)
    assert out.width <= 3
    assert out.depth <= _log_bound(g.size())


def test_loggta_on_long_chain():
    q = chain_query(64)
    g = chain_ghd(64)
    assert g.depth == 63
    out = log_gta(g, q, check=True)
    out.validate(q)
    assert out.width <= 3  # w=1, iw=1 -> max(1,3)
    assert out.depth <= _log_bound(g.size())
    assert out.depth < g.depth


def test_loggta_never_increases_depth():
    q = star_query(8)
    g = star_ghd(8)
    out = log_gta(g, q)
    assert out.depth <= max(g.depth, _log_bound(g.size()))


@pytest.mark.parametrize("n_tri", [1, 2, 4, 8, 16])
def test_loggta_triangle_chain_family(n_tri):
    q = triangle_chain_query(n_tri)
    g = triangle_chain_ghd(n_tri)
    out = log_gta(g, q, check=(n_tri <= 4))
    out.validate(q)
    assert out.width <= max(g.width, 3 * g.intersection_width(q))
    assert out.depth <= _log_bound(g.size())


# ------------------------------------------------------------ property tests
@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=2, max_value=28), st.randoms(use_true_random=False))
def test_loggta_property_acyclic(n_atoms, rnd):
    rng = random.Random(rnd.randint(0, 2**31))
    q = random_acyclic_query(rng, n_atoms)
    g = gyo_join_tree(q)
    w, iw = g.width, g.intersection_width(q)
    out = log_gta(g, q, check=(n_atoms <= 10))
    out.validate(q)
    assert out.width <= max(w, 3 * iw)
    assert out.depth <= _log_bound(g.size())


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=2, max_value=16), st.randoms(use_true_random=False))
def test_loggta_prime_property(n_atoms, rnd):
    from repro.core.queries import random_query

    rng = random.Random(rnd.randint(0, 2**31))
    q = random_query(rng, n_atoms, max(3, n_atoms))
    g = ghd_for(q)
    out = log_gta_prime(g, q)
    out.validate(q)
    assert out.width <= 3 * g.width
    assert out.depth <= _log_bound(g.size())


# ------------------------------------------------------------------- C-GTA
def test_cgta_pass_shrinks_and_doubles_width():
    q = chain_query(32)
    g = chain_ghd(32)
    g2 = cgta_pass(g, q)
    g2.validate(q)
    assert g2.size() < g.size()
    assert g2.width <= 2 * g.width


def test_cgta_composed_with_loggta():
    q = chain_query(48)
    g = chain_ghd(48)
    for i in (1, 2):
        out = cgta(g, q, passes=i)
        out.validate(q)
        assert out.depth <= _log_bound(g.size())


def test_extend_covers_within_iw():
    q = triangle_chain_query(4)
    g = triangle_chain_ghd(4)
    iw = g.intersection_width(q)
    ext = ExtendedGHD.extend(g, q)
    for cover in ext.cc.values():
        assert len(cover) <= iw
