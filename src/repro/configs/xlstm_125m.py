"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0 per the assignment: xLSTM blocks carry their own up/down
projections (mLSTM pf=2, sLSTM pf=4/3).  Pattern: one sLSTM per three
mLSTM blocks (the paper's x:1 ratios)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "mlstm", "mlstm", "slstm") * 3,
    chunk=256,
    tie_embeddings=True,
    notes="runs long_500k (linear-time recurrence)",
)
