"""Skew resilience: hash vs grid vs hybrid on zipf-skewed Table-1
families (S_8 / C_8 at zipf s in {0, 1.1}) plus the planted-heavy-key
S_8 adversarial instance, p=8.

The acceptance bar this bench enforces:

- all three engines produce bit-identical row sets on every instance
  (the hybrid routing is a repacking, never a semantics change);
- the hybrid engine finishes every instance with ZERO abort-retries;
- on the planted heavy-key instance the hybrid engine ships strictly
  fewer padded wire cells than hash (the heavy key is spread/broadcast
  instead of piling onto one reducer's calibrated pad).

Writes ``BENCH_skew.json`` at the repo root (padded cells, retries,
heavy/light split per family x engine) — the skew-resilience trajectory
future PRs regress against.  ``BENCH_SKEW_ONLY=S_8_heavy`` (comma list)
limits the families; filtered runs write ``BENCH_skew.partial.json`` so
they never clobber the committed full baseline (CI smoke runs just
``S_8_heavy``).
"""
from __future__ import annotations

import os
import time

from benchmarks._io import write_json_atomic
from repro.core.gym import GymConfig, gym
from repro.core.queries import chain_ghd, chain_query, star_ghd, star_query
from repro.data.synthetic import (
    chain_data_zipf,
    star_data_heavy,
    star_data_zipf,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_skew.json")
PARTIAL_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_skew.partial.json"
)

P = 8
ENGINES = ("hash", "grid", "hybrid")

# zipf families at the bench_shuffle scales; s=0 is the uniform control,
# s=1.1 the heavy-hitter regime, and S_8_heavy the planted single-key
# adversary the acceptance asserts on.  Note S_8_z11's rank-1 share sits
# right AT the detection threshold (arrival ~3x the balanced share):
# depending on hash collisions the conservative detector may decline to
# route, in which case hybrid falls back to hash bit-for-bit — the
# recorded padded/heavy columns make that visible, which is the point;
# C_8_z11's compounding per-relation skew routes decisively.
FAMILIES = {
    "S_8_z0": lambda: (
        star_query(8),
        star_ghd(8),
        star_data_zipf(8, domain=64, hub_rows=256, spoke_extra=32, s=0.0, seed=31),
    ),
    "S_8_z11": lambda: (
        star_query(8),
        star_ghd(8),
        star_data_zipf(8, domain=64, hub_rows=256, spoke_extra=32, s=1.1, seed=31),
    ),
    "C_8_z0": lambda: (
        chain_query(8),
        chain_ghd(8),
        chain_data_zipf(8, domain=96, rows=192, s=0.0, seed=34),
    ),
    "C_8_z11": lambda: (
        chain_query(8),
        chain_ghd(8),
        chain_data_zipf(8, domain=96, rows=192, s=1.1, seed=34),
    ),
    "S_8_heavy": lambda: (
        star_query(8),
        star_ghd(8),
        star_data_heavy(
            8, domain=64, hub_rows=256, heavy_share=0.8, spoke_extra=16, seed=5
        ),
    ),
}

#: families where the skew is strong enough that hybrid must strictly
#: beat hash on padded wire cells (the others only require parity+no-loss)
ASSERT_PADDED_WIN = ("S_8_heavy",)


def run() -> list:
    only = os.environ.get("BENCH_SKEW_ONLY")
    names = only.split(",") if only else list(FAMILIES)
    out = []
    trajectory = []
    for name in names:
        q, g, data = FAMILIES[name]()
        res = {}
        for engine in ENGINES:
            # the uniform C_8 control has a large TRUE output (random
            # dense chains, not a matching database), which the grid
            # engine concentrates per cell — raise the M-tied default
            # capacity ceiling so legitimate growth isn't diagnosed as
            # skew-bound
            cfg = GymConfig(strategy=engine, seed=23, max_cap_tuples=1 << 18)
            t0 = time.time()
            rows, _, led = gym(q, data, ghd=g, p=P, config=cfg)
            secs = time.time() - t0
            res[engine] = (rows, led)
            rec = dict(
                bench="skew",
                query=name,
                engine=engine,
                secs=round(secs, 2),
                rows=len(rows),
                comm_tuples=led.comm_tuples,
                shuffle_tuples=led.shuffle_tuples,
                padded_slots=led.padded_slots,
                heavy_tuples=led.heavy_tuples,
                light_tuples=led.light_tuples,
                payload_efficiency=round(led.payload_efficiency, 4),
                retries=led.retries,
                dispatches=led.measured_dispatches,
                measure_dispatches=led.measure_dispatches,
                payload_dispatches=led.payload_dispatches,
            )
            out.append(rec)
            trajectory.append(rec)
        # engines must agree on WHAT is computed, at any skew
        sets = {e: {tuple(r) for r in rows} for e, (rows, _) in res.items()}
        assert sets["hash"] == sets["grid"] == sets["hybrid"], name
        # the hybrid engine's routing absorbs the skew: no abort-retries
        assert res["hybrid"][1].retries == 0, (name, res["hybrid"][1].retries)
        if name in ASSERT_PADDED_WIN:
            assert (
                res["hybrid"][1].padded_slots < res["hash"][1].padded_slots
            ), (
                name,
                res["hybrid"][1].padded_slots,
                res["hash"][1].padded_slots,
            )
            assert res["hybrid"][1].heavy_tuples > 0, name
    path = OUT_PATH if not only else PARTIAL_PATH
    write_json_atomic(
        path,
        {
            "bench": "skew",
            "p": P,
            "engines": list(ENGINES),
            "families": names,
            "results": trajectory,
        },
    )
    return out
