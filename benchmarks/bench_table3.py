"""Table 3: performance on TC_n — Shares vs ACQ-MR vs GYM(Log-GTA) vs
GYM(direct).

The paper's Table 3 is a WORST-CASE communication table; its ordering is
driven by the widths each algorithm must materialize (IN^2 vs IN^3 vs
IN^6).  We assert exactly that structural mechanism — width(D)=2 <=
width(Log-GTA(D))<=3 <= width(Log-GTA'(D))<=6 plus the depth collapse
Theta(n) -> O(log n) — and report the measured per-ledger rounds/comm on
sparse data (instance costs, not worst-case)."""
from __future__ import annotations

import math

from repro.core.acq_mr import acq_mr, gym_loggta
from repro.core.gym import GymConfig, gym
from repro.core.loggta import log_gta
from repro.core.loggta_prime import log_gta_prime
from repro.core.queries import triangle_chain_ghd, triangle_chain_query
from repro.core.shares import shares_join
from repro.data.synthetic import tc_data_sparse


def run() -> list:
    n_tri = 4  # TC_12
    q = triangle_chain_query(n_tri)
    g = triangle_chain_ghd(n_tri)
    data = tc_data_sparse(n_tri, seed=3)

    # --- the structural mechanism behind Table 3's ordering --------------
    gc = g.make_complete(q)
    g_log = log_gta(gc, q)
    g_acq = log_gta_prime(gc, q)
    iw = g.intersection_width(q)
    assert g.width == 2 and iw == 1
    assert g_log.width <= max(g.width, 3 * iw) == 3
    assert g_acq.width <= 3 * g.width == 6
    assert g_log.width <= g_acq.width
    log_bound = 2 * math.ceil(math.log2(max(2, gc.size()))) + 2
    assert g_log.depth <= log_bound

    # --- measured instance costs ------------------------------------------
    r_sh, _, led_sh = shares_join(q, data, p=8)
    r_gd, _, led_gd = gym(q, data, ghd=g, p=8, config=GymConfig(seed=4))
    r_gl, _, led_gl = gym_loggta(q, data, ghd=g, p=8, config=GymConfig(seed=4))
    r_aq, _, led_aq = acq_mr(q, data, ghd=g, p=8, config=GymConfig(seed=4))
    want = {tuple(r) for r in r_sh}
    assert {tuple(r) for r in r_gd} == want
    assert {tuple(r) for r in r_gl} == want
    assert {tuple(r) for r in r_aq} == want
    assert led_sh.rounds == 1

    return [
        dict(bench="table3", alg="Shares", width=None, rounds=led_sh.rounds,
             comm=led_sh.comm_tuples),
        dict(bench="table3", alg="ACQ-MR", width=g_acq.width,
             rounds=led_aq.rounds, comm=led_aq.comm_tuples),
        dict(bench="table3", alg="GYM(Log-GTA)", width=g_log.width,
             rounds=led_gl.rounds, comm=led_gl.comm_tuples),
        dict(bench="table3", alg="GYM(direct)", width=g.width,
             rounds=led_gd.rounds, comm=led_gd.comm_tuples),
        dict(bench="table3_structure", w=g.width, iw=iw,
             w_loggta=g_log.width, w_acqmr=g_acq.width,
             depth_direct=gc.depth, depth_loggta=g_log.depth),
    ]
