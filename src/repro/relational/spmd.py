"""SPMD execution of per-shard functions: one code path, two runtimes.

Per-shard functions take/return arrays WITHOUT the reducer axis and may use
``jax.lax`` collectives over the named axis ``AXIS``.  ``SPMD`` runs them:

- simulation (default, 1 device): ``jax.vmap(fn, axis_name=AXIS)`` — the
  reducer axis is the leading array axis.  This is the paper's PRAM-style
  simulation and what CI uses.
- production: ``jax.shard_map`` over a real mesh axis — identical per-shard
  code; the leading axis is device-sharded.  The multi-pod dry-run lowers
  this path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AXIS = "r"


class SPMD:
    def __init__(self, p: int, mesh: Optional[Mesh] = None):
        """``p`` logical reducers; if ``mesh`` given it must have axis AXIS
        of size p (production path), else simulation on one device."""
        self.p = p
        self.mesh = mesh
        if mesh is not None:
            assert mesh.shape[AXIS] == p, (mesh.shape, p)
        self._cache: Dict[Any, Callable] = {}
        # program dispatches actually issued (one per ``run`` call, compiled
        # or cache-hit) — the *measured* counterpart of the ledger's claimed
        # BSP rounds; round fusion is proven by this counter going down.
        self.dispatch_count: int = 0

    # -- execution --------------------------------------------------------
    def _build(self, fn: Callable, statics: Tuple) -> Callable:
        bound = functools.partial(fn, **dict(statics)) if statics else fn
        if self.mesh is None:
            mapped = jax.vmap(bound, axis_name=AXIS)
        else:
            def strip(blk):
                return jax.tree_util.tree_map(lambda x: x[0], blk)

            def readd(blk):
                return jax.tree_util.tree_map(lambda x: x[None], blk)

            def per_block(*args):
                return readd(bound(*[strip(a) for a in args]))

            mapped = jax.shard_map(
                per_block,
                mesh=self.mesh,
                in_specs=P(AXIS),
                out_specs=P(AXIS),
                check_vma=False,
            )
        return jax.jit(mapped)

    def run(self, fn: Callable, *args, **statics):
        """Run per-shard ``fn`` over the reducer axis.  ``statics`` must be
        hashable and are part of the compilation cache key."""
        key = (fn, tuple(sorted(statics.items())))
        if key not in self._cache:
            self._cache[key] = self._build(fn, tuple(sorted(statics.items())))
        self.dispatch_count += 1
        return self._cache[key](*args)

    def seeds(self, seed: int) -> jnp.ndarray:
        """Per-shard traced seed array: hash seeds ride as DATA (not jit
        statics) so reseeded retries reuse compiled programs."""
        return jnp.full((self.p,), seed & 0xFFFFFFFF, jnp.uint32)

    def device_put(self, tree):
        if self.mesh is None:
            return tree
        sh = NamedSharding(self.mesh, P(AXIS))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)
