"""Batched ("round-fused") distributed operators.

A DYM round schedules k independent operator instances.  Running them as k
separate SPMD dispatches costs k program launches and k ``all_to_all``
barriers — but the paper's BSP model (Sec. 3.2) charges the round ONCE.
These variants stack the k instances along a new batch axis between the
reducer axis and the row axis — DTable (p, cap, ar) -> stacked
(p, k, cap, ar) — and run the per-shard operator body under an inner
``jax.vmap``, so one dispatch (and one all_to_all per shuffle stage)
serves the whole group.

Uniformity contract (enforced by the physical layer's grouping, asserted
here): shard shapes (cap, arity), key-column COUNT, and every capacity
static must be equal across the k instances.  Key column POSITIONS and
hash seeds may differ per instance — they ride as int32 DATA with a
leading k axis and are applied with ``jnp.take``, so one compiled program
covers any mix of schemas and reseeded retries.

Hash-path batched ops produce bit-identical results (and identical
``sent``/``dropped`` stats) to their sequential counterparts in ``ops.py``
given the same seeds and capacities; the fused/sequential parity tests
pin this down.

Calibration pre-passes: every payload operator here has a ``measure_*``
sibling — ONE extra tiny dispatch per op group that runs the same
destination logic but ships only per-destination bucket counts
(``shuffle.exchange_counts``).  The result is a ``GroupMeasure`` of tight
pow2 send/receive capacities (max over the group, so one program still
serves the whole group) that the capacity manager threads back into the
payload dispatch via the ``c_out``/``cap_recv`` parameters.  The hash
join measure additionally exchanges the key projections and counts the
exact join output (the ``dist_join_count`` idea, moved BEFORE the payload)
so blown output capacities are pre-floored instead of abort-retried.

Donation: the stacked ``(p, k, cap, ar)`` inputs are freshly built by
``_stack`` and dead after the dispatch, so they are donated
(``SPMD.run(donate=...)``) — XLA reuses their HBM for the exchange
outputs instead of double-buffering (no-op on backends without donation).

Hybrid (heavy-hitter) routing: the measure pre-passes detect heavy
destinations from the counts they already ship (``relational.skew``);
``measure_*_many(hybrid=True)`` re-measures flagged groups under hybrid
routing and ``hybrid_semijoin_many``/``hybrid_join_many`` run the
payload with light keys hashed and heavy keys spread/broadcast — same
row sets as the hash operators, balanced capacities under any skew.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses

from .grid import _grid_send_one, _grid_shares, _position_groups
from .hashing import dense_ranks, hash_columns
from .localops import (
    get_local_backend,
    local_dedup_mask,
    local_join_count,
    local_join_ranked,
    local_semijoin_mask,
)
from .shuffle import (
    bucket_counts,
    exchange,
    exchange_counts,
    exchange_finish,
    exchange_multi,
    exchange_multi_start,
    exchange_start,
    padded_slots,
    pow2,
    ship_segments,
)
from .skew import (
    DEFAULT_SKEW_THRESHOLD,
    bcast_dests,
    heavy_dest_flags_many,
    split_dests,
)
from .spmd import AXIS, SPMD
from .table import DTable, schema_join
from .wire import (
    WireFormat,
    count_wire_bytes,
    dense_wire_bytes,
    packed_wire_bytes,
)


def _xbytes(p: int, c_out: int, arity: int, fmt: Optional[WireFormat]) -> int:
    """Bytes ONE exchange of this shape ships end-to-end: dense cells +
    valid plane when ``fmt`` is None, the packed bit stream otherwise."""
    if fmt is None:
        return dense_wire_bytes(p, c_out, arity)
    return packed_wire_bytes(p, c_out, fmt)


# Width of the packed join pre-count's key hash when the actual key
# projection is wider (see ``join_pair_measure_spec``).  Narrow enough to
# beat the packed keys on any multi-attribute schema, wide enough that
# extra collisions (which only OVER-count the join output) stay deep in
# the pow2 rounding noise of the derived ``out_need``.
JOIN_HASH_BITS = 16
_JOIN_HASH_FMT = WireFormat((JOIN_HASH_BITS,))


# ------------------------------------------------------------ stack helpers
def _stack(tables: Sequence[DTable]) -> Tuple[jax.Array, jax.Array]:
    """(p, cap, ar) x k -> data (p, k, cap, ar), valid (p, k, cap)."""
    assert len({(t.cap, t.arity) for t in tables}) == 1, (
        "batched group must have uniform shard shapes: "
        + str([(t.cap, t.arity) for t in tables])
    )
    data = jnp.stack([t.data for t in tables], axis=1)
    valid = jnp.stack([t.valid for t in tables], axis=1)
    return data, valid


def _unstack(data, valid, schemas: Sequence[Tuple[str, ...]]) -> List[DTable]:
    return [DTable(data[:, i], valid[:, i], s) for i, s in enumerate(schemas)]


def _key_array(keys: Sequence[Sequence[int]], p: int) -> jax.Array:
    """Per-instance key column indices as (p, k, n_keys) traced data."""
    assert len({len(k) for k in keys}) == 1, "key-column count must be uniform"
    ks = np.asarray([list(k) for k in keys], np.int32).reshape(len(keys), -1)
    return jnp.broadcast_to(jnp.asarray(ks), (p,) + ks.shape)


def _seed_array(seeds: Sequence[int], p: int) -> jax.Array:
    s = jnp.asarray([int(x) & 0xFFFFFFFF for x in seeds], jnp.uint32)
    return jnp.broadcast_to(s, (p, len(seeds)))


def _per_op_stats(
    sent, dropped, padded: int = 0, heavy=None, wire_bytes: int = 0,
    ubytes=None,
) -> List[Dict[str, int]]:
    """(p, k) shard stats -> one {'sent','dropped','padded'} dict per
    instance; ``padded`` (dense slots the wire shipped, a static of the
    dispatch) is identical across the group's instances.  ``heavy`` (the
    hybrid ops' per-shard count of tuple-sends routed through the
    heavy-hitter path) adds a ``'heavy'`` key when given — hash/grid ops
    omit the key so their stats stay byte-identical to the sequential
    operators'.  ``wire_bytes`` (byte-true shipped size, static like
    ``padded``) and ``ubytes`` ((p, k) useful dense-int32 bytes actually
    occupied, traced like ``sent``) feed the ledger's byte accounting."""
    s = np.asarray(sent).sum(axis=0)
    d = np.asarray(dropped).sum(axis=0)
    out = [
        {
            "sent": int(a),
            "dropped": int(b),
            "padded": int(padded),
            "wire_bytes": int(wire_bytes),
        }
        for a, b in zip(s, d)
    ]
    if heavy is not None:
        for st, h in zip(out, np.asarray(heavy).sum(axis=0)):
            st["heavy"] = int(h)
    if ubytes is not None:
        for st, u in zip(out, np.asarray(ubytes).sum(axis=0)):
            st["ubytes"] = int(u)
    return out


# --------------------------------------------------- calibration pre-passes
@dataclasses.dataclass(frozen=True)
class SideCaps:
    """Tight pow2 capacities for ONE exchange side: ``c_out`` (per-
    destination send bucket) and ``cap_recv`` (post-all_to_all compact).
    Frozen + pow2-bucketed: equal occupancy buckets hash equal, so the
    payload program these become statics of is reused across rounds."""

    c_out: int
    cap_recv: int
    # packed wire format of this side's exchange (None = dense).  Recorded
    # by the engine when a WirePolicy is active so the payload dispatch,
    # the caps cache, and snapshots all agree on the encoding.
    fmt: Optional[WireFormat] = None

    @staticmethod
    def from_counts(out_counts, recv_tot) -> "SideCaps":
        return SideCaps(
            pow2(max(1, int(np.asarray(out_counts).max()))),
            pow2(max(1, int(np.asarray(recv_tot).max()))),
        )


@dataclasses.dataclass(frozen=True)
class GroupMeasure:
    """What one count-only pre-pass dispatch learned about an op group.

    ``lhs``/``rhs``: per-side tight capacities (max over the group's k
    instances — the whole group still runs as one program).  ``out_recv``:
    the receive requirement of the exchange whose buffer IS the op's
    output (semijoin S side, intersect A side, dedup), so the capacity
    manager can pre-floor a managed capacity that would have aborted.
    ``out_need``: exact join-output requirement (hash joins only).
    ``padded``: int32 cells the pre-pass ITSELF shipped (the (p,)-int
    count vectors, plus the keys-only exchange of the join output count)
    — charged to the ledger so calibrated payload efficiency never hides
    the cost of measuring.

    Heavy-hitter surface (``relational.skew``): ``heavy`` is the (k, p)
    bool per-instance heavy-destination flags the count pre-pass
    detected (None where detection doesn't apply), ``n_heavy`` the total
    flagged destination count (the capacity manager's diagnostic hint),
    ``lhs_heavy_rows``/``rhs_heavy_rows`` each side's row mass bound for
    the flagged destinations, and ``hybrid_routed`` is True when the
    capacities in ``lhs``/``rhs``/``out_*`` were re-measured under HYBRID
    routing and the payload must run the hybrid exchange to stay within
    them.  ``swap_spread`` assigns the hybrid join's roles: False spreads
    the lhs and broadcasts the rhs; True the reverse — the measure picks
    the side with the LARGER heavy mass to spread (broadcasting the small
    side is what keeps both the wire and the join output balanced)."""

    lhs: SideCaps
    rhs: Optional[SideCaps] = None
    out_recv: Optional[int] = None
    out_need: Optional[int] = None
    padded: int = 0
    # byte-true size of the pre-pass's OWN traffic (count vectors +
    # keys-only join-count exchanges) — the ``padded`` slot charge's
    # byte sibling, accumulated into the ledger's payload_bytes
    wire_bytes: int = 0
    heavy: Optional[np.ndarray] = None
    n_heavy: int = 0
    lhs_heavy_rows: int = 0
    rhs_heavy_rows: int = 0
    hybrid_routed: bool = False
    swap_spread: bool = False


# ------------------------------------------------- cross-request batching
def cross_request_key(kind, engine, cap, lhs, rhs, xcaps) -> Optional[Tuple]:
    """Cross-REQUEST bucketing key of one prepared op group — the serving
    layer's merge key.  Groups from *different queries* with equal keys
    can run as ONE stacked dispatch: the k axis of the ``dist_*_many``
    operators spans requests instead of one query's op group, and the
    uniformity contract above is exactly this key — engine strategy +
    local backend, op kind, managed output capacity, per-side shard
    shapes, and shared-key-column count (key positions and seeds already
    ride as per-instance data, so they may differ freely).

    The measured pow2 exchange caps are PART of the key: merging riders
    with unequal calibrated caps would run every rider at the
    elementwise max (sound, but the tighter riders ship pure padding),
    turning the dispatch savings into wire cost.  Requiring equal
    buckets makes a merge free by construction — identical hot queries
    (the zipf serving head) always collide, heterogeneous stragglers
    dispatch solo.

    None = dispatch solo: packed wire formats are per-query (their bit
    widths come from that query's base-relation value ranges, so a merged
    group would re-encode every rider), and hybrid-routed payloads carry
    per-instance heavy-destination flags whose spread/broadcast roles are
    not mergeable across measures."""
    if engine.wire_policy is not None:
        return None
    if xcaps is not None and xcaps.hybrid_routed:
        return None
    key: Tuple = (
        engine.name, engine.local_backend, kind, int(cap),
        lhs[0].cap, lhs[0].arity,
    )
    if xcaps is None:
        key += (None,)
    else:
        key += (
            xcaps.lhs, xcaps.rhs, xcaps.out_recv, xcaps.out_need,
        )
    if rhs is not None:
        n_shared = sum(1 for x in lhs[0].schema if x in set(rhs[0].schema))
        key += (rhs[0].cap, rhs[0].arity, n_shared)
    return key


def merge_measures(
    ms: Sequence[Optional[GroupMeasure]],
) -> Optional[GroupMeasure]:
    """Elementwise-max merge of the measures of same-key groups for a
    cross-request fused dispatch.  Wider capacities are always sound (an
    instance merely ships more padding than its solo measure required —
    rows, ``sent`` and drops are unaffected), so the merged dispatch runs
    every rider at the max of the measured pow2 buckets.  Returns None
    when ANY measure is missing — then the merged dispatch must run at
    the group defaults, because a measured instance's tight caps say
    nothing about an unmeasured rider's arrival.

    The measures' own wire charges (``padded``/``wire_bytes``) are NOT
    merged: each request already accounts for its pre-pass traffic in its
    own ledger (``GroupWork.mpad``/``mbytes``)."""
    if any(m is None for m in ms):
        return None
    assert not any(m.hybrid_routed for m in ms), "hybrid measures don't merge"
    if len(ms) == 1:
        return ms[0]

    def side(sel) -> Optional[SideCaps]:
        sides = [sel(m) for m in ms]
        if any(s is None for s in sides):
            return None
        assert all(s.fmt is None for s in sides), "packed fmts don't merge"
        return SideCaps(
            max(s.c_out for s in sides), max(s.cap_recv for s in sides)
        )

    def opt_max(sel) -> Optional[int]:
        vals = [sel(m) for m in ms if sel(m) is not None]
        return max(vals) if vals else None

    return GroupMeasure(
        lhs=side(lambda m: m.lhs),
        rhs=side(lambda m: m.rhs),
        out_recv=opt_max(lambda m: m.out_recv),
        out_need=opt_max(lambda m: m.out_need),
        padded=0,
        wire_bytes=0,
    )


def _take(data: jax.Array, cols: jax.Array) -> jax.Array:
    return jnp.take(data, cols, axis=1)


def _dests(keys: jax.Array, valid: jax.Array, p: int, seed, backend: str) -> jax.Array:
    """Destinations from a pre-gathered (cap, n_keys) key matrix — hashes
    columns in order, identical to ``dests_for(data, key_cols, ...)``."""
    be = get_local_backend(backend)
    return be.dests(keys, valid, tuple(range(keys.shape[1])), p, seed)


# -------------------------------------------- hash-path measure dispatches
def _measure_pair_one(ad, av, bd, bv, seed, ak, bk, *, p, dedup_b, backend):
    """Count both sides' exchanges of one (a, b) instance with the SAME
    seeds/keys the payload dispatch will use."""
    da = _dests(_take(ad, ak), av, p, seed, backend)
    oa, ra = exchange_counts(da, p)
    bkeys = _take(bd, bk)
    bv2 = (
        local_dedup_mask(bkeys, bv, tuple(range(bk.shape[0])))
        if dedup_b
        else bv
    )
    db = _dests(bkeys, bv2, p, seed, backend)
    ob, rb = exchange_counts(db, p)
    return oa, ra, ob, rb


def _measure_pair_shard_b(ad, av, bd, bv, seed, ak, bk, *, p, dedup_b, backend):
    one = functools.partial(
        _measure_pair_one, p=p, dedup_b=dedup_b, backend=backend
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, ak, bk)


def _measure_keys(akeys, bkeys, ak, bk, seed, fmt):
    """Shared key-source policy of the fused and fallback join counts:
    dense ships a single 32-bit hashed-key column; packed ships the
    actual key projection when it bit-packs narrower than a hashed
    column (exact count), else a ``JOIN_HASH_BITS``-bit hash (equal keys
    keep equal hashes, so the count only OVER-counts — sound).  Returns
    (sa, sb, key column ids, wire format to ship with)."""
    if fmt is not None and fmt.row_bits <= _JOIN_HASH_FMT.row_bits:
        return akeys, bkeys, tuple(range(ak.shape[0])), fmt
    if fmt is not None:
        mask = jnp.uint32((1 << JOIN_HASH_BITS) - 1)
        sa = jax.lax.bitcast_convert_type(
            hash_columns(akeys, tuple(range(ak.shape[0])), seed) & mask,
            jnp.int32,
        )[:, None]
        sb = jax.lax.bitcast_convert_type(
            hash_columns(bkeys, tuple(range(bk.shape[0])), seed) & mask,
            jnp.int32,
        )[:, None]
        return sa, sb, (0,), _JOIN_HASH_FMT
    return akeys, bkeys, tuple(range(ak.shape[0])), None


def _join_count_one(ad, av, bd, bv, seed, ak, bk, *,
                    p, c_out_a, c_out_b, cap_a, cap_b, fmt=None, backend):
    """Keys-only exchange at the ALREADY-CALIBRATED tight capacities,
    then the exact per-shard join output count — the ``dist_join_count``
    retry floor, moved BEFORE the payload at calibrated (not worst-case)
    wire cost."""
    akeys = _take(ad, ak)
    da = _dests(akeys, av, p, seed, backend)
    bkeys = _take(bd, bk)
    db = _dests(bkeys, bv, p, seed, backend)
    sa, sb, kc, sfmt = _measure_keys(akeys, bkeys, ak, bk, seed, fmt)
    if sfmt is not None:
        aw, _sa, _dsa = exchange_start(sa, av, da, p=p, c_out=c_out_a, fmt=sfmt)
        bw, _sb, _dsb = exchange_start(sb, bv, db, p=p, c_out=c_out_b, fmt=sfmt)
        aw2, bw2 = ship_segments([aw, bw])
        a2, a2v, _ = exchange_finish(
            aw2, p=p, c_out=c_out_a, cap_recv=cap_a, fmt=sfmt
        )
        b2, b2v, _ = exchange_finish(
            bw2, p=p, c_out=c_out_b, cap_recv=cap_b, fmt=sfmt
        )
    else:
        a2, a2v, *_ = exchange(sa, av, da, p=p, c_out=c_out_a, cap_recv=cap_a)
        b2, b2v, *_ = exchange(sb, bv, db, p=p, c_out=c_out_b, cap_recv=cap_b)
    return local_join_count(a2, a2v, b2, b2v, kc, kc, backend)


def _join_count_shard_b(ad, av, bd, bv, seed, ak, bk, *,
                        p, c_out_a, c_out_b, cap_a, cap_b, backend):
    one = functools.partial(
        _join_count_one, p=p, c_out_a=c_out_a, c_out_b=c_out_b,
        cap_a=cap_a, cap_b=cap_b, backend=backend,
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, ak, bk)


# ------------------------------------------ hybrid-routing measure helpers
def _heavy_array(heavy: np.ndarray, p: int) -> jax.Array:
    """Per-instance heavy-destination flags as (p, k, p) traced DATA —
    one compiled hybrid program serves every flag pattern."""
    h = jnp.asarray(np.asarray(heavy, bool).reshape(len(heavy), p))
    return jnp.broadcast_to(h, (p,) + h.shape)


def _hybrid_exchange(data, valid, dest, hw, *, p, c_out, cap_recv, spread,
                     fmt=None):
    """One side of a hybrid exchange: ``spread=True`` deals the heavy rows
    positionally (single-dest ``exchange``), ``spread=False`` broadcasts
    them to every reducer (``exchange_multi``).  Returns
    (rdata, rvalid, sent, dropped, heavy_sends)."""
    if spread:
        d2, hvy = split_dests(dest, hw, p)
        rd, rv, sent, ds, dr = exchange(
            data, valid, d2, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt
        )
        return rd, rv, sent, ds + dr, hvy.sum()
    d2, hvy = bcast_dests(dest, hw, p)
    rd, rv, sent, ds, dr = exchange_multi(
        data, valid, d2, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt
    )
    return rd, rv, sent, ds + dr, p * hvy.sum()


def _hybrid_counts_one_side(dest, hw, *, p, spread):
    d2, _ = (split_dests if spread else bcast_dests)(dest, hw, p)
    return exchange_counts(d2, p)


def _hybrid_pair_counts_one(ad, av, bd, bv, seed, ak, bk, hw, *,
                            p, dedup_b, swap, backend):
    """Count both sides of one instance under HYBRID routing: the spread
    side's heavy rows dealt positionally, the broadcast side's heavy rows
    to every reducer — same dests the hybrid payload will use.  ``swap``
    spreads the rhs and broadcasts the lhs instead."""
    da = _dests(_take(ad, ak), av, p, seed, backend)
    oa, ra = _hybrid_counts_one_side(da, hw, p=p, spread=not swap)
    bkeys = _take(bd, bk)
    bv2 = (
        local_dedup_mask(bkeys, bv, tuple(range(bk.shape[0])))
        if dedup_b
        else bv
    )
    db = _dests(bkeys, bv2, p, seed, backend)
    ob, rb = _hybrid_counts_one_side(db, hw, p=p, spread=swap)
    return oa, ra, ob, rb


def _hybrid_pair_counts_shard_b(ad, av, bd, bv, seed, ak, bk, hw, *,
                                p, dedup_b, swap, backend):
    one = functools.partial(
        _hybrid_pair_counts_one, p=p, dedup_b=dedup_b, swap=swap,
        backend=backend,
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, ak, bk, hw)


def _hybrid_pair_counts(
    spmd: SPMD, as_, bs, a_keys, b_keys, seeds, heavy, *,
    dedup_b, swap, backend,
) -> Tuple[SideCaps, SideCaps]:
    """ONE count-only dispatch re-measuring an op group's exchanges under
    hybrid routing (run only when the hash counts flagged heavy
    destinations)."""
    p = spmd.p
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    oa, ra, ob, rb = spmd.run(
        _hybrid_pair_counts_shard_b,
        ad, av, bd, bv, _seed_array(seeds, p),
        _key_array(a_keys, p), _key_array(b_keys, p), _heavy_array(heavy, p),
        p=p, dedup_b=dedup_b, swap=swap, backend=backend,
        donate=(0, 1, 2, 3, 4, 5, 6, 7),
        measure=True,
    )
    return SideCaps.from_counts(oa, ra), SideCaps.from_counts(ob, rb)


def _hybrid_join_count_one(ad, av, bd, bv, seed, ak, bk, hw, *,
                           p, c_out_a, c_out_b, cap_a, cap_b, swap, fmt=None,
                           backend):
    """Keys-only exchange at the hybrid-calibrated capacities, then the
    exact per-shard join output count UNDER HYBRID PLACEMENT — the spread
    join's true requirement, not the hash join's one-reducer pile-up."""
    akeys = _take(ad, ak)
    da = _dests(akeys, av, p, seed, backend)
    bkeys = _take(bd, bk)
    db = _dests(bkeys, bv, p, seed, backend)
    sa, sb, kc, sfmt = _measure_keys(akeys, bkeys, ak, bk, seed, fmt)
    a2, a2v, *_ = _hybrid_exchange(
        sa, av, da, hw, p=p, c_out=c_out_a, cap_recv=cap_a, spread=not swap,
        fmt=sfmt,
    )
    b2, b2v, *_ = _hybrid_exchange(
        sb, bv, db, hw, p=p, c_out=c_out_b, cap_recv=cap_b, spread=swap,
        fmt=sfmt,
    )
    return local_join_count(a2, a2v, b2, b2v, kc, kc, backend)


def _hybrid_join_count_shard_b(ad, av, bd, bv, seed, ak, bk, hw, *,
                               p, c_out_a, c_out_b, cap_a, cap_b, swap,
                               backend):
    one = functools.partial(
        _hybrid_join_count_one, p=p, c_out_a=c_out_a, c_out_b=c_out_b,
        cap_a=cap_a, cap_b=cap_b, swap=swap, backend=backend,
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, ak, bk, hw)


def _finalize_pair_counts(
    oa_np: np.ndarray,
    ra,
    ob_np: np.ndarray,
    rb,
    *,
    p: int,
    count_padded: int,
    count_bytes: int = 0,
    skew_threshold: float = DEFAULT_SKEW_THRESHOLD,
) -> GroupMeasure:
    """Host-side tail shared by the per-group pair measure and the
    combined round pre-pass: tight pow2 caps per side plus the free
    heavy-destination detection.  The hash is key-consistent across both
    sides, so per-destination overload on EITHER side flags the
    destination's keys heavy for both."""
    heavy = heavy_dest_flags_many(oa_np, p, skew_threshold) | heavy_dest_flags_many(
        ob_np, p, skew_threshold
    )
    arrivals_a = oa_np.reshape(oa_np.shape[0], -1, p).sum(axis=0)  # (k, p)
    arrivals_b = ob_np.reshape(ob_np.shape[0], -1, p).sum(axis=0)
    return GroupMeasure(
        lhs=SideCaps.from_counts(oa_np, ra),
        rhs=SideCaps.from_counts(ob_np, rb),
        out_recv=None,
        padded=count_padded,
        wire_bytes=count_bytes,
        heavy=heavy,
        n_heavy=int(heavy.sum()),
        lhs_heavy_rows=int(arrivals_a[heavy].sum()),
        rhs_heavy_rows=int(arrivals_b[heavy].sum()),
    )


def _measure_pair_many(
    spmd: SPMD,
    as_: Sequence[DTable],
    bs: Sequence[DTable],
    a_keys: Sequence[Sequence[int]],
    b_keys: Sequence[Sequence[int]],
    seeds: Sequence[int],
    *,
    dedup_b: bool,
    backend: str = "jnp",
    skew_threshold: float = DEFAULT_SKEW_THRESHOLD,
) -> GroupMeasure:
    p = spmd.p
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    oa, ra, ob, rb = spmd.run(
        _measure_pair_shard_b,
        ad, av, bd, bv, _seed_array(seeds, p),
        _key_array(a_keys, p), _key_array(b_keys, p),
        p=p, dedup_b=dedup_b, backend=backend,
        donate=(0, 1, 2, 3, 4, 5, 6),
        measure=True,
    )
    return _finalize_pair_counts(
        np.asarray(oa), ra, np.asarray(ob), rb,
        p=p,
        count_padded=2 * len(as_) * p * p,  # two (p,)-int count vectors each
        count_bytes=count_wire_bytes(p, 2 * len(as_)),
        skew_threshold=skew_threshold,
    )


def measure_semijoin_many(
    spmd: SPMD, ss, rs, *, seeds, backend: str = "jnp",
    hybrid: bool = False, skew_threshold: float = DEFAULT_SKEW_THRESHOLD,
) -> GroupMeasure:
    """Pre-pass of ``dist_semijoin_many``: S side raw, R side the
    deduplicated key projection — the S receive count bounds the output.

    ``hybrid=True``: when the counts flag heavy destinations, ONE more
    count-only dispatch re-measures both sides under hybrid routing (S
    spread, R keys broadcast) and the returned capacities/``out_recv``
    are the hybrid payload's — ``hybrid_routed`` marks them so."""
    shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
    s_keys = [s.cols(sh) for s, sh in zip(ss, shareds)]
    r_keys = [r.cols(sh) for r, sh in zip(rs, shareds)]
    m = _measure_pair_many(
        spmd, ss, rs, s_keys, r_keys, seeds, dedup_b=True, backend=backend,
        skew_threshold=skew_threshold,
    )
    return finish_semijoin_measure(
        spmd, ss, rs, seeds, m, hybrid=hybrid, backend=backend
    )


def finish_semijoin_measure(
    spmd: SPMD, ss, rs, seeds, m: GroupMeasure, *,
    hybrid: bool, backend: str = "jnp",
) -> GroupMeasure:
    """Tail of the semijoin pre-pass given pair counts ``m`` from ANY
    source — the per-group dispatch above or one slice of the combined
    round pre-pass (``RoundCounts``)."""
    if hybrid and m.n_heavy:
        # roles are fixed for a semijoin: S (the output side, one copy
        # per row) spreads, R's deduplicated key projection broadcasts —
        # a heavy KEY is a single R-side row after dedup, so broadcast
        # costs n_heavy * p keys, never a relation's row mass
        p = spmd.p
        shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
        s_keys = [s.cols(sh) for s, sh in zip(ss, shareds)]
        r_keys = [r.cols(sh) for r, sh in zip(rs, shareds)]
        lhs, rhs = _hybrid_pair_counts(
            spmd, ss, rs, s_keys, r_keys, seeds, m.heavy,
            dedup_b=True, swap=False, backend=backend,
        )
        return dataclasses.replace(
            m, lhs=lhs, rhs=rhs, out_recv=lhs.cap_recv,
            padded=m.padded + 2 * len(ss) * p * p,
            wire_bytes=m.wire_bytes + count_wire_bytes(p, 2 * len(ss)),
            hybrid_routed=True,
        )
    return dataclasses.replace(m, out_recv=m.lhs.cap_recv)


def hybridize_join_measure(
    spmd: SPMD, as_, bs, seeds, m: GroupMeasure, *,
    hybrid: bool, backend: str = "jnp",
) -> GroupMeasure:
    """Join-measure middle stage shared by ``measure_join_many`` and the
    combined round pre-pass: when heavy destinations were flagged,
    re-measure both sides under hybrid routing (one extra count-only
    dispatch, skew-dependent and rare)."""
    if not (hybrid and m.n_heavy):
        return m
    # spread the side carrying the LARGER heavy row mass, broadcast
    # the smaller — that balances both the wire and the join output
    # (broadcasting the heavy mass would replicate it p ways AND pile
    # the join's output rows onto the light partner's reducers)
    p = spmd.p
    shareds = [[x for x in a.schema if x in b.schema] for a, b in zip(as_, bs)]
    a_keys = [a.cols(sh) for a, sh in zip(as_, shareds)]
    b_keys = [b.cols(sh) for b, sh in zip(bs, shareds)]
    swap = m.rhs_heavy_rows > m.lhs_heavy_rows
    lhs, rhs = _hybrid_pair_counts(
        spmd, as_, bs, a_keys, b_keys, seeds, m.heavy,
        dedup_b=False, swap=swap, backend=backend,
    )
    # any light-placement output count is void under hybrid routing (the
    # spread side repositions the join output); the fused join-need pass
    # recomputes it at the hybrid placement
    return dataclasses.replace(
        m, lhs=lhs, rhs=rhs, out_need=None,
        padded=m.padded + 2 * len(as_) * p * p,
        wire_bytes=m.wire_bytes + count_wire_bytes(p, 2 * len(as_)),
        hybrid_routed=True, swap_spread=swap,
    )


def measure_join_many(
    spmd: SPMD, as_, bs, *, seeds, backend: str = "jnp",
    hybrid: bool = False, skew_threshold: float = DEFAULT_SKEW_THRESHOLD,
) -> GroupMeasure:
    """Pre-pass of ``dist_join_many``: first the count dispatch (tight
    shuffle capacities), then a keys-only exchange AT those calibrated
    capacities whose exact output count pre-sizes ``out_need`` — two tiny
    dispatches, both priced into ``padded``.

    ``hybrid=True``: when the counts flag heavy destinations, the
    capacities are re-measured under hybrid routing (A spread, B
    broadcast) and the keys-only output count runs at the HYBRID
    placement — so ``out_need`` is the true per-shard requirement of the
    spread join, not the one-reducer pile-up of the hash join."""
    p = spmd.p
    shareds = [[x for x in a.schema if x in b.schema] for a, b in zip(as_, bs)]
    a_keys = [a.cols(sh) for a, sh in zip(as_, shareds)]
    b_keys = [b.cols(sh) for b, sh in zip(bs, shareds)]
    m = _measure_pair_many(
        spmd, as_, bs, a_keys, b_keys, seeds, dedup_b=False, backend=backend,
        skew_threshold=skew_threshold,
    )
    k, nk = len(as_), len(a_keys[0])
    m = hybridize_join_measure(
        spmd, as_, bs, seeds, m, hybrid=hybrid, backend=backend
    )
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    if not m.hybrid_routed:
        cnt = spmd.run(
            _join_count_shard_b,
            ad, av, bd, bv, _seed_array(seeds, p),
            _key_array(a_keys, p), _key_array(b_keys, p),
            p=p, c_out_a=m.lhs.c_out, c_out_b=m.rhs.c_out,
            cap_a=m.lhs.cap_recv, cap_b=m.rhs.cap_recv, backend=backend,
            donate=(0, 1, 2, 3, 4, 5, 6),
            measure=True,
        )
    else:
        cnt = spmd.run(
            _hybrid_join_count_shard_b,
            ad, av, bd, bv, _seed_array(seeds, p),
            _key_array(a_keys, p), _key_array(b_keys, p),
            _heavy_array(m.heavy, p),
            p=p, c_out_a=m.lhs.c_out, c_out_b=m.rhs.c_out,
            cap_a=m.lhs.cap_recv, cap_b=m.rhs.cap_recv, swap=m.swap_spread,
            backend=backend,
            donate=(0, 1, 2, 3, 4, 5, 6, 7),
            measure=True,
        )
    return dataclasses.replace(
        m,
        out_need=pow2(max(1, int(np.asarray(cnt).max()))),
        padded=m.padded
        + k * (
            padded_slots(p, m.lhs.c_out, nk) + padded_slots(p, m.rhs.c_out, nk)
        ),
        # the keys-only exchanges ride the dense path; charge them dense
        wire_bytes=m.wire_bytes
        + k * (
            dense_wire_bytes(p, m.lhs.c_out, nk)
            + dense_wire_bytes(p, m.rhs.c_out, nk)
        ),
    )


def measure_intersect_many(
    spmd: SPMD, as_, bs, *, seeds, backend: str = "jnp"
) -> GroupMeasure:
    """Pre-pass of ``dist_intersect_many`` (A = full row key)."""
    m = _measure_pair_many(
        spmd, as_, bs,
        [tuple(range(a.arity)) for a in as_],
        [b.cols(a.schema) for a, b in zip(as_, bs)],
        seeds, dedup_b=False, backend=backend,
    )
    return dataclasses.replace(m, out_recv=m.lhs.cap_recv)


def _measure_one_shard_b(d, v, seed, cols, *, p, backend):
    def one(d, v, seed, cols):
        return exchange_counts(_dests(_take(d, cols), v, p, seed, backend), p)

    return jax.vmap(one)(d, v, seed, cols)


def measure_dedup_many(
    spmd: SPMD, ts, *, seeds, backend: str = "jnp"
) -> GroupMeasure:
    """Pre-pass of ``dist_dedup_many`` (full-row key, single exchange)."""
    p = spmd.p
    d, v = _stack(ts)
    cols = _key_array([tuple(range(t.arity)) for t in ts], p)
    o, r = spmd.run(
        _measure_one_shard_b, d, v, _seed_array(seeds, p), cols,
        p=p, backend=backend, donate=(0, 1, 2, 3),
        measure=True,
    )
    caps = SideCaps.from_counts(o, r)
    return GroupMeasure(
        lhs=caps, out_recv=caps.cap_recv, padded=len(ts) * p * p,
        wire_bytes=count_wire_bytes(p, len(ts)),
    )


# -------------------------------------------- grid-path measure dispatches
def _grid_pair_dests(av, bv, *, g_a, g_b, cap_a, cap_b, offs_a, offs_b,
                     stride_a, stride_b, p):
    grp_a = _position_groups(av, g_a, cap_a, p)
    dest_a = jnp.where(
        (grp_a < g_a)[:, None],
        grp_a[:, None] * stride_a + jnp.asarray(offs_a, jnp.int32)[None, :],
        p,
    ).astype(jnp.int32)
    grp_b = _position_groups(bv, g_b, cap_b, p)
    dest_b = jnp.where(
        (grp_b < g_b)[:, None],
        grp_b[:, None] * stride_b + jnp.asarray(offs_b, jnp.int32)[None, :],
        p,
    ).astype(jnp.int32)
    return dest_a, dest_b


def _grid_measure_shard_b(av, bv, *, plan, p):
    def one(av, bv):
        da, db = _grid_pair_dests(av, bv, p=p, **dict(plan))
        oa, ra = exchange_counts(da, p)
        ob, rb = exchange_counts(db, p)
        return oa, ra, ob, rb

    return jax.vmap(one)(av, bv)


def _grid_measure_rkeys_shard_b(av, rd, rv, rk, *, plan, p):
    """Grid semijoin pre-pass: S positional, R the dedup'd key projection
    (its valid mask shrinks, so its position groups must be recounted on
    the masked rows, exactly as the mark stage does)."""

    def one(av, rd, rv, rk):
        rkeys = _take(rd, rk)
        rkv = local_dedup_mask(rkeys, rv, tuple(range(rk.shape[0])))
        da, db = _grid_pair_dests(av, rkv, p=p, **dict(plan))
        oa, ra = exchange_counts(da, p)
        ob, rb = exchange_counts(db, p)
        return oa, ra, ob, rb

    return jax.vmap(one)(av, rd, rv, rk)


def _grid_pair_plan(g_a, g_b, cap_a, cap_b):
    """Static dest plan of a 2-relation grid — cell = grp_a * g_b + grp_b,
    which is both the Lemma 8 (w=2) join layout and the Lemma 10 mark
    layout (S major, R-projection minor)."""
    stride_a, stride_b = g_b, 1
    offs_a = tuple(range(g_b))
    offs_b = tuple(c * g_b for c in range(g_a))
    return (
        ("g_a", g_a), ("g_b", g_b), ("cap_a", cap_a), ("cap_b", cap_b),
        ("offs_a", offs_a), ("offs_b", offs_b),
        ("stride_a", stride_a), ("stride_b", stride_b),
    )


def _stack_valid(tables: Sequence[DTable]) -> jax.Array:
    """Valid masks only, (p, k, cap) — the grid pre-passes are positional,
    so they never need the payload columns on device."""
    assert len({t.cap for t in tables}) == 1
    return jnp.stack([t.valid for t in tables], axis=1)


def measure_grid_join_many(
    spmd: SPMD, as_, bs, *, backend: str = "jnp"
) -> GroupMeasure:
    """Pre-pass of ``grid_join_many``: positional dests need no seeds, so
    the counts are exact for the payload send regardless of hashing."""
    p = spmd.p
    a0, b0 = as_[0], bs[0]
    g = _grid_shares([a0.cap * a0.p, b0.cap * b0.p], p)
    plan = _grid_pair_plan(g[0], g[1], a0.cap, b0.cap)
    oa, ra, ob, rb = spmd.run(
        _grid_measure_shard_b, _stack_valid(as_), _stack_valid(bs),
        plan=plan, p=p, donate=(0, 1),
        measure=True,
    )
    return GroupMeasure(
        lhs=SideCaps.from_counts(oa, ra),
        rhs=SideCaps.from_counts(ob, rb),
        padded=2 * len(as_) * p * p,
        wire_bytes=count_wire_bytes(p, 2 * len(as_)),
    )


def measure_grid_semijoin_many(
    spmd: SPMD, ss, rs, *, backend: str = "jnp"
) -> GroupMeasure:
    """Pre-pass of ``grid_semijoin_many``'s mark stage (the trailing hash
    dedup keeps its managed capacity — its input is the mark output, which
    does not exist yet)."""
    p = spmd.p
    s0, r0 = ss[0], rs[0]
    g_s, g_r = _grid_shares([s0.cap * s0.p, r0.cap * r0.p], p)
    plan = _grid_pair_plan(g_s, g_r, s0.cap, r0.cap)
    shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
    rd, rv = _stack(rs)  # R's key projection needs the data; S only its mask
    rk = _key_array([r.cols(sh) for r, sh in zip(rs, shareds)], p)
    oa, ra, ob, rb = spmd.run(
        _grid_measure_rkeys_shard_b, _stack_valid(ss), rd, rv, rk,
        plan=plan, p=p, donate=(0, 1, 2, 3),
        measure=True,
    )
    return GroupMeasure(
        lhs=SideCaps.from_counts(oa, ra),
        rhs=SideCaps.from_counts(ob, rb),
        padded=2 * len(ss) * p * p,
        wire_bytes=count_wire_bytes(p, 2 * len(ss)),
    )


# ---------------------------------------- combined round-level measure pass
@dataclasses.dataclass
class MeasureSpec:
    """One op group's slice of a round's COMBINED count pre-pass.

    Building a spec stacks the group's inputs on device but dispatches
    NOTHING; ``RoundCounts`` fuses every spec of a round stage into one
    program whose count blocks ride a single ``(m, p)`` ``all_to_all`` —
    the per-group ``measure_*_many`` dispatches collapsed into one.

    ``entry`` is the static per-group descriptor (part of the jit cache
    key: rounds with the same group structure reuse the compiled
    program); ``arrays`` are the traced inputs, all freshly stacked and
    donated.  ``rows`` is how many count rows the spec owns in the
    stacked block (2k for two-sided groups, k for single exchanges)."""

    tag: str  # 'pair' | 'join_pair' | 'single' | 'grid_pair' | 'grid_rkeys'
    entry: Tuple
    arrays: Tuple
    k: int
    rows: int
    count_padded: int  # int32 cells this spec's count vectors ship
    count_bytes: int = 0  # byte-true size of the same pre-pass traffic
    skew_threshold: float = DEFAULT_SKEW_THRESHOLD
    join_rows: int = 0  # rows this spec owns in the fused join-count block


def pair_measure_spec(
    spmd: SPMD, as_, bs, a_keys, b_keys, seeds, *,
    dedup_b: bool, skew_threshold: float = DEFAULT_SKEW_THRESHOLD,
) -> MeasureSpec:
    """Hash pair exchange counts (semijoin/join/intersect pre-pass)."""
    p = spmd.p
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    k = len(as_)
    return MeasureSpec(
        tag="pair",
        entry=("pair", k, bool(dedup_b)),
        arrays=(
            ad, av, bd, bv, _seed_array(seeds, p),
            _key_array(a_keys, p), _key_array(b_keys, p),
        ),
        k=k, rows=2 * k, count_padded=2 * k * p * p,
        count_bytes=count_wire_bytes(p, 2 * k),
        skew_threshold=skew_threshold,
    )


def join_pair_measure_spec(
    spmd: SPMD, as_, bs, a_keys, b_keys, seeds, *,
    g_a: int, g_b: int, skew_threshold: float = DEFAULT_SKEW_THRESHOLD,
    fmt: Optional[WireFormat] = None,
) -> MeasureSpec:
    """Hash join pre-pass with the output count FUSED into the same
    dispatch: besides both sides' exchange counts, the program ships a
    keys-only exchange per side at the STATIC guess capacities
    ``g_a``/``g_b`` and counts the join output exactly per destination.

    The guesses break the circular dependency (a tight keys-only
    exchange would need the very ``c_out`` this dispatch measures): the
    fetched counts themselves prove post-hoc whether the guess held
    (max per-destination send <= g); ``_finalize_spec`` only trusts the
    fused output count when it did, so an undershot guess costs one
    fallback ``join_need_many`` dispatch, never an undercounted
    capacity.

    Dense (``fmt=None``) ships a single hashed-key column per side:
    matching on the 32-bit key hash can only OVER-count (colliding keys
    land on one destination and count as matches), so the derived
    ``out_need`` stays a sound capacity at width-1 wire cost.  Packed
    (``fmt`` = the group's shared-key ``WireFormat``) ships the actual
    key projections bit-packed when they fit in fewer bits than a
    hashed column — then the count is exact, which can only tighten
    ``out_need`` — and otherwise (wide multi-attribute keys) a
    bit-packed ``JOIN_HASH_BITS``-bit key hash, which keeps the
    overcount soundness at the narrowest wire cost of all."""
    p = spmd.p
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    k = len(as_)
    keyed = False
    sfmt = fmt
    if fmt is not None:
        # the SHIPPED format after the _measure_keys policy (the entry
        # keeps the original so the shard body resolves identically)
        keyed = fmt.row_bits <= _JOIN_HASH_FMT.row_bits
        if not keyed:
            sfmt = _JOIN_HASH_FMT
    if fmt is None:
        # count vectors + the two hashed-key (width 1) dense exchanges
        pad = 2 * k * p * p + k * p * p * (g_a + g_b)
        byt = count_wire_bytes(p, 2 * k) + k * (
            dense_wire_bytes(p, g_a, 1) + dense_wire_bytes(p, g_b, 1)
        )
    else:
        # count vectors + the two packed keys-only exchanges (the slot
        # metric stays width-weighted: one cell per shipped column)
        pad = 2 * k * p * p + k * p * p * (g_a + g_b) * sfmt.arity
        byt = count_wire_bytes(p, 2 * k) + k * (
            packed_wire_bytes(p, g_a, sfmt) + packed_wire_bytes(p, g_b, sfmt)
        )
    return MeasureSpec(
        tag="join_pair",
        entry=("join_pair", k, g_a, g_b, fmt, keyed),
        arrays=(
            ad, av, bd, bv, _seed_array(seeds, p),
            _key_array(a_keys, p), _key_array(b_keys, p),
        ),
        k=k, rows=2 * k,
        count_padded=pad,
        count_bytes=byt,
        skew_threshold=skew_threshold,
        join_rows=k,
    )


def single_measure_spec(spmd: SPMD, ts, seeds) -> MeasureSpec:
    """Full-row-key single exchange counts (dedup pre-pass)."""
    p = spmd.p
    d, v = _stack(ts)
    cols = _key_array([tuple(range(t.arity)) for t in ts], p)
    k = len(ts)
    return MeasureSpec(
        tag="single",
        entry=("single", k),
        arrays=(d, v, _seed_array(seeds, p), cols),
        k=k, rows=k, count_padded=k * p * p,
        count_bytes=count_wire_bytes(p, k),
    )


def grid_pair_measure_spec(spmd: SPMD, as_, bs) -> MeasureSpec:
    """Positional grid join send counts (seedless, exact)."""
    p = spmd.p
    a0, b0 = as_[0], bs[0]
    g = _grid_shares([a0.cap * a0.p, b0.cap * b0.p], p)
    plan = _grid_pair_plan(g[0], g[1], a0.cap, b0.cap)
    k = len(as_)
    return MeasureSpec(
        tag="grid_pair",
        entry=("grid_pair", k, plan),
        arrays=(_stack_valid(as_), _stack_valid(bs)),
        k=k, rows=2 * k, count_padded=2 * k * p * p,
        count_bytes=count_wire_bytes(p, 2 * k),
    )


def grid_rkeys_measure_spec(spmd: SPMD, ss, rs) -> MeasureSpec:
    """Grid semijoin mark-stage counts: S positional, R the dedup'd key
    projection (masked rows recounted, exactly as the mark stage does)."""
    p = spmd.p
    s0, r0 = ss[0], rs[0]
    g_s, g_r = _grid_shares([s0.cap * s0.p, r0.cap * r0.p], p)
    plan = _grid_pair_plan(g_s, g_r, s0.cap, r0.cap)
    shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
    rd, rv = _stack(rs)
    rk = _key_array([r.cols(sh) for r, sh in zip(rs, shareds)], p)
    k = len(ss)
    return MeasureSpec(
        tag="grid_rkeys",
        entry=("grid_rkeys", k, plan),
        arrays=(_stack_valid(ss), rd, rv, rk),
        k=k, rows=2 * k, count_padded=2 * k * p * p,
        count_bytes=count_wire_bytes(p, 2 * k),
    )


def _measure_round_shard(*arrays, entries, p, backend):
    """Per-shard body of the combined pre-pass: every group's local
    per-destination counts are computed with the SAME destination logic
    as its payload/legacy measure, concatenated into one ``(m, p)``
    block, and shipped over ONE ``all_to_all`` (split/concat on the
    count-vector axis — each shard receives column s from sender s).

    Returns ``(local_counts (m, p), recv_totals (m,), join_counts (j,))``
    — the first two exactly the ``(out, recv.sum())`` pair
    ``shuffle.exchange_counts`` yields per instance (so the host-side
    finalizers are shared with the legacy per-group dispatches), the
    last this shard's per-destination join output counts for every
    ``join_pair`` spec (empty when the stage has none)."""
    blocks = []
    jblocks = []
    i = 0
    for e in entries:
        tag = e[0]
        if tag == "pair":
            _, k, dedup_b = e
            ad, av, bd, bv, seed, ak, bk = arrays[i : i + 7]
            i += 7

            def pair_one(ad, av, bd, bv, seed, ak, bk, _dd=dedup_b):
                da = _dests(_take(ad, ak), av, p, seed, backend)
                bkeys = _take(bd, bk)
                bv2 = (
                    local_dedup_mask(bkeys, bv, tuple(range(bk.shape[0])))
                    if _dd
                    else bv
                )
                db = _dests(bkeys, bv2, p, seed, backend)
                return bucket_counts(da, p), bucket_counts(db, p)

            oa, ob = jax.vmap(pair_one)(ad, av, bd, bv, seed, ak, bk)
            blocks += [oa, ob]
        elif tag == "join_pair":
            _, k, g_a, g_b, jfmt, keyed = e
            ad, av, bd, bv, seed, ak, bk = arrays[i : i + 7]
            i += 7

            def jp_one(ad, av, bd, bv, seed, ak, bk,
                       _ga=g_a, _gb=g_b, _fmt=jfmt, _keyed=keyed):
                akeys = _take(ad, ak)
                da = _dests(akeys, av, p, seed, backend)
                bkeys = _take(bd, bk)
                db = _dests(bkeys, bv, p, seed, backend)
                if _fmt is not None:
                    # packed: _measure_keys picks the actual bit-packed
                    # key projection (narrow keys, exact count) or a
                    # JOIN_HASH_BITS-bit hash (wide keys, sound
                    # over-count); one segmented collective either way
                    sa, sb, kc, sfmt = _measure_keys(
                        akeys, bkeys, ak, bk, seed, _fmt
                    )
                    aw, _sa, _dsa = exchange_start(
                        sa, av, da, p=p, c_out=_ga, fmt=sfmt
                    )
                    bw, _sb, _dsb = exchange_start(
                        sb, bv, db, p=p, c_out=_gb, fmt=sfmt
                    )
                    aw2, bw2 = ship_segments([aw, bw])
                    a2, a2v, _ = exchange_finish(
                        aw2, p=p, c_out=_ga, cap_recv=p * _ga, fmt=sfmt
                    )
                    b2, b2v, _ = exchange_finish(
                        bw2, p=p, c_out=_gb, cap_recv=p * _gb, fmt=sfmt
                    )
                else:
                    # dense: a single hashed-key column stands in for the
                    # nk-wide projection: equal keys keep equal hashes
                    # (and equal destinations), so the exchanged count can
                    # only over-count — a sound out_need at width-1 cost
                    sa = jax.lax.bitcast_convert_type(
                        hash_columns(akeys, tuple(range(ak.shape[0])), seed),
                        jnp.int32,
                    )[:, None]
                    sb = jax.lax.bitcast_convert_type(
                        hash_columns(bkeys, tuple(range(bk.shape[0])), seed),
                        jnp.int32,
                    )[:, None]
                    kc = (0,)
                    a2, a2v, *_ = exchange(
                        sa, av, da, p=p, c_out=_ga, cap_recv=p * _ga
                    )
                    b2, b2v, *_ = exchange(
                        sb, bv, db, p=p, c_out=_gb, cap_recv=p * _gb
                    )
                jc = local_join_count(a2, a2v, b2, b2v, kc, kc, backend)
                return bucket_counts(da, p), bucket_counts(db, p), jc

            oa, ob, jc = jax.vmap(jp_one)(ad, av, bd, bv, seed, ak, bk)
            blocks += [oa, ob]
            jblocks.append(jc)
        elif tag == "single":
            _, k = e
            d, v, seed, cols = arrays[i : i + 4]
            i += 4

            def single_one(d, v, seed, cols):
                return bucket_counts(
                    _dests(_take(d, cols), v, p, seed, backend), p
                )

            blocks.append(jax.vmap(single_one)(d, v, seed, cols))
        elif tag == "grid_pair":
            _, k, plan = e
            gav, gbv = arrays[i : i + 2]
            i += 2

            def grid_one(av, bv, _plan=plan):
                da, db = _grid_pair_dests(av, bv, p=p, **dict(_plan))
                return bucket_counts(da, p), bucket_counts(db, p)

            oa, ob = jax.vmap(grid_one)(gav, gbv)
            blocks += [oa, ob]
        else:  # grid_rkeys
            _, k, plan = e
            sv, rd, rv, rk = arrays[i : i + 4]
            i += 4

            def grkeys_one(sv, rd, rv, rk, _plan=plan):
                rkeys = _take(rd, rk)
                rkv = local_dedup_mask(rkeys, rv, tuple(range(rk.shape[0])))
                da, db = _grid_pair_dests(sv, rkv, p=p, **dict(_plan))
                return bucket_counts(da, p), bucket_counts(db, p)

            oa, ob = jax.vmap(grkeys_one)(sv, rd, rv, rk)
            blocks += [oa, ob]
    cnts = jnp.concatenate(blocks, axis=0)  # (m, p)
    recv = jax.lax.all_to_all(
        cnts, AXIS, split_axis=1, concat_axis=1, tiled=False
    )
    jcnt = (
        jnp.concatenate(jblocks, axis=0)
        if jblocks
        else jnp.zeros((0,), jnp.int32)
    )
    return cnts, recv.sum(axis=1), jcnt


def _finalize_spec(
    spec: MeasureSpec, cnts: np.ndarray, recv: np.ndarray, off: int, p: int,
    jcnt: Optional[np.ndarray] = None, joff: int = 0,
) -> GroupMeasure:
    """Slice one spec's rows out of the fetched combined counts and
    reproduce the exact host-side semantics of its legacy measure."""
    k = spec.k
    if spec.tag == "single":
        o, r = cnts[:, off : off + k, :], recv[:, off : off + k]
        caps = SideCaps.from_counts(o, r)
        return GroupMeasure(
            lhs=caps, out_recv=caps.cap_recv, padded=spec.count_padded,
            wire_bytes=spec.count_bytes,
        )
    oa, ra = cnts[:, off : off + k, :], recv[:, off : off + k]
    ob, rb = cnts[:, off + k : off + 2 * k, :], recv[:, off + k : off + 2 * k]
    if spec.tag in ("pair", "join_pair"):
        m = _finalize_pair_counts(
            oa, ra, ob, rb, p=p,
            count_padded=spec.count_padded,
            count_bytes=spec.count_bytes,
            skew_threshold=spec.skew_threshold,
        )
        if spec.tag == "join_pair":
            # trust the fused output count only when the counts prove
            # the hashed-key exchanges held every send (guess capacity
            # not exceeded) — otherwise out_need stays None and the
            # executor falls back to the exact join_need_many dispatch
            _, _, g_a, g_b, _jfmt, _keyed = spec.entry
            if int(oa.max()) <= g_a and int(ob.max()) <= g_b:
                jc = jcnt[:, joff : joff + spec.join_rows]
                m = dataclasses.replace(
                    m, out_need=pow2(max(1, int(jc.max())))
                )
        return m
    # grid variants: positional routing, no heavy-destination surface
    return GroupMeasure(
        lhs=SideCaps.from_counts(oa, ra),
        rhs=SideCaps.from_counts(ob, rb),
        padded=spec.count_padded,
        wire_bytes=spec.count_bytes,
    )


class RoundCounts:
    """Handle over ONE combined count dispatch covering every measuring
    op group of a round stage.

    Construction launches the dispatch and returns immediately — the
    results are JAX futures, so the executor can issue it while the
    previous round's payload exchanges are still in flight (measure
    prefetch).  ``fetch()`` performs the round's SINGLE
    ``jax.device_get`` (the one host sync of the whole measure path);
    ``measures()`` finalizes every group from the fetched block."""

    def __init__(self, spmd: SPMD, specs: Sequence[MeasureSpec], *,
                 backend: str = "jnp"):
        self.spmd = spmd
        self.specs = list(specs)
        self.p = spmd.p
        arrays: List[jax.Array] = []
        entries = []
        for s in self.specs:
            entries.append(s.entry)
            arrays.extend(s.arrays)
        self._cnts, self._recv, self._jcnt = spmd.run(
            _measure_round_shard, *arrays,
            entries=tuple(entries), p=spmd.p, backend=backend,
            donate=tuple(range(len(arrays))),
            measure=True,
        )
        self._host: Optional[
            Tuple[np.ndarray, np.ndarray, np.ndarray]
        ] = None

    @property
    def count_padded(self) -> int:
        return sum(s.count_padded for s in self.specs)

    @property
    def count_bytes(self) -> int:
        return sum(s.count_bytes for s in self.specs)

    def fetch(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self._host is None:
            self._host = jax.device_get(
                (self._cnts, self._recv, self._jcnt)
            )
        return self._host

    def measures(self) -> List[GroupMeasure]:
        cnts, recv, jcnt = self.fetch()
        out = []
        off = 0
        joff = 0
        for s in self.specs:
            out.append(
                _finalize_spec(s, cnts, recv, off, self.p, jcnt, joff)
            )
            off += s.rows
            joff += s.join_rows
        return out


def _join_need_round_shard(*arrays, entries, p, backend):
    """Per-shard body of the fused join output-count pass: every join
    group's keys-only exchange (at its already-calibrated capacities)
    plus exact local join count, concatenated — one dispatch per round
    stage instead of one per join group."""
    outs = []
    i = 0
    for e in entries:
        if e[0] == "hash":
            _, k, coa, cob, ca, cb, fmt = e
            ad, av, bd, bv, seed, ak, bk = arrays[i : i + 7]
            i += 7
            one = functools.partial(
                _join_count_one, p=p, c_out_a=coa, c_out_b=cob,
                cap_a=ca, cap_b=cb, fmt=fmt, backend=backend,
            )
            outs.append(jax.vmap(one)(ad, av, bd, bv, seed, ak, bk))
        else:  # hybrid placement
            _, k, coa, cob, ca, cb, swap, fmt = e
            ad, av, bd, bv, seed, ak, bk, hw = arrays[i : i + 8]
            i += 8
            one = functools.partial(
                _hybrid_join_count_one, p=p, c_out_a=coa, c_out_b=cob,
                cap_a=ca, cap_b=cb, swap=swap, fmt=fmt, backend=backend,
            )
            outs.append(jax.vmap(one)(ad, av, bd, bv, seed, ak, bk, hw))
    return jnp.concatenate(outs, axis=0)  # (sum_k,) per shard


def join_need_many(
    spmd: SPMD,
    items: Sequence[Tuple[Sequence[DTable], Sequence[DTable], Sequence[int], GroupMeasure]],
    *,
    fmts: Optional[Sequence[Optional[WireFormat]]] = None,
    backend: str = "jnp",
) -> List[GroupMeasure]:
    """ONE dispatch computing the exact join-output requirement for EVERY
    join group of a round stage; each returned measure carries
    ``out_need`` with the keys-only exchange priced into ``padded`` —
    identical numbers to ``measure_join_many``'s per-group tail.

    ``fmts`` (one shared-key ``WireFormat`` or None per item) packs the
    keys-only exchanges with the ``_measure_keys`` policy — the same
    wire the fused pre-count would have used."""
    p = spmd.p
    if fmts is None:
        fmts = [None] * len(items)
    arrays: List[jax.Array] = []
    entries = []
    nks = []
    for (as_, bs, seeds, m), fmt in zip(items, fmts):
        shareds = [
            [x for x in a.schema if x in b.schema] for a, b in zip(as_, bs)
        ]
        a_keys = [a.cols(sh) for a, sh in zip(as_, shareds)]
        b_keys = [b.cols(sh) for b, sh in zip(bs, shareds)]
        nks.append(len(a_keys[0]))
        ad, av = _stack(as_)
        bd, bv = _stack(bs)
        base = (
            ad, av, bd, bv, _seed_array(seeds, p),
            _key_array(a_keys, p), _key_array(b_keys, p),
        )
        if m.hybrid_routed:
            entries.append((
                "hybrid", len(as_), m.lhs.c_out, m.rhs.c_out,
                m.lhs.cap_recv, m.rhs.cap_recv, m.swap_spread, fmt,
            ))
            arrays.extend(base + (_heavy_array(m.heavy, p),))
        else:
            entries.append((
                "hash", len(as_), m.lhs.c_out, m.rhs.c_out,
                m.lhs.cap_recv, m.rhs.cap_recv, fmt,
            ))
            arrays.extend(base)
    cnt = np.asarray(spmd.run(
        _join_need_round_shard, *arrays,
        entries=tuple(entries), p=p, backend=backend,
        donate=tuple(range(len(arrays))),
        measure=True,
    ))  # (p, sum_k)
    out = []
    off = 0
    for (as_, bs, seeds, m), e, nk, fmt in zip(items, entries, nks, fmts):
        k = e[1]
        c = cnt[:, off : off + k]
        off += k
        if fmt is not None:
            # the shipped format after the _measure_keys policy: actual
            # keys when narrow enough, the JOIN_HASH_BITS hash otherwise
            sfmt = (
                fmt if fmt.row_bits <= _JOIN_HASH_FMT.row_bits
                else _JOIN_HASH_FMT
            )
            pad_x = k * (
                padded_slots(p, m.lhs.c_out, sfmt.arity)
                + padded_slots(p, m.rhs.c_out, sfmt.arity)
            )
            byt_x = k * (
                packed_wire_bytes(p, m.lhs.c_out, sfmt)
                + packed_wire_bytes(p, m.rhs.c_out, sfmt)
            )
        else:
            pad_x = k * (
                padded_slots(p, m.lhs.c_out, nk)
                + padded_slots(p, m.rhs.c_out, nk)
            )
            byt_x = k * (
                dense_wire_bytes(p, m.lhs.c_out, nk)
                + dense_wire_bytes(p, m.rhs.c_out, nk)
            )
        out.append(dataclasses.replace(
            m,
            out_need=pow2(max(1, int(c.max()))),
            padded=m.padded + pad_x,
            wire_bytes=m.wire_bytes + byt_x,
        ))
    return out


# ------------------------------------------------------------ hash semijoin
def _semijoin_one(sd, sv, rd, rv, seed, sk, rk, *,
                  p, c_out_s, c_out_r, cap_s, cap_r,
                  fmt_s=None, fmt_r=None, backend):
    nk = rk.shape[0]
    kcols = tuple(range(nk))
    # ship only the deduplicated key projection of R (as in ops._semijoin_shard)
    rkeys = _take(rd, rk)
    rkv = local_dedup_mask(rkeys, rv, kcols)
    rkeys = jnp.where(rkv[:, None], rkeys, 0)
    rdest = _dests(rkeys, rkv, p, seed, backend)
    sdest = _dests(_take(sd, sk), sv, p, seed, backend)
    if fmt_s is not None and fmt_r is not None:
        # packed: both sides encode, concatenate into ONE segmented
        # buffer, ship a single all_to_all, then decode per side.  Under
        # the group vmap this collective fuses across the k instances.
        rwire, sent_r, dsr = exchange_start(
            rkeys, rkv, rdest, p=p, c_out=c_out_r, fmt=fmt_r
        )
        swire, sent_s, dss = exchange_start(
            sd, sv, sdest, p=p, c_out=c_out_s, fmt=fmt_s
        )
        rw2, sw2 = ship_segments([rwire, swire])
        rk2, rkv2, drr = exchange_finish(
            rw2, p=p, c_out=c_out_r, cap_recv=cap_r, fmt=fmt_r
        )
        s2, s2v, drs = exchange_finish(
            sw2, p=p, c_out=c_out_s, cap_recv=cap_s, fmt=fmt_s
        )
    else:
        rk2, rkv2, sent_r, dsr, drr = exchange(
            rkeys, rkv, rdest, p=p, c_out=c_out_r, cap_recv=cap_r
        )
        s2, s2v, sent_s, dss, drs = exchange(
            sd, sv, sdest, p=p, c_out=c_out_s, cap_recv=cap_s
        )
    rkv2 = local_dedup_mask(rk2, rkv2, kcols)
    mask = local_semijoin_mask(_take(s2, sk), s2v, kcols, rk2, rkv2, kcols, backend)
    s2 = jnp.where(mask[:, None], s2, 0)
    ub = 4 * (nk * sent_r + sd.shape[1] * sent_s)  # dense int32 bytes occupied
    return s2, mask, sent_r + sent_s, dsr + drr + dss + drs, ub


def _semijoin_shard_b(sd, sv, rd, rv, seed, sk, rk, *,
                      p, c_out_s, c_out_r, cap_s, cap_r,
                      fmt_s=None, fmt_r=None, backend):
    one = functools.partial(
        _semijoin_one, p=p, c_out_s=c_out_s, c_out_r=c_out_r,
        cap_s=cap_s, cap_r=cap_r, fmt_s=fmt_s, fmt_r=fmt_r, backend=backend,
    )
    return jax.vmap(one)(sd, sv, rd, rv, seed, sk, rk)


def dist_semijoin_many(
    spmd: SPMD,
    ss: Sequence[DTable],
    rs: Sequence[DTable],
    *,
    seeds: Sequence[int],
    cap_recv: Tuple[int, int],
    c_out: Optional[Tuple[int, int]] = None,
    fmts: Optional[Tuple] = None,  # (fmt_s, fmt_r) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold S_i |>< R_i in ONE dispatch; semantics of ``dist_semijoin``."""
    p = spmd.p
    shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
    assert all(shareds), "semijoin with no shared attrs in batch"
    c_out = c_out or (ss[0].cap, rs[0].cap)
    fmt_s, fmt_r = fmts if fmts is not None else (None, None)
    sd, sv = _stack(ss)
    rd, rv = _stack(rs)
    sk = _key_array([s.cols(sh) for s, sh in zip(ss, shareds)], p)
    rk = _key_array([r.cols(sh) for r, sh in zip(rs, shareds)], p)
    od, ov, sent, dropped, ub = spmd.run(
        _semijoin_shard_b,
        sd, sv, rd, rv, _seed_array(seeds, p), sk, rk,
        p=p, c_out_s=c_out[0], c_out_r=c_out[1],
        cap_s=cap_recv[0], cap_r=cap_recv[1],
        fmt_s=fmt_s, fmt_r=fmt_r, backend=backend,
        donate=(0, 1, 2, 3),
    )
    return _unstack(od, ov, [s.schema for s in ss]), _per_op_stats(
        sent, dropped,
        # S ships full rows; R ships its deduplicated key projection
        padded_slots(p, c_out[0], ss[0].arity)
        + padded_slots(p, c_out[1], len(shareds[0])),
        wire_bytes=_xbytes(p, c_out[0], ss[0].arity, fmt_s)
        + _xbytes(p, c_out[1], len(shareds[0]), fmt_r),
        ubytes=ub,
    )


# ---------------------------------------------------------------- hash join
def _join_one(ad, av, bd, bv, seed, ak, bk, bkeep, *,
              p, c_out_a, c_out_b, cap_a, cap_b, out_cap,
              fmt_a=None, fmt_b=None, backend):
    nk = ak.shape[0]
    kcols = tuple(range(nk))
    adest = _dests(_take(ad, ak), av, p, seed, backend)
    bdest = _dests(_take(bd, bk), bv, p, seed, backend)
    if fmt_a is not None and fmt_b is not None:
        awire, sent_a, dsa = exchange_start(
            ad, av, adest, p=p, c_out=c_out_a, fmt=fmt_a
        )
        bwire, sent_b, dsb = exchange_start(
            bd, bv, bdest, p=p, c_out=c_out_b, fmt=fmt_b
        )
        aw2, bw2 = ship_segments([awire, bwire])
        a2, a2v, dra = exchange_finish(
            aw2, p=p, c_out=c_out_a, cap_recv=cap_a, fmt=fmt_a
        )
        b2, b2v, drb = exchange_finish(
            bw2, p=p, c_out=c_out_b, cap_recv=cap_b, fmt=fmt_b
        )
    else:
        a2, a2v, sent_a, dsa, dra = exchange(
            ad, av, adest, p=p, c_out=c_out_a, cap_recv=cap_a
        )
        b2, b2v, sent_b, dsb, drb = exchange(
            bd, bv, bdest, p=p, c_out=c_out_b, cap_recv=cap_b
        )
    ra, rb = dense_ranks(_take(a2, ak), a2v, kcols, _take(b2, bk), b2v, kcols)
    out, out_v, over = local_join_ranked(
        a2, a2v, ra, b2, b2v, rb, bkeep, out_cap, backend
    )
    ub = 4 * (ad.shape[1] * sent_a + bd.shape[1] * sent_b)
    return out, out_v, sent_a + sent_b, dsa + dra + dsb + drb + over, ub


def _join_shard_b(ad, av, bd, bv, seed, ak, bk, bkeep, *,
                  p, c_out_a, c_out_b, cap_a, cap_b, out_cap,
                  fmt_a=None, fmt_b=None, backend):
    one = functools.partial(
        _join_one, p=p, c_out_a=c_out_a, c_out_b=c_out_b,
        cap_a=cap_a, cap_b=cap_b, out_cap=out_cap,
        fmt_a=fmt_a, fmt_b=fmt_b, backend=backend,
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, ak, bk, bkeep)


def dist_join_many(
    spmd: SPMD,
    as_: Sequence[DTable],
    bs: Sequence[DTable],
    *,
    seeds: Sequence[int],
    out_cap: int,
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    fmts: Optional[Tuple] = None,  # (fmt_a, fmt_b) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold A_i |><| B_i in ONE dispatch; semantics of ``dist_join``."""
    p = spmd.p
    shareds = [[x for x in a.schema if x in b.schema] for a, b in zip(as_, bs)]
    # DYM rounds only join GHD-adjacent nodes, which share attributes, so
    # attribute-disjoint pairs cannot arrive here via the planner (a fully
    # disconnected query already fails the upstream semijoin assert); the
    # cross-join case is served by sequential dist_join's broadcast plan
    assert all(shareds), "attribute-disjoint join in batch; use dist_join"
    keeps = [
        tuple(i for i, x in enumerate(b.schema) if x not in set(a.schema))
        for a, b in zip(as_, bs)
    ]
    schemas = [schema_join(a.schema, b.schema) for a, b in zip(as_, bs)]
    c_out = c_out or (as_[0].cap, bs[0].cap)
    cap_recv = cap_recv or (p * as_[0].cap, p * bs[0].cap)
    fmt_a, fmt_b = fmts if fmts is not None else (None, None)
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    ak = _key_array([a.cols(sh) for a, sh in zip(as_, shareds)], p)
    bk = _key_array([b.cols(sh) for b, sh in zip(bs, shareds)], p)
    bkeep = _key_array(keeps, p)
    od, ov, sent, dropped, ub = spmd.run(
        _join_shard_b,
        ad, av, bd, bv, _seed_array(seeds, p), ak, bk, bkeep,
        p=p, c_out_a=c_out[0], c_out_b=c_out[1],
        cap_a=cap_recv[0], cap_b=cap_recv[1], out_cap=out_cap,
        fmt_a=fmt_a, fmt_b=fmt_b, backend=backend,
        donate=(0, 1, 2, 3),
    )
    return _unstack(od, ov, schemas), _per_op_stats(
        sent, dropped,
        padded_slots(p, c_out[0], as_[0].arity)
        + padded_slots(p, c_out[1], bs[0].arity),
        wire_bytes=_xbytes(p, c_out[0], as_[0].arity, fmt_a)
        + _xbytes(p, c_out[1], bs[0].arity, fmt_b),
        ubytes=ub,
    )


# ------------------------------------------- hybrid (heavy-hitter) semijoin
def _hybrid_semijoin_one(sd, sv, rd, rv, seed, sk, rk, hw, *,
                         p, c_out_s, c_out_r, cap_s, cap_r,
                         fmt_s=None, fmt_r=None, backend):
    """``_semijoin_one`` with hybrid routing: S (the output side) spread,
    R's deduplicated key projection broadcast for heavy keys.  An S row
    lands on exactly one reducer either way, and every R key it can match
    is present there (hash-co-located for light keys, broadcast for
    heavy), so the mask — and the output row set — is identical to the
    hash semijoin's."""
    nk = rk.shape[0]
    kcols = tuple(range(nk))
    rkeys = _take(rd, rk)
    rkv = local_dedup_mask(rkeys, rv, kcols)
    rkeys = jnp.where(rkv[:, None], rkeys, 0)
    rk2, rkv2, sent_r, dr_r, hvy_r = _hybrid_exchange(
        rkeys, rkv, _dests(rkeys, rkv, p, seed, backend), hw,
        p=p, c_out=c_out_r, cap_recv=cap_r, spread=False, fmt=fmt_r,
    )
    rkv2 = local_dedup_mask(rk2, rkv2, kcols)
    s2, s2v, sent_s, dr_s, hvy_s = _hybrid_exchange(
        sd, sv, _dests(_take(sd, sk), sv, p, seed, backend), hw,
        p=p, c_out=c_out_s, cap_recv=cap_s, spread=True, fmt=fmt_s,
    )
    mask = local_semijoin_mask(_take(s2, sk), s2v, kcols, rk2, rkv2, kcols, backend)
    s2 = jnp.where(mask[:, None], s2, 0)
    ub = 4 * (nk * sent_r + sd.shape[1] * sent_s)
    return s2, mask, sent_r + sent_s, dr_r + dr_s, hvy_s + hvy_r, ub


def _hybrid_semijoin_shard_b(sd, sv, rd, rv, seed, sk, rk, hw, *,
                             p, c_out_s, c_out_r, cap_s, cap_r,
                             fmt_s=None, fmt_r=None, backend):
    one = functools.partial(
        _hybrid_semijoin_one, p=p, c_out_s=c_out_s, c_out_r=c_out_r,
        cap_s=cap_s, cap_r=cap_r, fmt_s=fmt_s, fmt_r=fmt_r, backend=backend,
    )
    return jax.vmap(one)(sd, sv, rd, rv, seed, sk, rk, hw)


def hybrid_semijoin_many(
    spmd: SPMD,
    ss: Sequence[DTable],
    rs: Sequence[DTable],
    *,
    seeds: Sequence[int],
    heavy: np.ndarray,  # (k, p) per-instance heavy-destination flags
    cap_recv: Tuple[int, int],
    c_out: Optional[Tuple[int, int]] = None,
    fmts: Optional[Tuple] = None,  # (fmt_s, fmt_r) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold skew-resilient S_i |>< R_i in ONE dispatch: light keys hash,
    heavy keys spread/broadcast (``relational.skew``).  Same row sets as
    ``dist_semijoin_many``; stats carry the extra ``'heavy'`` count of
    tuple-sends routed through the heavy path."""
    p = spmd.p
    shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
    assert all(shareds), "semijoin with no shared attrs in batch"
    # a row reaches each destination at most once, so the worst-case send
    # bucket is the shard cap even for the broadcast side
    c_out = c_out or (ss[0].cap, rs[0].cap)
    fmt_s, fmt_r = fmts if fmts is not None else (None, None)
    sd, sv = _stack(ss)
    rd, rv = _stack(rs)
    sk = _key_array([s.cols(sh) for s, sh in zip(ss, shareds)], p)
    rk = _key_array([r.cols(sh) for r, sh in zip(rs, shareds)], p)
    od, ov, sent, dropped, hvy, ub = spmd.run(
        _hybrid_semijoin_shard_b,
        sd, sv, rd, rv, _seed_array(seeds, p), sk, rk, _heavy_array(heavy, p),
        p=p, c_out_s=c_out[0], c_out_r=c_out[1],
        cap_s=cap_recv[0], cap_r=cap_recv[1],
        fmt_s=fmt_s, fmt_r=fmt_r, backend=backend,
        donate=(0, 1, 2, 3),
    )
    return _unstack(od, ov, [s.schema for s in ss]), _per_op_stats(
        sent, dropped,
        padded_slots(p, c_out[0], ss[0].arity)
        + padded_slots(p, c_out[1], len(shareds[0])),
        heavy=hvy,
        wire_bytes=_xbytes(p, c_out[0], ss[0].arity, fmt_s)
        + _xbytes(p, c_out[1], len(shareds[0]), fmt_r),
        ubytes=ub,
    )


# ----------------------------------------------- hybrid (heavy-hitter) join
def _hybrid_join_one(ad, av, bd, bv, seed, ak, bk, bkeep, hw, *,
                     p, c_out_a, c_out_b, cap_a, cap_b, out_cap, swap,
                     fmt_a=None, fmt_b=None, backend):
    """``_join_one`` with hybrid routing: one side spread, the other
    broadcast for heavy keys (``swap`` picks which — the measure spreads
    the heavier side).  A heavy pair (a, b) meets exactly once — at the
    unique reducer holding the spread copy (the broadcast copy is
    everywhere); light pairs meet at ``hash(key)`` as before; heavy and
    light keys cannot cross-match because heaviness is a function of the
    key."""
    kcols = tuple(range(ak.shape[0]))
    a2, a2v, sent_a, dr_a, hvy_a = _hybrid_exchange(
        ad, av, _dests(_take(ad, ak), av, p, seed, backend), hw,
        p=p, c_out=c_out_a, cap_recv=cap_a, spread=not swap, fmt=fmt_a,
    )
    b2, b2v, sent_b, dr_b, hvy_b = _hybrid_exchange(
        bd, bv, _dests(_take(bd, bk), bv, p, seed, backend), hw,
        p=p, c_out=c_out_b, cap_recv=cap_b, spread=swap, fmt=fmt_b,
    )
    ra, rb = dense_ranks(_take(a2, ak), a2v, kcols, _take(b2, bk), b2v, kcols)
    out, out_v, over = local_join_ranked(
        a2, a2v, ra, b2, b2v, rb, bkeep, out_cap, backend
    )
    ub = 4 * (ad.shape[1] * sent_a + bd.shape[1] * sent_b)
    return out, out_v, sent_a + sent_b, dr_a + dr_b + over, hvy_a + hvy_b, ub


def _hybrid_join_shard_b(ad, av, bd, bv, seed, ak, bk, bkeep, hw, *,
                         p, c_out_a, c_out_b, cap_a, cap_b, out_cap, swap,
                         fmt_a=None, fmt_b=None, backend):
    one = functools.partial(
        _hybrid_join_one, p=p, c_out_a=c_out_a, c_out_b=c_out_b,
        cap_a=cap_a, cap_b=cap_b, out_cap=out_cap, swap=swap,
        fmt_a=fmt_a, fmt_b=fmt_b, backend=backend,
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, ak, bk, bkeep, hw)


def hybrid_join_many(
    spmd: SPMD,
    as_: Sequence[DTable],
    bs: Sequence[DTable],
    *,
    seeds: Sequence[int],
    out_cap: int,
    heavy: np.ndarray,  # (k, p) per-instance heavy-destination flags
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    swap: bool = False,  # True: spread B / broadcast A (GroupMeasure.swap_spread)
    fmts: Optional[Tuple] = None,  # (fmt_a, fmt_b) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold skew-resilient A_i |><| B_i in ONE dispatch; same row sets
    as ``dist_join_many`` with heavy keys routed spread/broadcast."""
    p = spmd.p
    shareds = [[x for x in a.schema if x in b.schema] for a, b in zip(as_, bs)]
    assert all(shareds), "attribute-disjoint join in batch; use dist_join"
    keeps = [
        tuple(i for i, x in enumerate(b.schema) if x not in set(a.schema))
        for a, b in zip(as_, bs)
    ]
    schemas = [schema_join(a.schema, b.schema) for a, b in zip(as_, bs)]
    c_out = c_out or (as_[0].cap, bs[0].cap)
    cap_recv = cap_recv or (p * as_[0].cap, p * bs[0].cap)
    fmt_a, fmt_b = fmts if fmts is not None else (None, None)
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    ak = _key_array([a.cols(sh) for a, sh in zip(as_, shareds)], p)
    bk = _key_array([b.cols(sh) for b, sh in zip(bs, shareds)], p)
    bkeep = _key_array(keeps, p)
    od, ov, sent, dropped, hvy, ub = spmd.run(
        _hybrid_join_shard_b,
        ad, av, bd, bv, _seed_array(seeds, p), ak, bk, bkeep,
        _heavy_array(heavy, p),
        p=p, c_out_a=c_out[0], c_out_b=c_out[1],
        cap_a=cap_recv[0], cap_b=cap_recv[1], out_cap=out_cap, swap=swap,
        fmt_a=fmt_a, fmt_b=fmt_b, backend=backend,
        donate=(0, 1, 2, 3),
    )
    return _unstack(od, ov, schemas), _per_op_stats(
        sent, dropped,
        padded_slots(p, c_out[0], as_[0].arity)
        + padded_slots(p, c_out[1], bs[0].arity),
        heavy=hvy,
        wire_bytes=_xbytes(p, c_out[0], as_[0].arity, fmt_a)
        + _xbytes(p, c_out[1], bs[0].arity, fmt_b),
        ubytes=ub,
    )


# ----------------------------------------------------------- hash intersect
def _intersect_one(ad, av, bd, bv, seed, bcols, *,
                   p, c_out_a, c_out_b, cap_a, cap_b,
                   fmt_a=None, fmt_b=None, backend):
    acols = tuple(range(ad.shape[1]))
    adest = _dests(ad, av, p, seed, backend)
    bdest = _dests(_take(bd, bcols), bv, p, seed, backend)
    if fmt_a is not None and fmt_b is not None:
        awire, sent_a, dsa = exchange_start(
            ad, av, adest, p=p, c_out=c_out_a, fmt=fmt_a
        )
        bwire, sent_b, dsb = exchange_start(
            bd, bv, bdest, p=p, c_out=c_out_b, fmt=fmt_b
        )
        aw2, bw2 = ship_segments([awire, bwire])
        a2, a2v, dra = exchange_finish(
            aw2, p=p, c_out=c_out_a, cap_recv=cap_a, fmt=fmt_a
        )
        b2, b2v, drb = exchange_finish(
            bw2, p=p, c_out=c_out_b, cap_recv=cap_b, fmt=fmt_b
        )
    else:
        a2, a2v, sent_a, dsa, dra = exchange(
            ad, av, adest, p=p, c_out=c_out_a, cap_recv=cap_a
        )
        b2, b2v, sent_b, dsb, drb = exchange(
            bd, bv, bdest, p=p, c_out=c_out_b, cap_recv=cap_b
        )
    mask = local_semijoin_mask(a2, a2v, acols, _take(b2, bcols), b2v, acols, backend)
    a2 = jnp.where(mask[:, None], a2, 0)
    ub = 4 * (ad.shape[1] * sent_a + bd.shape[1] * sent_b)
    return a2, mask, sent_a + sent_b, dsa + dra + dsb + drb, ub


def _intersect_shard_b(ad, av, bd, bv, seed, bcols, *,
                       p, c_out_a, c_out_b, cap_a, cap_b,
                       fmt_a=None, fmt_b=None, backend):
    one = functools.partial(
        _intersect_one, p=p, c_out_a=c_out_a, c_out_b=c_out_b,
        cap_a=cap_a, cap_b=cap_b, fmt_a=fmt_a, fmt_b=fmt_b, backend=backend,
    )
    return jax.vmap(one)(ad, av, bd, bv, seed, bcols)


def dist_intersect_many(
    spmd: SPMD,
    as_: Sequence[DTable],
    bs: Sequence[DTable],
    *,
    seeds: Sequence[int],
    cap_recv: Tuple[int, int],
    c_out: Optional[Tuple[int, int]] = None,
    fmts: Optional[Tuple] = None,  # (fmt_a, fmt_b) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold A_i ^ B_i (same attr sets) in ONE dispatch."""
    p = spmd.p
    for a, b in zip(as_, bs):
        assert set(a.schema) == set(b.schema), (a.schema, b.schema)
    c_out = c_out or (as_[0].cap, bs[0].cap)
    fmt_a, fmt_b = fmts if fmts is not None else (None, None)
    ad, av = _stack(as_)
    bd, bv = _stack(bs)
    bcols = _key_array([b.cols(a.schema) for a, b in zip(as_, bs)], p)
    od, ov, sent, dropped, ub = spmd.run(
        _intersect_shard_b,
        ad, av, bd, bv, _seed_array(seeds, p), bcols,
        p=p, c_out_a=c_out[0], c_out_b=c_out[1],
        cap_a=cap_recv[0], cap_b=cap_recv[1],
        fmt_a=fmt_a, fmt_b=fmt_b, backend=backend,
        donate=(0, 1, 2, 3),
    )
    return _unstack(od, ov, [a.schema for a in as_]), _per_op_stats(
        sent, dropped,
        padded_slots(p, c_out[0], as_[0].arity)
        + padded_slots(p, c_out[1], bs[0].arity),
        wire_bytes=_xbytes(p, c_out[0], as_[0].arity, fmt_a)
        + _xbytes(p, c_out[1], bs[0].arity, fmt_b),
        ubytes=ub,
    )


# --------------------------------------------------------------- hash dedup
def _dedup_one(d, v, seed, *, p, c_out, cap_recv, fmt=None, backend):
    d2, v2, sent, ds, dr = exchange(
        d, v, _dests(d, v, p, seed, backend),
        p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt,
    )
    mask = local_dedup_mask(d2, v2, tuple(range(d.shape[1])))
    d2 = jnp.where(mask[:, None], d2, 0)
    return d2, mask, sent, ds + dr


def _dedup_shard_b(d, v, seed, *, p, c_out, cap_recv, fmt=None, backend):
    one = functools.partial(
        _dedup_one, p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt,
        backend=backend,
    )
    return jax.vmap(one)(d, v, seed)


def dist_dedup_many(
    spmd: SPMD,
    ts: Sequence[DTable],
    *,
    seeds: Sequence[int],
    cap_recv: int,
    c_out: Optional[int] = None,
    fmt: Optional[WireFormat] = None,
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    p = spmd.p
    c_out = c_out or ts[0].cap
    d, v = _stack(ts)
    od, ov, sent, dropped = spmd.run(
        _dedup_shard_b, d, v, _seed_array(seeds, p),
        p=p, c_out=c_out, cap_recv=cap_recv, fmt=fmt, backend=backend,
        donate=(0, 1),
    )
    return _unstack(od, ov, [t.schema for t in ts]), _per_op_stats(
        sent, dropped, padded_slots(p, c_out, ts[0].arity),
        wire_bytes=_xbytes(p, c_out, ts[0].arity, fmt),
        # single exchange: useful bytes are 4 * arity * sent, host-side
        ubytes=4 * ts[0].arity * np.asarray(sent),
    )


# ---------------------------------------------- grid semijoin (Lemma 10)
def _grid_semijoin_mark_one(sd, sv, rd, rv, sk, rk, *,
                            g_s, g_r, s_cap, r_cap, p, c_out_s, c_out_r,
                            cap_s, cap_r, fmt_s=None, fmt_r=None, backend):
    nk = rk.shape[0]
    kcols = tuple(range(nk))
    grp_s = _position_groups(sv, g_s, s_cap, p)
    offs_s = jnp.arange(g_r, dtype=jnp.int32)
    dest_s = jnp.where(
        (grp_s < g_s)[:, None], grp_s[:, None] * g_r + offs_s[None, :], p
    ).astype(jnp.int32)
    s2, s2v, sent_s, dss, drs = exchange_multi(
        sd, sv, dest_s, p=p, c_out=c_out_s, cap_recv=cap_s, fmt=fmt_s
    )
    rkeys = _take(rd, rk)
    rkv = local_dedup_mask(rkeys, rv, kcols)
    rkeys = jnp.where(rkv[:, None], rkeys, 0)
    grp_r = _position_groups(rkv, g_r, r_cap, p)
    offs_r = jnp.arange(g_s, dtype=jnp.int32) * g_r
    dest_r = jnp.where(
        (grp_r < g_r)[:, None], grp_r[:, None] + offs_r[None, :], p
    ).astype(jnp.int32)
    r2, r2v, sent_r, dsr, drr = exchange_multi(
        rkeys, rkv, dest_r, p=p, c_out=c_out_r, cap_recv=cap_r, fmt=fmt_r
    )
    mask = local_semijoin_mask(_take(s2, sk), s2v, kcols, r2, r2v, kcols, backend)
    s2 = jnp.where(mask[:, None], s2, 0)
    ub = 4 * (sd.shape[1] * sent_s + nk * sent_r)
    return s2, mask, sent_s + sent_r, dss + drs + dsr + drr, ub


def _grid_semijoin_mark_b(sd, sv, rd, rv, sk, rk, *,
                          g_s, g_r, s_cap, r_cap, p, c_out_s, c_out_r,
                          cap_s, cap_r, fmt_s=None, fmt_r=None, backend):
    one = functools.partial(
        _grid_semijoin_mark_one,
        g_s=g_s, g_r=g_r, s_cap=s_cap, r_cap=r_cap, p=p,
        c_out_s=c_out_s, c_out_r=c_out_r, cap_s=cap_s, cap_r=cap_r,
        fmt_s=fmt_s, fmt_r=fmt_r, backend=backend,
    )
    return jax.vmap(one)(sd, sv, rd, rv, sk, rk)


def grid_semijoin_many(
    spmd: SPMD,
    ss: Sequence[DTable],
    rs: Sequence[DTable],
    *,
    seeds: Sequence[int],
    out_cap: int,
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    fmts: Optional[Tuple] = None,  # (fmt_s, fmt_rkeys) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold Lemma-10 grid semijoin: one MARK dispatch for the whole group
    + one batched hash-dedup dispatch for the marked duplicates (2 claimed
    BSP rounds either way).  ``c_out``/``cap_recv`` (per (S, R-keys) side)
    override the worst-case mark-stage capacities with calibrated ones
    (``measure_grid_semijoin_many``)."""
    p = spmd.p
    s0, r0 = ss[0], rs[0]
    shareds = [[x for x in s.schema if x in r.schema] for s, r in zip(ss, rs)]
    assert all(shareds)
    sz_s, sz_r = s0.cap * s0.p, r0.cap * r0.p
    g_s, g_r = _grid_shares([sz_s, sz_r], p)
    c_out = c_out or (s0.cap * g_r, r0.cap * g_s)
    cap_recv = cap_recv or (-(-sz_s // g_s), -(-sz_r // g_r))
    fmt_s, fmt_r = fmts if fmts is not None else (None, None)
    sd, sv = _stack(ss)
    rd, rv = _stack(rs)
    sk = _key_array([s.cols(sh) for s, sh in zip(ss, shareds)], p)
    rk = _key_array([r.cols(sh) for r, sh in zip(rs, shareds)], p)
    md, mv, sent, dropped, ub = spmd.run(
        _grid_semijoin_mark_b,
        sd, sv, rd, rv, sk, rk,
        g_s=g_s, g_r=g_r, s_cap=s0.cap, r_cap=r0.cap, p=p,
        c_out_s=c_out[0], c_out_r=c_out[1],
        cap_s=cap_recv[0], cap_r=cap_recv[1],
        fmt_s=fmt_s, fmt_r=fmt_r, backend=backend,
        donate=(0, 1, 2, 3),
    )
    marked = _unstack(md, mv, [s.schema for s in ss])
    mark_stats = _per_op_stats(
        sent, dropped,
        padded_slots(p, c_out[0], s0.arity)
        + padded_slots(p, c_out[1], len(shareds[0])),
        wire_bytes=_xbytes(p, c_out[0], s0.arity, fmt_s)
        + _xbytes(p, c_out[1], len(shareds[0]), fmt_r),
        ubytes=ub,
    )
    ded, ded_stats = dist_dedup_many(
        spmd, marked, seeds=[s + 7 for s in seeds],
        c_out=marked[0].cap, cap_recv=out_cap, fmt=fmt_s, backend=backend,
    )
    stats = [
        {
            "sent": m["sent"] + d["sent"],
            "dropped": m["dropped"] + d["dropped"],
            "padded": m["padded"] + d["padded"],
            "wire_bytes": m["wire_bytes"] + d["wire_bytes"],
            "ubytes": m.get("ubytes", 0) + d.get("ubytes", 0),
        }
        for m, d in zip(mark_stats, ded_stats)
    ]
    return ded, stats


# -------------------------------------------------- grid join (Lemma 8, w=2)
def _grid_send_shard_b(data, valid, *, g_self, stride, offsets, p, cap, c_out,
                       cap_recv, fmt=None):
    one = functools.partial(
        _grid_send_one, g_self=g_self, stride=stride, offsets=offsets,
        p=p, cap=cap, c_out=c_out, cap_recv=cap_recv, fmt=fmt,
    )
    return jax.vmap(one)(data, valid)


def _local_join_one(ad, av, bd, bv, ak, bk, bkeep, *, out_cap, backend):
    nk = ak.shape[0]
    kcols = tuple(range(nk))
    ra, rb = dense_ranks(_take(ad, ak), av, kcols, _take(bd, bk), bv, kcols)
    out, out_v, over = local_join_ranked(
        ad, av, ra, bd, bv, rb, bkeep, out_cap, backend
    )
    return out, out_v, jnp.int32(0), over


def _local_join_shard_b(ad, av, bd, bv, ak, bk, bkeep, *, out_cap, backend):
    one = functools.partial(_local_join_one, out_cap=out_cap, backend=backend)
    return jax.vmap(one)(ad, av, bd, bv, ak, bk, bkeep)


def grid_join_many(
    spmd: SPMD,
    as_: Sequence[DTable],
    bs: Sequence[DTable],
    *,
    out_cap: int,
    c_out: Optional[Tuple[int, int]] = None,
    cap_recv: Optional[Tuple[int, int]] = None,
    fmts: Optional[Tuple] = None,  # (fmt_a, fmt_b) or None = dense
    backend: str = "jnp",
) -> Tuple[List[DTable], List[Dict]]:
    """k-fold Lemma-8 grid join (w=2): two batched position-group send
    dispatches + one batched local-join dispatch — one claimed BSP round.
    ``c_out``/``cap_recv`` (per (A, B) relation) override the worst-case
    send capacities with calibrated ones (``measure_grid_join_many``)."""
    p = spmd.p
    a0, b0 = as_[0], bs[0]
    sizes = [a0.cap * a0.p, b0.cap * b0.p]
    g = _grid_shares(sizes, p)
    # mixed-radix grid: table 0 strides by g[1], table 1 strides by 1
    strides = [g[1], 1]
    plans = [
        # (g_self, stride, offsets over the OTHER dim)
        (g[0], strides[0], tuple(c * strides[1] for c in range(g[1]))),
        (g[1], strides[1], tuple(c * strides[0] for c in range(g[0]))),
    ]
    parts = []
    send_stats = []
    for i, (tables, (g_self, stride, offs)) in enumerate(zip((as_, bs), plans)):
        t0 = tables[0]
        d, v = _stack(tables)
        co = c_out[i] if c_out else t0.cap * (g[0] * g[1] // g_self)
        cr = cap_recv[i] if cap_recv else -(-(t0.p * t0.cap) // g_self)
        fmt = fmts[i] if fmts is not None else None
        rd, rv, stats = spmd.run(
            _grid_send_shard_b, d, v,
            g_self=g_self, stride=stride, offsets=offs, p=p, cap=t0.cap,
            c_out=co, cap_recv=cr, fmt=fmt,
            donate=(0, 1),
        )
        parts.append((rd, rv))
        send_stats.append(
            _per_op_stats(
                stats["sent"], stats["dropped"], padded_slots(p, co, t0.arity),
                wire_bytes=_xbytes(p, co, t0.arity, fmt),
                ubytes=stats["ubytes"],
            )
        )
    shareds = [[x for x in a.schema if x in b.schema] for a, b in zip(as_, bs)]
    keeps = [
        tuple(i for i, x in enumerate(b.schema) if x not in set(a.schema))
        for a, b in zip(as_, bs)
    ]
    schemas = [schema_join(a.schema, b.schema) for a, b in zip(as_, bs)]
    ak = _key_array([a.cols(sh) for a, sh in zip(as_, shareds)], p)
    bk = _key_array([b.cols(sh) for b, sh in zip(bs, shareds)], p)
    bkeep = _key_array(keeps, p)
    (ad, av), (bd, bv) = parts
    od, ov, sent_j, over = spmd.run(
        _local_join_shard_b, ad, av, bd, bv, ak, bk, bkeep,
        out_cap=out_cap, backend=backend,
        donate=(0, 1, 2, 3),
    )
    join_stats = _per_op_stats(sent_j, over)
    stats = [
        {
            "sent": sa["sent"] + sb["sent"] + sj["sent"],
            "dropped": sa["dropped"] + sb["dropped"] + sj["dropped"],
            "padded": sa["padded"] + sb["padded"],
            "wire_bytes": sa["wire_bytes"] + sb["wire_bytes"],
            "ubytes": sa.get("ubytes", 0) + sb.get("ubytes", 0),
        }
        for sa, sb, sj in zip(send_stats[0], send_stats[1], join_stats)
    ]
    return _unstack(od, ov, schemas), stats
