"""Multi-tenant join serving: submit a mixed workload of GYM queries to
one ``JoinServer``, let it fuse compatible rounds across requests into
shared SPMD dispatches, and read back per-tenant cost ledgers.

    PYTHONPATH=src python examples/serve_joins.py
"""
from repro.core.gym import GymConfig
from repro.core.queries import chain_ghd, chain_query, star_ghd, star_query
from repro.data.synthetic import chain_data_sparse, star_data_sparse
from repro.relational.spmd import SPMD
from repro.serve.join_server import JoinServer

spmd = SPMD(4)
server = JoinServer(spmd, max_in_flight=4)

# --- 1. three tenants, two query shapes ---------------------------------
# alice and bob run the same star join on their own data snapshots (their
# rounds share schema signatures, so the server fuses them into one SPMD
# dispatch per stage); carol's chain join rides alongside solo.
star = (star_query(4), star_ghd(4))
chain = (chain_query(4), chain_ghd(4))
sdata = star_data_sparse(4, domain=32, hub_rows=64, spoke_extra=16, seed=7)
cdata = chain_data_sparse(4, domain=64, ident=16, extra=48, seed=9)

tickets = [
    server.submit("alice", *star, sdata, GymConfig(seed=3)),
    server.submit("bob", *star, sdata, GymConfig(seed=3)),
    server.submit("carol", *chain, cdata, GymConfig(seed=3), priority=-1.0),
]

# --- 2. drive every admitted query round-by-round to completion ---------
aggregate = server.drain()

for t in tickets:
    print(f"[{t.tenant}] {len(t.rows())} rows, "
          f"admitted@tick {t.admit_tick}, finished@tick {t.finish_tick}")
    print(f"    {t.ledger}")

# --- 3. the server ledger reconciles exactly with the tenant ledgers ----
tenant_leds = [l for leds in aggregate.tenants.values() for l in leds]
assert aggregate.comm_tuples == sum(l.comm_tuples for l in tenant_leds)
print(f"\n[server] {aggregate.queries} queries, "
      f"comm={aggregate.comm_tuples} tuples, "
      f"{aggregate.fused_dispatches} fused dispatches covered "
      f"{aggregate.fused_riders} rider groups "
      f"({aggregate.dispatches_saved} dispatches saved)")
