"""Regenerate ``tests/fixtures/wire_s8_packed.npz`` — the golden byte
snapshot pinning the packed wire format's bit layout (see the layout
paragraph in ``repro/relational/wire.py``).  The fixture is the encoded
bytes of a deterministic S_8 hub-relation exchange buffer; any codec
change that moves a single bit fails
``tests/test_wire_format.py::test_golden_fixture_pins_s8_packed_bytes``.

Only rerun this after an intentional, documented format change:

    PYTHONPATH=src python scripts/make_wire_fixture.py
"""
from __future__ import annotations

import os

import numpy as np

from repro.core.queries import star_query
from repro.data.synthetic import star_data_sparse
from repro.relational.wire import WirePolicy, wire_encode

OUT = os.path.join(
    os.path.dirname(__file__), "..", "tests", "fixtures", "wire_s8_packed.npz"
)


def main() -> None:
    q = star_query(8)
    data = star_data_sparse(8, domain=64, hub_rows=256, spoke_extra=64, seed=21)
    pol = WirePolicy.from_columns([(a.attrs, data[a.rel]) for a in q.atoms])
    hub = next(a for a in q.atoms if len(a.attrs) > 2)
    fmt = pol.format_for(hub.attrs)

    # the same deterministic bucketization the test rebuilds: row i of
    # the deduped hub -> bucket i % 8, slot i // 8
    rows = np.unique(data[hub.rel], axis=0)[:200]
    p, c_out = 8, 32
    buf = np.zeros((p, c_out, rows.shape[1]), np.int32)
    valid = np.zeros((p, c_out), bool)
    for i, r in enumerate(rows):
        buf[i % p, i // p] = r
        valid[i % p, i // p] = True
    wire = np.asarray(wire_encode(buf, valid, fmt))

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    np.savez(
        OUT,
        wire=wire,
        col_bits=np.asarray(fmt.col_bits, np.int32),
        c_out=np.asarray(c_out),
    )
    print(f"wrote {os.path.normpath(OUT)}: wire {wire.shape}, "
          f"col_bits {fmt.col_bits}")


if __name__ == "__main__":
    main()
