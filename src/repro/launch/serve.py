"""Serving driver: load (or init) a model, prefill a batch of prompts,
decode N tokens, report tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --prompt 16 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_model, reduced_config
from repro.serve import generate, generate_whisper
from repro.train import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None, help="restore params from dir")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        restored, _ = ckpt.restore(args.ckpt, {"params": params})
        params = restored["params"]

    t0 = time.time()
    if cfg.encdec:
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, args.prompt, cfg.d_model),
            cfg.jdtype,
        )
        toks = generate_whisper(
            model, params, frames, steps=args.steps,
            dec_cache=args.steps + 4, temperature=args.temperature,
        )
    else:
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt), 0, cfg.vocab
        )
        toks = generate(
            model, params, prompt, steps=args.steps,
            temperature=args.temperature,
        )
    dt = time.time() - t0
    n = args.batch * args.steps
    print(f"arch={cfg.name} generated {n} tokens in {dt:.2f}s "
          f"({n/dt:.0f} tok/s incl. compile)")
    for row in toks.tolist():
        print(" ", row)


if __name__ == "__main__":
    main()
