import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, record memory/cost analysis + collective
bytes (the roofline inputs).  MUST be run as its own process (the two
lines above must execute before jax initializes devices):

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --cells all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch gemma2-9b --shape train_4k

Results accumulate in dryrun_results.json (incremental, crash-safe) —
EXPERIMENTS.md tables are generated from it."""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config, get_model, input_specs
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    batch_specs,
    cache_specs,
    named,
    opt_state_specs,
    param_specs,
)
from repro.train import OptConfig, TrainConfig, init_train_state_shapes, make_train_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "dryrun_results.json")


def _opt_for(arch: str) -> OptConfig:
    # factored second moment for the giant MoEs (state memory), AdamW else
    if arch in ("kimi-k2-1t-a32b", "grok-1-314b"):
        return OptConfig(kind="adafactor")
    return OptConfig(kind="adamw", moments_dtype="bfloat16")


def run_cell(arch: str, shape: str, mesh_kind: str, overrides: Optional[Dict] = None) -> Dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    s, b, kind = SHAPES[shape]
    specs = input_specs(cfg, shape)
    model = get_model(cfg)
    t0 = time.time()

    # ``with mesh`` = legacy ambient mesh (spec'd); ``set_mesh`` additionally
    # exposes the abstract mesh so in-model with_sharding_constraint hints
    # (e.g. the MoE dispatch layout, Perf iteration B) bind to the axes.
    with mesh, jax.set_mesh(mesh):
        if kind == "train":
            tcfg = TrainConfig(opt=_opt_for(arch), remat=True)
            params_s, opt_s = init_train_state_shapes(model, tcfg)
            psp = named(mesh, param_specs(params_s, mesh))
            osp = named(mesh, opt_state_specs(opt_s, None, mesh))
            bsp = named(mesh, batch_specs(specs["batch"], mesh))
            step = make_train_step(model, tcfg)
            jitted = jax.jit(
                step,
                in_shardings=(psp, osp, bsp),
                out_shardings=(psp, osp, None),
            )
            lowered = jitted.lower(params_s, opt_s, specs["batch"])
            tokens = b * (s if not cfg.encdec else s // cfg.dec_ratio)
            n_act = rf.active_param_count(cfg, params_s)
            mf = rf.model_flops_train(n_act, tokens)
        elif kind == "prefill":
            params_s = model.init_shapes()
            # serve layout: pure-TP weights when the model fits TP-sharded
            # (no per-layer FSDP all-gathers) — Perf iteration C
            pbytes = sum(
                l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(params_s)
            )
            tp_only = pbytes // mesh.shape["model"] <= 8 << 30
            psp = named(mesh, param_specs(params_s, mesh, serve_tp_only=tp_only))
            bsp = named(mesh, batch_specs(specs["batch"], mesh))

            def prefill_step(params, batch):
                return model.prefill(params, batch)

            jitted = jax.jit(prefill_step, in_shardings=(psp, bsp))
            lowered = jitted.lower(params_s, specs["batch"])
            n_act = rf.active_param_count(cfg, params_s)
            mf = rf.model_flops_decode(n_act, b * s)
        else:  # decode
            params_s = model.init_shapes()
            pbytes = sum(
                l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(params_s)
            )
            tp_only = pbytes // mesh.shape["model"] <= 8 << 30
            psp = named(mesh, param_specs(params_s, mesh, serve_tp_only=tp_only))
            csp = named(mesh, cache_specs(specs["caches"], mesh))
            tsp = named(mesh, batch_specs({"t": specs["tokens"]}, mesh))["t"]

            def serve_step(params, caches, tokens):
                return model.decode_step(params, caches, tokens)

            jitted = jax.jit(
                serve_step,
                in_shardings=(psp, csp, tsp),
                out_shardings=(None, csp),
            )
            lowered = jitted.lower(params_s, specs["caches"], specs["tokens"])
            n_act = rf.active_param_count(cfg, params_s)
            mf = rf.model_flops_decode(n_act, b)

        compiled = lowered.compile()

    t_compile = time.time() - t0
    try:  # the deliverable prints: proves it fits / feeds the roofline
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        print(f"  cost_analysis: flops={compiled.cost_analysis().get('flops')} "
              f"bytes={compiled.cost_analysis().get('bytes accessed')}")
    except Exception:  # noqa: BLE001
        pass
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
        ):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:  # noqa: BLE001
        mem["error"] = str(e)
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        cost["error"] = str(e)

    coll = rf.parse_collective_bytes(compiled.as_text())
    # cost_analysis/HLO text describe the per-device partitioned module;
    # the spec's roofline formulas take GLOBAL quantities -> scale by chips.
    flops = cost.get("flops", 0.0) * chips
    hbm = cost.get("bytes accessed", 0.0) * chips
    coll_global = {k: v * chips for k, v in coll.items()}
    terms = rf.roofline_terms(
        flops, hbm, float(sum(coll_global.values())), chips, model_flops=mf
    )
    n_params = rf.param_count(params_s)
    arg_bytes = mem.get("argument_size_in_bytes", 0)
    out = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "chips": int(chips),
        "status": "ok",
        "compile_s": round(t_compile, 1),
        "n_params": n_params,
        "n_active_params": int(n_act),
        "memory": mem,
        # per-device steady state: sharded args (params/opt/caches) + temps
        # (temp_size appears module-global under forced-host compilation —
        # recorded raw in "memory"; this derives a per-device view)
        "bytes_per_device": int(
            arg_bytes
            + mem.get("output_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0) // max(1, chips)
        ) if arg_bytes else None,
        "cost_per_device": {
            k: v for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals") or k == "error"
        },
        "collective_bytes_global": coll_global,
        "roofline": terms,
    }
    return out


def _load() -> Dict:
    path = os.path.abspath(RESULTS)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _save(db: Dict) -> None:
    path = os.path.abspath(RESULTS)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(db, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--cells", default=None, help="'all' = every enabled cell")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.cells == "all":
        for a, sh in cells():
            for m in meshes:
                todo.append((a, sh, m))
    else:
        assert args.arch and args.shape
        for m in meshes:
            todo.append((args.arch, args.shape, m))

    db = _load()
    for arch, shape, m in todo:
        key = f"{arch}|{shape}|{m}"
        if key in db and db[key].get("status") == "ok" and not args.force:
            print(f"[skip] {key}")
            continue
        print(f"[run ] {key}", flush=True)
        try:
            res = run_cell(arch, shape, m)
        except Exception as e:  # noqa: BLE001
            res = {
                "arch": arch, "shape": shape, "mesh": m,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
        db = _load()  # re-merge (parallel runners)
        db[key] = res
        _save(db)
        st = res.get("status")
        r = res.get("roofline", {})
        print(
            f"[done] {key} status={st} compile={res.get('compile_s')}s "
            f"dominant={r.get('dominant')} bound={r.get('bound_s'):.4g}s"
            if st == "ok" else f"[FAIL] {key}: {res.get('error')}",
            flush=True,
        )


if __name__ == "__main__":
    main()
