"""Hashing and exact multi-column ordering utilities (per-shard, pure jnp).

- ``hash_columns``: 32-bit murmur-style column-combining hash -> reducer
  destinations.  Only needs *consistency*, not injectivity (exactness
  everywhere else comes from lexsort-based dense ranks).
- ``dense_ranks``: exact dictionary encoding of multi-column keys across two
  operand tables via concat + lexsort + run ids.  Gives collision-free int32
  keys usable with sort/searchsorted — no attribute-domain bounds anywhere.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_C1 = jnp.uint32(0x85EBCA6B)
_C2 = jnp.uint32(0xC2B2AE35)
_GOLD = jnp.uint32(0x9E3779B9)


def mix32(x: jax.Array) -> jax.Array:
    """fmix32 from murmur3 (bijective on uint32)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_columns(data: jax.Array, cols: Sequence[int], seed) -> jax.Array:
    """(cap, arity) int32, selected cols -> (cap,) uint32 hash.

    ``seed`` may be a python int OR a traced scalar — engine code passes it
    traced so reseeded retries reuse the compiled program."""
    if isinstance(seed, int):
        seed = np.uint32(seed & 0xFFFFFFFF)  # top-bit-set ints overflow int32
    s = jnp.asarray(seed).astype(jnp.uint32)
    h = mix32(jnp.broadcast_to(s, (data.shape[0],)))
    for c in cols:
        h = mix32(h ^ (mix32(data[:, c].astype(jnp.uint32)) + _GOLD))
    return h


def dests_for(data: jax.Array, valid: jax.Array, cols: Sequence[int], p: int, seed) -> jax.Array:
    """Reducer destination in [0,p) for valid rows; p for invalid (drop)."""
    h = hash_columns(data, cols, seed)
    d = (h % jnp.uint32(p)).astype(jnp.int32)
    return jnp.where(valid, d, p)


def _lexsort_cols(cols: Tuple[jax.Array, ...], invalid: jax.Array) -> jax.Array:
    """Order: valid rows sorted lexicographically by cols, invalid last.

    jnp.lexsort sorts by the LAST key first, so pass (minor..major, invalid).
    """
    keys = tuple(reversed(cols)) + (invalid.astype(jnp.int32),)
    return jnp.lexsort(keys)


def sort_rows(data: jax.Array, valid: jax.Array, cols: Sequence[int]) -> jax.Array:
    """Permutation sorting the table by ``cols`` (invalid rows last)."""
    return _lexsort_cols(tuple(data[:, c] for c in cols), ~valid)


def dense_ranks(
    a_data: jax.Array, a_valid: jax.Array, a_cols: Sequence[int],
    b_data: jax.Array, b_valid: jax.Array, b_cols: Sequence[int],
) -> Tuple[jax.Array, jax.Array]:
    """Exact shared dictionary encoding of the key columns of two tables.

    Returns int32 (rank_a, rank_b): equal multi-column keys (across either
    table) get equal ranks; distinct keys get distinct ranks.  Invalid rows
    get rank -1 (a) / -2 (b) so they never match anything.
    """
    assert len(a_cols) == len(b_cols)
    na, nb = a_data.shape[0], b_data.shape[0]
    cols = tuple(
        jnp.concatenate([a_data[:, ca], b_data[:, cb]])
        for ca, cb in zip(a_cols, b_cols)
    )
    if not cols:  # zero-attr key (cartesian): every valid row matches
        ra = jnp.where(a_valid, 0, -1)
        rb = jnp.where(b_valid, 0, -2)
        return ra.astype(jnp.int32), rb.astype(jnp.int32)
    invalid = jnp.concatenate([~a_valid, ~b_valid])
    order = _lexsort_cols(cols, invalid)
    sorted_cols = [c[order] for c in cols]
    sorted_invalid = invalid[order]
    new_run = jnp.zeros((na + nb,), bool).at[0].set(True)
    if na + nb > 1:
        diff = jnp.zeros((na + nb - 1,), bool)
        for c in sorted_cols:
            diff = diff | (c[1:] != c[:-1])
        diff = diff | (sorted_invalid[1:] != sorted_invalid[:-1])
        new_run = new_run.at[1:].set(diff)
    run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    ranks = jnp.zeros((na + nb,), jnp.int32).at[order].set(run_id)
    ra = jnp.where(a_valid, ranks[:na], -1)
    rb = jnp.where(b_valid, ranks[na:], -2)
    return ra.astype(jnp.int32), rb.astype(jnp.int32)


def self_ranks(data: jax.Array, valid: jax.Array, cols: Sequence[int]) -> jax.Array:
    """Dense ranks of one table's key columns (invalid -> -1)."""
    n = data.shape[0]
    if not cols:
        return jnp.where(valid, 0, -1).astype(jnp.int32)
    colt = tuple(data[:, c] for c in cols)
    invalid = ~valid
    order = _lexsort_cols(colt, invalid)
    sorted_cols = [c[order] for c in colt]
    sorted_invalid = invalid[order]
    new_run = jnp.zeros((n,), bool).at[0].set(True)
    if n > 1:
        diff = jnp.zeros((n - 1,), bool)
        for c in sorted_cols:
            diff = diff | (c[1:] != c[:-1])
        diff = diff | (sorted_invalid[1:] != sorted_invalid[:-1])
        new_run = new_run.at[1:].set(diff)
    run_id = jnp.cumsum(new_run.astype(jnp.int32)) - 1
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(run_id)
    return jnp.where(valid, ranks, -1).astype(jnp.int32)
