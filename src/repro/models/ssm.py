"""Mamba2 block (chunked SSD algorithm), TPU-adapted: intra-chunk work is
parallel masked matmuls (MXU), inter-chunk state is a short ``lax.scan``
over chunk boundaries -- the standard sub-quadratic path that makes
``long_500k`` viable for zamba2/xlstm.

State-space semantics per head h (scalar A):
  s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t^T ;  y_t = C_t . s_t + D x_t
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, init_norm, rms_norm, scaled_init


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    p = 64 if d_in % 64 == 0 else d_in // max(1, cfg.ssm_heads or 1)
    if cfg.ssm_heads:
        h = cfg.ssm_heads
        p = d_in // h
    else:
        h = d_in // p
    return d_in, h, p, n


def init_mamba(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    d_in, h, p, n = _dims(cfg)
    ks = jax.random.split(rng, 6)
    conv_ch = d_in + 2 * n  # x, B, C go through the depthwise conv
    return {
        "ln": init_norm(d, cfg.jdtype),
        # order: [z, x, B, C, dt]
        "w_in": scaled_init(ks[0], (d, 2 * d_in + 2 * n + h), 0, cfg.jdtype),
        "conv": scaled_init(ks[1], (cfg.conv_kernel, conv_ch), 0, cfg.jdtype),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) = -1
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ~ 0.12
        "d_skip": jnp.ones((h,), jnp.float32),
        "ln_out": init_norm(d_in, cfg.jdtype),
        "w_out": scaled_init(ks[2], (d_in, d), 0, cfg.jdtype),
    }


def _segsum(logdecay: jax.Array) -> jax.Array:
    """L[i, j] = sum_{k=j+1..i} logdecay[k] for i >= j else -inf.
    logdecay: (..., Q) -> (..., Q, Q)."""
    q = logdecay.shape[-1]
    cs = jnp.cumsum(logdecay, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{j+1..i}
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32, positive
    a: jax.Array,  # (H,) f32, negative
    bmat: jax.Array,  # (B, S, N)
    cmat: jax.Array,  # (B, S, N)
    chunk: int,
    init_state: jax.Array = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked scan; returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = -s % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    xc = x.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    da = dtc * a  # (b, nc, q, h) log-decay per step
    xdt = xc * dtc[..., None]  # dt-weighted input

    # intra-chunk (parallel): y_intra = ((C B^T) o L) @ (x dt)
    L = _segsum(jnp.moveaxis(da, -1, -2))  # (b, nc, h, q, q)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)  # (b,nc,q,q)
    att = cb[:, :, None] * jnp.exp(L)  # (b,nc,h,q,q)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", att, xdt)

    # chunk summaries: state contribution of each chunk
    cum = jnp.cumsum(da, axis=2)  # (b,nc,q,h)
    tot = cum[:, :, -1:]  # (b,nc,1,h)
    decay_to_end = jnp.exp(tot - cum)  # exp(sum_{k>j} da_k)
    chunk_state = jnp.einsum(
        "bcqn,bcqhp,bcqh->bchpn", bc, xdt, decay_to_end
    )  # (b,nc,h,p,n)

    # inter-chunk: scan over chunks carrying state (b,h,p,n)
    tot_h = jnp.exp(tot[:, :, 0])  # (b,nc,h) total chunk decay
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inputs):
        cs, td = inputs  # (b,h,p,n), (b,h)
        out_prev = state
        new = state * td[:, :, None, None] + cs
        return new, out_prev

    cs_t = jnp.moveaxis(chunk_state, 1, 0)  # (nc,b,h,p,n)
    td_t = jnp.moveaxis(tot_h, 1, 0)  # (nc,b,h)
    final_state, prev_states = jax.lax.scan(step, init_state, (cs_t, td_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,h,p,n)

    # y_inter[i] = (C_i . state_prev) * exp(cum_{<=i})
    y_inter = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", cc, prev_states, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :s]
    return y, final_state


def mamba_forward(
    p: Dict, x: jax.Array, cfg: ArchConfig, state: Dict = None
) -> jax.Array:
    """Full-sequence forward (train / prefill). x (B,S,D)."""
    b, s, d = x.shape
    d_in, h, hp, n = _dims(cfg)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = xin @ p["w_in"]  # (B,S, 2*d_in + 2n + h)
    z, xi, bm, cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    # depthwise causal conv over (x, B, C)
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    k = cfg.conv_kernel
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + s] * p["conv"][i][None, None, :] for i in range(k)
    )
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xi, bm, cm = jnp.split(conv, [d_in, d_in + n], axis=-1)

    a = -jnp.exp(p["a_log"])
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, _ = ssd_chunked(
        xi.reshape(b, s, h, hp), dtp, a, bm, cm, cfg.chunk
    )
    y = y + xi.reshape(b, s, h, hp).astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    return x + (y @ p["w_out"]).astype(x.dtype)


def mamba_prefill(
    p: Dict, x: jax.Array, cfg: ArchConfig
) -> Tuple[jax.Array, Dict]:
    """Forward that also returns the recurrent state for decode."""
    b, s, d = x.shape
    d_in, h, hp, n = _dims(cfg)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = xin @ p["w_in"]
    z, xi, bm, cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    k = cfg.conv_kernel
    conv_tail = xbc[:, -(k - 1):].astype(jnp.float32)
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + s] * p["conv"][i][None, None, :] for i in range(k)
    )
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xi, bm, cm = jnp.split(conv, [d_in, d_in + n], axis=-1)
    a = -jnp.exp(p["a_log"])
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, fstate = ssd_chunked(xi.reshape(b, s, h, hp), dtp, a, bm, cm, cfg.chunk)
    y = y + xi.reshape(b, s, h, hp).astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    out = x + (y @ p["w_out"]).astype(x.dtype)
    return out, {"conv": conv_tail, "ssm": fstate}


# --------------------------------------------------------------- decode
def mamba_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_in, h, p, n = _dims(cfg)
    k = cfg.conv_kernel
    return {
        "conv": jnp.zeros((batch, k - 1, d_in + 2 * n), dtype),
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def mamba_decode(
    p: Dict, x: jax.Array, state: Dict, cfg: ArchConfig
) -> Tuple[jax.Array, Dict]:
    """One-token recurrent step. x (B,1,D)."""
    b, _, d = x.shape
    d_in, h, hp, n = _dims(cfg)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)[:, 0]  # (B,D)
    zxbcdt = xin @ p["w_in"]
    z, xi, bm, cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)  # (B, conv_ch)
    hist = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)  # (B,k,ch)
    conv = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), p["conv"].astype(jnp.float32))
    conv = jax.nn.silu(conv).astype(x.dtype)
    xi, bm, cm = jnp.split(conv, [d_in, d_in + n], axis=-1)

    a = -jnp.exp(p["a_log"])
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,h)
    da = jnp.exp(dtp * a)  # (B,h)
    xh = xi.reshape(b, h, hp).astype(jnp.float32)
    ssm = state["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", bm.astype(jnp.float32), xh, dtp
    )
    y = jnp.einsum("bn,bhpn->bhp", cm.astype(jnp.float32), ssm)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["ln_out"], cfg.norm_eps)
    out = x + (y @ p["w_out"]).astype(x.dtype)[:, None]
    return out, {"conv": hist[:, 1:], "ssm": ssm}
