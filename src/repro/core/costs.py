"""Analytic cost formulas from the paper (Tables 2 & 3, Lemmas 8-11,
Theorems 12/14/15/23).  Used by the benchmarks to place measured ledger
numbers next to the paper's worst-case predictions."""
from __future__ import annotations

import math
from typing import Dict, Optional

from .ghd import GHD
from .hypergraph import Query


def B(X: float, M: float) -> float:
    """The paper's B(X, M) = X^2 / M (assumption 4, Sec. 3.3)."""
    return X * X / M


def lemma8_join_comm(sizes, M: float, out: float) -> float:
    """One-round grid join of w relations: (sum |R_i|)^w / M^(w-1) + OUT."""
    s = float(sum(sizes))
    w = len(sizes)
    return s**w / M ** (w - 1) + out


def lemma10_semijoin_comm(r: float, s: float, M: float) -> float:
    """O(B(|R| + |S|, M))."""
    return B(r + s, M)


def gym_comm(n: int, IN: float, OUT: float, M: float, w: int) -> float:
    """Theorem 15: O(n * B(IN^w + OUT, M))."""
    return n * B(IN**w + OUT, M)


def gym_rounds(d: int, n: int) -> float:
    """Theorem 15: O(d + log n)."""
    return d + math.log2(max(2, n))


def gym_loggta_comm(
    n: int, IN: float, OUT: float, M: float, w: int, iw: int
) -> float:
    """Theorem 23: O(n * B(IN^max(w,3iw) + OUT, M))."""
    return n * B(IN ** max(w, 3 * iw) + OUT, M)


def acqmr_comm(n: int, IN: float, OUT: float, M: float, w: int) -> float:
    """Sec. 2.2: O(n * B(IN^{3w} + OUT, M))."""
    return n * B(IN ** (3 * w) + OUT, M)


def shares_comm_star(n: int, IN: float, M: float, OUT: float) -> float:
    """Table 2 (S_n): O(IN^{n/2} / M^{n/2} + OUT) worst case."""
    half = n / 2.0
    return IN**half / M**half + OUT


def shares_comm_tc(n: int, IN: float, M: float, OUT: float) -> float:
    """Table 3 (TC_n): O(IN^{n/6} / M^{n/6} + OUT) worst case."""
    sixth = n / 6.0
    return IN**sixth / M**sixth + OUT


def one_round_chain_lower_bound(n: int, IN: float, M: float) -> float:
    """Sec. 1: any 1-round algorithm for C_n needs >= (IN/M)^{n/4} comm."""
    return (IN / M) ** (n / 4.0)


def predicted_table(
    query: Query, ghd: GHD, IN: float, OUT: float, M: float
) -> Dict[str, float]:
    w = ghd.width
    iw = ghd.intersection_width(query)
    n = query.n
    d = ghd.depth
    return {
        "width": w,
        "iw": iw,
        "depth": d,
        "gym_rounds": gym_rounds(d, n),
        "gym_comm": gym_comm(n, IN, OUT, M, w),
        "gym_loggta_rounds": gym_rounds(int(math.log2(max(2, 4 * n))) + 1, n),
        "gym_loggta_comm": gym_loggta_comm(n, IN, OUT, M, w, iw),
        "acqmr_comm": acqmr_comm(n, IN, OUT, M, w),
    }
