"""Hypergraphs and join queries (Section 3.1 of the paper).

A natural join query is a hypergraph: one vertex per attribute, one
hyperedge per relation *atom*.  Atoms carry an ``alias`` (unique within the
query, so self-joins are representable) and the name of the underlying
``rel`` whose data they read.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Atom:
    """One relation occurrence in a query: alias, base-relation name, attrs."""

    alias: str
    rel: str
    attrs: Tuple[str, ...]

    def __post_init__(self):
        if len(set(self.attrs)) != len(self.attrs):
            raise ValueError(f"atom {self.alias}: repeated attribute in {self.attrs}")

    @property
    def attr_set(self) -> FrozenSet[str]:
        return frozenset(self.attrs)


@dataclass
class Query:
    """A full conjunctive (natural-join) query, possibly with self-joins."""

    atoms: List[Atom]
    name: str = "Q"

    def __post_init__(self):
        aliases = [a.alias for a in self.atoms]
        if len(set(aliases)) != len(aliases):
            raise ValueError("atom aliases must be unique")
        self._by_alias = {a.alias: a for a in self.atoms}

    # -- hypergraph view ----------------------------------------------------
    @property
    def vertices(self) -> FrozenSet[str]:
        out = set()
        for a in self.atoms:
            out |= a.attr_set
        return frozenset(out)

    @property
    def edges(self) -> Dict[str, FrozenSet[str]]:
        """alias -> attribute set."""
        return {a.alias: a.attr_set for a in self.atoms}

    def atom(self, alias: str) -> Atom:
        return self._by_alias[alias]

    @property
    def n(self) -> int:
        return len(self.atoms)

    @property
    def output_attrs(self) -> Tuple[str, ...]:
        """Full queries: output schema = all attributes (stable order)."""
        seen: List[str] = []
        for a in self.atoms:
            for v in a.attrs:
                if v not in seen:
                    seen.append(v)
        return tuple(seen)

    def is_connected(self) -> bool:
        if not self.atoms:
            return True
        todo = [self.atoms[0].alias]
        seen = {self.atoms[0].alias}
        while todo:
            cur = self._by_alias[todo.pop()]
            for other in self.atoms:
                if other.alias not in seen and cur.attr_set & other.attr_set:
                    seen.add(other.alias)
                    todo.append(other.alias)
        return len(seen) == len(self.atoms)

    def primal_graph(self) -> Dict[str, set]:
        """Attribute co-occurrence graph (for tree-decomposition heuristics)."""
        adj: Dict[str, set] = {v: set() for v in self.vertices}
        for a in self.atoms:
            for u, v in itertools.combinations(a.attrs, 2):
                adj[u].add(v)
                adj[v].add(u)
        return adj


def min_edge_cover(
    target: FrozenSet[str],
    edges: Dict[str, FrozenSet[str]],
    max_k: Optional[int] = None,
) -> Optional[FrozenSet[str]]:
    """Smallest set of hyperedges (by alias) whose union covers ``target``.

    Exact search by increasing cardinality; the candidates are restricted to
    edges that intersect ``target``.  Used for intersection-width (paper
    Sec. 3.1) where the answer is <= the GHD width, i.e. tiny.
    Returns None if no cover exists (cannot happen for GHD-induced targets).
    """
    if not target:
        return frozenset()
    cands = [(alias, e & target) for alias, e in edges.items() if e & target]
    # Deduplicate by covered set, keeping one representative alias (smallest
    # alias for determinism); dominated candidates are pruned.
    best_for_cover: Dict[FrozenSet[str], str] = {}
    for alias, cov in sorted(cands):
        if cov not in best_for_cover:
            best_for_cover[cov] = alias
    items = sorted(best_for_cover.items(), key=lambda kv: (-len(kv[0]), kv[1]))
    covers = [cov for cov, _ in items]
    aliases = [al for _, al in items]
    limit = max_k if max_k is not None else len(covers)
    for k in range(1, min(limit, len(covers)) + 1):
        for combo in itertools.combinations(range(len(covers)), k):
            u = set()
            for i in combo:
                u |= covers[i]
            if target <= u:
                return frozenset(aliases[i] for i in combo)
    return None
