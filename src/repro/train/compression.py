"""Gradient compression: int8 stochastic-rounding codec + a real int8
all-reduce built on manual collectives (shard_map / named axes).

``int8_allreduce(x, axis)`` — the wire-honest path: per-tensor scale is
psum-maxed, values are stochastically rounded to int8, the sum runs over
int32 (no overflow below 2^23 shards), and the result is dequantized.
Under pjit-only training the codec wraps the gradient-accumulation
boundary instead (XLA's own all-reduce stays bf16) — both paths are
exposed and the trade-off is documented in DESIGN.md Sec. 5."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, rng: Optional[jax.Array] = None):
    """Per-tensor symmetric int8 with optional stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    y = x.astype(jnp.float32) / scale
    if rng is not None:
        y = jnp.floor(y + jax.random.uniform(rng, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def codec_roundtrip(tree, rng: Optional[jax.Array] = None):
    """Quantize+dequantize every leaf (the pjit-path codec)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    rngs = (
        jax.random.split(rng, len(leaves)) if rng is not None else [None] * len(leaves)
    )
    out = []
    for l, r in zip(leaves, rngs):
        q, s = quantize_int8(l, r)
        out.append(dequantize_int8(q, s, l.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def int8_allreduce(x: jax.Array, axis: str, rng: Optional[jax.Array] = None):
    """Mean over ``axis`` with int8 payload: must run under shard_map/vmap
    with named axis ``axis``.  Wire cost: 1 byte/elem + one f32 scale."""
    scale = jax.lax.pmax(
        jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12), axis
    ) / 127.0
    y = x.astype(jnp.float32) / scale
    if rng is not None:
        y = jnp.floor(y + jax.random.uniform(rng, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis)
    return (total.astype(jnp.float32) * scale / n.astype(jnp.float32)).astype(x.dtype)
