"""Elastic scaling + straggler policy for multi-controller runs.

What is mechanized here (single-controller semantics, multi-pod design):
  - ``fit_batch_to_world``: re-plan global batch / accumulation when the
    data-parallel world size changes between runs (checkpoints are logical
    arrays, so restore works at any world size whose mesh divides the
    sharded dims — see checkpoint.restore(shardings=...)).
  - ``HeartbeatMonitor``: wall-clock watchdog that flags straggling steps
    (> k x median) — the hook a launcher uses to trigger speculative
    re-execution or slice eviction.
The BSP-engine-side story (round retry with reseeded hashing on reducer
overflow) lives in core/gym.py; both are documented in DESIGN.md Sec. 6."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple


@dataclasses.dataclass
class BatchPlan:
    global_batch: int
    accum: int
    per_device_batch: int


def fit_batch_to_world(
    global_batch: int, dp_world: int, per_device_max: int
) -> BatchPlan:
    """Keep the *global* batch (optimization semantics) fixed while the
    world size changes: raise accumulation when fewer chips, lower when
    more.  Requires dp_world | global_batch."""
    assert global_batch % dp_world == 0, (global_batch, dp_world)
    per_step = global_batch // dp_world
    accum = max(1, -(-per_step // per_device_max))
    while per_step % accum:
        accum += 1
    return BatchPlan(global_batch, accum, per_step // accum)


class HeartbeatMonitor:
    """Flags steps slower than ``factor`` x running median."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.durations: List[float] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> Tuple[float, bool]:
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._t0 = None
        hist = sorted(self.durations[-self.window:])
        median = hist[len(hist) // 2] if hist else dt
        straggler = len(hist) >= 8 and dt > self.factor * median
        self.durations.append(dt)
        return dt, straggler
