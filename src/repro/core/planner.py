"""BSP round planner for distributed Yannakakis (paper Sections 4.2, 4.3).

The planner is pure tree algorithmics: given a (materialized) join tree it
emits a round-by-round schedule of semijoin/intersection/join operations.
The executor (``gym.py``) runs each schedule round as one BSP round-group
and the ledger accounts actual engine rounds + tuples moved.

Schedules (both registered in ``SCHEDULES`` with their paper metadata,
which is what the plan advisor in ``core/optimizer.py`` enumerates):
  - ``dym_n_schedule``: the serial Yannakakis order (Sec. 4.1/4.2,
    Theorem 12): 2(n-1) semijoins one-at-a-time, then n-1 bottom-up
    joins -> O(n) rounds, O(n * B(IN + OUT, M)) communication.
  - ``dym_d_schedule``: the parallel-contraction order (Sec. 4.3,
    Theorem 14): upward semijoin phase + downward semijoin phase + join
    phase, each contracting all eligible leaves per iteration
    -> O(d + log n) rounds at the same communication bound.

Op kinds (target := result):
  semijoin      (S, R)          S := S |>< R                [upward L1]
  pair_filter   (R1, S, R2)     R1 := (S |>< R1) ^ (S |>< R2)  [upward L2]
  triple_filter (R1, S, R2, R3) R1 := ^ of three semijoins  [upward L2 odd]
  down_semijoin (R, S)          R := R |>< S                [downward]
  join          (S, R)          S := S |><| R               [join L1]
  pair_join     (R1, S, R2)     R1 := (R1|><|S) |><| (R2|><|S)  [join L2]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from .ghd import GHD


@dataclasses.dataclass(frozen=True)
class Op:
    kind: str
    target: int
    args: Tuple[int, ...]  # other participating nodes

    def __repr__(self) -> str:
        return f"{self.kind}({self.target};{','.join(map(str, self.args))})"


@dataclasses.dataclass
class Round:
    phase: str  # 'upward' | 'downward' | 'join'
    ops: List[Op]


@dataclasses.dataclass
class _Tree:
    """Mutable contraction scratch tree."""

    parent: Dict[int, Optional[int]]
    children: Dict[int, List[int]]
    root: int

    @staticmethod
    def of(g: GHD) -> "_Tree":
        return _Tree(
            parent=dict(g.parent),
            children={k: list(v) for k, v in g.children.items()},
            root=g.root,
        )

    def remove_leaf(self, n: int) -> None:
        p = self.parent[n]
        if p is not None:
            self.children[p].remove(n)
        del self.parent[n]
        self.children.pop(n, None)

    def leaves(self) -> List[int]:
        return [n for n in self.parent if not self.children.get(n)]

    def size(self) -> int:
        return len(self.parent)


def _contraction_rounds(g: GHD, phase: str, join: bool) -> List[Round]:
    """One upward pass (Sec. 4.3 induction): per iteration, group current
    leaves by parent; parents with one leaf child absorb it (L1-style,
    single writer); parents with >= 2 leaf children get their leaves paired
    (odd count -> one triple), no write to the parent."""
    t = _Tree.of(g)
    rounds: List[Round] = []
    guard = 0
    while t.size() > 1:
        guard += 1
        assert guard <= 2 * t.size() + 64, "contraction failed to terminate"
        by_parent: Dict[int, List[int]] = {}
        for l in t.leaves():
            p = t.parent[l]
            if p is None:
                continue
            by_parent.setdefault(p, []).append(l)
        ops: List[Op] = []
        for p, ls in sorted(by_parent.items()):
            ls = sorted(ls)
            if len(ls) == 1:
                l = ls[0]
                ops.append(Op("join" if join else "semijoin", p, (l,)))
                t.remove_leaf(l)
            else:
                i = 0
                # pairs; if odd, the last group is a triple
                while len(ls) - i >= 2:
                    if len(ls) - i == 3:
                        a, b, c = ls[i], ls[i + 1], ls[i + 2]
                        ops.append(
                            Op(
                                "triple_join" if join else "triple_filter",
                                a,
                                (p, b, c),
                            )
                        )
                        t.remove_leaf(b)
                        t.remove_leaf(c)
                        i += 3
                    else:
                        a, b = ls[i], ls[i + 1]
                        ops.append(
                            Op("pair_join" if join else "pair_filter", a, (p, b))
                        )
                        t.remove_leaf(b)
                        i += 2
        assert ops, "no progress in contraction"
        rounds.append(Round(phase, ops))
    return rounds


def _downward_rounds(g: GHD) -> List[Round]:
    """Per depth level (top-down), every child semijoins with its parent —
    all children at a level in parallel: O(d) rounds."""
    levels: Dict[int, List[int]] = {}
    stack = [(g.root, 0)]
    while stack:
        n, d = stack.pop()
        for c in g.children.get(n, []):
            levels.setdefault(d + 1, []).append(c)
            stack.append((c, d + 1))
    rounds = []
    for d in sorted(levels):
        ops = [Op("down_semijoin", c, (g.parent[c],)) for c in sorted(levels[d])]
        rounds.append(Round("downward", ops))
    return rounds


def dym_d_schedule(g: GHD) -> List[Round]:
    """Sec. 4.3 / Theorem 14: O(d + log n) upward contraction rounds +
    O(d) downward rounds + O(d + log n) join contraction rounds."""
    return (
        _contraction_rounds(g, "upward", join=False)
        + _downward_rounds(g)
        + _contraction_rounds(g, "join", join=True)
    )


def dym_n_schedule(g: GHD) -> List[Round]:
    """Sec. 4.2 / Theorem 12 (serial Yannakakis order): one op per round,
    3(n-1) rounds total on an n-node GHD.

    Upward: recursive leaf-at-a-time semijoins into parents; Downward:
    reverse order parent->child semijoins; Join: bottom-up one at a time.
    """
    # upward: repeatedly pick any leaf, semijoin into parent
    t = _Tree.of(g)
    up: List[Round] = []
    order: List[Tuple[int, int]] = []  # (leaf, parent) removal order
    while t.size() > 1:
        l = min(t.leaves(), key=lambda n: (n != t.root, n))
        if t.parent[l] is None:  # only the root left as a "leaf"
            break
        p = t.parent[l]
        up.append(Round("upward", [Op("semijoin", p, (l,))]))
        order.append((l, p))
        t.remove_leaf(l)
    # downward: reverse order, R := R |>< S
    down = [
        Round("downward", [Op("down_semijoin", l, (p,))]) for l, p in reversed(order)
    ]
    # join phase: bottom-up, one join per round
    t2 = _Tree.of(g)
    joins: List[Round] = []
    while t2.size() > 1:
        l = min(t2.leaves())
        p = t2.parent[l]
        joins.append(Round("join", [Op("join", p, (l,))]))
        t2.remove_leaf(l)
    return up + down + joins


# --------------------------------------------------------------------------
# schedule registry: paper metadata the plan advisor enumerates over
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScheduleInfo:
    """One named schedule with its claimed paper bounds.

    ``round_bound(g)`` is the *claimed* worst-case round count on GHD
    ``g`` (with the same constants the round-bound tests assert);
    ``fn(g)`` emits the actual rounds.  The advisor uses ``fn`` for
    exact per-plan costing and ``round_bound``/``claimed_rounds`` for
    the explain() teaching columns.
    """

    name: str
    fn: Callable[[GHD], List["Round"]]
    paper: str  # section / theorem this schedule implements
    claimed_rounds: str  # the O(.) round bound, human-readable
    round_bound: Callable[[GHD], int]


def _dym_n_bound(g: GHD) -> int:
    # Theorem 12: 2(n-1) semijoin rounds + (n-1) join rounds
    return 3 * max(1, g.size() - 1)


def _dym_d_bound(g: GHD) -> int:
    # Theorem 14: O(d + log n) per phase, 3 phases (constants as asserted
    # by tests/test_gym_engine.py round-bound tests)
    return 3 * (g.depth + int(math.ceil(math.log2(max(2, g.size())))) + 2)


SCHEDULES: Dict[str, ScheduleInfo] = {
    "dym_n": ScheduleInfo(
        name="dym_n",
        fn=dym_n_schedule,
        paper="Sec. 4.2 / Theorem 12",
        claimed_rounds="O(n)",
        round_bound=_dym_n_bound,
    ),
    "dym_d": ScheduleInfo(
        name="dym_d",
        fn=dym_d_schedule,
        paper="Sec. 4.3 / Theorem 14",
        claimed_rounds="O(d + log n)",
        round_bound=_dym_d_bound,
    ),
}


def get_schedule(name: str) -> ScheduleInfo:
    try:
        return SCHEDULES[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; registered: {sorted(SCHEDULES)}"
        ) from None


def schedule_stats(rounds: List[Round]) -> Dict[str, int]:
    out: Dict[str, int] = {"rounds": len(rounds), "ops": 0}
    for r in rounds:
        out["ops"] += len(r.ops)
        out[r.phase] = out.get(r.phase, 0) + 1
    return out
