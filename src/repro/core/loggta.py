"""Log-GTA (paper Section 6): transform any GHD with width w and
intersection width iw into an O(log n)-depth GHD of width <= max(w, 3iw).

The extended GHD carries:
  - active/inactive vertex labels (active(T') is the up-closed top subtree),
  - per-vertex heights assigned at inactivation time,
  - common covers cc(u,v) (size <= iw) on the edges of active(T').

Each iteration inactivates all active leaves plus a pairwise non-adjacent
set of unique-c-gc vertices (with their unique children) — together at least
1/4 of the active vertices (Lemma 16) — so O(log n) iterations suffice
(Lemma 19), and heights grow by at most 1 per iteration (Lemma 20).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .ghd import GHD
from .hypergraph import Query, min_edge_cover


@dataclass
class ExtendedGHD:
    ghd: GHD
    active: Set[int]
    cc: Dict[Tuple[int, int], FrozenSet[str]]  # (parent,child) in active(T')
    height: Dict[int, int]
    next_id: int

    @staticmethod
    def extend(ghd: GHD, query: Query, max_cover: Optional[int] = None) -> "ExtendedGHD":
        g = ghd.copy()
        cc: Dict[Tuple[int, int], FrozenSet[str]] = {}
        for p, c in g.tree_edges():
            shared = g.chi[p] & g.chi[c]
            cover = min_edge_cover(shared, query.edges, max_k=max_cover)
            assert cover is not None, "GHD edge must have a finite cover"
            cc[(p, c)] = cover
        return ExtendedGHD(
            ghd=g,
            active=set(g.nodes()),
            cc=cc,
            height={},
            next_id=max(g.nodes()) + 1,
        )

    # ------------------------------------------------------------------ helpers
    def active_children(self, n: int) -> List[int]:
        return [c for c in self.ghd.children.get(n, []) if c in self.active]

    def active_leaves(self) -> List[int]:
        return [n for n in self.active if not self.active_children(n)]

    def unique_cgc(self) -> List[int]:
        """Active vertices u with exactly one active child c, where c also
        has exactly one active child gc."""
        out = []
        for u in self.active:
            cs = self.active_children(u)
            if len(cs) != 1:
                continue
            gcs = self.active_children(cs[0])
            if len(gcs) == 1:
                out.append(u)
        return out

    def _inactive_children(self, n: int) -> List[int]:
        return [c for c in self.ghd.children.get(n, []) if c not in self.active]

    def _assign_height(self, n: int) -> None:
        kids = self._inactive_children(n)
        self.height[n] = 0 if not kids else 1 + max(self.height[k] for k in kids)

    # ---------------------------------------------------------------- operations
    def inactivate_leaf(self, l: int) -> None:
        assert l in self.active and not self.active_children(l)
        p = self.ghd.parent[l]
        if p is not None:
            self.cc.pop((p, l), None)
        self.active.remove(l)
        self._assign_height(l)

    def inactivate_unique_cgc(self, u: int) -> int:
        """Perform unique-c-gc inactivation at u; returns the new vertex s."""
        g = self.ghd
        cs = self.active_children(u)
        assert len(cs) == 1, f"{u} not unique-c-gc"
        c = cs[0]
        gcs = self.active_children(c)
        assert len(gcs) == 1, f"{u} not unique-c-gc (child has {len(gcs)} active)"
        gc = gcs[0]
        p = g.parent[u]  # active by up-closedness (or None if u is root)

        cc_pu = self.cc.get((p, u), frozenset()) if p is not None else frozenset()
        cc_uc = self.cc[(u, c)]
        cc_cgc = self.cc[(c, gc)]

        s = self.next_id
        self.next_id += 1
        chi_s: FrozenSet[str] = frozenset(
            ((g.chi[p] & g.chi[u]) if p is not None else frozenset())
            | (g.chi[u] & g.chi[c])
            | (g.chi[c] & g.chi[gc])
        )
        lam_s = frozenset(cc_pu | cc_uc | cc_cgc)

        # rewire: s replaces the u->c->gc chain segment
        if p is not None:
            g.children[p].remove(u)
            g.children[p].append(s)
        else:
            g.root = s
        g.parent[s] = p
        g.children[s] = [u, c, gc]
        g.parent[u] = s
        g.children[c].remove(gc)
        g.children[u].remove(c)
        g.parent[c] = s
        g.parent[gc] = s
        g.chi[s] = chi_s
        g.lam[s] = lam_s

        # common covers: (p,s) inherits cc(p,u); (s,gc) inherits cc(c,gc)
        if p is not None:
            del self.cc[(p, u)]
            self.cc[(p, s)] = cc_pu
        del self.cc[(u, c)]
        del self.cc[(c, gc)]
        self.cc[(s, gc)] = cc_cgc

        # inactivate u and c (heights from their *inactive* children)
        self.active.add(s)
        self.active.discard(u)
        self.active.discard(c)
        self._assign_height(u)
        self._assign_height(c)
        return s

    # ------------------------------------------------------------- invariants
    def check_invariants(self, query: Query, max_width: int) -> None:
        g = self.ghd
        # 1: active(T') is an up-closed tree containing the root
        if self.active:
            assert g.root in self.active
            for n in self.active:
                p = g.parent[n]
                assert p is None or p in self.active, "active set not up-closed"
        # 2: inactive subtrees fully inactive (implied by up-closedness)
        # 3: heights correct for inactive vertices
        for n in g.nodes():
            if n in self.active:
                continue
            kids = g.children.get(n, [])
            expect = 0 if not kids else 1 + max(self.height[k] for k in kids)
            assert self.height[n] == expect, f"height({n}) wrong"
        # 4: covers valid
        for (p, c), cover in self.cc.items():
            shared = g.chi[p] & g.chi[c]
            u = set()
            for alias in cover:
                u |= query.edges[alias]
            assert shared <= u, f"cc({p},{c}) does not cover"
        # 5: GHD valid with width bound
        g.validate(query)
        assert g.width <= max_width, f"width {g.width} > {max_width}"


def select_inactivation_sets(ext: ExtendedGHD) -> Tuple[List[int], List[int]]:
    """Lemma 16 selection: L' = all active leaves; U' = top-down greedy
    pairwise-non-adjacent unique-c-gc vertices (Lemma 26), excluding any
    vertex adjacent to an already-selected one."""
    leaves = set(ext.active_leaves())
    ucgc = set(ext.unique_cgc())
    g = ext.ghd
    # top-down order over active nodes
    order = [n for n in g.topo_order() if n in ext.active]
    selected: List[int] = []
    forbidden: Set[int] = set()
    for n in order:
        if n in ucgc and n not in forbidden:
            selected.append(n)
            # forbid the unique active child (Lemma 26) and active parent
            forbidden.add(ext.active_children(n)[0])
            p = g.parent[n]
            if p is not None:
                forbidden.add(p)
    return sorted(leaves), selected


def log_gta(
    ghd: GHD,
    query: Query,
    check: bool = False,
    trace: Optional[List[Dict]] = None,
) -> GHD:
    """Main Result 2: returns a GHD with width <= max(w, 3iw) and depth
    min(depth, O(log n))."""
    w = ghd.width
    iw = ghd.intersection_width(query)
    bound = max(w, 3 * iw)
    ext = ExtendedGHD.extend(ghd, query)
    iters = 0
    while ext.active:
        leaves, ucgcs = select_inactivation_sets(ext)
        # unique-c-gc ops first (bottom-up so chains re-resolve consistently)
        ucgcs_bottom_up = sorted(ucgcs, key=lambda n: -ext.ghd.depth_of(n))
        for u in ucgcs_bottom_up:
            ext.inactivate_unique_cgc(u)
        for l in leaves:
            if l in ext.active and not ext.active_children(l):
                ext.inactivate_leaf(l)
        iters += 1
        if trace is not None:
            trace.append(
                {
                    "iter": iters,
                    "active": len(ext.active),
                    "size": ext.ghd.size(),
                    "width": ext.ghd.width,
                    "depth": ext.ghd.depth,
                }
            )
        if check:
            ext.check_invariants(query, bound)
        assert iters <= 4 * max(4, ghd.size()).bit_length() + 8, (
            "Log-GTA failed to converge in O(log n) iterations"
        )
    out = ext.ghd
    out.validate(query)
    assert out.width <= bound
    return out
