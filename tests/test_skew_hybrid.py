"""Skew-resilient hybrid exchange: heavy-hitter detection on the count
pre-pass, hybrid routing parity (hybrid == hash == grid on rows), the
pinned padded-slot win under a planted heavy key, the capacity-manager
ceiling, and the exchange_multi duplicate-destination dedupe."""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gym import GymConfig, GymDriver, gym
from repro.core.optimizer import (
    MachineProfile,
    choose_plan,
    skew_from_data,
    skew_share,
)
from repro.core.physical import CapacityCeiling, CapacityManager
from repro.core.queries import star_ghd, star_query
from repro.data.synthetic import star_data_heavy, star_data_sparse, zipf_values
from repro.relational import batched as B
from repro.relational.ops import (
    Overflow,
    dist_join,
    dist_join_hybrid,
    dist_semijoin,
    dist_semijoin_hybrid,
)
from repro.relational.shuffle import exchange_multi
from repro.relational.skew import (
    bcast_dests,
    heavy_dest_flags,
    heavy_dest_flags_many,
    split_dests,
)
from repro.relational.spmd import AXIS, SPMD
from repro.relational.table import DTable


def mk(rows, schema, p=4, cap=None):
    rows = np.asarray(rows, np.int32).reshape(-1, len(schema))
    cap = cap or max(1, -(-rows.shape[0] // p))
    return DTable.scatter_numpy(rows, schema, p, cap=cap)


def planted_pair(p=4, heavy=30, light=10, seed=0):
    """(A, B) join pair with ``heavy`` distinct A-rows sharing B=0."""
    rng = np.random.default_rng(seed)
    a_rows = np.stack(
        [
            rng.permutation(heavy + light),
            np.concatenate([np.zeros(heavy, int), rng.integers(1, 16, light)]),
        ],
        1,
    )
    b_rows = np.stack([np.arange(16), rng.integers(0, 9, 16)], 1)
    return (
        mk(np.unique(a_rows.astype(np.int32), axis=0), ("A", "B"), p, cap=16),
        mk(np.unique(b_rows.astype(np.int32), axis=0), ("B", "C"), p, cap=8),
    )


# ------------------------------------------------------- detection (host)
def test_heavy_dest_flags_threshold_semantics():
    p = 4
    # balanced: 40 rows over 4 dests -> nothing heavy
    counts = np.full((2, p), 5)
    assert not heavy_dest_flags(counts, p, 3.0).any()
    # one dest takes 36 of 48 rows: 3x the balanced share of 12
    skewed = np.array([[18, 2, 2, 2], [18, 2, 2, 2]])
    flags = heavy_dest_flags(skewed, p, 2.0)
    assert flags.tolist() == [True, False, False, False]
    # tiny totals never flag (MIN_HEAVY_ARRIVAL floor)
    tiny = np.array([[4, 0, 0, 0]])
    assert not heavy_dest_flags(tiny, p, 2.0).any()


def test_heavy_dest_flags_many_per_instance():
    p = 4
    counts = np.zeros((2, 2, p), int)  # (shards, k, p)
    counts[:, 0] = [[20, 1, 1, 1]] * 1  # instance 0: skewed
    counts[:, 1] = [[5, 5, 5, 5]] * 1  # instance 1: balanced
    flags = heavy_dest_flags_many(counts, p, 3.0)
    assert flags[0].tolist() == [True, False, False, False]
    assert not flags[1].any()


# -------------------------------------------------- routing (per-shard)
def test_split_and_bcast_dests_route_exactly():
    p = 4
    dest = jnp.asarray([0, 1, 0, 0, p, 2], jnp.int32)  # slot 4 dead
    heavy = jnp.asarray([True, False, False, False])

    def shard(dest):
        return split_dests(dest, heavy, p) + bcast_dests(dest, heavy, p)

    sd, s_hvy, bd, b_hvy = jax.jit(jax.vmap(shard, axis_name=AXIS))(
        jnp.stack([dest] * p)
    )
    # light rows keep their hash dest; dead rows stay dead
    for s in range(p):
        assert int(sd[s, 1]) == 1 and int(sd[s, 5]) == 2 and int(sd[s, 4]) == p
        # heavy rows (0, 2, 3) spread round-robin offset by shard id
        assert sorted(int(x) for x in sd[s, [0, 2, 3]]) == sorted(
            (i + s) % p for i in range(3)
        )
        # broadcast: heavy rows to all p dests, light to slot-0 dest only
        assert bd[s, 0].tolist() == list(range(p))
        assert int(bd[s, 1, 0]) == 1 and all(int(x) == p for x in bd[s, 1, 1:])
    assert s_hvy.sum() == p * 3 and b_hvy.sum() == p * 3


def test_exchange_multi_dedupes_duplicate_destinations():
    """A row listing the same live destination twice must be delivered
    (and counted) once — duplicate slots collapse to the skip slot p."""
    p = 2
    data = jnp.asarray([[7, 8]], jnp.int32)
    valid = jnp.ones((1,), bool)
    dests = jnp.asarray([[1, 1, 0, 0]], jnp.int32)  # each real dest twice

    def shard(d, v, dst):
        return exchange_multi(d, v, dst, p=p, c_out=4, cap_recv=8)

    rd, rv, sent, ds, dr = jax.jit(jax.vmap(shard, axis_name=AXIS))(
        jnp.stack([data] * p), jnp.stack([valid] * p), jnp.stack([dests] * p)
    )
    assert int(sent.sum()) == p * 2  # 2 distinct dests per row, not 4
    assert int(ds.sum()) == 0 and int(dr.sum()) == 0
    # every shard received one copy from each sender, no duplicates
    assert int(rv.sum()) == p * 2
    for s in range(p):
        got = [tuple(map(int, r)) for r, ok in zip(rd[s], rv[s]) if ok]
        assert got == [(7, 8)] * 2


def test_grid_size_one_dimension_emits_distinct_destinations():
    """Grid shares with a size-1 dimension (tiny relation vs large one)
    must not double-send: sent == rows * cells-per-row exactly."""
    rng = random.Random(3)
    spmd = SPMD(4)
    big = mk(
        [[rng.randint(0, 9), rng.randint(0, 9)] for _ in range(24)],
        ("A", "B"), 4, cap=8,
    )
    tiny = mk([[1, 2]], ("B", "C"), 4, cap=8)
    from repro.relational.grid import _grid_shares, grid_join

    g = _grid_shares([big.cap * big.p, tiny.cap * tiny.p], spmd.p)
    out, st = grid_join(spmd, big, tiny, out_cap=64)
    ref, _ = dist_join(spmd, big, tiny, seed=1, out_cap=64)
    assert out.to_set() == ref.to_set()
    # each relation sends each row to exactly prod(g)/g_self cells
    n_big = int(np.asarray(big.valid).sum())
    n_tiny = int(np.asarray(tiny.valid).sum())
    cells = g[0] * g[1]
    assert st["sent"] == n_big * (cells // g[0]) + n_tiny * (cells // g[1])


# ------------------------------------------------ operator-level parity
def test_hybrid_join_matches_hash_and_reports_heavy():
    spmd = SPMD(4)
    a, b = planted_pair(seed=1)
    ref, ref_st = dist_join(spmd, a, b, seed=5, out_cap=256)
    hyb, hyb_st = dist_join_hybrid(spmd, a, b, seed=5, out_cap=256)
    assert hyb.to_set() == ref.to_set()
    assert hyb_st["dropped"] == 0
    assert hyb_st["heavy"] > 0  # the planted key actually routed heavy


def test_hybrid_semijoin_matches_hash():
    spmd = SPMD(4)
    a, b = planted_pair(seed=2)
    ref, _ = dist_semijoin(spmd, a, b, seed=7)
    hyb, st = dist_semijoin_hybrid(spmd, a, b, seed=7)
    assert hyb.to_set() == ref.to_set()
    assert st["dropped"] == 0 and st["heavy"] > 0


def test_hybrid_unskewed_is_bit_identical_to_hash():
    """No heavy keys detected -> the hybrid ops ARE the hash ops (same
    rows, same sent, zero heavy)."""
    rng = random.Random(4)
    spmd = SPMD(4)
    rows_a = np.unique(
        np.asarray([[rng.randint(0, 30), rng.randint(0, 30)] for _ in range(20)],
                   np.int32), axis=0)
    rows_b = np.unique(
        np.asarray([[rng.randint(0, 30), rng.randint(0, 30)] for _ in range(20)],
                   np.int32), axis=0)
    a, b = mk(rows_a, ("A", "B"), cap=8), mk(rows_b, ("B", "C"), cap=8)
    ref, ref_st = dist_join(spmd, a, b, seed=9, out_cap=128)
    hyb, hyb_st = dist_join_hybrid(spmd, a, b, seed=9, out_cap=128)
    assert hyb.to_set() == ref.to_set()
    assert hyb_st["heavy"] == 0
    assert hyb_st["sent"] == ref_st["sent"]


def test_measure_join_swaps_spread_to_the_heavy_side():
    """The measure must spread the side with the larger heavy mass: with
    the planted mass on the RIGHT operand, swap_spread is True and the
    hybrid out_need stays balanced (strictly below the hash pile-up)."""
    spmd = SPMD(4)
    a, b = planted_pair(seed=3)
    m_fwd = B.measure_join_many(spmd, [a], [b], seeds=[11], hybrid=True)
    assert m_fwd.hybrid_routed and not m_fwd.swap_spread  # heavy mass on lhs
    m_rev = B.measure_join_many(spmd, [b], [a], seeds=[11], hybrid=True)
    assert m_rev.hybrid_routed and m_rev.swap_spread  # heavy mass on rhs
    m_hash = B.measure_join_many(spmd, [b], [a], seeds=[11])
    assert not m_hash.hybrid_routed
    assert m_rev.out_need <= m_hash.out_need


# --------------------------------------------------- end-to-end (pinned)
def _planted_star():
    q, g = star_query(8), star_ghd(8)
    data = star_data_heavy(
        8, hub_rows=64, heavy_share=0.8, domain=32, spoke_extra=8, seed=5
    )
    return q, g, data


def _run_star(engine, data=None, **cfg):
    q, g, d = _planted_star()
    rows, _, led = gym(
        q, d if data is None else data, ghd=g, p=4,
        config=GymConfig(strategy=engine, seed=3, **cfg),
    )
    return sorted(map(tuple, rows)), led


def test_planted_heavy_star_hybrid_parity_and_padded_win():
    """The acceptance pin: on a planted heavy-key S_8 instance the hybrid
    engine produces bit-identical rows to hash AND grid, with zero
    abort-retries and strictly fewer padded wire slots than hash."""
    rows_hash, led_hash = _run_star("hash")
    rows_grid, led_grid = _run_star("grid")
    rows_hyb, led_hyb = _run_star("hybrid")
    assert rows_hyb == rows_hash == rows_grid
    assert led_hyb.retries == 0
    assert led_hyb.padded_slots < led_hash.padded_slots, (
        led_hyb.padded_slots, led_hash.padded_slots,
    )
    assert led_hyb.heavy_tuples > 0
    assert led_hyb.light_tuples == led_hyb.shuffle_tuples - led_hyb.heavy_tuples


def test_hybrid_uniform_star_identical_to_hash():
    """On an unskewed instance the hybrid engine IS the hash engine —
    rows, comm, padded slots, and dispatch count all bit-identical."""
    q, g = star_query(5), star_ghd(5)
    data = star_data_sparse(5, seed=9)
    out = {}
    for eng in ("hash", "hybrid"):
        rows, _, led = gym(
            q, data, ghd=g, p=4, config=GymConfig(strategy=eng, seed=3)
        )
        out[eng] = (sorted(map(tuple, rows)), led)
    (rh, lh), (ry, ly) = out["hash"], out["hybrid"]
    assert rh == ry
    assert lh.comm_tuples == ly.comm_tuples
    assert lh.padded_slots == ly.padded_slots
    assert lh.measured_dispatches == ly.measured_dispatches
    assert ly.heavy_tuples == 0


def test_hybrid_snapshot_resume_replays_heavy_decision(tmp_path):
    """Snapshot mid-query under the hybrid engine: the snapshot
    round-trips the routing decision's inputs (strategy + skew
    threshold), so a resuming driver — even one constructed with a plain
    hash config — keeps routing heavy keys and finishes with the
    uninterrupted answer.  (Per-round seeds restart on resume, exactly
    as for the hash engine, so comm/heavy may differ by a few tuples;
    the row set may not.)"""
    q, g, data = _planted_star()
    cfg = GymConfig(strategy="hybrid", seed=3, skew_threshold=3.0)
    want, _, led_full = gym(q, data, ghd=g, p=4, config=cfg)
    want = sorted(map(tuple, want))
    assert led_full.heavy_tuples > 0

    drv = GymDriver(q, g, data, SPMD(4), cfg)
    drv.step()
    drv.step()
    snap = str(tmp_path / "hybrid_snap.npz")
    drv.save(snap)
    drv2 = GymDriver(q, g, data, SPMD(4), GymConfig(seed=3))
    drv2.load(snap)
    assert drv2.config.strategy == "hybrid"
    assert drv2.config.skew_threshold == 3.0
    assert drv2.executor.engine.name == "hybrid"
    assert drv2.executor.calibrate  # forced on by requires_measure
    out = drv2.run()
    assert sorted(map(tuple, out.to_numpy())) == want
    assert drv2.ledger.heavy_tuples > 0  # heavy routing survived resume


# --------------------------------------------------- capacity ceiling
def test_capacity_manager_ceiling_is_actionable():
    capman = CapacityManager(SPMD(2), max_cap=64)
    capman.heavy_hint = 3
    capman.ensure(0, 64)  # at the bound: fine
    with pytest.raises(CapacityCeiling) as ei:
        capman.grow((0,), dropped=1000)
    msg = str(ei.value)
    assert "3 heavy destination(s)" in msg
    assert "engine='hybrid'" in msg and "engine='grid'" in msg
    assert "max_cap 64" in msg
    # CapacityCeiling is an Overflow: existing retry plumbing catches it
    assert isinstance(ei.value, Overflow)
    with pytest.raises(CapacityCeiling):
        capman.ensure(1, 65)
    # unbounded manager never raises
    CapacityManager(SPMD(2)).grow((0,), dropped=1 << 30)


def test_driver_derives_finite_max_cap():
    q, g, data = _planted_star()
    drv = GymDriver(q, g, data, SPMD(4), GymConfig(seed=3))
    assert drv.capman.max_cap is not None
    assert drv.capman.max_cap >= 1 << 16  # generous floor
    drv2 = GymDriver(
        q, g, data, SPMD(4), GymConfig(seed=3, max_cap_tuples=12345)
    )
    assert drv2.capman.max_cap == 12345


# ------------------------------------------------------------- advisor
def test_advisor_picks_hybrid_on_skew_hash_on_uniform():
    q, g = star_query(8), star_ghd(8)
    skewed = star_data_heavy(8, hub_rows=64, heavy_share=0.8, seed=5)
    uniform = star_data_sparse(8, seed=21)
    from repro.core.optimizer import stats_from_data

    for data, want_engine in ((skewed, "hybrid"), (uniform, "hash")):
        stats = stats_from_data(q, data)
        skew = skew_from_data(q, data)
        plan = choose_plan(
            q, stats, profile=MachineProfile(p=8), hand_ghd=g, skew=skew
        )
        assert plan.engine == want_engine, (want_engine, plan.key, skew)


def test_skew_share_statistic():
    assert skew_share(np.zeros((0, 2))) == 0.0
    rows = np.array([[0, 1], [0, 2], [0, 3], [1, 4]])
    assert skew_share(rows) == pytest.approx(0.75)  # column A: 3/4 zeros
    rng = np.random.default_rng(0)
    z = zipf_values(rng, 1000, 32, 1.1)
    u = zipf_values(rng, 1000, 32, 0.0)
    share_z = np.bincount(z).max() / 1000
    share_u = np.bincount(u).max() / 1000
    assert share_z > 3 * share_u  # zipf plants a real heavy hitter


# -------------------------------------------------- hypothesis property
@pytest.mark.slow
def test_hybrid_join_property_matches_hash():
    """Property pin: random tables with random planted duplication — the
    hybrid join's row set always equals the hash join's, drops nothing,
    at any skew threshold."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rows=st.integers(1, 24),
        dom=st.integers(1, 8),
        heavy=st.integers(0, 20),
        thresh=st.sampled_from([1.5, 3.0, 6.0]),
    )
    def prop(seed, rows, dom, heavy, thresh):
        rng = np.random.default_rng(seed)
        spmd = SPMD(4)
        a_rows = np.stack(
            [
                rng.integers(0, 64, rows + heavy),
                np.concatenate(
                    [rng.integers(0, dom, rows), np.zeros(heavy, int)]
                ),
            ],
            1,
        )
        b_rows = np.stack(
            [rng.integers(0, dom, rows), rng.integers(0, 5, rows)], 1
        )
        a = mk(np.unique(a_rows.astype(np.int32), axis=0), ("A", "B"), cap=16)
        b = mk(np.unique(b_rows.astype(np.int32), axis=0), ("B", "C"), cap=16)
        ref, _ = dist_join(spmd, a, b, seed=seed & 0xFFFF, out_cap=512)
        hyb, st_h = dist_join_hybrid(
            spmd, a, b, seed=seed & 0xFFFF, out_cap=512, skew_threshold=thresh
        )
        assert hyb.to_set() == ref.to_set()
        assert st_h["dropped"] == 0

    prop()
