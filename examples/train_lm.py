"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on batches assembled by the GYM relational pipeline.

Full run (about an hour on CPU):
    PYTHONPATH=src python examples/train_lm.py --steps 300
Quick check:
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import CONFIGS
from repro.configs.registry import get_model
from repro.data import CorpusConfig, batches
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step
from repro.train import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param smollm-family config (12 x 768, 49k vocab ~ 97M params)
    base = CONFIGS["smollm-360m"]
    if args.tiny:
        cfg = dataclasses.replace(
            base, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=256, vocab=1024, pattern=(), dtype="float32",
        )
        batch, seq = 4, 64
    else:
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_ff=2048, vocab=49152, pattern=(), dtype="float32",
        )
        batch, seq = 8, 256

    model = get_model(cfg)
    n_params = sum(
        l.size for l in jax.tree_util.tree_leaves(model.init_shapes())
    )
    print(f"arch={cfg.name}-variant params={n_params/1e6:.1f}M")

    tcfg = TrainConfig(opt=OptConfig(lr=3e-4, warmup=20, decay_steps=args.steps))
    params, opt = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    data = batches(CorpusConfig(seed=23), batch=batch, seq=seq, vocab=cfg.vocab)
    t0 = time.time()
    for step in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step_fn(params, opt, b)
        if step % 10 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss {float(m['loss']):.4f} "
                f"({(time.time()-t0):.0f}s)", flush=True,
            )
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt, step + 1, {"params": params, "opt": opt})
            print(f"  checkpoint @ {step+1}")
    print("done")


if __name__ == "__main__":
    main()
