"""Table 2: worst-case performance on S_n — Shares vs ACQ-MR vs GYM.

Measured on synthetic data via the engine ledger; the paper's ordering to
reproduce: GYM uses O(log n) rounds like ACQ-MR but strictly less
communication; Shares is 1 round."""
from __future__ import annotations

import math

from repro.core.acq_mr import acq_mr
from repro.core.gym import GymConfig, gym
from repro.core.queries import star_ghd, star_query
from repro.core.shares import shares_join
from repro.data.synthetic import star_data_sparse


def run() -> list:
    n = 5
    q = star_query(n)
    g = star_ghd(n)
    data = star_data_sparse(n, seed=1)

    r_sh, _, led_sh = shares_join(q, data, p=8)
    r_gym, _, led_gym = gym(q, data, ghd=g, p=8, config=GymConfig(seed=2))
    r_acq, _, led_acq = acq_mr(q, data, ghd=g, p=8, config=GymConfig(seed=2))
    assert {tuple(r) for r in r_sh} == {tuple(r) for r in r_gym} == {
        tuple(r) for r in r_acq
    }

    out = [
        dict(bench="table2", alg="Shares", rounds=led_sh.rounds,
             comm=led_sh.comm_tuples, out=led_sh.output_tuples),
        dict(bench="table2", alg="ACQ-MR", rounds=led_acq.rounds,
             comm=led_acq.comm_tuples, out=led_acq.output_tuples),
        dict(bench="table2", alg="GYM", rounds=led_gym.rounds,
             comm=led_gym.comm_tuples, out=led_gym.output_tuples),
    ]
    # paper orderings: Shares = 1 round; GYM comm <= ACQ-MR comm (ACQ-MR
    # materializes 3-relation joins; GYM's star GHD is width-1)
    assert led_sh.rounds == 1
    assert led_gym.shuffle_tuples <= led_acq.shuffle_tuples, (
        led_gym.shuffle_tuples, led_acq.shuffle_tuples
    )
    # GYM on the depth-1 GHD uses O(log n) rounds
    assert led_gym.rounds <= 4 * (math.ceil(math.log2(max(2, n))) + 2)
    return out
