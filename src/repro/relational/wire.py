"""Packed wire format for the exchange: bit-level codec + width policy.

The dense exchange ships ``(p, c_out, arity)`` int32 cells plus a
``(p, c_out)`` bool valid plane — 32 bits per cell and 8 per flag even
when every value fits in 6 bits.  This module closes that gap with an
exact, shape-static codec:

- ``WireFormat`` fixes a per-column bit width; a row packs as
  ``1 valid bit + sum(col_bits)`` contiguous bits, and a whole
  destination bucket of ``c_out`` rows packs as one contiguous bit
  stream padded up to bytes.  ``wire_encode``/``wire_decode`` are exact
  inverses for any int32 whose value fits the column width (a 32-bit
  column round-trips arbitrary int32, sign bit included, via uint32
  bitcast).
- ``WirePolicy`` derives sound widths from the *base relations'* value
  ranges, observed once on the host before sharding.  Joins, semijoins,
  intersections and dedups never create new attribute values, so a
  width that covers the base columns of an attribute covers every
  intermediate of the query — the format is safe across rounds, caps
  cache hits, retries and prefetch without any runtime overflow guard.
  (``wire_overflow`` exists for tests and hand-built formats.)
- A fused op group's mixed-schema exchanges concatenate their encoded
  buffers into ONE segmented uint8 buffer (``pack_segments`` /
  ``split_segments``), so the group ships a single ``all_to_all``
  instead of one data + one valid collective per exchange per op.
- ``register_codec`` is the compression hook: a codec wraps the packed
  bytes right before/after the collective, mirroring the
  encode/decode/roundtrip shape of ``train.compression`` (its int8
  quantizer is the lossy archetype; the exchange's exact channel ships
  the ``raw`` identity codec by default).

Bit layout (pinned by the golden fixture in ``tests/fixtures``): a
bucket's slots are processed in groups of 8 consecutive slots (the
bucket is padded with invalid slots up to a multiple of 8 — free for
the pow2 capacities the calibrator emits); each group packs to exactly
``row_bits`` bytes, where byte ``b`` holds bit ``b`` of every slot's
row stream — slot ``r`` of the group lands in bit ``r`` of the byte.
Within a row stream the valid bit comes first, then each column's bits
least-significant-first.  The transposed (bit-planar) order lets the
codec run as one static gather plus eight shift-or folds instead of a
per-bit byte re-alignment — ~4x cheaper on the CPU simulator, which is
what keeps packed steady-state wall clock at parity with dense.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MAX_BITS = 32  # columns are int32; 32-bit columns bitcast via uint32


def value_bits(lo: int, hi: int) -> int:
    """Bits needed to represent every integer in [lo, hi] exactly.

    Negative values fall back to the full 32-bit width (the codec
    bitcasts through uint32, so 32 bits round-trip any int32)."""
    if lo < 0:
        return MAX_BITS
    return min(MAX_BITS, max(1, int(hi).bit_length()))


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Per-column bit widths of one exchange payload.  Frozen and
    hashable so it rides through ``SPMD.run`` as a jit static next to
    ``c_out``/``cap_recv``."""

    col_bits: Tuple[int, ...]
    codec: str = "raw"

    @property
    def arity(self) -> int:
        return len(self.col_bits)

    @property
    def row_bits(self) -> int:
        return 1 + sum(self.col_bits)  # leading valid bit

    def bucket_bytes(self, c_out: int) -> int:
        """Bytes one destination bucket of ``c_out`` slots packs to:
        ``row_bits`` bytes per group of 8 slots (bucket padded up to a
        multiple of 8 — exact for the pow2 capacities in practice)."""
        return (-(-c_out // 8)) * self.row_bits

    def bit_map(self) -> Tuple[np.ndarray, np.ndarray]:
        """Static per-row-bit source map: bit ``b`` of the row stream
        reads ``(source column, shift)`` where source 0 is the valid
        plane and source ``1+j`` is payload column ``j``."""
        srcs, shifts = [0], [0]
        for j, nb in enumerate(self.col_bits):
            srcs.extend([j + 1] * nb)
            shifts.extend(range(nb))
        return np.asarray(srcs), np.asarray(shifts, dtype=np.uint32)

    @property
    def row_payload_bytes(self) -> int:
        """Dense int32 bytes of one useful row (the tuple-accounting
        byte value, independent of the wire encoding)."""
        return 4 * max(1, self.arity)

    @staticmethod
    def union(fmts: Sequence["WireFormat"]) -> "WireFormat":
        """Widest-per-column union — the group-uniform format of a fused
        op group (wider is always sound)."""
        assert fmts
        ar = fmts[0].arity
        assert all(f.arity == ar for f in fmts), [f.arity for f in fmts]
        assert all(f.codec == fmts[0].codec for f in fmts)
        return WireFormat(
            tuple(max(f.col_bits[j] for f in fmts) for j in range(ar)),
            codec=fmts[0].codec,
        )


@dataclasses.dataclass(frozen=True)
class WirePolicy:
    """Sound per-attribute bit widths for one query, derived from the
    base relations before sharding.  ``format_for`` projects the policy
    onto any intermediate schema."""

    attr_bits: Tuple[Tuple[str, int], ...]
    default_bits: int = MAX_BITS
    codec: str = "raw"

    @classmethod
    def from_columns(
        cls,
        items: Iterable[Tuple[Sequence[str], np.ndarray]],
        *,
        codec: str = "raw",
    ) -> "WirePolicy":
        """items: (schema, host rows (n, arity)) per base relation.  An
        attribute's width covers its values in EVERY base column that
        carries it; attributes with no rows pack to 1 bit."""
        bits: Dict[str, int] = {}
        for schema, rows in items:
            rows = np.asarray(rows)
            for j, attr in enumerate(schema):
                if rows.shape[0]:
                    col = rows[:, j]
                    b = value_bits(int(col.min()), int(col.max()))
                else:
                    b = 1
                bits[attr] = max(bits.get(attr, 1), b)
        return cls(tuple(sorted(bits.items())), codec=codec)

    def bits_for(self, attr: str) -> int:
        for a, b in self.attr_bits:
            if a == attr:
                return b
        return self.default_bits

    def format_for(self, schema: Sequence[str]) -> WireFormat:
        return WireFormat(
            tuple(self.bits_for(a) for a in schema), codec=self.codec
        )


# ------------------------------------------------------------------- codec
def wire_encode(buf: jax.Array, valid: jax.Array, fmt: WireFormat) -> jax.Array:
    """Pack ``buf (..., c, arity) int32`` + ``valid (..., c) bool`` into
    a ``(..., fmt.bucket_bytes(c)) uint8`` bit stream.  Values must fit
    their column width (``WirePolicy`` guarantees this; see
    ``wire_overflow`` for checking hand-built formats)."""
    c = valid.shape[-1]
    cp = -(-c // 8) * 8  # slots padded to whole groups of 8
    u = jax.lax.bitcast_convert_type(buf.astype(jnp.int32), jnp.uint32)
    u2 = jnp.concatenate([valid.astype(jnp.uint32)[..., None], u], axis=-1)
    if cp != c:
        width = [(0, 0)] * (u2.ndim - 2) + [(0, cp - c), (0, 0)]
        u2 = jnp.pad(u2, width)  # padded slots are invalid all-zero rows
    srcs, shifts = fmt.bit_map()
    # one static gather fans (..., cp, 1+arity) words out to the per-bit
    # lanes; eight shift-or folds transpose each group of 8 slots into
    # its row_bits output bytes (bit r of a byte = slot r of the group)
    bits = ((u2[..., srcs] >> jnp.asarray(shifts)) & 1).astype(jnp.uint8)
    g = bits.reshape(bits.shape[:-2] + (cp // 8, 8, fmt.row_bits))
    acc = g[..., 0, :]
    for r in range(1, 8):
        acc = acc | (g[..., r, :] << r)
    return acc.reshape(acc.shape[:-2] + (cp // 8 * fmt.row_bits,))


def wire_decode(
    packed: jax.Array, fmt: WireFormat, c_out: int
) -> Tuple[jax.Array, jax.Array]:
    """Exact inverse of ``wire_encode``: ``(..., nbytes) uint8`` back to
    ``(buf (..., c_out, arity) int32, valid (..., c_out) bool)``.
    Invalid slots decode to all-zero rows — bit-identical to the dense
    path's zero-filled buckets."""
    cp = -(-c_out // 8) * 8
    bb = packed.reshape(packed.shape[:-1] + (cp // 8, fmt.row_bits))
    shifts = jnp.arange(8, dtype=jnp.uint8)[:, None]
    # undo the group transpose: slot r of a group reads bit r of every
    # one of its row_bits bytes
    lanes = (bb[..., None, :] >> shifts) & 1  # (..., cp/8, 8, row_bits)
    rows = lanes.reshape(lanes.shape[:-3] + (cp, fmt.row_bits))
    valid = rows[..., 0].astype(bool)
    cols = []
    off = 1
    for nb in fmt.col_bits:
        chunk = rows[..., off : off + nb].astype(jnp.uint32)
        weights = jnp.uint32(1) << jnp.arange(nb, dtype=jnp.uint32)
        acc = jnp.sum(chunk * weights, axis=-1)  # wraps mod 2^32: exact
        cols.append(jax.lax.bitcast_convert_type(acc, jnp.int32))
        off += nb
    if cols:
        buf = jnp.stack(cols, axis=-1)
    else:
        buf = jnp.zeros(valid.shape + (0,), jnp.int32)
    return buf[..., :c_out, :], valid[..., :c_out]


def wire_overflow(buf: jax.Array, valid: jax.Array, fmt: WireFormat):
    """True where a VALID row holds a value its column width cannot
    represent (negative, or >= 2^bits, for widths < 32).  A policy
    derived via ``WirePolicy.from_columns`` never overflows; this guards
    tests and hand-built formats."""
    bad = jnp.zeros(valid.shape, bool)
    for j, nb in enumerate(fmt.col_bits):
        if nb >= MAX_BITS:
            continue
        col = buf[..., j]
        bad = bad | (col < 0) | ((col >> nb) != 0)
    return bad & valid


# -------------------------------------------------------------- segmentation
def pack_segments(wires: Sequence[jax.Array]) -> jax.Array:
    """Concatenate per-exchange encoded buffers ``(p, nbytes_i)`` into
    one segmented ``(p, sum nbytes_i)`` buffer — the fused group ships a
    single ``all_to_all`` for every op and side."""
    return jnp.concatenate(list(wires), axis=-1)


def split_segments(
    seg: jax.Array, sizes: Sequence[int]
) -> List[jax.Array]:
    """Undo ``pack_segments`` with the static per-segment byte sizes."""
    out = []
    off = 0
    for n in sizes:
        out.append(seg[..., off : off + n])
        off += n
    assert off == seg.shape[-1], (off, seg.shape)
    return out


# ------------------------------------------------------------ byte accounting
def dense_wire_bytes(p: int, c_out: int, arity: int = 1) -> int:
    """Bytes the DENSE exchange ships end-to-end: p shards x p bucket
    segments x c_out slots of (4-byte int32 cells + 1-byte valid flag).
    The byte-true sibling of ``shuffle.padded_slots``."""
    return p * p * c_out * (4 * max(1, arity) + 1)


def packed_wire_bytes(p: int, c_out: int, fmt: WireFormat) -> int:
    """Bytes the PACKED exchange ships end-to-end for the same grid."""
    return p * p * fmt.bucket_bytes(c_out)


def count_wire_bytes(p: int, n: int = 1) -> int:
    """Bytes of ``n`` count-only pre-pass vectors ((p,)-int32 per shard,
    no valid plane) — the pre-pass's own traffic, previously hidden by
    the slot metric."""
    return n * p * p * 4


def wire_gain(fmts: Sequence[Optional[WireFormat]]) -> float:
    """Advisor-side mean compression ratio of a set of exchange formats:
    dense row bits (32/col + 8 valid) over packed row bits.  1.0 for
    dense (None) entries; used by ``costs.shuffle_pad_factor`` to
    reprice packed plans."""
    ratios = []
    for f in fmts:
        if f is None:
            ratios.append(1.0)
        else:
            dense_bits = 32 * max(1, f.arity) + 8
            ratios.append(dense_bits / f.row_bits)
    return float(np.mean(ratios)) if ratios else 1.0


# ------------------------------------------------------------ compression hook
# A codec wraps the packed bytes right before/after the collective:
# encode(u8) -> (payload, aux), decode(payload, aux) -> u8 — the same
# encode/decode/roundtrip contract as train.compression's int8
# quantizer (the lossy archetype for non-exact channels; the exchange's
# exact channel registers only shape-static, lossless codecs).
_CODECS: Dict[str, Tuple[Callable, Callable]] = {}


def register_codec(name: str, encode: Callable, decode: Callable) -> None:
    _CODECS[name] = (encode, decode)


def get_codec(name: str) -> Tuple[Callable, Callable]:
    if name not in _CODECS:
        raise KeyError(f"unknown wire codec {name!r}: {sorted(_CODECS)}")
    return _CODECS[name]


def codec_roundtrip(buf: jax.Array, name: str = "raw") -> jax.Array:
    """Encode+decode through a registered codec (test mirror of
    ``train.compression.codec_roundtrip``)."""
    enc, dec = get_codec(name)
    payload, aux = enc(buf)
    return dec(payload, aux)


register_codec("raw", lambda b: (b, ()), lambda b, aux: b)
