"""Round fusion: measured SPMD dispatches and wall-clock, fused (one
dispatch per homogeneous op group) vs sequential (one dispatch per op),
on schedules with real per-round parallelism.

The claimed BSP rounds are identical either way — the schedule decides
those — so the interesting columns are ``dispatches`` (must strictly drop
for fused) and per-phase dispatch/op ratios.
"""
from __future__ import annotations

import time

from repro.core.gym import GymConfig, gym
from repro.core.queries import (
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import star_data_sparse, tc_data_sparse

DYM_PHASES = ("upward", "downward", "join")


def _dym_stats(ledger):
    recs = [r for r in ledger.records if r.phase in DYM_PHASES]
    return {
        "dym_dispatches": sum(r.dispatches for r in recs),
        "dym_ops": sum(len(r.ops) for r in recs),
        "dym_rounds_claimed": sum(r.n_rounds for r in recs),
    }


def run() -> list:
    out = []
    cases = [
        ("S_8", star_query(8), star_ghd(8), star_data_sparse(8, seed=21)),
        ("TC_9", triangle_chain_query(3), triangle_chain_ghd(3), tc_data_sparse(3, seed=22)),
    ]
    for name, q, g, data in cases:
        for strat in ("hash", "grid"):
            res = {}
            for fused in (True, False):
                cfg = GymConfig(strategy=strat, seed=23, fused=fused)
                t0 = time.time()
                rows, _, led = gym(q, data, ghd=g, p=8, config=cfg)
                secs = time.time() - t0
                res[fused] = (rows, led, secs)
                stats = _dym_stats(led)
                out.append(
                    dict(
                        bench="fusion",
                        query=name,
                        strategy=strat,
                        mode="fused" if fused else "sequential",
                        dispatches=led.measured_dispatches,
                        rounds_claimed=led.rounds,
                        comm=led.comm_tuples,
                        secs=round(secs, 2),
                        **stats,
                    )
                )
            rows_f, led_f, _ = res[True]
            rows_s, led_s, _ = res[False]
            # fusion repacks work; it must not change results or cost model
            assert {tuple(r) for r in rows_f} == {tuple(r) for r in rows_s}
            assert led_f.comm_tuples == led_s.comm_tuples, (name, strat)
            assert led_f.rounds == led_s.rounds
            # and it must strictly reduce measured dispatches
            assert led_f.measured_dispatches < led_s.measured_dispatches, (
                name, strat, led_f.measured_dispatches, led_s.measured_dispatches,
            )
    return out
