"""Example scripts as smoke tests: ``examples/quickstart.py`` and
``examples/gym_fault_tolerance.py`` have drifted silently across past
refactors because CI never executed them.  Running them in-process (they
end in asserts of their own) pins the public API surface they exercise —
``gym()``, ``GymConfig``, ``GymDriver`` save/load, ``shares_join``,
``JoinServer`` — against exactly the code paths the docs tell users to
copy."""
from __future__ import annotations

import os
import runpy

import pytest

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples")


@pytest.mark.parametrize(
    "script",
    [
        "quickstart.py",
        "gym_fault_tolerance.py",
        "serve_joins.py",
        "moe_routing.py",
    ],
)
def test_example_runs_clean(script, capsys):
    path = os.path.abspath(os.path.join(EXAMPLES, script))
    runpy.run_path(path, run_name="__main__")
    out = capsys.readouterr().out
    assert "Ledger(" in out  # every example prints its cost ledger
