"""End-to-end training driver: GYM-assembled data pipeline -> sharded
train step -> checkpoint/restart loop with straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --batch 8 --seq 128 --reduced --ckpt /tmp/run1

``--reduced`` runs the family-faithful smoke-scale config on CPU; on a TPU
pod the full config + production mesh engage automatically."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_model, reduced_config
from repro.data import CorpusConfig, batches
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.shardings import batch_specs, named, opt_state_specs, param_specs
from repro.train import (
    OptConfig,
    TrainConfig,
    init_train_state,
    make_train_step,
)
from repro.train import checkpoint as ckpt
from repro.train.elastic import HeartbeatMonitor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt_every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress_grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = get_model(cfg)
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, warmup=10, decay_steps=max(100, args.steps)),
        accum=args.accum,
        compress_grads=args.compress_grads,
    )

    n_dev = len(jax.devices())
    mesh = make_debug_mesh(n_dev, 1) if n_dev < 256 else make_production_mesh()
    params, opt_state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    psp = named(mesh, param_specs(params, mesh))
    osp = named(mesh, opt_state_specs(opt_state, None, mesh))
    params = jax.device_put(params, psp)
    opt_state = jax.device_put(opt_state, osp)

    start = 0
    if args.resume and args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        restored, extra = ckpt.restore(
            args.ckpt, {"params": params, "opt": opt_state}
        )
        params, opt_state = restored["params"], restored["opt"]
        start = int(extra.get("next_step", 0))
        print(f"[resume] from step {start}")

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    data = batches(
        CorpusConfig(seed=17), batch=args.batch, seq=args.seq, vocab=cfg.vocab
    )
    hb = HeartbeatMonitor()
    pending = None
    for step in range(start, args.steps):
        hb.start()
        batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        loss = float(m["loss"])
        dt, straggling = hb.stop()
        print(
            f"step {step:5d} loss {loss:.4f} gnorm {float(m['grad_norm']):.3f} "
            f"{dt*1e3:.0f}ms{' STRAGGLER' if straggling else ''}",
            flush=True,
        )
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt.save_async(
                args.ckpt, step + 1,
                {"params": params, "opt": opt_state},
                extra={"next_step": step + 1},
            )
    if pending is not None:
        pending.join()
    if args.ckpt:
        ckpt.save(
            args.ckpt, args.steps, {"params": params, "opt": opt_state},
            extra={"next_step": args.steps},
        )
    print("[done]")


if __name__ == "__main__":
    main()
