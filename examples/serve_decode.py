"""Serving demo: batched prefill + decode on three cache families —
attention KV (smollm), recurrent state (xlstm), and enc-dec cross-KV
(whisper) — using the reduced configs on CPU.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp

from repro.configs import CONFIGS, get_model, make_smoke_batch, reduced_config
from repro.serve import generate, generate_whisper

for arch in ("smollm-360m", "xlstm-125m", "whisper-small"):
    cfg = reduced_config(CONFIGS[arch])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.encdec:
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (2, 32, cfg.d_model), cfg.jdtype
        )
        toks = generate_whisper(model, params, frames, steps=8, dec_cache=16)
    else:
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
        toks = generate(model, params, prompt, steps=8)
    assert toks.shape == (2, 8)
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab).all())
    print(f"{arch:14s} generated: {toks.tolist()}")
print("ok")
