"""Generic decoder LM over a block pattern: one model class serves dense,
MoE, SSM, xLSTM, hybrid (zamba2 shared-attention), and VLM (M-RoPE)
architectures.  Homogeneous pattern segments run under ``lax.scan`` over
stacked per-layer params (small HLO at 80+ layers); per-layer remat is a
config switch on the train path.

Public surface (used by train/serve/launch):
  init(rng) / init_shapes()                 params pytree
  loss(params, batch)                       f32 scalar
  prefill(params, batch)  -> (logits_last, caches)
  decode_step(params, caches, tokens) -> (logits, caches)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attn_decode, attn_forward, attn_prefill, init_attn
from .common import (
    ArchConfig,
    embed,
    init_embed,
    init_norm,
    rms_norm,
    softmax_xent,
    stack_init,
    unembed,
)
from .mlp import init_mlp, init_moe, mlp_forward, moe_forward, moe_forward_stats
from .ssm import (
    init_mamba,
    mamba_decode,
    mamba_forward,
    mamba_init_state,
    mamba_prefill,
)
from .xlstm import (
    init_mlstm,
    init_slstm,
    mlstm_decode,
    mlstm_forward,
    mlstm_init_state,
    mlstm_prefill,
    slstm_decode,
    slstm_forward,
    slstm_init_state,
    slstm_prefill,
)

ATTN_KINDS = ("attn", "local", "moe", "shared_attn")


def _init_block(kind: str, rng, cfg: ArchConfig):
    if kind in ("attn", "local", "shared_attn"):
        ka, km = jax.random.split(rng)
        return {"attn": init_attn(ka, cfg), "mlp": init_mlp(km, cfg)}
    if kind == "moe":
        ka, km = jax.random.split(rng)
        return {"attn": init_attn(ka, cfg), "moe": init_moe(km, cfg)}
    if kind == "mamba":
        return {"mamba": init_mamba(rng, cfg)}
    if kind == "mlstm":
        return {"mlstm": init_mlstm(rng, cfg)}
    if kind == "slstm":
        return {"slstm": init_slstm(rng, cfg)}
    raise ValueError(kind)


def _fwd_block(kind: str, p, x, cfg: ArchConfig, pos):
    if kind in ("attn", "shared_attn"):
        x = attn_forward(p["attn"], x, cfg, pos=pos, causal=True)
        return mlp_forward(p["mlp"], x, cfg)
    if kind == "local":
        x = attn_forward(p["attn"], x, cfg, pos=pos, causal=True, window=cfg.window)
        return mlp_forward(p["mlp"], x, cfg)
    if kind == "moe":
        x = attn_forward(p["attn"], x, cfg, pos=pos, causal=True)
        return moe_forward(p["moe"], x, cfg)
    if kind == "mamba":
        return mamba_forward(p["mamba"], x, cfg)
    if kind == "mlstm":
        return mlstm_forward(p["mlstm"], x, cfg)
    if kind == "slstm":
        return slstm_forward(p["slstm"], x, cfg)
    raise ValueError(kind)


def _prefill_block(kind: str, p, x, cfg: ArchConfig, pos):
    if kind in ("attn", "shared_attn", "local", "moe"):
        w = cfg.window if kind == "local" else 0
        x, cache = attn_prefill(
            p["attn"], x, cfg, pos=pos, causal=True, window=w
        )
        if kind == "moe":
            x = moe_forward(p["moe"], x, cfg)
        else:
            x = mlp_forward(p["mlp"], x, cfg)
        return x, cache
    if kind == "mamba":
        return mamba_prefill(p["mamba"], x, cfg)
    if kind == "mlstm":
        return mlstm_prefill(p["mlstm"], x, cfg)
    if kind == "slstm":
        return slstm_prefill(p["slstm"], x, cfg)
    raise ValueError(kind)


def _decode_block(kind: str, p, x, cache, cache_len, cfg: ArchConfig):
    if kind in ("attn", "shared_attn", "local", "moe"):
        w = cfg.window if kind == "local" else 0
        x, cache = attn_decode(p["attn"], x, cache, cache_len, cfg, window=w)
        if kind == "moe":
            x = moe_forward(p["moe"], x, cfg)
        else:
            x = mlp_forward(p["mlp"], x, cfg)
        return x, cache
    if kind == "mamba":
        return mamba_decode(p["mamba"], x, cache, cfg)
    if kind == "mlstm":
        return mlstm_decode(p["mlstm"], x, cache, cfg)
    if kind == "slstm":
        return slstm_decode(p["slstm"], x, cache, cfg)
    raise ValueError(kind)


def _init_cache(kind: str, cfg: ArchConfig, batch: int, s_cache: int):
    if kind in ("attn", "shared_attn", "local", "moe"):
        kv, hd = cfg.n_kv_heads, cfg.hd
        z = jnp.zeros((batch, kv, s_cache, hd), cfg.jdtype)
        return {"k": z, "v": z}
    if kind == "mamba":
        return mamba_init_state(cfg, batch)
    if kind == "mlstm":
        return mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return slstm_init_state(cfg, batch)
    raise ValueError(kind)


@dataclasses.dataclass
class DecoderLM:
    cfg: ArchConfig

    # ------------------------------------------------------------- params
    def init(self, rng) -> Dict:
        cfg = self.cfg
        segs = cfg.segments()
        keys = jax.random.split(rng, len(segs) + 3)
        params: Dict[str, Any] = {
            "embed": init_embed(keys[0], cfg.vocab, cfg.d_model, cfg.jdtype),
            "final_ln": init_norm(cfg.d_model, cfg.jdtype),
            "segments": [],
        }
        if not cfg.tie_embeddings:
            params["unembed"] = init_embed(
                keys[1], cfg.vocab, cfg.d_model, cfg.jdtype
            )
        shared_done = False
        for i, (kind, count) in enumerate(segs):
            if kind == "shared_attn":
                if not shared_done:
                    params["shared_attn"] = _init_block(
                        "shared_attn", keys[2], cfg
                    )
                    shared_done = True
                params["segments"].append({})  # placeholder, uses shared
            else:
                params["segments"].append(
                    stack_init(
                        keys[i + 3],
                        count,
                        lambda r, k=kind: _init_block(k, r, cfg),
                    )
                )
        return params

    def init_shapes(self) -> Dict:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------- helpers
    def _pos(self, batch_pos, b, s):
        cfg = self.cfg
        if batch_pos is not None:
            return batch_pos
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos[None], (3, b, s))
        return pos

    def _backbone(self, params, x, pos, remat: bool, collect_moe: bool = False):
        cfg = self.cfg
        totals = {k: jnp.int32(0) for k in ("routed", "dropped", "heavy")}
        for (kind, count), seg in zip(cfg.segments(), params["segments"]):
            if kind == "shared_attn":
                sp = params["shared_attn"]
                for _ in range(count):
                    x = _fwd_block(kind, sp, x, cfg, pos)
                continue

            want_stats = collect_moe and kind == "moe"
            if want_stats:

                def layer(xc, pl):
                    xa = attn_forward(pl["attn"], xc, cfg, pos=pos, causal=True)
                    return moe_forward_stats(pl["moe"], xa, cfg)

            else:

                def layer(xc, pl, k=kind):
                    return _fwd_block(k, pl, xc, cfg, pos), None

            if remat:
                layer = jax.checkpoint(layer)  # noqa: B023
            x, ys = jax.lax.scan(layer, x, seg)
            if want_stats:
                totals = {k: totals[k] + ys[k].sum() for k in totals}
        return (x, totals) if collect_moe else x

    def logits(
        self, params, tokens, pos=None, remat: bool = False,
        collect_moe: bool = False,
    ):
        cfg = self.cfg
        b, s = tokens.shape
        x = embed(tokens, params["embed"]["table"])
        x = self._backbone(
            params, x, self._pos(pos, b, s), remat, collect_moe=collect_moe
        )
        moe_stats = None
        if collect_moe:
            x, moe_stats = x
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        table = params.get("unembed", params["embed"])["table"]
        out = unembed(x, table, cfg.logit_softcap)
        return (out, moe_stats) if collect_moe else out

    # --------------------------------------------------------------- train
    def loss(self, params, batch: Dict, remat: bool = True) -> jax.Array:
        logits = self.logits(
            params, batch["tokens"], batch.get("pos"), remat=remat
        )
        return softmax_xent(logits, batch["targets"])

    def loss_and_stats(self, params, batch: Dict, remat: bool = True):
        """Loss plus per-step MoE routing stats summed over moe layers:
        {routed, dropped, heavy} int32 — the aux the train step surfaces
        as metrics when ``TrainConfig.moe_metrics`` is on."""
        logits, moe = self.logits(
            params, batch["tokens"], batch.get("pos"), remat=remat,
            collect_moe=True,
        )
        return softmax_xent(logits, batch["targets"]), moe

    # --------------------------------------------------------------- serve
    def prefill(self, params, batch: Dict, s_cache: Optional[int] = None):
        """Run the prompt; returns (last-token logits, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        s_cache = s_cache or s
        pos = self._pos(batch.get("pos"), b, s)
        x = embed(tokens, params["embed"]["table"])
        caches: List[Any] = []
        for (kind, count), seg in zip(cfg.segments(), params["segments"]):
            if kind == "shared_attn":
                sp = params["shared_attn"]
                sub = []
                for _ in range(count):
                    x, c = _prefill_block(kind, sp, x, cfg, pos)
                    c = self._pad_cache(kind, c, s, s_cache)
                    sub.append(c)
                caches.append(jax.tree_util.tree_map(lambda *a: jnp.stack(a), *sub))
                continue

            def layer(xc, pl, k=kind):
                xo, c = _prefill_block(k, pl, xc, cfg, pos)
                return xo, self._pad_cache(k, c, s, s_cache)

            x, seg_cache = jax.lax.scan(layer, x, seg)
            caches.append(seg_cache)
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        table = params.get("unembed", params["embed"])["table"]
        logits = unembed(x[:, -1:], table, cfg.logit_softcap)
        return logits[:, 0], {"segments": caches, "len": jnp.int32(s)}

    def _pad_cache(self, kind, cache, s, s_cache):
        if kind in ATTN_KINDS and s_cache > s:
            pad = s_cache - s
            cache = {
                k: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
                for k, v in cache.items()
            }
        return cache

    def init_caches(self, batch: int, s_cache: int, prefix_len) -> Dict:
        """Empty caches of a given size with a claimed valid prefix (the
        dry-run decode path: cache contents are inputs)."""
        cfg = self.cfg
        caches = []
        for kind, count in cfg.segments():
            one = _init_cache(kind, cfg, batch, s_cache)
            caches.append(
                jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a[None], (count,) + a.shape), one
                )
            )
        return {"segments": caches, "len": jnp.asarray(prefix_len, jnp.int32)}

    def decode_step(self, params, caches, tokens):
        """One token for every sequence. tokens (B,) -> logits (B, V)."""
        cfg = self.cfg
        b = tokens.shape[0]
        x = embed(tokens[:, None], params["embed"]["table"])
        clen = caches["len"]
        new_caches = []
        for (kind, count), seg, seg_cache in zip(
            cfg.segments(), params["segments"], caches["segments"]
        ):
            if kind == "shared_attn":
                sp = params["shared_attn"]
                subs = []
                for i in range(count):
                    ci = jax.tree_util.tree_map(lambda a: a[i], seg_cache)
                    x, c2 = _decode_block(kind, sp, x, ci, clen, cfg)
                    subs.append(c2)
                new_caches.append(
                    jax.tree_util.tree_map(lambda *a: jnp.stack(a), *subs)
                )
                continue

            def layer(xc, inp, k=kind):
                pl, cl = inp
                xo, c2 = _decode_block(k, pl, xc, cl, clen, cfg)
                return xo, c2

            x, seg_new = jax.lax.scan(layer, x, (seg, seg_cache))
            new_caches.append(seg_new)
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        table = params.get("unembed", params["embed"])["table"]
        logits = unembed(x, table, cfg.logit_softcap)[:, 0]
        return logits, {"segments": new_caches, "len": clen + 1}
