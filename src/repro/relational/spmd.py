"""SPMD execution of per-shard functions: one code path, two runtimes.

Per-shard functions take/return arrays WITHOUT the reducer axis and may use
``jax.lax`` collectives over the named axis ``AXIS``.  ``SPMD`` runs them:

- simulation (default, 1 device): ``jax.vmap(fn, axis_name=AXIS)`` — the
  reducer axis is the leading array axis.  This is the paper's PRAM-style
  simulation and what CI uses.
- production: ``jax.shard_map`` over a real mesh axis — identical per-shard
  code; the leading axis is device-sharded.  The multi-pod dry-run lowers
  this path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AXIS = "r"


class SPMD:
    def __init__(
        self,
        p: int,
        mesh: Optional[Mesh] = None,
        donate_buffers: Optional[bool] = None,
    ):
        """``p`` logical reducers; if ``mesh`` given it must have axis AXIS
        of size p (production path), else simulation on one device.

        ``donate_buffers``: honor ``donate=`` hints from callers by
        compiling with ``donate_argnums`` so XLA reuses the donated input
        HBM for outputs (no double-buffering across an exchange).  Default
        auto-detects: on CPU donation is a no-op that only emits warnings,
        so it is enabled only where XLA supports it (gpu/tpu)."""
        self.p = p
        self.mesh = mesh
        if mesh is not None:
            assert mesh.shape[AXIS] == p, (mesh.shape, p)
        if donate_buffers is None:
            donate_buffers = jax.default_backend() in ("gpu", "tpu")
        self.donate_buffers = donate_buffers
        self._cache: Dict[Any, Callable] = {}
        # program dispatches actually issued (one per ``run`` call, compiled
        # or cache-hit) — the *measured* counterpart of the ledger's claimed
        # BSP rounds; round fusion is proven by this counter going down.
        self.dispatch_count: int = 0
        # the subset of ``dispatch_count`` that were count-only measure
        # pre-passes (``run(..., measure=True)``).  Splitting the two is
        # what lets the ledger attribute wall-clock regressions: payload
        # dispatches track the schedule, measure dispatches track the
        # calibration policy (amortized to ~one per round by the combined
        # pre-pass + CapsCache, see ``core.caps_cache``).
        self.measure_dispatch_count: int = 0

    # -- execution --------------------------------------------------------
    def _build(self, fn: Callable, statics: Tuple, donate: Tuple[int, ...]) -> Callable:
        bound = functools.partial(fn, **dict(statics)) if statics else fn
        if self.mesh is None:
            mapped = jax.vmap(bound, axis_name=AXIS)
        else:
            def strip(blk):
                return jax.tree_util.tree_map(lambda x: x[0], blk)

            def readd(blk):
                return jax.tree_util.tree_map(lambda x: x[None], blk)

            def per_block(*args):
                return readd(bound(*[strip(a) for a in args]))

            mapped = jax.shard_map(
                per_block,
                mesh=self.mesh,
                in_specs=P(AXIS),
                out_specs=P(AXIS),
                check_vma=False,
            )
        if donate and self.donate_buffers:
            return jax.jit(mapped, donate_argnums=donate)
        return jax.jit(mapped)

    def run(
        self,
        fn: Callable,
        *args,
        donate: Tuple[int, ...] = (),
        measure: bool = False,
        **statics,
    ):
        """Run per-shard ``fn`` over the reducer axis.  ``statics`` must be
        hashable and are part of the compilation cache key.

        ``donate``: positional indices of ``args`` whose buffers the caller
        guarantees are dead after this dispatch (e.g. the freshly stacked
        exchange inputs in ``relational.batched``) — compiled with
        ``donate_argnums`` when the backend supports donation, so the
        exchange output reuses the input's HBM instead of double-buffering.
        Part of the cache key: the same fn with and without donation are
        distinct programs.

        ``measure``: tag this dispatch as a count-only calibration
        pre-pass (tallied in ``measure_dispatch_count`` as well); not part
        of the cache key.  Returned arrays are JAX futures either way —
        dispatch is async, and the host only blocks when a caller fetches
        values (``jax.device_get`` / ``np.asarray``).  That asymmetry is
        what the executor's measure prefetch exploits: round r+1's
        combined count pre-pass is launched while round r's payload
        exchanges are still in flight, and its count vectors are synced
        only when capacity planning actually needs them."""
        donate = tuple(sorted(donate))
        key = (fn, tuple(sorted(statics.items())), donate)
        if key not in self._cache:
            self._cache[key] = self._build(
                fn, tuple(sorted(statics.items())), donate
            )
        self.dispatch_count += 1
        if measure:
            self.measure_dispatch_count += 1
        return self._cache[key](*args)

    def seeds(self, seed: int) -> jnp.ndarray:
        """Per-shard traced seed array: hash seeds ride as DATA (not jit
        statics) so reseeded retries reuse compiled programs."""
        return jnp.full((self.p,), seed & 0xFFFFFFFF, jnp.uint32)

    def device_put(self, tree):
        if self.mesh is None:
            return tree
        sh = NamedSharding(self.mesh, P(AXIS))
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)
