"""MoE token dispatch as the routed exchange's second customer (PR 10).

Pins the contract the issue demands: ``moe_forward(route="calibrated")``
is numerically equivalent to the dense scatter whenever the dense path
does not drop, and on a planted hot-expert input where the dense path
PROVABLY drops, the calibrated path (measured capacities + heavy split)
drops nothing — with dropped counts exact, never estimated, in both
routes.  Plus the two end-to-end scenarios (train step on a launch mesh,
decode serving) and the jit-static plan discipline."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, get_model, make_smoke_batch, reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.shardings import named, param_specs
from repro.models.common import rms_norm
from repro.models.mlp import init_moe, moe_forward, moe_forward_stats
from repro.models.moe_routing import (
    MoEPlan,
    apply_plan,
    calibrate_moe,
    router_pairs,
)
from repro.relational import Ledger
from repro.serve.decode import generate
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def _moe_cfg(**kw):
    """Reduced kimi (4 experts, top-2, float32) with a capacity factor of
    ``e`` so the dense route cannot drop — parity inputs by construction."""
    cfg = reduced_config(CONFIGS["kimi-k2-1t-a32b"])
    return dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts), **kw)


def _layer_setup(seed=0, b=2, s=16, cfg=None):
    cfg = cfg or _moe_cfg()
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (b, s, cfg.d_model), jnp.float32
    )
    xf = rms_norm(x, p["ln"], cfg.norm_eps).reshape(b * s, cfg.d_model)
    return cfg, p, x, xf


def _hot_input(cfg, b, s, seed=99):
    """Near-identical tokens: every token's top-k picks the SAME k
    experts, so those experts' arrivals are ~t each — far past the dense
    capacity ``1.25*t*k/e`` whenever k < e.  The planted skew input."""
    kb, kn = jax.random.split(jax.random.PRNGKey(seed))
    base = jax.random.normal(kb, (1, 1, cfg.d_model), jnp.float32)
    noise = 0.01 * jax.random.normal(kn, (b, s, cfg.d_model), jnp.float32)
    return jnp.broadcast_to(base, (b, s, cfg.d_model)) + noise


def _dense_expected_drops(p, xf, cfg):
    """Exact pair count the dense scatter must drop: arrivals beyond
    ``cap`` per expert, from the SAME router math the layer runs."""
    t = xf.shape[0]
    e, k = cfg.n_experts, cfg.topk
    cap = max(1, int(cfg.capacity_factor * t * k / e))
    flat_e, _, _ = router_pairs(p, xf, cfg)
    arr = np.bincount(np.asarray(flat_e), minlength=e)
    return int(np.maximum(arr - cap, 0).sum()), arr


# ---------------------------------------------------------------- parity
def test_calibrated_matches_dense_when_no_drop():
    cfg, p, x, xf = _layer_setup()
    yd, sd = moe_forward_stats(p, x, cfg)
    assert int(sd["dropped"]) == 0  # capacity_factor=e: dense can't drop
    plan, _ = calibrate_moe(p, xf, cfg)
    yc, sc = moe_forward_stats(p, x, apply_plan(cfg, plan))
    assert int(sc["dropped"]) == 0
    assert int(sc["routed"]) == int(sd["routed"]) == x.shape[0] * x.shape[1] * cfg.topk
    np.testing.assert_allclose(
        np.asarray(yd), np.asarray(yc), atol=2e-5, rtol=2e-5
    )
    # moe_forward (stats-free wrapper) is the same computation
    np.testing.assert_array_equal(
        np.asarray(moe_forward(p, x, apply_plan(cfg, plan))), np.asarray(yc)
    )


def test_sound_plan_needs_no_measure():
    cfg, p, x, _ = _layer_setup(seed=3)
    t = x.shape[0] * x.shape[1]
    plan = MoEPlan.sound(t, cfg.topk, cfg.n_experts)
    yd, _ = moe_forward_stats(p, x, cfg)
    yc, sc = moe_forward_stats(p, x, apply_plan(cfg, plan))
    assert int(sc["dropped"]) == 0  # sound caps: drops impossible
    np.testing.assert_allclose(
        np.asarray(yd), np.asarray(yc), atol=2e-5, rtol=2e-5
    )


# ------------------------------------------------------- planted hot expert
def test_hot_expert_dense_drops_calibrated_does_not():
    """The acceptance scenario: an input where the dense scatter loses
    tokens over capacity (exact count asserted) while the calibrated
    route — capacities measured, hot expert heavy-split — drops zero."""
    cfg = reduced_config(CONFIGS["kimi-k2-1t-a32b"])  # capacity_factor 1.25
    p = init_moe(jax.random.PRNGKey(5), cfg)
    x = _hot_input(cfg, b=2, s=32)
    xf = rms_norm(x, p["ln"], cfg.norm_eps).reshape(64, cfg.d_model)

    want_drop, arrivals = _dense_expected_drops(p, xf, cfg)
    assert want_drop > 0, arrivals  # the plant worked: dense MUST drop

    _, sd = moe_forward_stats(p, x, cfg)
    assert int(sd["dropped"]) == want_drop  # exact, not approximate
    assert int(sd["routed"]) == xf.shape[0] * cfg.topk - want_drop

    plan, info = calibrate_moe(p, xf, cfg, threshold=1.5)
    assert plan.heavy, info  # the hot experts were flagged
    _, sc = moe_forward_stats(p, x, apply_plan(cfg, plan))
    assert int(sc["dropped"]) == 0  # measured caps: provably no drop
    assert int(sc["routed"]) == xf.shape[0] * cfg.topk
    assert int(sc["heavy"]) >= int(arrivals[plan.heavy[0]])


def test_recv_ceiling_reports_exact_drops():
    """Clipping the receive capacity (an M-style memory bound) makes the
    calibrated route drop — and the count must equal the host-side
    arrivals-over-capacity computation, not a bound."""
    cfg, p, x, xf = _layer_setup(seed=7)
    # no heavy spreading: drops land per-expert and are exactly predictable
    plan, _ = calibrate_moe(p, xf, cfg, threshold=1e9, cap_recv_ceiling=16)
    assert plan.cap_recv == 16 and not plan.heavy
    flat_e, _, _ = router_pairs(p, xf, cfg)
    arr = np.bincount(np.asarray(flat_e), minlength=cfg.n_experts)
    want = int(np.maximum(arr - plan.cap_recv, 0).sum())
    assert want > 0, arr
    _, sc = moe_forward_stats(p, x, apply_plan(cfg, plan))
    assert int(sc["dropped"]) == want


# ------------------------------------------------------------- train step
def test_train_step_scenario_parity_and_metrics():
    """Full train step on a launch mesh: the calibrated route trains —
    same loss as dense (no-drop input), grads flow through both
    exchanges, and moe_* metrics report the exact pair counts."""
    cfg = _moe_cfg()
    model = get_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-2, warmup=1), moe_metrics=True)
    params, opt_state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg, jax.random.PRNGKey(1), b=4, s=16)

    mesh = make_debug_mesh(1, 1)
    params = jax.device_put(params, named(mesh, param_specs(params, mesh)))

    # a sound plan covers every layer's routing without a per-layer measure
    plan = MoEPlan.sound(4 * 16, cfg.topk, cfg.n_experts)
    ccfg = apply_plan(cfg, plan)
    cmodel = get_model(ccfg)

    dstep = jax.jit(make_train_step(model, tcfg))
    cstep = jax.jit(make_train_step(cmodel, tcfg))
    pd, od, md = dstep(params, opt_state, batch)
    pc, oc, mc = cstep(params, opt_state, batch)
    np.testing.assert_allclose(
        float(md["loss"]), float(mc["loss"]), rtol=1e-5
    )
    n_moe = sum(1 for k in cfg.blocks() if k == "moe")
    assert int(mc["moe_routed"]) == 4 * 16 * cfg.topk * n_moe
    assert int(mc["moe_dropped"]) == 0
    for a, b in zip(jax.tree_util.tree_leaves(pd), jax.tree_util.tree_leaves(pc)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-5, rtol=5e-3,
        )
    # accumulation path carries the aux through the scan too
    t4 = TrainConfig(opt=OptConfig(lr=1e-2, warmup=1), accum=4, moe_metrics=True)
    _, _, m4 = jax.jit(make_train_step(cmodel, t4))(params, opt_state, batch)
    assert int(m4["moe_routed"]) == int(mc["moe_routed"])


# ------------------------------------------------------------ decode serve
def test_decode_serve_scenario_parity():
    """Serving: one MoEPlan covers prefill (t=b*s) AND per-token decode
    (t=b); generated tokens and per-step logits match the dense route."""
    cfg = _moe_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)

    plan = MoEPlan.sound(2 * 8, cfg.topk, cfg.n_experts)
    cmodel = get_model(apply_plan(cfg, plan))

    td, ld = generate(model, params, prompt, steps=4, return_logits=True)
    tc, lc = generate(cmodel, params, prompt, steps=4, return_logits=True)
    np.testing.assert_array_equal(np.asarray(td), np.asarray(tc))
    np.testing.assert_allclose(
        np.asarray(ld), np.asarray(lc), atol=5e-5, rtol=5e-4
    )


# ------------------------------------------------------------------ plan
def test_plan_is_hashable_and_jit_static():
    plan = MoEPlan(e=4, k=2, tpp=8, cap_send=8, cap_recv=32, heavy=(1,))
    assert hash(plan) == hash(
        MoEPlan(e=4, k=2, tpp=8, cap_send=8, cap_recv=32, heavy=(1,))
    )
    cfg = apply_plan(_moe_cfg(), plan)
    hash(cfg)  # the whole config stays a valid static argument
    assert plan.ret_cap_recv == 16 and plan.ret_cap_send == 16

    # pow2-bucketed capacities: one compiled program across batches
    cfg, p, x, xf = _layer_setup(seed=11)
    plan, _ = calibrate_moe(p, xf, cfg)
    traces = []

    @jax.jit
    def fwd(p, x):
        traces.append(1)
        return moe_forward_stats(p, x, apply_plan(cfg, plan))

    fwd(p, x)
    fwd(p, x + 1.0)
    assert len(traces) == 1


def test_calibration_ledger_record():
    from repro.models.moe_routing import record_dense_round, record_moe_round

    cfg, p, x, xf = _layer_setup(seed=13)
    plan, _ = calibrate_moe(p, xf, cfg)
    _, sc = moe_forward_stats(p, x, apply_plan(cfg, plan))
    _, sd = moe_forward_stats(p, x, cfg)
    led = Ledger()
    record_moe_round(led, {k: int(v) for k, v in sc.items()}, plan=plan,
                     d=cfg.d_model, note="calibrated")
    record_dense_round(led, {k: int(v) for k, v in sd.items()}, cfg=cfg,
                       t=xf.shape[0], d=cfg.d_model, note="dense")
    s = led.summary()
    assert s["comm_tuples"] == int(sc["routed"]) + int(sd["routed"])
    assert s["dropped_tuples"] == 0
    assert s["payload_bytes"] > 0 and s["useful_bytes"] > 0
    assert "heavy_dests" in s
    assert "Ledger(" in repr(led)
