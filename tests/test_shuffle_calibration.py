"""Occupancy-adaptive shuffle: the count-calibrated path must be
bit-compatible with the PR-3 fixed-capacity path (rows, comm_tuples,
retries) while shipping measurably fewer padded slots; pow2 bucketing
must keep the jit cache warm across occupancies; the single-sort
``_bucketize`` must match its two-pass predecessor exactly."""
from __future__ import annotations

import random
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gym import GymConfig, gym
from repro.core.queries import (
    chain_ghd,
    chain_query,
    star_ghd,
    star_query,
    triangle_chain_ghd,
    triangle_chain_query,
)
from repro.data.synthetic import chain_data_sparse, star_data_sparse, tc_data_sparse
from repro.relational import batched as B
from repro.relational.ops import (
    Overflow,
    check_no_drop,
    dist_join,
    dist_semijoin,
    measure_exchange,
)
from repro.relational.shuffle import (
    _bucketize,
    bucket_counts,
    exchange_counts,
    pow2,
)
from repro.relational.spmd import AXIS, SPMD
from repro.relational.table import DTable


def mk(rows, schema, p=4, cap=8):
    return DTable.scatter_numpy(np.asarray(rows, np.int32), schema, p, cap=cap)


def rand_tables(rng, schemas, p=4, cap=8, dom=6, rows=14):
    out = []
    for schema in schemas:
        r = [[rng.randint(0, dom - 1) for _ in schema] for _ in range(rows)]
        out.append(mk(np.unique(np.asarray(r, np.int32), axis=0), schema, p, cap))
    return out


# ----------------------------------------------------- _bucketize single-sort
def _bucketize_reference(data, valid_dest, p, c_out):
    """The pre-PR-4 two-pass implementation (stable argsort + gather +
    searchsorted over the sorted copy) — the oracle the single-sort
    rewrite must match bit-for-bit."""
    n, ar = data.shape
    order = jnp.argsort(valid_dest, stable=True)
    sdest = valid_dest[order]
    srows = data[order]
    starts = jnp.searchsorted(sdest, jnp.arange(p))
    pos = jnp.arange(n) - starts[jnp.clip(sdest, 0, p - 1)]
    live = sdest < p
    ok = live & (pos < c_out)
    d_idx = jnp.where(ok, sdest, p)
    pos_c = jnp.clip(pos, 0, c_out - 1)
    buf = jnp.zeros((p, c_out, ar), data.dtype).at[d_idx, pos_c].set(
        srows, mode="drop"
    )
    buf_valid = jnp.zeros((p, c_out), bool).at[d_idx, pos_c].set(ok, mode="drop")
    return buf, buf_valid, ok.sum(), (live & ~ok).sum()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_bucketize_matches_two_pass_reference(seed):
    rng = np.random.default_rng(seed)
    for n, p, c_out in [(1, 2, 4), (7, 3, 2), (16, 4, 4), (33, 5, 8)]:
        data = jnp.asarray(rng.integers(0, 9, (n, 3)), jnp.int32)
        # dests include dead rows (== p) and overfull buckets
        dest = jnp.asarray(rng.integers(0, p + 1, (n,)), jnp.int32)
        got = _bucketize(data, dest, p, c_out)
        want = _bucketize_reference(data, dest, p, c_out)
        for g, w in zip(got, want):
            assert jnp.array_equal(g, w), (n, p, c_out)


def test_bucket_counts_counts_live_dests_only():
    dest = jnp.asarray([0, 2, 2, 3, 3, 3, 1, 3], jnp.int32)  # 3 == p: dead
    assert bucket_counts(dest, 3).tolist() == [1, 1, 2]
    multi = jnp.asarray([[0, 1], [2, 2], [1, 2]], jnp.int32)
    assert bucket_counts(multi, 2).tolist() == [1, 2]  # 2 == p skipped


def test_exchange_counts_match_payload_sent():
    """The pre-pass must count exactly what the payload exchange sends:
    sum(out_counts) == sent, and the received totals are the transpose."""
    p = 4
    rng = np.random.default_rng(7)
    data = jnp.asarray(rng.integers(0, 9, (p, 10, 2)), jnp.int32)
    dest = jnp.asarray(rng.integers(0, p + 1, (p, 10)), jnp.int32)

    def shard(d, dst):
        oc, rt = exchange_counts(dst, p)
        _, _, sent, ds, _ = __import__("repro.relational.shuffle", fromlist=["exchange"]).exchange(
            d, dst < p, jnp.where(dst < p, dst, 0), p=p, c_out=10, cap_recv=p * 10
        )
        return oc, rt, sent, ds

    oc, rt, sent, ds = jax.jit(jax.vmap(shard, axis_name=AXIS))(data, dest)
    assert int(ds.sum()) == 0
    assert np.array_equal(np.asarray(oc).sum(axis=1), np.asarray(sent))
    # shard s receives exactly what every shard counted toward s
    assert np.array_equal(np.asarray(rt), np.asarray(oc).sum(axis=0))


def test_measure_exchange_tight_and_safe():
    spmd = SPMD(4)
    rng = random.Random(5)
    (t,) = rand_tables(rng, [("A", "B")], p=4, cap=16, rows=20)
    c_out, cap_recv = measure_exchange(spmd, t, ("A",), seed=11)
    # tight: never worse than the worst-case defaults
    assert c_out <= pow2(t.cap)
    assert cap_recv <= pow2(spmd.p * t.cap)
    # safe: a repartition at the measured capacities drops nothing
    from repro.relational.ops import repartition

    _, st = repartition(
        spmd, t, ("A",), seed=11, c_out=c_out, cap_recv=cap_recv
    )
    assert st["dropped"] == 0
    assert st["sent"] == int(np.asarray(t.valid).sum())
    assert st["padded"] == spmd.p * spmd.p * c_out * t.arity


# ------------------------------------------------- batched measure pre-pass
def test_measured_caps_preserve_batched_semijoin_bits():
    """Calibrated capacities (from the group pre-pass) must reproduce the
    worst-case-capacity semijoin exactly: same rows, same sent/dropped."""
    rng = random.Random(3)
    spmd = SPMD(4)
    ss = rand_tables(rng, [("A", "B"), ("C", "A")])
    rs = rand_tables(rng, [("B", "C"), ("A", "E")])
    seeds = [11, 22]
    m = B.measure_semijoin_many(spmd, ss, rs, seeds=seeds)
    cap = 16
    fixed, fixed_st = B.dist_semijoin_many(
        spmd, ss, rs, seeds=seeds, cap_recv=(cap, spmd.p * rs[0].cap)
    )
    cal, cal_st = B.dist_semijoin_many(
        spmd, ss, rs, seeds=seeds,
        c_out=(m.lhs.c_out, m.rhs.c_out),
        cap_recv=(max(cap, m.lhs.cap_recv), m.rhs.cap_recv),
    )
    for f, c, fs, cs in zip(fixed, cal, fixed_st, cal_st):
        assert f.to_set() == c.to_set()
        assert fs["sent"] == cs["sent"] and fs["dropped"] == cs["dropped"] == 0
        assert cs["padded"] < fs["padded"]
    # the S-side arrival bound is what the executor pre-floors with
    assert m.out_recv == m.lhs.cap_recv


def test_measure_join_pre_sizes_exact_output():
    """The join pre-pass must return the exact pow2 output requirement, so
    an out_cap floored at it never overflows while staying minimal."""
    spmd = SPMD(2)
    a = mk([(1, 1)] * 10, ("A", "B"), 2, cap=16)
    b = mk([(1, 2)] * 10, ("B", "C"), 2, cap=16)
    m = B.measure_join_many(spmd, [a], [b], seeds=[0])
    # the skewed key lands on one shard: its exact output is 1 * 1 = 1
    # distinct pair after dedup-on-load... rows here are duplicated, so
    # dist_join of the raw tables yields |a| x |b| matches on that shard
    out, st = dist_join(spmd, a, b, seed=0, out_cap=m.out_need)
    assert st["dropped"] == 0
    out_small, st_small = dist_join(spmd, a, b, seed=0, out_cap=m.out_need // 2)
    assert st_small["dropped"] > 0  # minimal: half the floor overflows


def test_grid_measured_caps_preserve_bits():
    rng = random.Random(2)
    spmd = SPMD(4)
    as_ = rand_tables(rng, [("A", "B"), ("C", "B")])
    bs = rand_tables(rng, [("B", "C"), ("B", "A")])
    m = B.measure_grid_join_many(spmd, as_, bs)
    fixed, fixed_st = B.grid_join_many(spmd, as_, bs, out_cap=256)
    cal, cal_st = B.grid_join_many(
        spmd, as_, bs, out_cap=256,
        c_out=(m.lhs.c_out, m.rhs.c_out),
        cap_recv=(m.lhs.cap_recv, m.rhs.cap_recv),
    )
    for f, c, fs, cs in zip(fixed, cal, fixed_st, cal_st):
        assert f.to_set() == c.to_set()
        assert fs["sent"] == cs["sent"] and fs["dropped"] == cs["dropped"] == 0
        assert cs["padded"] <= fs["padded"]


# --------------------------------------------------- pow2 program reuse
def test_pow2_bucketing_reuses_jit_programs_across_occupancies():
    """Two rounds with DIFFERENT occupancies but the same pow2 capacity
    bucket must hit the same compiled program — no recompilation, which is
    the point of bucketing calibrated capacities."""
    spmd = SPMD(4)
    rng = random.Random(9)

    def pair(rows):
        a = rand_tables(rng, [("A", "B")], rows=rows, dom=24, cap=16)[0]
        b = rand_tables(rng, [("B", "C")], rows=rows, dom=24, cap=16)[0]
        return a, b

    a1, b1 = pair(56)
    m1 = B.measure_join_many(spmd, [a1], [b1], seeds=[1])
    B.dist_join_many(
        spmd, [a1], [b1], seeds=[1], out_cap=m1.out_need,
        c_out=(m1.lhs.c_out, m1.rhs.c_out),
        cap_recv=(m1.lhs.cap_recv, m1.rhs.cap_recv),
    )
    n_programs = len(spmd._cache)
    # a NEARBY occupancy (under a fresh round seed) lands in the same pow2
    # buckets — that is the point of bucketing: whole ranges of counts
    # share one compiled program.  Find one and assert zero new programs.
    sig1 = (m1.lhs, m1.rhs, m1.out_need)
    for rows, seed in ((50, 2), (52, 3), (54, 4), (48, 5), (56, 6)):
        a2, b2 = pair(rows)
        m2 = B.measure_join_many(spmd, [a2], [b2], seeds=[seed])
        if (m2.lhs, m2.rhs, m2.out_need) == sig1:
            break
    else:
        pytest.fail("no nearby occupancy shared the pow2 capacity bucket")
    assert len(spmd._cache) == n_programs  # the measure pass itself reused
    B.dist_join_many(
        spmd, [a2], [b2], seeds=[seed], out_cap=m2.out_need,
        c_out=(m2.lhs.c_out, m2.rhs.c_out),
        cap_recv=(m2.lhs.cap_recv, m2.rhs.cap_recv),
    )
    assert len(spmd._cache) == n_programs, "pow2 bucket recompiled"


# --------------------------------------------------- overflow diagnostics
def test_overflow_message_names_op_and_capacity():
    with pytest.raises(Overflow) as ei:
        check_no_drop({"sent": 10, "dropped": 3}, op="dist_project", cap=64)
    msg = str(ei.value)
    assert "dist_project" in msg and "64" in msg and "3" in msg
    check_no_drop({"sent": 10, "dropped": 0}, op="dist_project", cap=64)


# --------------------------------------------------- donation plumbing
def test_donation_is_cache_keyed_and_value_preserving():
    spmd = SPMD(2, donate_buffers=True)

    def f(x):
        return x * 2

    x = jnp.arange(6, dtype=jnp.int32).reshape(2, 3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # CPU ignores donation with a warning
        r1 = spmd.run(f, x)
        r2 = spmd.run(f, jnp.arange(6, dtype=jnp.int32).reshape(2, 3), donate=(0,))
    assert jnp.array_equal(r1, r2)
    assert len(spmd._cache) == 2  # donated and plain are distinct programs
    assert spmd.dispatch_count == 2


# ------------------------------------------------------- end-to-end parity
CASES = {
    "chain": lambda: (chain_query(4), chain_ghd(4), chain_data_sparse(4, seed=7)),
    "star": lambda: (star_query(5), star_ghd(5), star_data_sparse(5, seed=9)),
    "tc": lambda: (
        triangle_chain_query(2),
        triangle_chain_ghd(2),
        tc_data_sparse(2, seed=8),
    ),
}


def _run(qname, strategy, fused, calibrate):
    q, g, data = CASES[qname]()
    rows, _, led = gym(
        q, data, ghd=g, p=4,
        config=GymConfig(
            strategy=strategy, seed=3, fused=fused, calibrate_shuffle=calibrate
        ),
    )
    return sorted(map(tuple, rows)), led


def test_calibrated_vs_fixed_parity_fast():
    """Fast-lane pin of the full property: calibrated == fixed on rows,
    comm, and retries, at >= 2x fewer padded slots (hash, fused)."""
    rows_c, led_c = _run("chain", "hash", True, True)
    rows_f, led_f = _run("chain", "hash", True, False)
    assert rows_c == rows_f
    assert led_c.comm_tuples == led_f.comm_tuples
    assert led_c.shuffle_tuples == led_f.shuffle_tuples
    assert led_c.retries == led_f.retries == 0
    assert 2 * led_c.padded_slots <= led_f.padded_slots
    assert led_c.payload_efficiency > led_f.payload_efficiency


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["hash", "grid"])
@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("qname", sorted(CASES))
def test_calibrated_vs_fixed_parity(strategy, fused, qname):
    """The full matrix: hash/grid x fused/sequential x three query shapes.
    Calibration repacks the wire; it must not change what is computed or
    what the cost model records."""
    rows_c, led_c = _run(qname, strategy, fused, True)
    rows_f, led_f = _run(qname, strategy, fused, False)
    assert rows_c == rows_f, (qname, strategy, fused)
    assert led_c.comm_tuples == led_f.comm_tuples, (qname, strategy, fused)
    assert led_c.retries == led_f.retries
    assert led_c.rounds == led_f.rounds
    assert led_c.padded_slots < led_f.padded_slots


@pytest.mark.slow
def test_calibrated_semijoin_property():
    """Property pin (hypothesis): random tables, random seeds — measured
    capacities never drop a tuple and always match the fixed path."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rows=st.integers(1, 24),
        dom=st.integers(1, 8),
    )
    def prop(seed, rows, dom):
        rng = random.Random(seed)
        spmd = SPMD(4)
        (s,) = rand_tables(rng, [("A", "B")], rows=rows, dom=dom, cap=8)
        (r,) = rand_tables(rng, [("B", "C")], rows=rows, dom=dom, cap=8)
        fixed, fst = dist_semijoin(spmd, s, r, seed=seed & 0xFFFF)
        m = B.measure_semijoin_many(spmd, [s], [r], seeds=[seed & 0xFFFF])
        cal, cst = B.dist_semijoin_many(
            spmd, [s], [r], seeds=[seed & 0xFFFF],
            c_out=(m.lhs.c_out, m.rhs.c_out),
            cap_recv=(max(spmd.p * s.cap, m.lhs.cap_recv), m.rhs.cap_recv),
        )
        assert cal[0].to_set() == fixed.to_set()
        assert cst[0]["sent"] == fst["sent"]
        assert cst[0]["dropped"] == 0

    prop()
