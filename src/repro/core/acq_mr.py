"""ACQ-MR (paper Sec. 2.2): the MR simulation of the ACQ PRAM algorithm.

Per the paper, ACQ-MR is realized as GYM running on the Log-GTA' transform
of the input GHD: every new vertex materializes a join of <= 3w *base*
relations (ACQ's shunt of three relations), giving Theta(log n) rounds and
O(n B(IN^{3w} + OUT, M)) communication — always matched, and sometimes
beaten, by GYM(Log-GTA) whose new vertices only need max(w, 3iw) relations.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from ..relational.ledger import Ledger
from ..relational.spmd import SPMD
from .decompose import ghd_for
from .ghd import GHD
from .gym import GymConfig, gym
from .hypergraph import Query
from .loggta import log_gta
from .loggta_prime import log_gta_prime


def acq_mr(
    query: Query,
    data: Dict[str, np.ndarray],
    *,
    ghd: Optional[GHD] = None,
    p: int = 4,
    spmd: Optional[SPMD] = None,
    config: Optional[GymConfig] = None,
) -> Tuple[np.ndarray, Tuple[str, ...], Ledger]:
    """Evaluate Q via GYM on Log-GTA'(D): the ACQ-MR baseline."""
    g = ghd if ghd is not None else ghd_for(query)
    g = g.make_complete(query)
    g3 = log_gta_prime(g, query)
    return gym(query, data, ghd=g3, p=p, spmd=spmd, config=config)


def gym_loggta(
    query: Query,
    data: Dict[str, np.ndarray],
    *,
    ghd: Optional[GHD] = None,
    p: int = 4,
    spmd: Optional[SPMD] = None,
    config: Optional[GymConfig] = None,
) -> Tuple[np.ndarray, Tuple[str, ...], Ledger]:
    """GYM(Log-GTA(D)): log-round GYM with width <= max(w, 3iw)."""
    g = ghd if ghd is not None else ghd_for(query)
    g = g.make_complete(query)
    g2 = log_gta(g, query)
    return gym(query, data, ghd=g2, p=p, spmd=spmd, config=config)
