"""Shared model substrate: arch config, norms, embeddings, RoPE/M-RoPE.

Pure-pytree models (no flax): params are nested dicts of jnp arrays; every
block kind has ``init(rng, cfg) -> params`` and a forward; homogeneous runs
of blocks are stacked (leading layer axis) and executed under ``lax.scan``
so the HLO stays small at 80+ layers (fast CPU compiles, clean dry-runs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # block pattern: tuple of block kinds, len == n_layers (decoder side)
    pattern: Tuple[str, ...] = ()
    # attention options
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    window: int = 0  # sliding window width for 'local' blocks
    # MoE
    n_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (kimi: 2048)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # MoE dispatch route: "dense" (Switch-style capacity scatter) or
    # "calibrated" (routed_all_to_all with a measured MoEPlan).  The plan
    # must be frozen/hashable — it rides this static config into jit.
    moe_route: str = "dense"
    moe_plan: Optional[Any] = None
    # SSM / xLSTM
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256
    # enc-dec (whisper)
    encdec: bool = False
    enc_layers: int = 0
    dec_ratio: int = 8  # train: decoder tokens = seq // dec_ratio
    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # notes for deviations from the public checkpoint
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def blocks(self) -> Tuple[str, ...]:
        if self.pattern:
            assert len(self.pattern) == self.n_layers, (
                self.name, len(self.pattern), self.n_layers
            )
            return self.pattern
        kind = "moe" if self.n_experts else "attn"
        return (kind,) * self.n_layers

    def segments(self) -> Tuple[Tuple[str, int], ...]:
        """Run-length encode the block pattern -> scan segments."""
        out = []
        for b in self.blocks():
            if out and out[-1][0] == b:
                out[-1] = (b, out[-1][1] + 1)
            else:
                out.append((b, 1))
        return tuple(out)


def scaled_init(rng, shape, scale_axis, dtype, scale=1.0):
    """Truncated-normal-ish init with 1/sqrt(fan_in)."""
    fan_in = shape[scale_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, g: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + g.astype(jnp.float32))).astype(dt)


def init_norm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # gain stored as (1 + g)


# ------------------------------------------------------------------- RoPE
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x (B, H, S, D), pos (B, S) int32 -> rotated x."""
    b, h, s, d = x.shape
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = pos[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, pos3: jax.Array, theta: float, sections=(2, 3, 3)
) -> jax.Array:
    """Qwen2-VL M-RoPE: pos3 (3, B, S) = (temporal, height, width) ids.

    The head-dim frequency bands are split 2:3:3 over the three axes
    (ratio per the paper); text tokens carry identical ids on all axes so
    M-RoPE == RoPE for pure text."""
    b, h, s, d = x.shape
    freqs = rope_freqs(d, theta)  # (d/2,)
    nb = d // 2
    tot = sum(sections)
    bounds = []
    acc = 0
    for sec in sections:
        acc += int(round(nb * sec / tot))
        bounds.append(acc)
    bounds[-1] = nb
    band = jnp.zeros((nb,), jnp.int32)
    prev = 0
    for i, bd in enumerate(bounds):
        band = band.at[prev:bd].set(i)
        prev = bd
    # per-frequency position: select the axis this band belongs to
    pos_sel = jnp.take(pos3, band, axis=0)  # (nb, B, S) -> via take on axis0
    pos_sel = jnp.transpose(pos_sel, (1, 2, 0))  # (B, S, nb)
    ang = pos_sel.astype(jnp.float32) * freqs  # (B,S,nb)
    cos = jnp.cos(ang)[:, None]  # (B,1,S,nb)
    sin = jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- embeddings
def init_embed(rng, vocab: int, d: int, dtype) -> Dict[str, jax.Array]:
    return {"table": scaled_init(rng, (vocab, d), 1, dtype)}


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy, f32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def stack_init(rng, n: int, init_fn) -> Params:
    """vmapped per-layer init -> params with leading layer axis n."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(init_fn)(rngs)
