"""Render EXPERIMENTS.md's §Dry-run and §Roofline tables from the dry-run
JSONs (baseline + optimized), plus the provenance table that links every
*predicted* benchmark column back to the formula (and paper citation) in
``repro.core.costs`` that produced it.  Run after a sweep:

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")

# Every predicted column a benchmark emits, mapped to the formula that
# computes it.  Each formula's docstring in ``repro.core.costs`` (or the
# schedule registry in ``repro.core.planner``) carries the full paper
# citation; this table is how a reader gets from a JSON row back to the
# equation.
PREDICTED_COLUMNS = [
    # (benchmark, column, formula, paper source)
    ("lemmas", "analytic", "repro.core.costs.lemma8_join_comm /"
     " lemma10_semijoin_comm", "Lemmas 8 & 10 (Sec. 3.3)"),
    ("table2/table3", "worst-case comm", "repro.core.costs.shares_comm_star /"
     " shares_comm_tc / gym_comm / acqmr_comm",
     "Tables 2 & 3; Theorem 15; Sec. 2.2/2.3"),
    ("table1", "width / depth / iw", "repro.core.ghd.GHD.width / .depth /"
     " .intersection_width", "Table 1 / Sec. 3.1"),
    ("fig6", "width_out / depth_out bounds", "repro.core.loggta.log_gta",
     "Theorem 23 / Sec. 6 (Figure 6)"),
    ("optimizer", "predicted_comm", "repro.core.costs.predict_plan_cost",
     "per-op Lemmas 8/10 + Theorem 15 stage walk; Appendix A sizes"),
    ("optimizer", "pred_rounds", "repro.core.costs.predict_plan_cost +"
     " repro.core.planner.SCHEDULES",
     "Theorem 12 (Sec. 4.2) / Theorem 14 (Sec. 4.3)"),
    ("optimizer_explain", "err", "repro.core.optimizer.explain",
     "signed relative error (pred - meas) / meas of the explain() table"),
    ("optimizer_calibration", "err_uncalibrated / err_calibrated",
     "repro.core.costs.prediction_error / fit_calibration",
     "|log(pred/meas)| — the quantity the log-space fit minimizes"),
    ("optimizer/optimizer_explain", "pred_wire",
     "repro.core.costs.predict_plan_cost + shuffle_pad_factor",
     "Sec. 3.2 useful-tuple comm inflated to the dense all_to_all slots"
     " the wire ships (fixed capacity ~p x; count-calibrated < 2x)"),
    ("shuffle", "padded_slots / payload_efficiency",
     "repro.relational.ledger.Ledger.padded_slots / .payload_efficiency",
     "measured dense slots shipped vs Sec. 3.2 useful tuples; calibration"
     " per Hu & Yi / Joglekar & Ré count statistics (PAPERS.md)"),
    ("shuffle", "payload_bytes / payload_efficiency_bytes",
     "repro.relational.ledger.Ledger.payload_bytes /"
     " .payload_efficiency_bytes + repro.relational.wire",
     "byte-true wire accounting: packed bit-stream bytes (or dense int32"
     " cells + valid flags) vs the Lemma-2/Sec. 3.2 useful-tuple bytes"),
    ("optimizer", "pred_wire (packed)",
     "repro.core.costs.shuffle_pad_factor(wire_gain=...) +"
     " repro.relational.wire.wire_gain",
     "pad factor deflated by the packed format's mean row compression"),
    ("moe", "dense_dropped / calibrated_dropped",
     "repro.models.mlp.moe_forward_stats +"
     " repro.models.moe_routing.calibrate_moe",
     "expert dispatch as a skewed exchange: measured SideCaps-style"
     " capacities + Lemma-8 heavy split make drops exactly zero where"
     " the Switch-style capacity factor silently loses tokens"),
    ("moe", "dense_payload_bytes / calibrated_payload_bytes",
     "repro.models.moe_routing.dense_scatter_bytes /"
     " .calibrated_dispatch_bytes",
     "the same dense-cell byte formula (wire.dense_wire_bytes) priced"
     " over both dispatch routes — one ledger vocabulary, two customers"),
]


def provenance_table() -> str:
    head = (
        "| benchmark | predicted column | formula (see its docstring for the"
        " equation) | paper source |\n|---|---|---|---|"
    )
    rows = [
        f"| {b} | {c} | `{f}` | {s} |" for b, c, f, s in PREDICTED_COLUMNS
    ]
    return head + "\n" + "\n".join(rows)


def load(name):
    with open(os.path.join(ROOT, name)) as f:
        return json.load(f)


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def dryrun_table(db, mesh):
    rows = []
    for k in sorted(db):
        v = db[k]
        if v.get("mesh") != mesh or v.get("status") != "ok":
            continue
        c = v.get("cost_per_device", {})
        coll = sum(v.get("collective_bytes_global", {}).values())
        rows.append(
            f"| {v['arch']} | {v['shape']} | {v['chips']} | "
            f"{v['n_params']/1e9:.2f}B | {fmt_bytes(v.get('bytes_per_device'))} | "
            f"{c.get('flops', 0):.3e} | {c.get('bytes accessed', 0):.3e} | "
            f"{coll/1e12:.2f} | {v['compile_s']}s |"
        )
    head = (
        "| arch | shape | chips | params | GB/dev | flops/dev | hbm B/dev | "
        "coll TB (global) | compile |\n|---|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def roofline_table(db, db_opt, mesh="single"):
    rows = []
    for k in sorted(db):
        v = db[k]
        if v.get("mesh") != mesh or v.get("status") != "ok":
            continue
        r = v["roofline"]
        o = db_opt.get(k, {}).get("roofline", {}) if db_opt else {}
        imp = (
            f"{r['bound_s']/o['bound_s']:.1f}x" if o.get("bound_s") else "-"
        )
        rows.append(
            f"| {v['arch']} | {v['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{100*r.get('roofline_frac',0):.1f}% | "
            f"{o.get('bound_s', float('nan')):.3g} | {imp} |"
        )
    head = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "roofline frac | optimized bound s | gain |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return head + "\n" + "\n".join(rows)


def main():
    try:
        base = load("dryrun_results_baseline.json")
    except FileNotFoundError:
        base = None
        print("(no dryrun_results_baseline.json — skipping dry-run/roofline tables)")
    if base is not None:
        try:
            opt = load("dryrun_results.json")
        except FileNotFoundError:
            opt = {}
        print("### Single-pod (16x16 = 256 chips) — baseline dry-run\n")
        print(dryrun_table(base, "single"))
        print("\n### Multi-pod (2x16x16 = 512 chips) — baseline dry-run\n")
        print(dryrun_table(base, "multi"))
        print("\n### Roofline (single-pod, baseline terms; optimized bound alongside)\n")
        print(roofline_table(base, opt))
    print("\n### Predicted-column provenance (benchmarks/run.py output)\n")
    print(provenance_table())


if __name__ == "__main__":
    main()
