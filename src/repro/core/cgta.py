"""C-GTA (paper Section 7): constant-factor tree shrinking by merging
adjacent vertices, doubling width per pass; composed with Log-GTA it yields
the Theorem 25 spectrum: width <= 2^i * max(w, 3iw), depth <= log((15/16)^i n).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Tuple

from .ghd import GHD
from .hypergraph import Query
from .loggta import log_gta


def _merge_into(g: GHD, keep: int, gone: int) -> None:
    """Merge ``gone`` into ``keep``; both adjacent or sibling leaves.

    chi/lam become unions; ``gone``'s children move under ``keep``.
    """
    g.chi[keep] = g.chi[keep] | g.chi[gone]
    g.lam[keep] = g.lam[keep] | g.lam[gone]
    for c in list(g.children.get(gone, [])):
        g.parent[c] = keep
        g.children[keep].append(c)
    p = g.parent[gone]
    if p is not None:
        g.children[p].remove(gone)
    elif g.root == gone:
        g.root = keep
        g.parent[keep] = None
    del g.parent[gone], g.chi[gone], g.lam[gone]
    g.children.pop(gone, None)


def cgta_pass(ghd: GHD, query: Query) -> GHD:
    """One C-GTA pass: (1)/(2) pair-merge leaf children (odd leftover merges
    into the parent); (3) merge unique-child chains when the child has an
    even number of leaf children.

    Merges within a pass are *disjoint* (each vertex participates in at most
    one), so a pass grows width by at most 2x while removing >= max(L,U)/2
    vertices (Lemma 24 gives >= N/16 per the paper's analysis).
    """
    g = ghd.copy()
    consumed: set = set()

    # steps 1 & 2: leaves under each parent
    for u in list(g.topo_order()):
        if u not in g.chi or u in consumed:
            continue
        leaf_kids = [
            c
            for c in g.children.get(u, [])
            if not g.children.get(c) and c not in consumed
        ]
        while len(leaf_kids) >= 2:
            a, b = leaf_kids[0], leaf_kids[1]
            _merge_into(g, a, b)
            consumed.update((a, b))
            leaf_kids = leaf_kids[2:]
        if len(leaf_kids) == 1:
            _merge_into(g, u, leaf_kids[0])
            consumed.update((u, leaf_kids[0]))

    # step 3: unique-child merges (disjoint from all earlier merges)
    for u in list(g.topo_order()):
        if u not in g.chi or u in consumed:
            continue
        kids = g.children.get(u, [])
        if len(kids) == 1 and kids[0] not in consumed:
            c = kids[0]
            leafs_of_c = [x for x in g.children.get(c, []) if not g.children.get(x)]
            if len(leafs_of_c) % 2 == 0:
                _merge_into(g, u, c)
                consumed.update((u, c))

    g.validate(query)
    return g


def cgta(ghd: GHD, query: Query, passes: int) -> GHD:
    """Theorem 25 composition: ``passes`` C-GTA shrink passes, then Log-GTA."""
    g = ghd
    for _ in range(passes):
        before = g.size()
        g = cgta_pass(g, query)
        if g.size() == before:  # nothing left to merge
            break
    return log_gta(g, query)
