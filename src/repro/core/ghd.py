"""Generalized hypertree decompositions (GHDs), paper Section 3.1.

A GHD of a query hypergraph H is (T, chi, lam):
  1. every hyperedge e is contained in chi(t) for some tree vertex t;
  2. for every attribute v, {t : v in chi(t)} is connected in T  (running
     intersection);
  3. chi(t) is covered by the union of the hyperedges in lam(t).

Width = max |lam(t)|; depth = depth of the rooted tree; intersection width
(the paper's new notion) = max over tree edges (t,t') of the smallest number
of hyperedges covering chi(t) & chi(t').
"""
from __future__ import annotations

import copy
import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

from .hypergraph import Query, min_edge_cover


@dataclass
class GHD:
    root: int
    parent: Dict[int, Optional[int]]
    children: Dict[int, List[int]]
    chi: Dict[int, FrozenSet[str]]
    lam: Dict[int, FrozenSet[str]]  # aliases of atoms

    # -- construction helpers -------------------------------------------------
    @staticmethod
    def build(
        root: int,
        edges: Iterable[Tuple[int, int]],  # (parent, child)
        chi: Dict[int, Iterable[str]],
        lam: Dict[int, Iterable[str]],
    ) -> "GHD":
        parent: Dict[int, Optional[int]] = {root: None}
        children: Dict[int, List[int]] = {n: [] for n in chi}
        for p, c in edges:
            parent[c] = p
            children[p].append(c)
        for n in chi:
            parent.setdefault(n, None)
        g = GHD(
            root=root,
            parent=parent,
            children=children,
            chi={n: frozenset(v) for n, v in chi.items()},
            lam={n: frozenset(v) for n, v in lam.items()},
        )
        g._check_tree()
        return g

    def _check_tree(self):
        seen = set()
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n in seen:
                raise ValueError("cycle in GHD tree")
            seen.add(n)
            stack.extend(self.children.get(n, []))
        if seen != set(self.chi):
            raise ValueError(
                f"tree nodes {sorted(seen)} != chi nodes {sorted(self.chi)}"
            )

    # -- basic accessors -------------------------------------------------------
    def nodes(self) -> List[int]:
        return list(self.chi.keys())

    def tree_edges(self) -> List[Tuple[int, int]]:
        return [(p, c) for c, p in self.parent.items() if p is not None]

    def copy(self) -> "GHD":
        return GHD(
            root=self.root,
            parent=dict(self.parent),
            children={k: list(v) for k, v in self.children.items()},
            chi=dict(self.chi),
            lam=dict(self.lam),
        )

    def depth_of(self, n: int) -> int:
        d = 0
        while self.parent[n] is not None:
            n = self.parent[n]
            d += 1
        return d

    @property
    def depth(self) -> int:
        """Depth of the tree = max #edges root->leaf (a single node has 0)."""
        best = 0
        stack = [(self.root, 0)]
        while stack:
            n, d = stack.pop()
            best = max(best, d)
            for c in self.children.get(n, []):
                stack.append((c, d + 1))
        return best

    @property
    def width(self) -> int:
        return max(len(l) for l in self.lam.values())

    def size(self) -> int:
        return len(self.chi)

    # -- serialization (snapshots must replay the exact decomposition) --------
    def to_dict(self) -> Dict:
        return {
            "root": self.root,
            "parent": {str(n): p for n, p in self.parent.items()},
            "children": {str(n): list(c) for n, c in self.children.items()},
            "chi": {str(n): sorted(v) for n, v in self.chi.items()},
            "lam": {str(n): sorted(v) for n, v in self.lam.items()},
        }

    @staticmethod
    def from_dict(d: Dict) -> "GHD":
        g = GHD(
            root=int(d["root"]),
            parent={int(n): p for n, p in d["parent"].items()},
            children={int(n): list(c) for n, c in d["children"].items()},
            chi={int(n): frozenset(v) for n, v in d["chi"].items()},
            lam={int(n): frozenset(v) for n, v in d["lam"].items()},
        )
        g._check_tree()
        return g

    # -- subtree / ordering helpers -------------------------------------------
    def topo_order(self) -> List[int]:
        """Root-first order."""
        out: List[int] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(self.children.get(n, []))
        return out

    def leaves(self) -> List[int]:
        return [n for n in self.nodes() if not self.children.get(n)]

    # -- validity ---------------------------------------------------------------
    def validate(self, query: Query, require_lambda_subset: bool = True) -> None:
        """Raise AssertionError unless this is a valid GHD of ``query``."""
        edges = query.edges
        nodes = set(self.nodes())
        # tree consistency
        for p, c in self.tree_edges():
            assert p in nodes and c in nodes
            assert c in self.children[p]
        # property 1: every hyperedge covered by some chi(t)
        for alias, e in edges.items():
            assert any(e <= self.chi[t] for t in nodes), (
                f"hyperedge {alias}={sorted(e)} not covered by any bag"
            )
        # property 2: running intersection per attribute
        for v in query.vertices:
            holders = {t for t in nodes if v in self.chi[t]}
            if not holders:
                continue
            # connected <=> exactly one holder whose parent is not a holder
            roots = [t for t in holders if self.parent[t] not in holders]
            assert len(roots) == 1, (
                f"attribute {v} bags not connected: {sorted(holders)}"
            )
        # property 3: lambda covers chi
        for t in nodes:
            if require_lambda_subset:
                for alias in self.lam[t]:
                    assert alias in edges, f"unknown alias {alias} in lam({t})"
            cov = set()
            for alias in self.lam[t]:
                cov |= edges[alias]
            assert self.chi[t] <= cov, (
                f"chi({t})={sorted(self.chi[t])} not covered by "
                f"lam({t})={sorted(self.lam[t])}"
            )

    # -- paper statistics --------------------------------------------------------
    def intersection_width(self, query: Query) -> int:
        """Max over adjacent (t,t') of min #hyperedges covering chi(t)&chi(t')."""
        edges = query.edges
        iw = 0
        for p, c in self.tree_edges():
            shared = self.chi[p] & self.chi[c]
            cover = min_edge_cover(shared, edges)
            assert cover is not None
            iw = max(iw, len(cover))
        return iw

    def edge_cover(self, t1: int, t2: int, query: Query) -> FrozenSet[str]:
        """A minimum cover of the shared attributes of adjacent t1,t2."""
        shared = self.chi[t1] & self.chi[t2]
        cover = min_edge_cover(shared, query.edges)
        assert cover is not None
        return cover

    def is_complete(self, query: Query) -> bool:
        assigned = set()
        for l in self.lam.values():
            assigned |= l
        return assigned >= set(query.edges)

    def is_strongly_complete(self, query: Query) -> bool:
        """Every atom R has a node t with R in lam(t) AND attrs(R) <= chi(t).

        This is what GYM's materialization stage needs so that
        ``join_v IDB_v == Q`` where ``IDB_v = proj_chi(v)(join lam(v))``:
        the node t is where atom R is actually *enforced*.
        """
        for alias, e in query.edges.items():
            if not any(
                alias in self.lam[t] and e <= self.chi[t] for t in self.nodes()
            ):
                return False
        return True

    # -- Lemma 7: minimal + complete form ----------------------------------------
    def make_complete(self, query: Query) -> "GHD":
        """Lemma 7: produce a *minimal, complete* GHD with depth <= d+1,
        same width / intersection width, and O(n) nodes.

        Step 1 (minimality): repeatedly delete degree-<=2 vertices that do not
        uniquely cover some hyperedge (leaves are dropped; degree-2 vertices
        are spliced out).
        Step 2 (completeness): for every unassigned hyperedge e, hang a new
        leaf l with chi(l)=lam(l)={e} under some vertex whose bag contains e.
        """
        g = self.copy()
        edges = query.edges

        def uniquely_covers(t: int) -> bool:
            others = [u for u in g.nodes() if u != t]
            for alias, e in edges.items():
                if e <= g.chi[t] and not any(e <= g.chi[u] for u in others):
                    return True
            return False

        changed = True
        while changed and g.size() > 1:
            changed = False
            for t in list(g.nodes()):
                if g.size() == 1:
                    break
                deg = len(g.children.get(t, [])) + (0 if g.parent[t] is None else 1)
                if deg > 2 or uniquely_covers(t):
                    continue
                if deg <= 1 and not (t == g.root and g.children.get(t)):
                    g._remove_leafish(t)
                    changed = True
                elif deg == 2:
                    g._splice_degree2(t)
                    changed = True

        # completeness (strong form: need a node with alias in lam AND
        # attrs <= chi -- what GYM's materialization semantics require)
        nid = max(g.nodes()) + 1
        for alias, e in edges.items():
            if any(alias in g.lam[t] and e <= g.chi[t] for t in g.nodes()):
                continue
            # preferred cheap fix: some node already has e <= chi; just add
            # the alias to its lam (never changes chi, keeps width if room —
            # else hang a new leaf).
            host = next(t for t in g.topo_order() if e <= g.chi[t])
            if len(g.lam[host]) < max(len(l) for l in g.lam.values()):
                g.lam[host] = g.lam[host] | {alias}
            else:
                g.parent[nid] = host
                g.children.setdefault(host, []).append(nid)
                g.children[nid] = []
                g.chi[nid] = frozenset(e)
                g.lam[nid] = frozenset([alias])
                nid += 1
        g.validate(query)
        assert g.is_strongly_complete(query)
        return g

    def _remove_leafish(self, t: int) -> None:
        """Remove a node of degree <=1 (a leaf, or an isolated/root-with-one-child)."""
        p = self.parent[t]
        kids = self.children.get(t, [])
        assert len(kids) + (0 if p is None else 1) <= 1
        if p is not None:
            self.children[p].remove(t)
        elif kids:  # t is root with exactly one child: child becomes root
            c = kids[0]
            self.parent[c] = None
            self.root = c
        del self.parent[t], self.chi[t], self.lam[t]
        self.children.pop(t, None)

    def _splice_degree2(self, t: int) -> None:
        p = self.parent[t]
        kids = self.children.get(t, [])
        if p is None:
            # root with two children: promote one child as root, attach other under it
            assert len(kids) == 2
            a, b = kids
            self.parent[a] = None
            self.root = a
            self.parent[b] = a
            self.children[a].append(b)
        else:
            assert len(kids) == 1
            c = kids[0]
            self.children[p].remove(t)
            self.children[p].append(c)
            self.parent[c] = p
        del self.parent[t], self.chi[t], self.lam[t]
        self.children.pop(t, None)

    def __repr__(self) -> str:  # compact debugging form
        lines = []
        stack = [(self.root, 0)]
        while stack:
            n, d = stack.pop()
            lines.append(
                "  " * d
                + f"[{n}] chi={{{','.join(sorted(self.chi[n]))}}} "
                + f"lam={{{','.join(sorted(self.lam[n]))}}}"
            )
            for c in reversed(self.children.get(n, [])):
                stack.append((c, d + 1))
        return "\n".join(lines)
