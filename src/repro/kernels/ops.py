"""Jit'd public entry points for the Pallas kernels.

``use_pallas`` switches between the TPU kernel (interpret=True on CPU — the
kernel body runs in Python for correctness validation) and the pure-jnp
reference (the default on CPU for speed).  On a real TPU deployment the
kernels run compiled (interpret=False).
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax

from . import ref
from .chunked import chunked_attention as _chunked
from .flash_attention import flash_attention as _flash
from .hash_partition import hash_partition as _hash_partition
from .semijoin_probe import semijoin_probe as _probe
from .sorted_probe import sorted_probe_ranges as _ranges

# KV lengths >= this use the chunked (flash-style) XLA path off-TPU:
# peak activation memory O(Sq*C) instead of O(Sq*Sk).  [Perf iteration A]
CHUNKED_MIN_KV = 2048


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def semijoin_probe(
    q: jax.Array, keys: jax.Array, *, use_pallas: Optional[bool] = None
) -> jax.Array:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _probe(q, keys, interpret=not _on_tpu())
    return ref.semijoin_probe_ref(q, keys)


def sorted_probe_ranges(
    q: jax.Array, keys: jax.Array, *, use_pallas: Optional[bool] = None
):
    """(lo, hi) match ranges of q against SORTED keys (searchsorted pair)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _ranges(q, keys, interpret=not _on_tpu())
    return ref.sorted_probe_ranges_ref(q, keys)


def hash_partition(
    rows: jax.Array,
    valid: jax.Array,
    cols: Sequence[int],
    p: int,
    seed,
    *,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    if use_pallas is None:
        use_pallas = _on_tpu()
    # zero key columns (seed-only hash) has no per-row work for the kernel
    if use_pallas and len(cols):
        return _hash_partition(rows, valid, cols, p, seed, interpret=not _on_tpu())
    return ref.hash_partition_ref(rows, valid, cols, p, seed)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    use_pallas: Optional[bool] = None,
    impl: Optional[str] = None,  # None=auto | 'pallas' | 'chunked' | 'dense'
) -> jax.Array:
    if impl is None:
        if use_pallas or (use_pallas is None and _on_tpu()):
            impl = "pallas"
        elif k.shape[2] >= CHUNKED_MIN_KV:
            impl = "chunked"
        else:
            impl = "dense"
    if impl == "pallas":
        return _flash(
            q, k, v,
            causal=causal, window=window, softcap=softcap, scale=scale,
            interpret=not _on_tpu(),
        )
    if impl == "chunked":
        return _chunked(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return ref.attention_ref(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
    )
