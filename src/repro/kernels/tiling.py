"""Shared host-side tiling for the 1-D probe/key kernels.

Both probe kernels (``semijoin_probe``, ``sorted_probe``) lay their
operands out as (rows, 128) lane tiles — (PROBE_ROWS, 128) probe blocks
against (KEY_ROWS, 128) key blocks — with the same padding invariants:

- probes pad with a value that can never equal (or count against) a live
  key; the padded rows are trimmed from the output;
- keys pad with INT32_MAX, the same sentinel used for invalid key slots,
  which by contract never matches and never counts;
- an EMPTY key vector still gets one full all-pad key block: the kernels
  merge per-key-tile partials into the output block, so a zero-length key
  grid axis would leave the output unwritten.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

LANES = 128
PROBE_ROWS = 8  # (8, 128) = one VPU register tile of probes
KEY_ROWS = 64  # (64, 128) = 8192 keys per VMEM block

KEY_PAD = jnp.int32(2**31 - 1)  # == the invalid-slot sentinel
PROBE_PAD = jnp.int32(-(2**31) + 1)  # never equals a valid key or KEY_PAD


def pad_probe_key_tiles(
    q: jax.Array, keys: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """(n,) probes + (m,) keys -> (nr, 128) and (mr, 128) lane tiles."""
    n, m = q.shape[0], keys.shape[0]
    npad = -n % (PROBE_ROWS * LANES)
    mpad = (KEY_ROWS * LANES) if m == 0 else (-m % (KEY_ROWS * LANES))
    q2 = jnp.pad(q, (0, npad), constant_values=PROBE_PAD).reshape(-1, LANES)
    k2 = jnp.pad(keys, (0, mpad), constant_values=KEY_PAD).reshape(-1, LANES)
    return q2, k2
