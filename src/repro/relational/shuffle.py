"""The MapReduce shuffle as a per-shard function over the named reducer axis.

``exchange``: hash-partitioned repartitioning (map stage: bucket rows by
destination; network: one ``lax.all_to_all``; reduce stage: compact).
``exchange_multi``: each row goes to ``g`` destinations (the replicated
sends of Lemma 8 grid joins / Shares hypercube).

Overflow anywhere is reported, never silently dropped — the driver retries
the round with doubled capacities (the paper's abort-and-retry semantics).

Both exchanges are batchable: the collective refers to the named reducer
axis only, so wrapping the calling shard function in an inner (anonymous)
``jax.vmap`` fuses k independent shuffles into one program with one
``all_to_all`` — the mechanism behind ``relational.batched`` round fusion.

Capacity calibration: the wire ships the dense ``(p, c_out)`` slot buffer,
so every ``all_to_all`` pays ``p * c_out`` slots per shard regardless of
occupancy.  Passing a ``wire.WireFormat`` (``fmt=``) replaces the dense
int32 cells + bool valid pair with ONE bit-packed uint8 buffer per
exchange (same rows out, exact round-trip); ``exchange_start`` /
``exchange_finish`` split an exchange around its collective so a fused
group can concatenate many encoded exchanges into a single segmented
``all_to_all`` (``ship_segments``).  ``exchange_counts`` is the count-only pre-pass behind the
engine's occupancy-adaptive shuffle: a tiny ``(p,)``-int ``all_to_all`` of
per-destination bucket counts, from which the capacity manager picks tight
``c_out``/``cap_recv`` *before* the payload moves (Hu & Yi's per-instance
load calibration, driven by Joglekar & Ré-style cheap count statistics —
see PAPERS.md).  Calibrated capacities are rounded up to power-of-two
buckets (``pow2``) so jitted programs are reused across rounds with
different occupancies instead of recompiled per capacity.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .localops import compact
from .spmd import AXIS
from .wire import (
    WireFormat,
    get_codec,
    pack_segments,
    split_segments,
    wire_decode,
    wire_encode,
)


def pow2(x: int) -> int:
    """Round capacities up to powers of two (min 4): distinct shapes
    collapse, so the per-op jit cache is reused across nodes, rounds,
    retries, and calibrated occupancies — and uniform shapes are what make
    op groups batchable at all."""
    return 1 << max(2, int(x - 1).bit_length())


def padded_slots(p: int, c_out: int, arity: int = 1) -> int:
    """int32 cells a fleet-wide exchange ships for one ``all_to_all``:
    each of the ``p`` shards sends the dense ``(p, c_out, arity)`` bucket
    buffer whether the buckets are full or empty.  Counting CELLS (slot
    rows x row width) rather than rows keeps keys-only exchanges (the
    semijoin R projection, the join measure pre-pass) honestly cheaper
    than full-payload ones.  This is the denominator of the ledger's
    payload-efficiency metric."""
    return p * p * c_out * max(1, arity)


def _bucketize(
    data: jax.Array, valid_dest: jax.Array, p: int, c_out: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Scatter rows into per-destination buckets.

    ``valid_dest``: (n,) int32 in [0,p) for live rows, == p for dead rows.
    Returns (buf (p,c_out,ar), buf_valid (p,c_out), sent, dropped).

    One sort total: rows are argsorted by destination, each sorted slot's
    in-bucket position is its distance to the last bucket boundary (a
    cummax of boundary indices), and the positions are scattered back to
    original row order — so the full-width row data is scattered into
    ``buf`` directly, with no second search over the sorted copy and no
    (n, ar) gather of a sorted row array."""
    n, ar = data.shape
    order = jnp.argsort(valid_dest, stable=True)
    sdest = valid_dest[order]
    idx = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sdest[1:] != sdest[:-1]]
    )
    bucket_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_sorted = idx - bucket_start
    # rank of original row ``order[i]`` within its bucket is pos_sorted[i]
    pos = jnp.zeros((n,), pos_sorted.dtype).at[order].set(pos_sorted)
    live = valid_dest < p
    ok = live & (pos < c_out)
    d_idx = jnp.where(ok, valid_dest, p)  # p == out-of-bounds -> dropped
    pos_c = jnp.clip(pos, 0, c_out - 1)
    buf = jnp.zeros((p, c_out, ar), data.dtype).at[d_idx, pos_c].set(
        data, mode="drop"
    )
    buf_valid = jnp.zeros((p, c_out), bool).at[d_idx, pos_c].set(ok, mode="drop")
    sent = ok.sum()
    dropped = (live & ~ok).sum()
    return buf, buf_valid, sent, dropped


def _wire_ship(
    buf: jax.Array, buf_valid: jax.Array, fmt: WireFormat, c_out: int
) -> Tuple[jax.Array, jax.Array]:
    """Packed collective: encode the dense buckets + valid plane into one
    bit-packed uint8 buffer, run ONE ``all_to_all`` (instead of the dense
    path's data + valid pair), decode back.  The optional codec hook
    wraps the bytes around the collective."""
    wire = wire_encode(buf, buf_valid, fmt)
    enc, dec = get_codec(fmt.codec)
    payload, aux = enc(wire)
    rpayload = jax.lax.all_to_all(
        payload, AXIS, split_axis=0, concat_axis=0, tiled=False
    )
    return wire_decode(dec(rpayload, aux), fmt, c_out)


# ------------------------------------------------------ count-only pre-pass
def bucket_counts(dest: jax.Array, p: int) -> jax.Array:
    """Per-destination outgoing bucket counts: (n,) or (n, g) destinations
    (== p for dead/skip slots) -> (p,) int32 counts.  The map-side half of
    the calibration pre-pass; costs one segment-add, no sort."""
    flat = dest.reshape(-1)
    live = (flat >= 0) & (flat < p)
    return (
        jnp.zeros((p,), jnp.int32)
        .at[jnp.clip(flat, 0, p - 1)]
        .add(live.astype(jnp.int32), mode="drop")
    )


def exchange_counts(dest: jax.Array, p: int) -> Tuple[jax.Array, jax.Array]:
    """The count-only pre-pass of an exchange: ship per-destination bucket
    COUNTS (a (p,)-int ``all_to_all``) instead of the payload.

    Returns ``(out_counts (p,), recv_total ())``:

    - ``max(out_counts)`` over all shards is the tight send-bucket
      capacity ``c_out`` (the payload exchange's per-destination buffer);
    - ``max(recv_total)`` over all shards is the tight receive capacity
      ``cap_recv`` (the post-``all_to_all`` compact size).

    Same collective pattern as the payload exchange (split/concat axis 0
    over the named reducer axis), so it is batchable under the same inner
    vmap as the operator bodies."""
    out = bucket_counts(dest, p)
    recv = jax.lax.all_to_all(out, AXIS, split_axis=0, concat_axis=0, tiled=False)
    return out, recv.sum()


def exchange(
    data: jax.Array,
    valid: jax.Array,
    dest: jax.Array,
    *,
    p: int,
    c_out: int,
    cap_recv: int,
    fmt: Optional[WireFormat] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Repartition rows to ``dest`` shards.

    ``fmt=None`` ships the dense int32 buckets + bool valid plane (two
    collectives); a ``WireFormat`` ships one bit-packed uint8 buffer.
    Rows out are bit-identical either way.

    Returns (rdata (cap_recv, ar), rvalid, sent, dropped_send, dropped_recv).
    """
    buf, buf_valid, sent, dropped_send = _bucketize(
        data, jnp.where(valid, dest, p), p, c_out
    )
    if fmt is None:
        rbuf = jax.lax.all_to_all(buf, AXIS, split_axis=0, concat_axis=0, tiled=False)
        rvalid = jax.lax.all_to_all(buf_valid, AXIS, split_axis=0, concat_axis=0, tiled=False)
    else:
        rbuf, rvalid = _wire_ship(buf, buf_valid, fmt, c_out)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    rdata, rv, dropped_recv = compact(flat, flatv, cap_recv)
    return rdata, rv, sent, dropped_send, dropped_recv


def exchange_multi(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,  # (n, g) int32, each in [0,p) (or p to skip)
    *,
    p: int,
    c_out: int,
    cap_recv: int,
    fmt: Optional[WireFormat] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Replicated send: each row goes to up to g destinations.

    Duplicate destinations WITHIN a row's ``dests`` are deduplicated to
    the skip slot ``p`` before bucketing: a row reaches each reducer at
    most once, so replicated sends can never double-count ``sent`` or
    double-deliver a tuple (which a local join would then double-join).
    Today's callers construct distinct destinations (grid offsets are
    distinct even with size-1 dimensions, hypercube wildcard offsets are
    a product of distinct coordinates, hybrid broadcast is ``arange``),
    so this is defense-in-depth; the regression tests pin both the
    construction-site distinctness and this dedupe."""
    tiled_rows, flat_dest = _multi_flatten(data, valid, dests, p)
    buf, buf_valid, sent, dropped_send = _bucketize(tiled_rows, flat_dest, p, c_out)
    if fmt is None:
        rbuf = jax.lax.all_to_all(buf, AXIS, split_axis=0, concat_axis=0, tiled=False)
        rvalid = jax.lax.all_to_all(buf_valid, AXIS, split_axis=0, concat_axis=0, tiled=False)
    else:
        rbuf, rvalid = _wire_ship(buf, buf_valid, fmt, c_out)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    rdata, rv, dropped_recv = compact(flat, flatv, cap_recv)
    return rdata, rv, sent, dropped_send, dropped_recv


def _multi_flatten(
    data: jax.Array, valid: jax.Array, dests: jax.Array, p: int
) -> Tuple[jax.Array, jax.Array]:
    """The map-side row tiling of ``exchange_multi``: dedupe each row's
    destination list to the skip slot, then flatten to one (n*g,) send."""
    g = dests.shape[1]
    if g > 1:
        eq = dests[:, :, None] == dests[:, None, :]  # (n, g, g)
        earlier = jnp.tril(jnp.ones((g, g), bool), -1)  # [j, k]: k < j
        dup = (eq & earlier[None]).any(-1)
        dests = jnp.where(dup, p, dests)
    tiled_rows = jnp.repeat(data, g, axis=0)  # (n*g, ar)
    flat_dest = jnp.where(jnp.repeat(valid, g, axis=0), dests.reshape(-1), p)
    return tiled_rows, flat_dest


# ------------------------------------------- segmented (fused-group) exchange
# An exchange split around its collective: ``*_start`` buckets + encodes
# one op's send into a (p, nbytes) segment, ``ship_segments`` runs ONE
# ``all_to_all`` over every segment of a fused op group concatenated
# (mixed schemas/arities each keep their own format — arity-aware
# segmentation instead of padding every op to the widest schema), and
# ``exchange_finish`` decodes + compacts each op's received segment.
def exchange_start(
    data: jax.Array,
    valid: jax.Array,
    dest: jax.Array,
    *,
    p: int,
    c_out: int,
    fmt: WireFormat,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map stage of a packed exchange: returns (wire segment (p, nbytes),
    sent, dropped_send)."""
    buf, buf_valid, sent, dropped_send = _bucketize(
        data, jnp.where(valid, dest, p), p, c_out
    )
    return wire_encode(buf, buf_valid, fmt), sent, dropped_send


def exchange_multi_start(
    data: jax.Array,
    valid: jax.Array,
    dests: jax.Array,
    *,
    p: int,
    c_out: int,
    fmt: WireFormat,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Map stage of a packed replicated send (``exchange_multi``)."""
    tiled_rows, flat_dest = _multi_flatten(data, valid, dests, p)
    buf, buf_valid, sent, dropped_send = _bucketize(tiled_rows, flat_dest, p, c_out)
    return wire_encode(buf, buf_valid, fmt), sent, dropped_send


def ship_segments(wires: Sequence[jax.Array]) -> List[jax.Array]:
    """ONE ``all_to_all`` for a whole fused group: concatenate each
    exchange's (p, nbytes_i) segment, ship, split back."""
    seg = pack_segments(wires)
    rseg = jax.lax.all_to_all(seg, AXIS, split_axis=0, concat_axis=0, tiled=False)
    return split_segments(rseg, [w.shape[-1] for w in wires])


def exchange_finish(
    rwire: jax.Array, *, p: int, c_out: int, cap_recv: int, fmt: WireFormat
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Reduce stage of a packed exchange: decode the received segment and
    compact.  Returns (rdata, rvalid, dropped_recv)."""
    rbuf, rvalid = wire_decode(rwire, fmt, c_out)
    flat = rbuf.reshape(p * c_out, -1)
    flatv = rvalid.reshape(p * c_out)
    return compact(flat, flatv, cap_recv)
